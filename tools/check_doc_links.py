#!/usr/bin/env python3
"""Dead-link check for the repo's markdown docs.

Kept as a standalone entry point for muscle memory; the logic moved into
the staticcheck analyzer (``tools/staticcheck/passes/doc_links.py``) and
this wrapper just runs that single pass:

    python3 tools/check_doc_links.py
    # == python3 tools/staticcheck/run.py --only doc-links
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
from staticcheck.run import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--only", "doc-links"]))
