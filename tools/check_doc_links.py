#!/usr/bin/env python3
"""Dead-link check for the repo's markdown docs.

Scans every tracked *.md file for relative markdown links
(``[text](path)`` / ``![alt](path)``) and fails if a target does not
exist on disk.  External schemes (http/https/mailto) and pure anchors
(``#section``) are skipped; ``path#fragment`` is checked as ``path``.
Fenced code blocks are ignored so exemplar snippets can't false-positive.

Run from the repo root (CI: the python job's "docs link check" step):

    python3 tools/check_doc_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

SKIP_DIRS = {".git", "target", "vendor", "node_modules", "__pycache__"}
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^()\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def check_file(path: Path, root: Path) -> list[str]:
    errors = []
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in LINK_RE.findall(line):
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (root / rel) if rel.startswith("/") \
                else (path.parent / rel)
            if not resolved.exists():
                errors.append(
                    f"{path.relative_to(root)}:{lineno}: dead link "
                    f"-> {target}")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = list(md_files(root))
    errors = [e for f in files for e in check_file(f, root)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} dead links)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
