#!/usr/bin/env python3
"""Gate BENCH_*.json emissions against the committed BENCH_baseline.json.

Every bench harness (util::benchkit) writes a machine-readable
``BENCH_<name>.json`` whose optional ``regress_on`` block names the scalars
CI guards: ``{"metric": {"value": <f64>, "higher_is_better": <bool>}}``.
The committed baseline mirrors that shape plus a global ``threshold``
(fractional regression allowed, default 0.10).

Rules, per metric present in the baseline:
  * baseline value ``null``  -> not seeded yet: print the current value as a
    SEED line and pass (deterministic metrics are committed seeded; wall
    -time metrics are seeded from the first CI run's artifact).
  * current bench missing or ``{"skipped": true}`` -> SKIP (benches that
    need model artifacts decline politely without them).
  * otherwise fail when the current value moves more than the threshold
    in the losing direction.  A metric entry may carry its own
    ``"threshold"`` (wall-time metrics on shared CI runners get a loose
    one; tick/element-denominated metrics keep the tight global default).

``--write-baseline out.json`` additionally emits a fully seeded baseline
from the current results; CI uploads it as an artifact so a maintainer can
commit it to (re)seed the trajectory.

``--merge-baseline`` instead rewrites the committed baseline IN PLACE,
filling only its ``null`` values from the current results (seeded values,
thresholds and the comment are preserved).  Arming the wall-clock gates is
therefore one command on any machine with a rust toolchain::

    cargo bench --bench session_swap && cargo bench --bench throughput \
      && cargo bench --bench mixed_tick
    python3 tools/check_bench_regression.py --merge-baseline
    git add BENCH_baseline.json   # commit the armed gate

stdlib only — runs on a bare CI python.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--dir", default=".",
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=None,
                    help="override the baseline's regression threshold")
    ap.add_argument("--write-baseline", default=None,
                    help="emit a baseline seeded from the current results")
    ap.add_argument("--merge-baseline", action="store_true",
                    help="rewrite --baseline in place, filling only its "
                         "null values from the current results")
    args = ap.parse_args()

    baseline = load(args.baseline)
    threshold = (args.threshold if args.threshold is not None
                 else baseline.get("threshold", 0.10))
    failures, seeded = [], {}

    for bench, spec in sorted(baseline.get("benches", {}).items()):
        cur_path = os.path.join(args.dir, f"BENCH_{bench}.json")
        gates = spec.get("regress_on", {})
        if not os.path.exists(cur_path):
            print(f"SKIP  {bench}: no {cur_path} (bench did not run)")
            continue
        cur = load(cur_path)
        if cur.get("skipped"):
            print(f"SKIP  {bench}: {cur.get('reason', 'skipped marker')}")
            continue
        cur_gates = cur.get("regress_on", {})
        seeded[bench] = {"regress_on": {}}
        for metric, base in sorted(gates.items()):
            entry = cur_gates.get(metric)
            if entry is None or entry.get("value") is None:
                print(f"WARN  {bench}.{metric}: absent from current run")
                continue
            value = float(entry["value"])
            higher = bool(base.get("higher_is_better",
                                   entry.get("higher_is_better", True)))
            thr = float(base.get("threshold", threshold))
            seeded[bench]["regress_on"][metric] = {
                "value": value, "higher_is_better": higher}
            if "threshold" in base:
                seeded[bench]["regress_on"][metric]["threshold"] = thr
            bval = base.get("value")
            if bval is None:
                print(f"SEED  {bench}.{metric} = {value:.6g}")
                continue
            bval = float(bval)
            if higher:
                limit = bval * (1.0 - thr)
                bad = value < limit
            else:
                limit = bval * (1.0 + thr)
                bad = value > limit
            verdict = "FAIL" if bad else "ok"
            arrow = ">=" if higher else "<="
            print(f"{verdict:5} {bench}.{metric}: {value:.6g} "
                  f"(baseline {bval:.6g}, must stay {arrow} {limit:.6g})")
            if bad:
                failures.append(f"{bench}.{metric}")

    if args.write_baseline:
        out = {"threshold": threshold, "benches": seeded}
        with open(args.write_baseline, "w") as f:
            json.dump(out, f, indent=1)
            f.write("\n")
        print(f"wrote seeded baseline: {args.write_baseline}")

    if args.merge_baseline:
        merged = 0
        for bench, spec in baseline.get("benches", {}).items():
            fresh = seeded.get(bench, {}).get("regress_on", {})
            for metric, base in spec.get("regress_on", {}).items():
                if base.get("value") is None and metric in fresh:
                    base["value"] = fresh[metric]["value"]
                    merged += 1
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=1)
            f.write("\n")
        print(f"merged {merged} null value(s) into {args.baseline}"
              if merged else
              f"no null values to seed in {args.baseline}")

    if failures:
        print(f"\nREGRESSION: {', '.join(failures)} (beyond threshold)")
        return 1
    print("\nbench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
