#!/usr/bin/env python3
"""staticcheck driver: run every analysis pass over a tree and report.

    python3 tools/staticcheck/run.py                 # analyze the repo, exit 1 on findings
    python3 tools/staticcheck/run.py --only lock-order
    python3 tools/staticcheck/run.py --json findings.json
    python3 tools/staticcheck/run.py --update-baseline   # ratchet panic-path baseline down

Passes live in ``tools/staticcheck/passes/`` (one module per rule); the
rule set, pragma syntax, and baseline workflow are documented in
``docs/STATIC_ANALYSIS.md``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from staticcheck.passes import ALL_PASSES  # noqa: E402
from staticcheck.passes import panic_path  # noqa: E402
from staticcheck.report import Context, Finding  # noqa: E402


def analyze(root, only: str | None = None) -> list[Finding]:
    ctx = Context(root)
    findings: list[Finding] = []
    ran: set[str] = set()
    for rule, module in ALL_PASSES:
        if only and rule != only:
            continue
        findings.extend(module.run(ctx))
        ran.add(rule)
    findings = ctx.apply_pragmas(findings, ran)
    findings.sort(key=lambda f: (f.rule, f.path, f.line))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="staticcheck", description=__doc__)
    ap.add_argument("--root", default=None,
                    help="tree to analyze (default: the repo root)")
    ap.add_argument("--only", default=None, metavar="RULE",
                    help="run a single pass: " +
                         ", ".join(r for r, _ in ALL_PASSES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write findings as a JSON report")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the panic-path baseline at current counts "
                         "(ratchets down only), then re-check")
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root \
        else Path(__file__).resolve().parent.parent.parent
    if args.only and args.only not in {r for r, _ in ALL_PASSES}:
        ap.error(f"unknown rule {args.only!r}")

    if args.update_baseline:
        baseline = panic_path.update_baseline(Context(root))
        total = sum(baseline["files"].values())
        print(f"panic-path baseline updated: {len(baseline['files'])} files, "
              f"{total} allowed sites")

    findings = analyze(root, args.only)
    for f in findings:
        print(f.render(), file=sys.stderr)
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"root": str(root), "findings": [f.as_dict() for f in findings]},
            indent=1) + "\n")
    ran = [r for r, _ in ALL_PASSES if not args.only or r == args.only]
    print(f"staticcheck: {len(ran)} passes ({', '.join(ran)}): "
          f"{'FAIL' if findings else 'ok'} ({len(findings)} findings)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
