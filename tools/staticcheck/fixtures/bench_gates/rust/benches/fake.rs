//! Fixture bench emitting two gated keys; the baseline covers one,
//! carries one stale key, and names a bench that no longer exists.

fn main() {
    let stats = run_fake_bench();
    let payload = Json::obj(vec![
        ("bench", Json::str("fake")),
        ("regress_on", Json::obj(vec![
            ("fake_a", gate(stats.mean_us, 0.10)),
            ("fake_b", gate(stats.p99_us, 0.15)),
        ])),
    ]);
    write_bench_json("fake", &payload);
}
