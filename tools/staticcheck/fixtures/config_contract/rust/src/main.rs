//! Fixture CLI spec with seeded violations.

fn common_spec() -> Spec {
    let d = EngineConfig::default();
    Spec::new()
        .opt("alpha", d.alpha.to_string(), "retention decay")
        .opt("beta", d.beta.to_string(), "window width")
        // seeded violations: apply_cli never consumes --omega, and its
        // default is a bare literal instead of deriving from d.
        .opt("omega", "42".to_string(), "dead flag")
}
