//! Fixture EngineConfig with seeded contract violations.

pub struct EngineConfig {
    pub alpha: f32,
    pub beta: usize,
    // seeded violation: no from_toml_str arm targets gamma
    pub gamma: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            alpha: 0.5,
            beta: 64,
            gamma: true,
        }
    }
}

impl EngineConfig {
    pub fn from_toml_str(text: &str) -> Self {
        let mut cfg = Self::default();
        for (key, v) in toml_pairs(text) {
            match key {
                "engine.alpha" => cfg.alpha = v.parse().unwrap_or(cfg.alpha),
                "engine.beta" => cfg.beta = v.parse().unwrap_or(cfg.beta),
                _ => {}
            }
        }
        cfg
    }

    pub fn apply_cli(&mut self, args: &Args) {
        if let Some(v) = args.get("alpha") {
            self.alpha = v.parse().unwrap_or(self.alpha);
        }
        if let Some(v) = args.get("beta") {
            self.beta = v.parse().unwrap_or(self.beta);
        }
    }
}
