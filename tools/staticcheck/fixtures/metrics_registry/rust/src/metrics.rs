//! Fixture: one undocumented series, one near-miss rename, one clean.

pub fn samples() -> Vec<Sample> {
    vec![
        Sample::counter("trimkv_requests_total", 2),
        // seeded violation: not documented at all
        Sample::counter("trimkv_orphan_total", 1),
        // seeded violation: docs say trimkv_prefix_bytes_total (near-miss)
        Sample::counter("trimkv_prefix_byte_total", 3),
    ]
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_only_names_do_not_count() {
        assert_eq!(name(), "trimkv_test_only_total");
    }
}
