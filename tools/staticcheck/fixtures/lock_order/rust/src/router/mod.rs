//! Fixture router with seeded lock-discipline violations.

impl Router {
    pub fn ok_nesting(&self) {
        // silent: alpha -> beta is a declared edge
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        g.touch(&h);
    }

    pub fn bad_nesting(&self) {
        // seeded violation: beta -> alpha is not a declared edge
        let g = self.b.lock().unwrap();
        let h = self.a.lock().unwrap();
        g.touch(&h);
    }

    pub fn relock(&self) {
        // seeded violation: alpha re-acquired while held
        let g = self.a.lock().unwrap();
        let h = self.a.lock().unwrap();
        g.touch(&h);
    }

    pub fn blocking(&self) {
        // seeded violation: channel recv while holding alpha
        let g = self.a.lock().unwrap();
        let msg = self.rx.recv().expect("peer alive");
        g.push(msg);
    }

    pub fn dropped_before_blocking(&self) {
        // silent: the guard is dropped before the recv
        let g = self.a.lock().unwrap();
        g.bump();
        drop(g);
        let _ = self.rx.recv();
    }

    pub fn undeclared(&self) {
        // seeded violation: `secret` is not on the ledger
        let s = self.secret.lock().unwrap();
        s.peek();
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn nesting_in_tests_is_ignored() {
        let g = R.b.lock().unwrap();
        let h = R.a.lock().unwrap();
        assert!(g.touch(&h));
    }
}
