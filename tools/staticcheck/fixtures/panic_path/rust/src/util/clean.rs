//! Fixture util file that burned down below its baselined count (2).

pub fn one_site(s: &str) -> u32 {
    s.parse().expect("caller validated")
}
