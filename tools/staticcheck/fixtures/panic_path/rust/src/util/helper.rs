//! Fixture util file that grew past its baselined panic-site count (1).

pub fn parse_pair(s: &str) -> (u32, u32) {
    let mut it = s.split(',');
    let a = it.next().unwrap().parse().unwrap();
    (a, 0)
}
