//! Fixture engine hot path with seeded panic-discipline violations.

impl Engine {
    pub fn tick(&mut self) {
        // seeded violation: bare unwrap on the hot path
        let x = self.queue.pop().unwrap();
        // staticcheck: allow(panic-path, index proven in range by the scan above)
        let y = self.slots.get(0).expect("in range");
        // staticcheck: allow(panic-path)
        let z = self.slots.get(1).expect("seeded violation: reasonless pragma");
        // staticcheck: allow(panic-path, seeded violation: suppresses nothing)
        let w = x + y + z;
        self.emit(w);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_in_tests_are_fine() {
        Engine::new().queue.pop().unwrap();
    }
}
