"""Findings, the per-tree analysis context, and pragma suppression.

A pass is a module exposing ``run(ctx) -> list[Finding]``.  The driver
builds one :class:`Context` per analyzed tree (the real repo or a fixture
mini-tree), runs every pass against it, then applies the inline
``// staticcheck: allow(<rule>, <reason>)`` pragmas:

- a pragma suppresses findings of its rule on the SAME line or the NEXT
  line (so it can ride above a statement without fighting rustfmt);
- a pragma that suppressed nothing is itself a finding (stale suppressions
  rot into lies about the code);
- a pragma with no reason is a finding even when it fires — the reason is
  the reviewable content.
"""
from __future__ import annotations

import sys
from dataclasses import dataclass
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from staticcheck import rustlex  # noqa: E402


@dataclass
class Finding:
    rule: str
    path: str      # repo-relative, '' for tree-level findings
    line: int      # 1-based, 0 for file-level findings
    message: str

    def render(self) -> str:
        loc = self.path if self.path else "<tree>"
        if self.line:
            loc = f"{loc}:{self.line}"
        return f"[{self.rule}] {loc}: {self.message}"

    def as_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


class Context:
    """One analyzed tree: scrub cache + path helpers shared by all passes."""

    def __init__(self, root):
        self.root = Path(root).resolve()
        self._scrubs: dict[str, rustlex.Scrub] = {}

    def exists(self, rel: str) -> bool:
        return (self.root / rel).exists()

    def read(self, rel: str) -> str:
        return (self.root / rel).read_text()

    def rust_files(self, sub: str = "rust/src") -> list[str]:
        return [str(p.relative_to(self.root))
                for p in rustlex.rust_files(self.root, sub)]

    def scrub(self, rel: str) -> rustlex.Scrub:
        if rel not in self._scrubs:
            self._scrubs[rel] = rustlex.scrub_path(self.root / rel, rel)
        return self._scrubs[rel]

    # -- pragma application ------------------------------------------------

    def apply_pragmas(self, findings: list[Finding],
                      rules: set[str] | None = None) -> list[Finding]:
        """Drop pragma-suppressed findings; append pragma-hygiene findings
        for every Rust file the passes touched.  `rules` is the set of
        rules that actually ran — a pragma for a rule that did not run
        this invocation (e.g. under --only) is never "unused"."""
        kept = []
        for f in findings:
            pragma = self._pragma_for(f)
            if pragma is None:
                kept.append(f)
            else:
                pragma.used = True
        for rel, s in sorted(self._scrubs.items()):
            for p in s.pragmas:
                if rules is not None and p.rule not in rules:
                    continue
                if not p.reason:
                    kept.append(Finding(
                        "pragma", rel, p.line,
                        f"allow({p.rule}) carries no reason — justify the "
                        f"suppression: // staticcheck: allow({p.rule}, why)"))
                if not p.used:
                    kept.append(Finding(
                        "pragma", rel, p.line,
                        f"unused allow({p.rule}) pragma — the finding it "
                        f"suppressed is gone; delete the pragma"))
        return kept

    def _pragma_for(self, f: Finding):
        if not f.path or not f.path.endswith(".rs") or not f.line:
            return None
        if f.path not in self._scrubs:
            return None  # pass never scrubbed it -> no pragmas collected
        for p in self._scrubs[f.path].pragmas:
            if p.rule == f.rule and p.line in (f.line, f.line - 1):
                return p
        return None


def parse_toml_lite(text: str) -> dict:
    """Tiny TOML subset parser (the container python predates tomllib):
    ``[section]`` / ``[two.part.section]`` headers, ``key = value`` with
    string, bool, int and flat string-array values.  Enough for
    lockorder.toml; anything fancier is a config error."""
    out: dict = {}
    section = out
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip() if not raw.strip().startswith('"') \
            else raw.strip()
        if not line:
            continue
        if line.startswith("[") and line.endswith("]"):
            section = out
            for part in line[1:-1].strip().split("."):
                section = section.setdefault(part.strip(), {})
            continue
        if "=" not in line:
            raise ValueError(f"lockorder.toml:{lineno}: expected key = value")
        key, _, val = line.partition("=")
        section[key.strip()] = _toml_value(val.strip(), lineno)
    return out


def _toml_value(val: str, lineno: int):
    if val.startswith("[") and val.endswith("]"):
        inner = val[1:-1].strip()
        if not inner:
            return []
        return [_toml_value(v.strip(), lineno) for v in inner.split(",")]
    if val.startswith('"') and val.endswith('"') and len(val) >= 2:
        return val[1:-1]
    if val in ("true", "false"):
        return val == "true"
    try:
        return int(val)
    except ValueError:
        raise ValueError(f"lockorder.toml:{lineno}: bad value {val!r}")
