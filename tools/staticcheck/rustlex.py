"""Minimal hand-rolled Rust lexer/scrubber for the staticcheck passes.

No rust toolchain exists in the build container, so every pass works on a
*scrubbed* view of the source produced here by a single character scan:

- ``code``      — comments blanked AND string/char-literal contents blanked
                  (newlines kept, so byte offsets and line numbers survive).
                  Regex passes run on this view: an ``unwrap()`` inside a
                  doc comment or a log string can never count.
- ``code_str``  — comments blanked, string literals kept verbatim.  Passes
                  that read string keys (config match arms, metric names in
                  emission tables) run on this view.
- ``strings``   — every string literal as ``(line, value)``.
- ``pragmas``   — ``// staticcheck: allow(<rule>, <reason>)`` suppressions.
- ``test_lines``— the 1-based line numbers inside ``#[cfg(test)] mod …``
                  blocks (brace-matched on the scrubbed view).

The scan understands line comments, nested block comments, plain/byte
strings with escapes, raw strings (``r"…"`` … ``r###"…"###``), char
literals, and tells lifetimes (``'a``) from char literals.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from pathlib import Path

PRAGMA_RE = re.compile(
    r"staticcheck:\s*allow\(\s*([a-z0-9-]+)\s*(?:,\s*(.*?))?\s*\)\s*$")

CFG_TEST_RE = re.compile(
    r"#\[cfg\(test\)\]\s*(?:#\[[^\]]*\]\s*)*mod\s+\w+\s*\{")


@dataclass
class Pragma:
    line: int
    rule: str
    reason: str
    used: bool = False


@dataclass
class Scrub:
    path: str
    text: str
    code: str
    code_str: str
    strings: list = field(default_factory=list)      # (line, value)
    pragmas: list = field(default_factory=list)
    test_lines: set = field(default_factory=set)     # 1-based line numbers
    _offsets: list = field(default_factory=list)

    def line_of(self, pos: int) -> int:
        """1-based line number of byte offset ``pos``."""
        import bisect
        return bisect.bisect_right(self._offsets, pos - 1) + 1

    def in_test(self, line: int) -> bool:
        return line in self.test_lines


def _is_ident(ch: str) -> bool:
    return ch.isalnum() or ch == "_"


def scrub(text: str, path: str = "<mem>") -> Scrub:
    n = len(text)
    code = list(text)
    code_str = list(text)
    strings: list = []
    pragmas: list = []

    def blank(arr, lo, hi):
        for k in range(lo, min(hi, n)):
            if arr[k] != "\n":
                arr[k] = " "

    i, line = 0, 1
    while i < n:
        c = text[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        # line comment (also the pragma carrier)
        if c == "/" and i + 1 < n and text[i + 1] == "/":
            j = text.find("\n", i)
            j = n if j == -1 else j
            m = PRAGMA_RE.search(text[i:j])
            if m:
                pragmas.append(Pragma(line, m.group(1),
                                      (m.group(2) or "").strip()))
            blank(code, i, j)
            blank(code_str, i, j)
            i = j
            continue
        # block comment (rust nests them)
        if c == "/" and i + 1 < n and text[i + 1] == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if text[j] == "/" and j + 1 < n and text[j + 1] == "*":
                    depth += 1
                    j += 2
                elif text[j] == "*" and j + 1 < n and text[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    if text[j] == "\n":
                        line += 1
                    j += 1
            blank(code, i, j)
            blank(code_str, i, j)
            i = j
            continue
        # raw string r"…" / r#"…"# / br"…"; not an identifier tail
        if (c in "rb" and (i == 0 or not _is_ident(text[i - 1]))):
            m = re.match(r'(?:br|r)(#*)"', text[i:i + 8])
            if m:
                hashes = m.group(1)
                start = i + m.end()
                term = '"' + hashes
                end = text.find(term, start)
                end = n if end == -1 else end
                val = text[start:end]
                strings.append((line, val))
                stop = min(end + len(term), n)
                blank(code, start, end)  # keep the quotes, blank contents
                line += text.count("\n", i, stop)
                i = stop
                continue
        # plain / byte string
        if c == '"':
            j = i + 1
            while j < n:
                if text[j] == "\\":
                    j += 2
                    continue
                if text[j] == '"':
                    break
                j += 1
            val = text[i + 1:j]
            strings.append((line, val))
            blank(code, i + 1, j)
            line += text.count("\n", i, min(j + 1, n))
            i = min(j + 1, n)
            continue
        # char literal vs lifetime
        if c == "'":
            if i + 1 < n and text[i + 1] == "\\":
                j = i + 2
                while j < n and text[j] != "'":
                    j += 1
                blank(code, i + 1, j)
                i = min(j + 1, n)
                continue
            if i + 2 < n and text[i + 2] == "'" and text[i + 1] != "'":
                blank(code, i + 1, i + 2)
                i += 3
                continue
            i += 1  # lifetime: skip the quote
            continue
        i += 1

    out = Scrub(path=path, text=text, code="".join(code),
                code_str="".join(code_str), strings=strings, pragmas=pragmas)
    out._offsets = [m.start() for m in re.finditer("\n", text)]

    # mark #[cfg(test)] mod … { … } extents on the scrubbed view
    for m in CFG_TEST_RE.finditer(out.code):
        open_pos = out.code.rfind("{", m.start(), m.end())
        close = match_brace(out.code, open_pos)
        for ln in range(out.line_of(m.start()), out.line_of(close) + 1):
            out.test_lines.add(ln)
    return out


def match_brace(code: str, open_pos: int) -> int:
    """Offset of the ``}`` closing the ``{`` at ``open_pos`` (scrubbed view,
    so braces inside strings/comments cannot desync the walk)."""
    depth = 0
    for j in range(open_pos, len(code)):
        if code[j] == "{":
            depth += 1
        elif code[j] == "}":
            depth -= 1
            if depth == 0:
                return j
    return len(code) - 1


def scrub_path(path: Path, rel: str | None = None) -> Scrub:
    return scrub(path.read_text(), rel or str(path))


def rust_files(root: Path, sub: str = "rust/src") -> list:
    """Sorted .rs files under ``root/sub`` (vendor/ and target/ excluded)."""
    base = root / sub
    if not base.exists():
        return []
    skip = {"vendor", "target"}
    return sorted(p for p in base.rglob("*.rs")
                  if not skip.intersection(q.name for q in p.parents))
