"""staticcheck — the repo-contract static analyzer.

Multi-pass analysis over the Rust tree (via the hand-rolled scrubber in
``rustlex``) plus the cross-language contract files (docs/OPERATIONS.md,
BENCH_baseline.json, lockorder.toml).  Entry point:

    python3 tools/staticcheck/run.py

See docs/STATIC_ANALYSIS.md for the rule catalogue, the
``// staticcheck: allow(<rule>, <reason>)`` pragma syntax, and the
panic-path baseline ratchet workflow.
"""
