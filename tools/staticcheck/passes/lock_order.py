"""lock-order: syntactic lock discipline across the serving modules.

``tools/staticcheck/lockorder.toml`` declares every mutex the scoped
modules (router, prefixcache, session, server, engine) are allowed to
hold, the guard-returning helper methods that stand in for a raw
``.lock()`` (poison-recovery wrappers), and the acquisition-order DAG
(``edges = ["outer -> inner"]`` means: holding `outer`, you may take
`inner`).  The pass then extracts every acquisition site and its
*syntactic* guard live range:

- ``let g = <acquire>`` holds to the end of the enclosing block, or to an
  explicit ``drop(g)``;
- a temporary (``<acquire>.field``) holds to the end of the statement.

Findings:

- acquiring a ``.lock()`` receiver the TOML does not declare (every lock
  in the serving core must be on the ledger);
- nested acquisition whose ``outer -> inner`` edge is not declared
  (including re-acquiring the same lock: std mutexes self-deadlock);
- a blocking call (``.recv(`` / ``.recv_timeout(`` / ``.submit(`` /
  ``.wait(`` / ``.join(``) while a guard is live — the
  blocking-while-locked hazard a fleet sharing a PrefixStore across
  replica threads cannot afford.

Everything is a line-level approximation over scrubbed source (no rust
toolchain in the container), deliberately conservative: a finding means
"restructure or declare the edge", not "proved deadlock".
"""
from __future__ import annotations

import re

from staticcheck.report import Context, Finding, parse_toml_lite
from staticcheck.rustlex import Scrub

RULE = "lock-order"
TOML = "tools/staticcheck/lockorder.toml"
SCOPED = {"router", "prefixcache", "session", "server", "engine"}

ACQ_RE = re.compile(r"(\w+)\s*\.\s*(lock|read|write)\s*\(\s*\)")
BLOCKING_RE = re.compile(r"\.\s*(recv|recv_timeout|submit|wait|join)\s*\(")
LET_RE = re.compile(r"\blet\s+(?:mut\s+)?(\w+)\s*=\s*$|\blet\s+(?:mut\s+)?(\w+)\s*=")


def run(ctx: Context) -> list[Finding]:
    if not ctx.exists(TOML):
        return []
    try:
        cfg = parse_toml_lite(ctx.read(TOML))
    except ValueError as e:
        return [Finding(RULE, TOML, 0, str(e))]
    locks = cfg.get("locks", {})
    by_module: dict[str, dict[str, str]] = {}   # module -> field -> lock id
    helpers: dict[str, dict[str, str]] = {}     # module -> helper -> lock id
    for lock_id, spec in locks.items():
        by_module.setdefault(spec["module"], {})[spec["field"]] = lock_id
        for h in spec.get("helpers", []):
            helpers.setdefault(spec["module"], {})[h] = lock_id
    edges = set()
    out: list[Finding] = []
    for e in cfg.get("order", {}).get("edges", []):
        a, _, b = e.partition("->")
        a, b = a.strip(), b.strip()
        if a not in locks or b not in locks:
            out.append(Finding(RULE, TOML, 0,
                               f"edge `{e}` references an undeclared lock"))
        edges.add((a, b))

    for rel in ctx.rust_files():
        module = _module_of(rel)
        if module not in SCOPED:
            continue
        out.extend(_check_file(ctx.scrub(rel), module,
                               by_module.get(module, {}),
                               helpers.get(module, {}), edges))
    return out


def _module_of(rel: str) -> str:
    parts = rel.split("/")
    if len(parts) < 3 or parts[0] != "rust" or parts[1] != "src":
        return ""
    return parts[2][:-3] if parts[2].endswith(".rs") else parts[2]


def _check_file(s: Scrub, module, fields, helper_map, edges):
    out = []
    acqs = []   # (lock_id, pos, end, line)
    for m in ACQ_RE.finditer(s.code):
        recv, kind = m.group(1), m.group(2)
        line = s.line_of(m.start())
        if s.in_test(line):
            continue
        if recv in fields:
            acqs.append((fields[recv], m.start(), m.end(), line))
        elif kind == "lock":
            out.append(Finding(
                RULE, s.path, line,
                f"acquisition of undeclared lock `{recv}.lock()` in module "
                f"`{module}` — declare it in {TOML}"))
        # bare .read()/.write() on undeclared receivers are ignored: too
        # many io methods share the names; RwLocks must be declared to
        # be checked at all
    for helper, lock_id in helper_map.items():
        for m in re.finditer(r"\.\s*" + re.escape(helper) + r"\s*\(\s*\)",
                             s.code):
            line = s.line_of(m.start())
            if not s.in_test(line):
                acqs.append((lock_id, m.start(), m.end(), line))
    acqs.sort(key=lambda a: a[1])

    ranges = [(lock_id, pos, _live_end(s.code, pos, end), line)
              for lock_id, pos, end, line in acqs]
    for i, (outer, pos, stop, line) in enumerate(ranges):
        for inner, ipos, _, iline in ranges:
            if ipos <= pos or ipos >= stop:
                continue
            if inner == outer:
                out.append(Finding(
                    RULE, s.path, iline,
                    f"`{inner}` re-acquired while already held (taken at "
                    f"line {line}) — std mutexes self-deadlock"))
            elif (outer, inner) not in edges:
                out.append(Finding(
                    RULE, s.path, iline,
                    f"`{inner}` acquired while holding `{outer}` (taken at "
                    f"line {line}) but `{outer} -> {inner}` is not a "
                    f"declared edge in {TOML}"))
        for b in BLOCKING_RE.finditer(s.code, pos, stop):
            bline = s.line_of(b.start())
            out.append(Finding(
                RULE, s.path, bline,
                f"blocking call `.{b.group(1)}(` while holding `{outer}` "
                f"(guard taken at line {line}) — a stalled peer would wedge "
                f"every thread contending for the lock"))
    return out


def _live_end(code: str, pos: int, acq_end: int) -> int:
    """End offset of the guard born by the acquisition at `pos`."""
    # statement head: text since the previous ; { or }
    head_start = max(code.rfind(c, 0, pos) for c in ";{}") + 1
    m = LET_RE.search(code, head_start, pos)
    if not m:
        return _stmt_end(code, acq_end)
    var = m.group(1) or m.group(2)
    block_end = _block_end(code, acq_end)
    d = re.search(r"\bdrop\s*\(\s*" + re.escape(var) + r"\s*\)",
                  code[acq_end:block_end])
    return acq_end + d.start() if d else block_end


def _block_end(code: str, pos: int) -> int:
    depth = 0
    for j in range(pos, len(code)):
        c = code[j]
        if c == "{":
            depth += 1
        elif c == "}":
            if depth == 0:
                return j
            depth -= 1
    return len(code)


def _stmt_end(code: str, pos: int) -> int:
    depth = 0
    for j in range(pos, len(code)):
        c = code[j]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth < 0:
                return j
        elif c == ";" and depth <= 0:
            return j
    return len(code)
