"""bench-gates: every deterministic metric a bench emits under
``regress_on`` must have a matching entry in BENCH_baseline.json, and
every baselined gate must still be emitted by some bench.

Extraction is syntactic over the scrubbed bench sources: the bench name
comes from ``write_bench_json("<name>", ...)``, the gated keys from
``("<key>", gate(...))`` pairs inside each ``("regress_on", Json::obj(
vec![...]))`` block (bracket-matched on the blanked view so string
contents cannot desync it).  A bench that emits several payloads (e.g. a
quick-skip marker plus the real run) contributes the union of its keys.
"""
from __future__ import annotations

import json
import re

from staticcheck.report import Context, Finding
from staticcheck.rustlex import Scrub

RULE = "bench-gates"
BASELINE = "BENCH_baseline.json"
NAME_RE = re.compile(r'write_bench_json\(\s*"(\w+)"')
KEY_RE = re.compile(r'\(\s*"([A-Za-z0-9_]+)"\s*,\s*gate\s*\(')


def run(ctx: Context) -> list[Finding]:
    emitted: dict[str, dict] = {}  # bench name -> {"keys", "path", "line"}
    for rel in ctx.rust_files("rust/benches"):
        s = ctx.scrub(rel)
        names = [(m.group(1), s.line_of(m.start()))
                 for m in NAME_RE.finditer(s.code_str)
                 if not s.in_test(s.line_of(m.start()))]
        if not names:
            continue
        keys = set()
        for block in _regress_blocks(s):
            keys.update(KEY_RE.findall(block))
        for name, line in names:
            e = emitted.setdefault(name,
                                   {"keys": set(), "path": rel, "line": line})
            e["keys"].update(keys)

    gated = {n: e for n, e in emitted.items() if e["keys"]}
    if not ctx.exists(BASELINE):
        if gated:
            return [Finding(RULE, BASELINE, 0,
                            f"{len(gated)} benches emit regress_on gates "
                            f"but {BASELINE} does not exist")]
        return []
    baseline = json.loads(ctx.read(BASELINE)).get("benches", {})

    out = []
    for name, e in sorted(gated.items()):
        want = set(baseline.get(name, {}).get("regress_on", {}))
        if name not in baseline:
            out.append(Finding(
                RULE, e["path"], e["line"],
                f"bench `{name}` emits regress_on gates but {BASELINE} has "
                f"no `{name}` entry — its regressions go ungated in CI"))
            continue
        for k in sorted(e["keys"] - want):
            out.append(Finding(
                RULE, e["path"], e["line"],
                f"bench `{name}` gates `{k}` but {BASELINE} has no "
                f"regress_on entry for it"))
        for k in sorted(want - e["keys"]):
            out.append(Finding(
                RULE, BASELINE, 0,
                f"baseline gates `{name}.{k}` but the bench no longer "
                f"emits it — stale entry"))
    for name in sorted(set(baseline) - set(emitted)):
        out.append(Finding(
            RULE, BASELINE, 0,
            f"baseline entry `{name}` has no bench emitting "
            f"write_bench_json(\"{name}\")"))
    return out


def _regress_blocks(s: Scrub) -> list[str]:
    """The `vec![...]` span of every regress_on block, from the
    string-bearing view (keys intact), bracket-matched on the blanked
    view (strings can't desync the walk)."""
    blocks = []
    for m in re.finditer(r'"regress_on"', s.code_str):
        open_pos = s.code.find("[", m.end())
        if open_pos == -1:
            continue
        depth = 0
        for j in range(open_pos, len(s.code)):
            if s.code[j] == "[":
                depth += 1
            elif s.code[j] == "]":
                depth -= 1
                if depth == 0:
                    blocks.append(s.code_str[open_pos:j + 1])
                    break
    return blocks
