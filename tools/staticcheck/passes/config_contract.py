"""config-contract: EngineConfig field <-> TOML key <-> CLI flag <->
OPERATIONS.md row, with defaults cross-checked.

Sources of truth, all parsed from scrubbed views (comments stripped,
strings intact):

- ``rust/src/config.rs``: the `EngineConfig` struct fields, the literal
  defaults in `impl Default`, the `"section.key" =>` match arms of
  `from_toml_str`, and the `args.get("name")` / `args.flag("name")` ->
  `self.field` pairs of `apply_cli`;
- ``rust/src/main.rs``: the `.opt("name", <default>, ...)` /
  `.flag("name", ...)` declarations inside `common_spec()` — every
  declared default must derive from `EngineConfig::default()` (contain a
  `d.` reference), the repo's one-source-of-truth rule;
- ``docs/OPERATIONS.md``: the configuration table rows
  ``| `[section] key` | `--flag` | default | meaning |``.

The pass enforces the full cycle: every TOML arm documented and vice
versa, every arm targeting a real field and every field reachable from
TOML, CLI consumption (`apply_cli`) equal to CLI declaration
(`common_spec`), the docs CLI column pointing at the flag that really
sets that field (em-dash rows must NOT be CLI-settable), and the docs
default column equal to the evaluated `Default` literal (`on`/`off`
normalize to bools, `64 << 20` evaluates).
"""
from __future__ import annotations

import re

from staticcheck.report import Context, Finding

RULE = "config-contract"
CONFIG = "rust/src/config.rs"
MAIN = "rust/src/main.rs"
DOCS = "docs/OPERATIONS.md"


def run(ctx: Context) -> list[Finding]:
    if not ctx.exists(CONFIG):
        return []
    s = ctx.scrub(CONFIG)
    out: list[Finding] = []

    fields = _struct_fields(s)
    defaults = _default_literals(s)
    arms = _toml_arms(s)          # toml key -> (field, line)
    cli = _apply_cli(s)           # cli name -> (field, kind, line)

    for key, (field, line) in sorted(arms.items()):
        if field not in fields:
            out.append(Finding(
                RULE, CONFIG, line,
                f"TOML arm `{key}` assigns `cfg.{field}` which is not an "
                f"EngineConfig field"))
    armed_fields = {f for f, _ in arms.values()}
    for field, line in sorted(fields.items()):
        if field not in armed_fields:
            out.append(Finding(
                RULE, CONFIG, line,
                f"EngineConfig field `{field}` is not settable via TOML "
                f"(no from_toml_str arm targets it)"))

    if ctx.exists(MAIN):
        spec = _common_spec(ctx.scrub(MAIN))  # name -> (kind, expr, line)
        for name, (kind, expr, line) in sorted(spec.items()):
            if name not in cli:
                out.append(Finding(
                    RULE, MAIN, line,
                    f"common_spec declares --{name} but apply_cli never "
                    f"consumes it (dead flag)"))
            elif cli[name][1] != kind:
                out.append(Finding(
                    RULE, MAIN, line,
                    f"--{name} is a {kind} in common_spec but a "
                    f"{cli[name][1]} in apply_cli"))
            if kind == "opt" and "d." not in expr:
                out.append(Finding(
                    RULE, MAIN, line,
                    f"--{name} default `{expr.strip()}` is not derived from "
                    f"EngineConfig::default() — the CLI and the library "
                    f"must share one source of truth"))
        for name, (_, kind, line) in sorted(cli.items()):
            if name not in spec:
                out.append(Finding(
                    RULE, CONFIG, line,
                    f"apply_cli consumes --{name} but common_spec never "
                    f"declares it (unreachable override)"))

    if ctx.exists(DOCS):
        rows = _docs_rows(ctx)    # toml key -> (cli cell, default, line)
        for key, (_, _, line) in sorted(rows.items()):
            if key not in arms:
                out.append(Finding(
                    RULE, DOCS, line,
                    f"documented TOML key `{key}` has no from_toml_str arm"))
        for key, (field, line) in sorted(arms.items()):
            if key not in rows:
                out.append(Finding(
                    RULE, DOCS, 0,
                    f"TOML key `{key}` (field `{field}`) is missing from "
                    f"the {DOCS} configuration table"))
        cli_fields = {f: (n, k) for n, (f, k, _) in cli.items()}
        for key, (cli_cell, default_cell, line) in sorted(rows.items()):
            if key not in arms:
                continue
            field = arms[key][0]
            if cli_cell is None:
                if field in cli_fields:
                    n, _ = cli_fields[field]
                    out.append(Finding(
                        RULE, DOCS, line,
                        f"`{key}` is documented as CLI-less (em-dash) but "
                        f"apply_cli sets `{field}` from --{n}"))
            else:
                name, kind = cli_cell
                if name not in cli:
                    out.append(Finding(
                        RULE, DOCS, line,
                        f"`{key}` documents --{name} which apply_cli never "
                        f"consumes"))
                else:
                    got_field, got_kind, _ = cli[name]
                    if got_field != field:
                        out.append(Finding(
                            RULE, DOCS, line,
                            f"`{key}` documents --{name}, but that flag "
                            f"sets `{got_field}`, not `{field}`"))
                    if got_kind != kind:
                        out.append(Finding(
                            RULE, DOCS, line,
                            f"--{name} kind mismatch: docs say {kind}, "
                            f"apply_cli treats it as {got_kind}"))
            if field in defaults and defaults[field] is not None:
                want = defaults[field]
                if not _defaults_equal(want, default_cell):
                    out.append(Finding(
                        RULE, DOCS, line,
                        f"`{key}` documents default `{default_cell}` but "
                        f"EngineConfig::default() says `{want}`"))
    return out


# -- config.rs extraction ---------------------------------------------------

def _find_block(s, pattern):
    """(start, end) offsets of the brace block after `pattern`, or None."""
    from staticcheck.rustlex import match_brace
    m = re.search(pattern, s.code)
    if not m:
        return None
    open_pos = s.code.find("{", m.end())
    if open_pos == -1:
        return None
    return open_pos, match_brace(s.code, open_pos)


def _struct_fields(s) -> dict:
    span = _find_block(s, r"pub\s+struct\s+EngineConfig\b")
    if not span:
        return {}
    lo, hi = span
    return {m.group(1): s.line_of(lo + m.start())
            for m in re.finditer(r"pub\s+(\w+)\s*:", s.code[lo:hi])}


def _default_literals(s) -> dict:
    span = _find_block(s, r"impl\s+Default\s+for\s+EngineConfig\b")
    if not span:
        return {}
    lo, hi = span
    inner = _find_block_within(s, lo, hi, r"EngineConfig\s*")
    if inner:
        lo, hi = inner
    out = {}
    for m in re.finditer(r"(\w+)\s*:\s*([^\n]+?),\s*$",
                         s.code_str[lo:hi], re.M):
        out[m.group(1)] = _eval_default(m.group(2))
    return out


def _find_block_within(s, lo, hi, pattern):
    from staticcheck.rustlex import match_brace
    m = re.search(pattern + r"\{", s.code[lo + 1:hi])
    if not m:
        return None
    open_pos = lo + 1 + m.end() - 1
    return open_pos, match_brace(s.code, open_pos)


def _eval_default(expr: str):
    e = expr.strip().rstrip(",").strip()
    for pat in (r'^PathBuf::from\("([^"]*)"\)$', r'^"([^"]*)"\s*\.into\(\)$',
                r'^"([^"]*)"\s*\.to_string\(\)$'):
        m = re.match(pat, e)
        if m:
            return m.group(1)
    if e in ("true", "false"):
        return e == "true"
    m = re.match(r"^(\d[\d_]*)\s*<<\s*(\d+)$", e)
    if m:
        return int(m.group(1).replace("_", "")) << int(m.group(2))
    try:
        return int(e.replace("_", ""))
    except ValueError:
        pass
    try:
        return float(e)
    except ValueError:
        return None  # not statically evaluable; skip the docs comparison


def _toml_arms(s) -> dict:
    span = _find_block(s, r"fn\s+from_toml_str\b")
    if not span:
        return {}
    lo, hi = span
    out = {}
    body = s.code_str[lo:hi]
    for m in re.finditer(r'"([a-z0-9_.]+)"\s*=>', body):
        tail = body[m.end():m.end() + 400]
        f = re.search(r"cfg\.(\w+)\s*=", tail)
        if f:
            out[m.group(1)] = (f.group(1), s.line_of(lo + m.start()))
    return out


def _apply_cli(s) -> dict:
    span = _find_block(s, r"fn\s+apply_cli\b")
    if not span:
        return {}
    lo, hi = span
    out = {}
    body = s.code_str[lo:hi]
    for m in re.finditer(r'args\.(get|flag)\(\s*"([a-z0-9-]+)"\s*\)', body):
        tail = body[m.end():m.end() + 400]
        f = re.search(r"self\.(\w+)\s*=", tail)
        if f:
            kind = "flag" if m.group(1) == "flag" else "opt"
            out[m.group(2)] = (f.group(1), kind, s.line_of(lo + m.start()))
    return out


# -- main.rs extraction -----------------------------------------------------

def _common_spec(s) -> dict:
    span = _find_block(s, r"fn\s+common_spec\b")
    if not span:
        return {}
    lo, hi = span
    out = {}
    body = s.code_str[lo:hi]
    for m in re.finditer(r'\.opt\(\s*"([a-z0-9-]+)"\s*,\s*([^,]+),', body):
        out[m.group(1)] = ("opt", m.group(2).strip(),
                           s.line_of(lo + m.start()))
    for m in re.finditer(r'\.flag\(\s*"([a-z0-9-]+)"', body):
        out[m.group(1)] = ("flag", "", s.line_of(lo + m.start()))
    return out


# -- OPERATIONS.md table ----------------------------------------------------

def _docs_rows(ctx: Context) -> dict:
    rows = {}
    for lineno, line in enumerate(ctx.read(DOCS).splitlines(), 1):
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) < 4:
            continue
        m = re.match(r"^`(?:\[(\w+)\]\s+)?(\w+)`$", cells[0])
        if not m:
            continue
        key = f"{m.group(1)}.{m.group(2)}" if m.group(1) else m.group(2)
        cli = None
        c = re.match(r"^`--([a-z0-9-]+)`(\s*\(flag\))?$", cells[1])
        if c:
            cli = (c.group(1), "flag" if c.group(2) else "opt")
        rows[key] = (cli, cells[2].strip("`"), lineno)
    return rows


def _defaults_equal(code_val, docs_cell: str) -> bool:
    cell = docs_cell.strip()
    if isinstance(code_val, bool):
        return cell.lower() in (("true", "on", "1") if code_val
                                else ("false", "off", "0"))
    if isinstance(code_val, (int, float)):
        try:
            return float(cell) == float(code_val)
        except ValueError:
            return False
    return cell == str(code_val)
