"""The pass registry.  Order is report order, not dependency order —
every pass is independent and runs against the same Context."""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent.parent))

from staticcheck.passes import (  # noqa: E402
    bench_gates,
    config_contract,
    doc_links,
    lock_order,
    metrics_registry,
    panic_path,
)

ALL_PASSES = [
    ("metrics-registry", metrics_registry),
    ("config-contract", config_contract),
    ("lock-order", lock_order),
    ("panic-path", panic_path),
    ("bench-gates", bench_gates),
    ("doc-links", doc_links),
]
