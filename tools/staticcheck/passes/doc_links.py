"""doc-links: no dead relative links in the repo's markdown docs.

Formerly the standalone ``tools/check_doc_links.py`` (now a thin wrapper
over this pass).  Every tracked *.md file is scanned for markdown links
(``[text](path)`` / ``![alt](path)``); a relative target that does not
exist on disk is a finding.  External schemes (http/https/mailto) and
pure anchors (``#section``) are skipped; ``path#fragment`` is checked as
``path``; fenced code blocks are ignored so exemplar snippets can't
false-positive.
"""
from __future__ import annotations

import re
from pathlib import Path

from staticcheck.report import Context, Finding

RULE = "doc-links"
# `fixtures` holds staticcheck's own seeded-violation corpora; each mini-
# tree is only scanned when analyzed as its own root.
SKIP_DIRS = {".git", "target", "vendor", "node_modules", "__pycache__",
             "fixtures"}
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^()\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not SKIP_DIRS.intersection(path.relative_to(root).parts[:-1]):
            yield path


def run(ctx: Context) -> list[Finding]:
    out = []
    for path in md_files(ctx.root):
        rel = str(path.relative_to(ctx.root))
        in_fence = False
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(EXTERNAL) or target.startswith("#"):
                    continue
                frag = target.split("#", 1)[0]
                if not frag:
                    continue
                resolved = (ctx.root / frag.lstrip("/")) \
                    if frag.startswith("/") else (path.parent / frag)
                if not resolved.exists():
                    out.append(Finding(
                        RULE, rel, lineno, f"dead link -> {target}"))
    return out
