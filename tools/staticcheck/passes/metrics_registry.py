"""metrics-registry: every `trimkv_*` series the Rust tree emits must be
documented in docs/OPERATIONS.md, and every documented name must still be
emitted.  Near-miss pairs (edit distance <= 2 across the two difference
sets) are called out explicitly — they are almost always a rename that
updated one side only.

Emitted = every non-test string literal in rust/src that is exactly a
metric name (`trimkv_[a-z0-9_]+`).  The exposition layer derives
`_sum`/`_count`/`_bucket`/quantile series from base names by
concatenation, so base names are the comparison universe on both sides
(OPERATIONS.md documents the derivation rule once, in prose).
"""
from __future__ import annotations

import re

from staticcheck.report import Context, Finding

RULE = "metrics-registry"
DOCS = "docs/OPERATIONS.md"
NAME_RE = re.compile(r"^trimkv_[a-z0-9_]+$")
DOC_NAME_RE = re.compile(r"trimkv_[a-z0-9_]+")


def run(ctx: Context) -> list[Finding]:
    emitted: dict[str, tuple[str, int]] = {}
    for rel in ctx.rust_files():
        s = ctx.scrub(rel)
        for line, val in s.strings:
            if NAME_RE.match(val) and not s.in_test(line):
                emitted.setdefault(val, (rel, line))
    if not emitted:
        return []
    if not ctx.exists(DOCS):
        return [Finding(RULE, DOCS, 0,
                        f"{len(emitted)} trimkv_* series are emitted but "
                        f"{DOCS} does not exist")]

    documented: dict[str, int] = {}
    for lineno, line in enumerate(ctx.read(DOCS).splitlines(), 1):
        for name in DOC_NAME_RE.findall(line):
            documented.setdefault(name, lineno)

    out = []
    undocumented = sorted(set(emitted) - set(documented))
    unemitted = sorted(set(documented) - set(emitted))
    for name in undocumented:
        rel, line = emitted[name]
        hint = _near_miss(name, unemitted)
        out.append(Finding(
            RULE, rel, line,
            f"series `{name}` is emitted but not documented in {DOCS}"
            + (f" (near-miss of documented `{hint}` — rename drift?)"
               if hint else "")))
    for name in unemitted:
        hint = _near_miss(name, undocumented)
        out.append(Finding(
            RULE, DOCS, documented[name],
            f"series `{name}` is documented but nothing in rust/src emits it"
            + (f" (near-miss of emitted `{hint}` — rename drift?)"
               if hint else "")))
    return out


def _near_miss(name: str, candidates: list[str]) -> str | None:
    best = None
    for c in candidates:
        d = _edit_distance(name, c)
        if d <= 2 and (best is None or d < best[0]):
            best = (d, c)
    return best[1] if best else None


def _edit_distance(a: str, b: str) -> int:
    if abs(len(a) - len(b)) > 2:
        return 3  # caller only cares about <= 2
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        for j, cb in enumerate(b, 1):
            cur.append(min(prev[j] + 1, cur[j - 1] + 1,
                           prev[j - 1] + (ca != cb)))
        prev = cur
    return prev[-1]
