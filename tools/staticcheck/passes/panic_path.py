"""panic-path: no unwrap/expect/panic!/unreachable! on serving paths.

Hot paths (engine tick, router, server, prefixcache) get zero tolerance:
every non-test site is a finding unless an adjacent
``// staticcheck: allow(panic-path, <reason>)`` pragma justifies it.

Everything else is held by ``tools/staticcheck/baseline.json``, a
per-file count of non-test, non-pragma'd sites that only ratchets DOWN:

- a file exceeding its baselined count fails (new panic sites never land
  silently; the baseline is not raised by --update-baseline);
- a file below its baselined count fails too ("stale baseline") until
  ``run.py --update-baseline`` records the lower count — so the burn-down
  is monotonic and visible in review.

The site patterns are exact: ``.unwrap()`` (never ``unwrap_or*``),
``.expect(`` (never ``expect_err``), ``panic!`` and ``unreachable!`` with
any delimiter.  Matching runs on the scrubbed view, so strings, comments
and ``#[cfg(test)]`` modules can never count.
"""
from __future__ import annotations

import json
import re

from staticcheck.report import Context, Finding

RULE = "panic-path"
BASELINE = "tools/staticcheck/baseline.json"
HOT = ("rust/src/engine/", "rust/src/router/", "rust/src/server/",
       "rust/src/prefixcache/")
SITE_RE = re.compile(
    r"\.unwrap\s*\(\s*\)|\.expect\s*\(|\bpanic!\s*[\(\[{]|"
    r"\bunreachable!\s*[\(\[{]")


def sites(ctx: Context, rel: str) -> list[tuple[int, str, bool]]:
    """(line, matched token, pragma'd) for every non-test site in `rel`.
    Consulting the pragma here (not in the driver) lets the baseline count
    exclude justified sites; the pragma is marked used either way."""
    s = ctx.scrub(rel)
    out = []
    for m in SITE_RE.finditer(s.code):
        line = s.line_of(m.start())
        if s.in_test(line):
            continue
        pragma = next((p for p in s.pragmas
                       if p.rule == RULE and p.line in (line, line - 1)),
                      None)
        if pragma:
            pragma.used = True
        out.append((line, m.group(0).split("(")[0].strip("."), bool(pragma)))
    return out


def survey(ctx: Context) -> tuple[dict, list[Finding]]:
    """Current non-pragma'd counts for baselined (non-hot) files, plus the
    zero-tolerance findings for hot files."""
    counts: dict[str, int] = {}
    hot_findings: list[Finding] = []
    for rel in ctx.rust_files():
        file_sites = sites(ctx, rel)
        if rel.startswith(HOT):
            for line, tok, pragmad in file_sites:
                if not pragmad:
                    hot_findings.append(Finding(
                        RULE, rel, line,
                        f"`{tok}` on a serving hot path — return an error, "
                        f"or justify it with // staticcheck: "
                        f"allow(panic-path, reason)"))
        else:
            n = sum(1 for _, _, pragmad in file_sites if not pragmad)
            if n:
                counts[rel] = n
    return counts, hot_findings


def run(ctx: Context) -> list[Finding]:
    counts, out = survey(ctx)
    baseline = load_baseline(ctx)
    files = baseline.get("files", {})
    for rel in sorted(set(counts) | set(files)):
        have, allowed = counts.get(rel, 0), files.get(rel, 0)
        if have > allowed:
            out.append(Finding(
                RULE, rel, 0,
                f"{have} non-test panic sites but the baseline allows "
                f"{allowed} — fix the new ones or pragma them with reasons "
                f"(the baseline only ratchets down)"))
        elif have < allowed:
            out.append(Finding(
                RULE, rel, 0,
                f"baseline is stale: allows {allowed} panic sites, the "
                f"file has {have} — run `python3 tools/staticcheck/run.py "
                f"--update-baseline` to lock in the progress"))
    return out


def load_baseline(ctx: Context) -> dict:
    if not ctx.exists(BASELINE):
        return {"files": {}}
    return json.loads(ctx.read(BASELINE))


def update_baseline(ctx: Context) -> dict:
    """Rewrite the baseline at the current counts, ratcheting down only:
    a file whose count grew keeps its old (lower) allowance, so the
    violation still fails after the update."""
    counts, _ = survey(ctx)
    baseline = load_baseline(ctx)
    old = baseline.get("files", {})
    baseline["files"] = {
        rel: min(n, old.get(rel, n)) for rel, n in sorted(counts.items())}
    (ctx.root / BASELINE).write_text(
        json.dumps(baseline, indent=1) + "\n")
    return baseline
