//! Integration tests over the full engine with artifacts when present
//! (`make artifacts`), falling back to SKIP messages otherwise, plus
//! artifact-free integration over the mock backend.

use std::path::Path;

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::model_meta::ModelMeta;
use trimkv::runtime::{MockBackend, PjrtBackend};
use trimkv::scheduler::Request;
use trimkv::vocab::Vocab;
use trimkv::workload::{grade, parse_golden_line, suites, Gen};

fn artifacts() -> Option<(ModelMeta, Vocab)> {
    let dir = Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("integration: artifacts missing, PJRT tests skipped");
        return None;
    }
    Some((
        ModelMeta::load(dir).unwrap(),
        Vocab::load(&dir.join("vocab.json")).unwrap(),
    ))
}

#[test]
fn golden_io_matches_python_export() {
    if artifacts().is_none() {
        return;
    }
    let report = trimkv::runtime::golden::run_goldens(Path::new("artifacts"))
        .expect("golden selftest");
    assert!(report.contains("ALL OK"), "{report}");
}

#[test]
fn golden_episodes_parse_and_self_grade() {
    let Some((_, vocab)) = artifacts() else { return };
    let text = std::fs::read_to_string("artifacts/golden_episodes.jsonl").unwrap();
    let mut n = 0;
    for line in text.lines() {
        let (task, tokens, prompt_end, answer) = parse_golden_line(line).unwrap();
        assert!(!task.is_empty());
        assert!(prompt_end < tokens.len());
        assert!(tokens.iter().all(|&t| (t as usize) < vocab.size));
        // the stored answer must match the tokens right after answer_start;
        // grading the gold continuation must yield a perfect score
        let continuation = &tokens[prompt_end..];
        let ep = trimkv::workload::Episode {
            task: task.clone(),
            prompt: tokens[..prompt_end].to_vec(),
            answer: answer.clone(),
            grade: if task == "chain" || task == "countdown" {
                trimkv::workload::GradeRule::AfterAns
            } else {
                trimkv::workload::GradeRule::ExactPrefix
            },
        };
        if task != "proc_table" {
            assert_eq!(grade(&ep, continuation, &vocab), 1.0,
                       "task {task} gold continuation does not self-grade");
        }
        n += 1;
    }
    assert!(n >= 30, "expected a full golden set, got {n}");
}

#[test]
fn pjrt_end_to_end_generation_under_eviction() {
    let Some((meta, vocab)) = artifacts() else { return };
    let budget = 48;
    let spec = meta.pick("decode", 1, budget + meta.chunk + 1, "mlp").unwrap();
    let backend =
        PjrtBackend::load(&meta, spec.b, spec.m, "default", "mlp", true).unwrap();
    let cfg = EngineConfig {
        policy: "trimkv".into(),
        budget,
        batch: 1,
        max_new_tokens: 8,
        ..Default::default()
    };
    let mut engine = Engine::new(backend, cfg, vocab.eos()).unwrap();
    let mut g = Gen::new(&vocab, 7);
    let ep = g.recall(12, 4);
    engine.submit(Request::new(0, ep.prompt.clone(), 8)).unwrap();
    let rs = engine.run_to_completion().unwrap();
    assert!(!rs[0].tokens.is_empty());
    assert!(engine.metrics.evictions > 0, "budget should force evictions");
    // every generated token is a valid vocab id
    assert!(rs[0].tokens.iter().all(|&t| (t as usize) < vocab.size));
}

#[test]
fn pjrt_full_cache_beats_or_ties_random_eviction() {
    // policy-quality smoke: with the trained model, random eviction at a
    // tight budget must not outperform the full cache on recall
    let Some((meta, vocab)) = artifacts() else { return };
    let spec = meta.pick("decode", 8, 200, "mlp").unwrap();
    let mut backend = Some(
        PjrtBackend::load(&meta, spec.b, spec.m, "default", "mlp", true).unwrap());
    let suite = suites::math(&vocab, "gsm8k", 16, 31);
    let mut scores = std::collections::BTreeMap::new();
    for (policy, budget) in [("fullkv", spec.m - meta.chunk - 1), ("random", 24)] {
        let cfg = EngineConfig { batch: 8, ..Default::default() };
        let (r, be) = trimkv::eval::run_suite(backend.take().unwrap(), &cfg,
                                              &vocab, policy, budget, &suite)
            .unwrap();
        backend = Some(be);
        scores.insert(policy, r.score);
    }
    assert!(scores["fullkv"] >= scores["random"] - 1e-9,
            "fullkv {} < random {}", scores["fullkv"], scores["random"]);
}

#[test]
fn mock_engine_handles_hundreds_of_requests() {
    let cfg = EngineConfig {
        policy: "trimkv".into(),
        budget: 16,
        batch: 4,
        chunked_prefill: true,
        ..Default::default()
    };
    let backend = MockBackend::new(4, 40);
    let mut engine = Engine::new(backend, cfg, 2).unwrap();
    for i in 0..200u64 {
        let plen = 3 + (i % 29) as usize;
        let prompt: Vec<u32> = (0..plen).map(|j| 32 + (j as u32 % 60)).collect();
        engine.submit(Request::new(i, prompt, 1 + (i % 7) as usize)).unwrap();
    }
    let rs = engine.run_to_completion().unwrap();
    assert_eq!(rs.len(), 200);
    assert_eq!(engine.metrics.requests_finished, 200);
}

#[test]
fn config_file_round_trip_drives_engine() {
    let toml = r#"
[engine]
policy = "h2o"
budget = 12
batch = 2
max_new_tokens = 3
chunked_prefill = false
"#;
    let cfg = EngineConfig::from_toml_str(toml).unwrap();
    let backend = MockBackend::new(cfg.batch, cfg.budget + 8);
    let mut engine = Engine::new(backend, cfg, 2).unwrap();
    engine.submit(Request::new(1, vec![1, 40, 41], 3)).unwrap();
    let rs = engine.run_to_completion().unwrap();
    assert_eq!(rs[0].tokens.len(), 3);
}
