//! Property tests over the cache manager, policies, scheduler and engine
//! (seeded mini-framework in util::proptest; no artifacts needed).

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::kvcache::{HeadState, SlotEntry};
use trimkv::policy::Policy;
use trimkv::runtime::MockBackend;
use trimkv::scheduler::Request;
use trimkv::util::proptest::forall;
use trimkv::util::rng::Rng;
use trimkv::{prop_assert, prop_assert_eq};

fn random_head(rng: &mut Rng, slots: usize, fill: usize) -> HeadState {
    let mut h = HeadState::new(slots, 8, true);
    for s in 0..fill.min(slots - 1) {
        let key: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        h.insert(
            s,
            SlotEntry {
                pos: s as i64,
                token: rng.below(512) as u32,
                log_beta: -(rng.f32() * 3.0 + 1e-4),
                acc_attn: rng.f32(),
                ema_attn: rng.f32(),
                last_attn: rng.f32(),
            },
            Some(&key),
        );
    }
    h
}

#[test]
fn prop_victim_is_always_live_and_not_trash() {
    forall("victim live", 300, |rng| {
        let slots = rng.range(4, 40);
        let fill = rng.range(1, slots);
        let head = random_head(rng, slots, fill);
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "locret", "random"];
        let name = names[rng.below(names.len())];
        let mut pol = Policy::from_name(name, 16, rng.next_u64()).unwrap();
        let now = rng.range(fill, fill + 100) as i64;
        let v = pol.select_victim(&head, now);
        let v = match v {
            Some(v) => v,
            None => return Err(format!("{name} returned None on non-empty head")),
        };
        prop_assert!(head.live[v], "{name} picked dead slot {v}");
        prop_assert!(v != head.slots() - 1, "{name} picked the trash slot");
        Ok(())
    });
}

#[test]
fn prop_trimkv_victim_is_true_argmin() {
    forall("trimkv argmin", 300, |rng| {
        let slots = rng.range(4, 40);
        let fill = rng.range(2, slots);
        let head = random_head(rng, slots, fill);
        let now = (fill + rng.below(50)) as i64;
        let mut pol = Policy::from_name("trimkv", 16, 0).unwrap();
        let v = pol.select_victim(&head, now).unwrap();
        let vs = head.retention_score(v, now);
        for s in head.live_slots() {
            prop_assert!(
                head.retention_score(s, now) >= vs,
                "slot {s} scores below victim {v}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_eviction_preserves_occupancy_count() {
    forall("occupancy", 200, |rng| {
        let slots = rng.range(4, 32);
        let mut head = random_head(rng, slots, slots - 1);
        let mut expected = head.used;
        let mut pol = Policy::from_name("trimkv", 8, 0).unwrap();
        for step in 0..rng.range(1, expected) {
            let v = pol.select_victim(&head, (slots + step) as i64).unwrap();
            head.evict(v);
            expected -= 1;
            prop_assert_eq!(head.used, expected);
            head.check_invariants();
        }
        Ok(())
    });
}

#[test]
fn prop_engine_budget_invariant_all_policies() {
    // the core paper invariant: the live set never exceeds the budget after
    // a tick, for every policy, prompt length and budget
    forall("engine budget", 40, |rng| {
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "random", "retrieval", "locret"];
        let policy = names[rng.below(names.len())];
        let budget = rng.range(8, 24);
        let cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch: 1,
            chunked_prefill: rng.bool(0.5),
            ..Default::default()
        };
        let backend = MockBackend::new(1, budget + 20);
        let mut engine = Engine::new(backend, cfg, 2).unwrap();
        let plen = rng.range(5, 60);
        let prompt: Vec<u32> = (0..plen).map(|_| 32 + rng.below(64) as u32).collect();
        engine
            .submit(Request::new(1, prompt, rng.range(1, 12)))
            .map_err(|e| format!("{e}"))?;
        while !engine.idle() {
            engine.tick().map_err(|e| format!("{e}"))?;
            if let Some(snap) = engine.retention_snapshot(0) {
                for (hi, head) in snap.iter().enumerate() {
                    prop_assert!(
                        head.len() <= budget,
                        "policy {policy}: head {hi} holds {} > budget {budget}",
                        head.len()
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mixed_ticks_token_equivalent_to_alternating() {
    // the mixed-tick scheduler invariant: fusing decode steps and prefill
    // chunks into one step plan changes scheduling only — every request
    // emits bit-identical tokens to the sequential prefill-then-decode
    // path.  (TRIM-KV scores tokens at creation time; each lane's cache
    // evolution — including retrieval's mirror pool and re-injections,
    // which ride the plan's inject operands since the step-plan API —
    // depends only on its own stream.)  All 7+1 deterministic policies are
    // in; only "random" is out: its shared rng interleaves differently by
    // construction.
    forall("mixed tick equivalence", 20, |rng| {
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "locret", "retrieval"];
        let policy = names[rng.below(names.len())];
        let budget = rng.range(12, 28);
        let batch = rng.range(2, 5);
        let n_req = rng.range(2, 7);
        let prompts: Vec<Vec<u32>> = (0..n_req)
            .map(|_| {
                (0..rng.range(2, 70))
                    .map(|_| 32 + rng.below(64) as u32)
                    .collect()
            })
            .collect();
        let max_new: Vec<usize> = (0..n_req).map(|_| rng.range(1, 8)).collect();
        // the alternating arm covers both head-of-line orders
        let priority = rng.bool(0.5);
        let mut streams: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
        for mixed in [true, false] {
            let cfg = EngineConfig {
                policy: policy.into(),
                budget,
                batch,
                chunked_prefill: true,
                mixed_ticks: mixed,
                prefill_priority: priority,
                ..Default::default()
            };
            let backend = MockBackend::new(batch, budget + 20);
            let mut engine = Engine::new(backend, cfg, 2).unwrap();
            for (i, p) in prompts.iter().enumerate() {
                engine
                    .submit(Request::new(i as u64, p.clone(), max_new[i]))
                    .map_err(|e| format!("{e}"))?;
            }
            let mut rs = engine.run_to_completion().map_err(|e| format!("{e}"))?;
            rs.sort_by_key(|r| r.id);
            prop_assert_eq!(rs.len(), n_req);
            if !mixed {
                prop_assert_eq!(engine.metrics.mixed_steps, 0);
            }
            streams.push(rs.into_iter().map(|r| (r.id, r.tokens)).collect());
        }
        prop_assert_eq!(&streams[0], &streams[1]);
        Ok(())
    });
}

#[test]
fn prop_pipelined_token_streams_match_serial() {
    // the pipelining tentpole invariant: overlapping the next tick's host
    // work (admission, chained snapshot swaps) with the in-flight device
    // step is a scheduling change only — every request emits bit-identical
    // tokens to the serial submit-then-wait loop, for all 7+1 deterministic
    // policies.  Sessions force mid-run parking, preemption and chase
    // swaps through the overlap window; eager vs lazy varies how many
    // transfers ride it.
    forall("pipelined equivalence", 15, |rng| {
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "locret", "retrieval"];
        let policy = names[rng.below(names.len())];
        let budget = rng.range(12, 28);
        let batch = rng.range(2, 5);
        let n_req = rng.range(2, 7);
        let prompts: Vec<Vec<u32>> = (0..n_req)
            .map(|_| {
                (0..rng.range(2, 70))
                    .map(|_| 32 + rng.below(64) as u32)
                    .collect()
            })
            .collect();
        let max_new: Vec<usize> = (0..n_req).map(|_| rng.range(1, 8)).collect();
        // ~half the requests belong to two dialogues, so lanes park, swap
        // in mid-run and get preempted; the rest are one-shots
        let sessions: Vec<Option<String>> = (0..n_req)
            .map(|_| match rng.below(4) {
                0 => Some("sa".to_string()),
                1 => Some("sb".to_string()),
                _ => None,
            })
            .collect();
        let mixed = rng.bool(0.5);
        let eager = rng.bool(0.5);
        let mut streams: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
        for pipeline in [true, false] {
            let cfg = EngineConfig {
                policy: policy.into(),
                budget,
                batch,
                chunked_prefill: true,
                mixed_ticks: mixed,
                swap_policy: if eager { "eager" } else { "lazy" }.into(),
                pipeline,
                ..Default::default()
            };
            let backend = MockBackend::new(batch, budget + 20);
            let mut engine = Engine::new(backend, cfg, 2).unwrap();
            for (i, p) in prompts.iter().enumerate() {
                let mut req = Request::new(i as u64, p.clone(), max_new[i]);
                if let Some(s) = &sessions[i] {
                    req = req.with_session(s.clone());
                }
                engine.submit(req).map_err(|e| format!("{e}"))?;
            }
            let mut rs = engine.run_to_completion().map_err(|e| format!("{e}"))?;
            rs.sort_by_key(|r| r.id);
            prop_assert_eq!(rs.len(), n_req);
            // flush must drain any in-flight step before snapshotting
            engine.flush_sessions().map_err(|e| format!("{e}"))?;
            streams.push(rs.into_iter().map(|r| (r.id, r.tokens)).collect());
        }
        prop_assert_eq!(&streams[0], &streams[1]);
        Ok(())
    });
}

#[test]
fn prop_eviction_monotonicity() {
    // paper constraint alpha_ti >= alpha_(t+1)i: once evicted, a token's
    // position never reappears in the cache (except via retrieval inject,
    // excluded here)
    forall("monotonicity", 30, |rng| {
        let budget = rng.range(6, 16);
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let backend = MockBackend::new(1, budget + 8);
        let mut engine = Engine::new(backend, cfg, 2).unwrap();
        let prompt: Vec<u32> = (0..40).map(|_| 32 + rng.below(64) as u32).collect();
        engine.submit(Request::new(1, prompt, 8)).map_err(|e| format!("{e}"))?;
        let nheads = 4 * 2;
        let mut dead: Vec<std::collections::BTreeSet<i64>> =
            vec![Default::default(); nheads];
        let mut prev_live: Vec<std::collections::BTreeSet<i64>> =
            vec![Default::default(); nheads];
        while !engine.idle() {
            engine.tick().map_err(|e| format!("{e}"))?;
            if let Some(snap) = engine.retention_snapshot(0) {
                for (hi, head) in snap.iter().enumerate() {
                    let live: std::collections::BTreeSet<i64> =
                        head.iter().map(|&(p, _, _)| p).collect();
                    for gone in prev_live[hi].difference(&live) {
                        dead[hi].insert(*gone);
                    }
                    for p in &live {
                        prop_assert!(!dead[hi].contains(p),
                                     "head {hi}: evicted pos {p} came back");
                    }
                    prev_live[hi] = live;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_session_swap_is_identity_on_lane_state() {
    // the session tentpole invariant: running the same dialogue with eager
    // swapping (host round-trip after every turn) and lazy parking (no
    // swap unless preempted) must be indistinguishable — same tokens, same
    // slot tables (live bits, entries, retention scores, attention stats),
    // same K/V slabs
    forall("session swap identity", 15, |rng| {
        let budget = rng.range(8, 20);
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm"];
        let policy = names[rng.below(names.len())];
        let chunked = rng.bool(0.5);
        let nturns = rng.range(2, 5);
        let turns: Vec<Vec<u32>> = (0..nturns)
            .map(|_| {
                (0..rng.range(3, 25))
                    .map(|_| 32 + rng.below(64) as u32)
                    .collect()
            })
            .collect();
        let mut outs = Vec::new();
        for swap_policy in ["eager", "lazy"] {
            let cfg = EngineConfig {
                policy: policy.into(),
                budget,
                batch: 1,
                chunked_prefill: chunked,
                swap_policy: swap_policy.into(),
                ..Default::default()
            };
            let backend = MockBackend::new(1, budget + 20);
            let mut engine = Engine::new(backend, cfg, 2).unwrap();
            let mut toks = Vec::new();
            for (i, t) in turns.iter().enumerate() {
                engine
                    .submit(Request::new(i as u64, t.clone(), 3)
                            .with_session("s"))
                    .map_err(|e| format!("{e}"))?;
                let rs = engine.run_to_completion().map_err(|e| format!("{e}"))?;
                prop_assert_eq!(rs.len(), 1);
                toks.push(rs[0].tokens.clone());
            }
            engine.flush_sessions().map_err(|e| format!("{e}"))?;
            let snap = engine
                .sessions()
                .get("s")
                .ok_or("no snapshot after flush")?
                .clone();
            outs.push((toks, snap));
        }
        let (t_eager, s_eager) = &outs[0];
        let (t_lazy, s_lazy) = &outs[1];
        prop_assert_eq!(t_eager, t_lazy);
        prop_assert!(s_eager.cache == s_lazy.cache,
                     "slot tables diverged across swap ({policy})");
        prop_assert_eq!(s_eager.fed, s_lazy.fed);
        prop_assert_eq!(&s_eager.history, &s_lazy.history);
        prop_assert_eq!(&s_eager.kv, &s_lazy.kv);
        Ok(())
    });
}

#[test]
fn prop_swapped_session_matches_flattened_run() {
    // a dialogue served turn-by-turn through sessions (with host swaps
    // between turns) generates the same tokens and converges to the same
    // cache state as one uninterrupted request over the identical stream
    forall("session vs flattened", 15, |rng| {
        let budget = rng.range(8, 20);
        let names = ["trimkv", "snapkv", "streaming_llm"];
        let policy = names[rng.below(names.len())];
        let cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch: 1,
            chunked_prefill: false,
            swap_policy: "eager".into(),
            ..Default::default()
        };
        let nturns = rng.range(2, 4);
        let turns: Vec<Vec<u32>> = (0..nturns)
            .map(|_| {
                (0..rng.range(3, 20))
                    .map(|_| 32 + rng.below(64) as u32)
                    .collect()
            })
            .collect();
        // session-served: every turn swaps out to host and back in
        let mut engine =
            Engine::new(MockBackend::new(1, budget + 20), cfg.clone(), 2).unwrap();
        let mut last_tokens = Vec::new();
        for (i, t) in turns.iter().enumerate() {
            let max_new = if i + 1 == turns.len() { 4 } else { 1 };
            engine
                .submit(Request::new(i as u64, t.clone(), max_new)
                        .with_session("s"))
                .map_err(|e| format!("{e}"))?;
            let rs = engine.run_to_completion().map_err(|e| format!("{e}"))?;
            prop_assert_eq!(rs.len(), 1);
            last_tokens = rs[0].tokens.clone();
        }
        prop_assert!(engine.metrics.swap_ins as usize == nturns - 1,
                     "every later turn must swap in");
        let snap_s = engine.sessions().get("s").ok_or("no snapshot")?.clone();
        // uninterrupted baseline: one request over the identical stream
        // (history minus the final turn's generation)
        let flat: Vec<u32> =
            snap_s.history[..snap_s.history.len() - last_tokens.len()].to_vec();
        let mut e2 =
            Engine::new(MockBackend::new(1, budget + 20), cfg, 2).unwrap();
        e2.submit(Request::new(9, flat, 4).with_session("f"))
            .map_err(|e| format!("{e}"))?;
        let rs = e2.run_to_completion().map_err(|e| format!("{e}"))?;
        prop_assert_eq!(&rs[0].tokens, &last_tokens);
        let snap_f = e2.sessions().get("f").ok_or("no flat snapshot")?.clone();
        prop_assert!(snap_s.cache == snap_f.cache,
                     "swapped session's slot tables diverged from the \
                      uninterrupted run ({policy})");
        prop_assert_eq!(snap_s.fed, snap_f.fed);
        prop_assert_eq!(&snap_s.history, &snap_f.history);
        prop_assert_eq!(&snap_s.kv, &snap_f.kv);
        Ok(())
    });
}

/// One decode-plan step writing `tokens[lane]` into slot `slots[lane]` of
/// every (layer, head) — fills lanes with distinct, reproducible content
/// through the unified `ModelBackend::execute` entrypoint.
fn seed_lanes(mb: &mut MockBackend, rng_tag: i32, slots: &[usize]) {
    use trimkv::runtime::{LaneOp, ModelBackend, StepPlan};
    let d = mb.dims;
    let (l, b, h, m, c) = (d.layers, mb.b, d.hkv, mb.m, mb.c);
    let ops = vec![LaneOp::Decode; b];
    let mut tokens = vec![0i32; b * c];
    let mut in_mask = vec![0.0f32; b * c];
    for lane in 0..b {
        tokens[lane * c] = 100 + rng_tag + lane as i32;
        in_mask[lane * c] = 1.0;
    }
    let pos = vec![0i32; b * c];
    let valid = vec![0.0f32; l * b * h * m];
    let mut ws = vec![(m - 1) as i32; l * b * h * c];
    for li in 0..l {
        for (lane, &slot) in slots.iter().enumerate() {
            for hh in 0..h {
                ws[((li * b + lane) * h + hh) * c] = slot as i32;
            }
        }
    }
    mb.execute(&StepPlan {
        ops: &ops,
        tokens: &tokens,
        pos: &pos,
        in_mask: &in_mask,
        valid: &valid,
        write_slots: &ws,
        inject_flag: None,
        inject_slot: None,
        inject_k: None,
        inject_v: None,
        want_attn: false,
        want_kv: true,
    })
    .unwrap();
}

#[test]
fn prop_batched_swap_subsets_roundtrip() {
    // swapping arbitrary lane subsets out and back in, in any interleaving
    // of mixed swap_lanes calls, reproduces lane K/V bit-exactly — and the
    // transfer counters account exactly O(lane) per lane moved
    use trimkv::runtime::{LaneKv, ModelBackend};
    forall("batched swap roundtrip", 25, |rng| {
        let b = rng.range(2, 6);
        let m = rng.range(6, 12);
        let mut mb = MockBackend::new(b, m);
        let slots: Vec<usize> = (0..b).map(|i| i % (m - 1)).collect();
        seed_lanes(&mut mb, rng.below(50) as i32, &slots);
        let all: Vec<usize> = (0..b).collect();
        // host model of what every lane must contain
        let mut expect: Vec<LaneKv> = mb.swap_lanes(&all, &[]).unwrap();
        let lane_elems = 2 * mb.lane_kv_len() as u64;
        for _ in 0..rng.range(2, 8) {
            let n_out = rng.below(b + 1);
            let out = rng.sample_indices(b, n_out);
            let n_in = rng.below(b + 1);
            let in_lanes = rng.sample_indices(b, n_in);
            let slabs: Vec<LaneKv> = in_lanes
                .iter()
                .map(|_| expect[rng.below(b)].clone())
                .collect();
            let inn: Vec<(usize, &LaneKv)> =
                in_lanes.iter().zip(&slabs).map(|(&l, s)| (l, s)).collect();
            let before = mb.swap_traffic();
            let down = mb.swap_lanes(&out, &inn).map_err(|e| format!("{e}"))?;
            let after = mb.swap_traffic();
            // downloads must reflect pre-call content, even for lanes that
            // the same call also overwrites
            for (i, &lane) in out.iter().enumerate() {
                prop_assert_eq!(&down[i], &expect[lane]);
            }
            for (&lane, slab) in in_lanes.iter().zip(&slabs) {
                expect[lane] = slab.clone();
            }
            prop_assert_eq!(after.swap_calls - before.swap_calls, 1);
            prop_assert_eq!(after.elems_out - before.elems_out,
                            out.len() as u64 * lane_elems);
            prop_assert_eq!(after.elems_in - before.elems_in,
                            inn.len() as u64 * lane_elems);
        }
        let fin = mb.swap_lanes(&all, &[]).unwrap();
        for lane in 0..b {
            prop_assert_eq!(&fin[lane], &expect[lane]);
        }
        Ok(())
    });
}

#[test]
fn prop_mixed_swap_equals_sequential_pair() {
    // one mixed swap_lanes(out, in) must equal swap_lanes(out, []) followed
    // by swap_lanes([], in) — both in what it returns and in the state it
    // leaves behind
    use trimkv::runtime::{LaneKv, ModelBackend};
    forall("mixed swap equivalence", 25, |rng| {
        let b = rng.range(2, 6);
        let m = rng.range(6, 10);
        let tag = rng.below(50) as i32;
        let slots: Vec<usize> = (0..b).map(|i| (i * 2) % (m - 1)).collect();
        let mut mixed = MockBackend::new(b, m);
        let mut seq = MockBackend::new(b, m);
        seed_lanes(&mut mixed, tag, &slots);
        seed_lanes(&mut seq, tag, &slots);
        let n_out = rng.below(b + 1);
        let out = rng.sample_indices(b, n_out);
        let n_in = rng.below(b + 1);
        let in_lanes = rng.sample_indices(b, n_in);
        let fill = rng.f32();
        let slab = LaneKv {
            k: vec![fill; mixed.lane_kv_len()],
            v: vec![-fill; mixed.lane_kv_len()],
        };
        let inn: Vec<(usize, &LaneKv)> =
            in_lanes.iter().map(|&l| (l, &slab)).collect();
        let d_mixed = mixed.swap_lanes(&out, &inn).map_err(|e| format!("{e}"))?;
        let d_seq = seq.swap_lanes(&out, &[]).map_err(|e| format!("{e}"))?;
        seq.swap_lanes(&[], &inn).map_err(|e| format!("{e}"))?;
        prop_assert_eq!(&d_mixed, &d_seq);
        let all: Vec<usize> = (0..b).collect();
        let f_mixed = mixed.swap_lanes(&all, &[]).unwrap();
        let f_seq = seq.swap_lanes(&all, &[]).unwrap();
        prop_assert_eq!(&f_mixed, &f_seq);
        Ok(())
    });
}

#[test]
fn prop_interleaved_sessions_match_dedicated_engines() {
    // serving S dialogues interleaved over 2 lanes — with all the parking,
    // batched preemption and swap-in that forces — must leave every session
    // in exactly the state it reaches on a dedicated single-lane engine:
    // same slot tables, same history, and bit-identical K/V for every LIVE
    // slot (dead slots are garbage by contract: they hold leftovers of
    // whatever occupied the lane before, masked by the valid bits)
    use trimkv::runtime::ModelBackend;
    use trimkv::session::SessionSnapshot;
    let live_content = |snap: &SessionSnapshot, m: usize, dh: usize| {
        let mut out: Vec<f32> = Vec::new();
        for (hi, head) in snap.cache.heads.iter().enumerate() {
            for s in head.live_slots() {
                let off = (hi * m + s) * dh;
                out.extend_from_slice(&snap.kv.k[off..off + dh]);
                out.extend_from_slice(&snap.kv.v[off..off + dh]);
            }
        }
        out
    };
    forall("interleaved sessions", 10, |rng| {
        let budget = rng.range(8, 16);
        let names = ["trimkv", "snapkv", "streaming_llm"];
        let policy = names[rng.below(names.len())];
        let nsess = 3usize;
        let nturns = rng.range(2, 4);
        let dialogs: Vec<Vec<Vec<u32>>> = (0..nsess)
            .map(|_| {
                (0..nturns)
                    .map(|_| {
                        (0..rng.range(2, 12))
                            .map(|_| 32 + rng.below(64) as u32)
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let mk_cfg = |batch: usize| EngineConfig {
            policy: policy.into(),
            budget,
            batch,
            chunked_prefill: false,
            ..Default::default()
        };
        let mut shared =
            Engine::new(MockBackend::new(2, budget + 20), mk_cfg(2), 2).unwrap();
        for j in 0..nturns {
            for (s, d) in dialogs.iter().enumerate() {
                shared
                    .submit(Request::new((j * nsess + s) as u64, d[j].clone(), 2)
                            .with_session(format!("s{s}")))
                    .map_err(|e| format!("{e}"))?;
            }
            shared.run_to_completion().map_err(|e| format!("{e}"))?;
        }
        prop_assert!(shared.metrics.preemptions > 0,
                     "3 sessions over 2 lanes must preempt");
        shared.flush_sessions().map_err(|e| format!("{e}"))?;
        let dims = shared.backend().dims();
        let (m, dh) = (budget + 20, dims.dh);
        for (s, d) in dialogs.iter().enumerate() {
            let mut solo =
                Engine::new(MockBackend::new(1, budget + 20), mk_cfg(1), 2)
                    .unwrap();
            for (j, t) in d.iter().enumerate() {
                solo.submit(Request::new(j as u64, t.clone(), 2)
                            .with_session("x"))
                    .map_err(|e| format!("{e}"))?;
                solo.run_to_completion().map_err(|e| format!("{e}"))?;
            }
            solo.flush_sessions().map_err(|e| format!("{e}"))?;
            let a = shared
                .sessions()
                .get(&format!("s{s}"))
                .ok_or("missing shared snapshot")?;
            let b = solo.sessions().get("x").ok_or("missing solo snapshot")?;
            prop_assert!(a.cache == b.cache,
                         "slot tables diverged ({policy}, session {s})");
            prop_assert_eq!(a.fed, b.fed);
            prop_assert_eq!(&a.history, &b.history);
            prop_assert!(!live_content(a, m, dh).is_empty(),
                         "live-slot comparison must cover something");
            prop_assert_eq!(live_content(a, m, dh), live_content(b, m, dh));
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_serves_all_requests_exactly_once() {
    forall("scheduler completeness", 25, |rng| {
        let batch = rng.range(1, 4);
        let cfg = EngineConfig {
            policy: "streaming_llm".into(),
            budget: 16,
            batch,
            chunked_prefill: false,
            ..Default::default()
        };
        let backend = MockBackend::new(batch, 24);
        let mut engine = Engine::new(backend, cfg, 2).unwrap();
        let n = rng.range(1, 12);
        for i in 0..n {
            let plen = rng.range(2, 20);
            let prompt: Vec<u32> =
                (0..plen).map(|_| 32 + rng.below(64) as u32).collect();
            engine
                .submit(Request::new(i as u64, prompt, rng.range(1, 6)))
                .map_err(|e| format!("{e}"))?;
        }
        let rs = engine.run_to_completion().map_err(|e| format!("{e}"))?;
        prop_assert_eq!(rs.len(), n);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        prop_assert_eq!(ids.len(), n);
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use trimkv::util::json::Json;
    forall("json roundtrip", 200, |rng| {
        fn random_json(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.below(100000) as f64) / 8.0),
                3 => Json::Str(format!("s{}-\"x\"\n", rng.below(1000))),
                4 => Json::Arr((0..rng.below(4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect()),
                _ => Json::Obj((0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect()),
            }
        }
        let v = random_json(rng, 3);
        let back = Json::parse(&v.to_string()).map_err(|e| format!("{e}"))?;
        prop_assert_eq!(v, back);
        Ok(())
    });
}

#[test]
fn prop_grading_never_rewards_wrong_prefix() {
    use trimkv::vocab::Vocab;
    use trimkv::workload::{grade, Gen};
    let vocab = Vocab::builtin();
    forall("grade soundness", 100, |rng| {
        let mut g = Gen::new(&vocab, rng.next_u64());
        let ep = g.recall(rng.range(2, 10), rng.range(0, 6));
        // a generation starting with a wrong token never scores
        let wrong = vec![ep.answer[0] ^ 1, ep.answer[0]];
        prop_assert_eq!(grade(&ep, &wrong, &vocab), 0.0);
        let mut right = ep.answer.clone();
        right.push(vocab.eos());
        prop_assert_eq!(grade(&ep, &right, &vocab), 1.0);
        Ok(())
    });
}

#[test]
fn prop_migrated_session_stream_matches_never_migrated() {
    // the replicated-serving tentpole invariant: cross-replica migration
    // (drain -> export_session -> import_session on another engine with
    // its own backend) is a placement change only — the session's token
    // stream is bit-exact with a never-migrated run, for all 7+1
    // deterministic policies.  TRIM-KV's creation-time, query-agnostic
    // retention scores make the migrated cache valid verbatim; the test
    // also covers every baseline because victim selection is a pure
    // function of the (migrated) head state.  Only "random" is out: the
    // policy rng's consumption history differs across two engines by
    // construction.  Sessionless churn on the source engine before the
    // cut proves lane-invariance survives the handoff.
    forall("migration equivalence", 12, |rng| {
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "locret", "retrieval"];
        let policy = names[rng.below(names.len())];
        let budget = rng.range(12, 28);
        let batch = rng.range(2, 5);
        let n_turns = rng.range(2, 6);
        let prompts: Vec<Vec<u32>> = (0..n_turns)
            .map(|t| {
                let len = if t == 0 { rng.range(2, 40) } else { rng.range(1, 12) };
                (0..len).map(|_| 32 + rng.below(64) as u32).collect()
            })
            .collect();
        let max_new: Vec<usize> = (0..n_turns).map(|_| rng.range(1, 7)).collect();
        // migrate at a turn boundary with at least one turn on each side
        let cut = rng.range(1, n_turns);
        let mixed = rng.bool(0.5);
        let eager = rng.bool(0.5);
        let pipeline = rng.bool(0.5);
        let cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch,
            chunked_prefill: true,
            mixed_ticks: mixed,
            swap_policy: if eager { "eager" } else { "lazy" }.into(),
            pipeline,
            ..Default::default()
        };
        let make = |cfg: &EngineConfig| {
            Engine::new(MockBackend::new(batch, budget + 20), cfg.clone(), 2)
                .unwrap()
        };
        // reference arm: one engine serves every turn
        let mut reference: Vec<Vec<u32>> = Vec::new();
        let mut eng = make(&cfg);
        for t in 0..n_turns {
            eng.submit(Request::new(t as u64, prompts[t].clone(), max_new[t])
                    .with_session("conv"))
                .map_err(|e| format!("{e}"))?;
            let rs = eng.run_to_completion().map_err(|e| format!("{e}"))?;
            prop_assert_eq!(rs.len(), 1);
            reference.push(rs[0].tokens.clone());
        }
        // migrated arm: turns < cut on the source engine (with sessionless
        // churn), then the snapshot moves to a second engine with its own
        // backend, which serves the rest
        let mut migrated: Vec<Vec<u32>> = Vec::new();
        let mut src = make(&cfg);
        let mut dst = make(&cfg);
        for t in 0..cut {
            if rng.bool(0.4) {
                let filler: Vec<u32> =
                    (0..rng.range(2, 10)).map(|_| 32 + rng.below(64) as u32)
                        .collect();
                src.submit(Request::new(100 + t as u64, filler, rng.range(1, 4)))
                    .map_err(|e| format!("{e}"))?;
            }
            src.submit(Request::new(t as u64, prompts[t].clone(), max_new[t])
                    .with_session("conv"))
                .map_err(|e| format!("{e}"))?;
            let mut rs = src.run_to_completion().map_err(|e| format!("{e}"))?;
            rs.retain(|r| r.session.as_deref() == Some("conv"));
            prop_assert_eq!(rs.len(), 1);
            migrated.push(rs[0].tokens.clone());
        }
        let snap = src
            .export_session("conv")
            .map_err(|e| format!("{e}"))?
            .ok_or_else(|| "source engine held no snapshot".to_string())?;
        prop_assert!(!src.sessions().contains("conv"),
                     "export must take the snapshot out of the source store");
        dst.import_session("conv", snap);
        for t in cut..n_turns {
            dst.submit(Request::new(t as u64, prompts[t].clone(), max_new[t])
                    .with_session("conv"))
                .map_err(|e| format!("{e}"))?;
            let rs = dst.run_to_completion().map_err(|e| format!("{e}"))?;
            prop_assert_eq!(rs.len(), 1);
            migrated.push(rs[0].tokens.clone());
        }
        prop_assert_eq!(&migrated, &reference);
        Ok(())
    });
}

#[test]
fn prop_prefix_hit_lane_decodes_identically_to_cold_prefill() {
    // the shared-prefix tentpole invariant: a lane seeded from the store
    // (cached slab + frozen retention state, tail-only prefill) emits a
    // token stream bit-identical to a cold lane that prefills the whole
    // prompt — for all 7+1 deterministic policies, chunked and unchunked
    // prefill, and random prefix/tail cut points.  TRIM-KV makes the reuse
    // sound by construction: retention scores are creation-time and
    // query-agnostic, so the frozen prefix state is exactly what the cold
    // run reaches at the same depth.  Only "random" is out: its policy rng
    // consumption differs across two engines by construction.
    forall("prefix hit equivalence", 20, |rng| {
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "locret", "retrieval"];
        let policy = names[rng.below(names.len())];
        let budget = rng.range(12, 28);
        let chunked = rng.bool(0.5);
        // chunked prefill publishes only when the store granularity lands
        // on backend-chunk boundaries (C = 16 on the mock)
        let chunk_tokens =
            if chunked { [16, 32][rng.below(2)] } else { rng.range(4, 24) };
        let cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch: 1,
            chunked_prefill: chunked,
            prefix_enabled: true,
            prefix_chunk_tokens: chunk_tokens,
            ..Default::default()
        };
        let tok = |rng: &mut Rng| 32 + rng.below(64) as u32;
        let plen = rng.range(chunk_tokens, 3 * chunk_tokens);
        let prefix: Vec<u32> = (0..plen).map(|_| tok(rng)).collect();
        let with_tail = |tail: &[u32]| {
            let mut p = prefix.clone();
            p.extend_from_slice(tail);
            p
        };
        let tail_a: Vec<u32> = (0..rng.range(1, 20)).map(|_| tok(rng)).collect();
        let tail_b: Vec<u32> = (0..rng.range(1, 20)).map(|_| tok(rng)).collect();
        let max_a = rng.range(1, 8);
        let max_b = rng.range(1, 8);
        // warm arm: P1 (a cold miss) publishes the prefix, P2 hits it
        let mut warm =
            Engine::new(MockBackend::new(1, budget + 20), cfg.clone(), 2)
                .unwrap();
        warm.submit(Request::new(1, with_tail(&tail_a), max_a))
            .map_err(|e| format!("{e}"))?;
        warm.run_to_completion().map_err(|e| format!("{e}"))?;
        warm.submit(Request::new(2, with_tail(&tail_b), max_b))
            .map_err(|e| format!("{e}"))?;
        let w2 = warm.run_to_completion().map_err(|e| format!("{e}"))?;
        prop_assert_eq!(w2.len(), 1);
        let c = warm.prefix_store().ok_or("engine lost its store")?.counters();
        prop_assert!(c.hits >= 1,
                     "P2 must hit ({policy}, chunked {chunked}, \
                      chunk {chunk_tokens}, plen {plen})");
        prop_assert!(c.prefill_tokens_saved > 0, "a hit must save prefill");
        // cold arm: a storeless engine prefills P2 end to end
        let cold_cfg = EngineConfig { prefix_enabled: false, ..cfg };
        let mut cold =
            Engine::new(MockBackend::new(1, budget + 20), cold_cfg, 2).unwrap();
        cold.submit(Request::new(2, with_tail(&tail_b), max_b))
            .map_err(|e| format!("{e}"))?;
        let c2 = cold.run_to_completion().map_err(|e| format!("{e}"))?;
        prop_assert!(w2[0].tokens == c2[0].tokens,
                     "hit lane diverged from cold prefill ({policy}, \
                      chunked {chunked}, chunk {chunk_tokens}, plen {plen}): \
                      warm {:?} vs cold {:?}", w2[0].tokens, c2[0].tokens);
        Ok(())
    });
}

#[test]
fn prop_group_shared_prefix_store_matches_cold_across_replicas() {
    // the fleet-sharing invariant: two replicas behind an EngineGroup,
    // sharing ONE prefix store, serve warm-hit requests bit-identically to
    // a storeless single engine — a replica can consume a prefix another
    // replica published, and the group's aggregated exposition carries the
    // store's counters exactly once.
    use std::sync::Arc;
    use trimkv::prefixcache::PrefixStore;
    use trimkv::router::EngineGroup;
    forall("group prefix equivalence", 6, |rng| {
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "locret", "retrieval"];
        let policy = names[rng.below(names.len())];
        let budget = rng.range(12, 28);
        let chunked = rng.bool(0.5);
        let chunk_tokens =
            if chunked { [16, 32][rng.below(2)] } else { rng.range(4, 24) };
        let cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch: 1,
            chunked_prefill: chunked,
            prefix_chunk_tokens: chunk_tokens,
            ..Default::default()
        };
        let tok = |rng: &mut Rng| 32 + rng.below(64) as u32;
        let plen = chunk_tokens + rng.below(2 * chunk_tokens);
        let prefix: Vec<u32> = (0..plen).map(|_| tok(rng)).collect();
        let n_req = 5usize; // one warm-up miss + four measured followers
        let tails: Vec<Vec<u32>> = (0..n_req)
            .map(|_| (0..rng.range(1, 16)).map(|_| tok(rng)).collect())
            .collect();
        let max_new: Vec<usize> = (0..n_req).map(|_| rng.range(1, 6)).collect();
        let prompt = |i: usize| {
            let mut p = prefix.clone();
            p.extend_from_slice(&tails[i]);
            p
        };
        // cold arm: a storeless single engine serves every request in turn
        let mut cold =
            Engine::new(MockBackend::new(1, budget + 20), cfg.clone(), 2)
                .unwrap();
        let mut want: Vec<Vec<u32>> = Vec::new();
        for i in 0..n_req {
            cold.submit(Request::new(i as u64, prompt(i), max_new[i]))
                .map_err(|e| format!("{e}"))?;
            let rs = cold.run_to_completion().map_err(|e| format!("{e}"))?;
            prop_assert_eq!(rs.len(), 1);
            want.push(rs[0].tokens.clone());
        }
        // warm arm: N=2 replicas, one shared store (the serve() wiring)
        let store = Arc::new(PrefixStore::new(16 << 20, chunk_tokens));
        let mut group = EngineGroup::spawn(2, true, |_| {
            let mut e = Engine::new(MockBackend::new(1, budget + 20),
                                    cfg.clone(), 2)?;
            e.set_prefix_store(store.clone());
            Ok(e)
        })
        .map_err(|e| format!("{e}"))?;
        group.attach_prefix_store(store.clone());
        // warm-up lands on one replica and publishes the shared prefix
        group.submit(Request::new(0, prompt(0), max_new[0]));
        let r0 = group.recv_blocking().ok_or("no warm-up response")?;
        prop_assert!(r0.tokens == want[0], "warm-up diverged ({policy})");
        // followers spread across BOTH replicas and all hit the store
        for i in 1..n_req {
            group.submit(Request::new(i as u64, prompt(i), max_new[i]));
        }
        let mut rs = Vec::new();
        for _ in 1..n_req {
            rs.push(group.recv_blocking().ok_or("replica died")?);
        }
        rs.sort_by_key(|r| r.id);
        for (i, r) in rs.iter().enumerate() {
            prop_assert!(r.tokens == want[i + 1],
                         "follower {} diverged ({policy}, chunked {chunked}, \
                          chunk {chunk_tokens})", i + 1);
        }
        let c = store.counters();
        prop_assert_eq!(c.hits, 4);
        prop_assert_eq!(c.misses, 1);
        prop_assert!(c.prefill_tokens_saved > 0);
        let text = group.metrics_snapshot().ok_or("no metrics")?;
        prop_assert!(text.contains("trimkv_prefix_hits_total 4"),
                     "group exposition lost the shared store:\n{text}");
        group.shutdown();
        Ok(())
    });
}

#[test]
fn prop_prefix_churn_evicts_without_corrupting_streams() {
    // ref-counted LRU churn: a store sized for ~2 slabs serving 4 prefix
    // families across repeated passes must evict (budget pressure is real)
    // while every response — hit, miss or re-warm — stays bit-identical to
    // a storeless engine.  The prefixcache unit tests pin the precise
    // never-free-a-pinned-entry semantics; this drives the whole engine
    // path through the churn.
    forall("prefix churn", 10, |rng| {
        let names = ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv",
                     "keydiff", "locret", "retrieval"];
        let policy = names[rng.below(names.len())];
        let budget = 16usize;
        let chunked = rng.bool(0.5);
        let chunk_tokens = if chunked { 16 } else { rng.range(6, 20) };
        // each payload's LaneKv alone is 2*layers*hkv*m*dh floats =
        // 2*4*2*36*32*4 bytes ~ 74 KiB, so 200 kB holds ~2 entries
        let max_bytes = 200_000;
        let cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch: 1,
            chunked_prefill: chunked,
            prefix_enabled: true,
            prefix_max_bytes: max_bytes,
            prefix_chunk_tokens: chunk_tokens,
            ..Default::default()
        };
        let tok = |rng: &mut Rng| 32 + rng.below(64) as u32;
        let n_fam = 4usize;
        let families: Vec<Vec<u32>> = (0..n_fam)
            .map(|_| {
                (0..chunk_tokens + rng.below(chunk_tokens))
                    .map(|_| tok(rng))
                    .collect()
            })
            .collect();
        // two passes over the families: the second mixes hits with
        // re-warms of whatever the LRU already threw out
        let mut prompts: Vec<Vec<u32>> = Vec::new();
        for _pass in 0..2 {
            for fam in &families {
                let mut p = fam.clone();
                p.extend((0..rng.range(1, 12)).map(|_| tok(rng)));
                prompts.push(p);
            }
        }
        let max_new: Vec<usize> =
            (0..prompts.len()).map(|_| rng.range(1, 6)).collect();
        let mut warm =
            Engine::new(MockBackend::new(1, budget + 20), cfg.clone(), 2)
                .unwrap();
        let cold_cfg = EngineConfig { prefix_enabled: false, ..cfg };
        let mut cold =
            Engine::new(MockBackend::new(1, budget + 20), cold_cfg, 2).unwrap();
        for (i, p) in prompts.iter().enumerate() {
            warm.submit(Request::new(i as u64, p.clone(), max_new[i]))
                .map_err(|e| format!("{e}"))?;
            let w = warm.run_to_completion().map_err(|e| format!("{e}"))?;
            cold.submit(Request::new(i as u64, p.clone(), max_new[i]))
                .map_err(|e| format!("{e}"))?;
            let c = cold.run_to_completion().map_err(|e| format!("{e}"))?;
            prop_assert!(w[0].tokens == c[0].tokens,
                         "request {i} diverged under churn ({policy}, \
                          chunked {chunked}, chunk {chunk_tokens})");
        }
        let c = warm.prefix_store().ok_or("engine lost its store")?.counters();
        prop_assert!(c.inserts >= n_fam as u64,
                     "each family must publish at least once");
        prop_assert!(c.evictions > 0,
                     "store must churn under the tiny byte budget \
                      (bytes {}, inserts {})", c.bytes, c.inserts);
        prop_assert!(c.bytes <= max_bytes,
                     "idle store left over budget: {} > {max_bytes}", c.bytes);
        Ok(())
    });
}
