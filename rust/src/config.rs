//! Engine configuration: TOML file + CLI overrides.
//!
//! ```toml
//! artifacts_dir = "artifacts"
//!
//! [engine]
//! policy = "trimkv"       # see policy::POLICY_NAMES
//! budget = 255            # live tokens per head (slots picked as > budget)
//! batch = 8               # batch lanes (must match an exported artifact)
//! max_new_tokens = 256
//! temperature = 0.0       # 0 = greedy
//! top_k = 0               # 0 = full distribution
//! seed = 0
//!
//! [scheduler]
//! queue_capacity = 1024
//! prefill_priority = false   # alternating fallback only; mixed ticks
//!                            # never face the prefill/decode choice
//! mixed_ticks = true         # fuse decode + chunked prefill into one
//!                            # step plan per tick (stall-free)
//! tick_token_budget = 0      # Sarathi-style cap on tokens per mixed tick
//!                            # (decoders reserved first; 0 = unbounded)
//! pipeline = true            # async submit/wait tick loop: host work
//!                            # (admission, swaps) overlaps the in-flight
//!                            # device step; off = serial submit-then-wait
//!
//! [session]
//! max_sessions = 256      # host-side snapshot store capacity (LRU beyond)
//! swap_policy = "lazy"    # lazy: park on the lane, swap out on demand
//!                         # eager: snapshot to host as soon as a turn ends
//!
//! [obs]
//! trace = true            # tick flight recorder (per-phase trace journal)
//! trace_capacity = 8192   # journal ring size, in events (hard memory cap)
//!
//! [router]
//! replicas = 1            # engine workers behind the session router
//!                         # (1 = plain single-engine serving, no group)
//! migration = "on"        # cross-replica session migration + automatic
//!                         # rebalancing ("off": sessions stay pinned to
//!                         # their hash home forever)
//!
//! [prefix]
//! enabled = false         # shared-prefix KV store: admission reuses the
//!                         # cached slab + retention state of a common
//!                         # prompt prefix, prefilling only the tail
//! max_bytes = 67108864    # store byte budget; LRU-evicts unreferenced
//!                         # entries beyond it (64 MiB)
//! chunk_tokens = 64       # prefix match/publish granularity in tokens
//!                         # (must divide into full backend chunks under
//!                         # chunked prefill to take effect)
//! ```

use std::path::{Path, PathBuf};

use crate::util::tomllite;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    pub policy: String,
    pub budget: usize,
    pub batch: usize,
    pub max_new_tokens: usize,
    pub temperature: f64,
    pub top_k: usize,
    pub seed: u64,
    pub queue_capacity: usize,
    pub prefill_priority: bool,
    /// Use chunked prefill (prefill graph) for prompts; otherwise prompts
    /// are fed token-by-token through the decode graph.
    pub chunked_prefill: bool,
    /// Fuse decode steps and prefill chunks into one mixed step plan per
    /// tick (no prefill/decode head-of-line blocking).  Requires
    /// `chunked_prefill`; with it off the engine schedules alternating
    /// decode/prefill phases.  How a mixed plan executes is the backend's
    /// business — a fused graph where exported, per-kind graph calls on
    /// legacy artifacts (still stall-free).
    pub mixed_ticks: bool,
    /// Token budget per mixed tick (Sarathi-style): decoding lanes are
    /// reserved one token each first, the remainder splits across
    /// mid-prefill lanes.  0 = unbounded (full chunk per filling lane).
    pub tick_token_budget: usize,
    /// Pipelined tick loop: submit the step asynchronously and overlap the
    /// next tick's host work (admission, batched swaps, deferred eager
    /// snapshots) with device execution, waiting one tick later.  Token
    /// streams are bit-identical to the serial loop; off restores the
    /// submit-then-wait tick.
    pub pipeline: bool,
    /// Capacity of the host-side session snapshot store; beyond it the
    /// least-recently-used conversation is dropped.
    pub max_sessions: usize,
    /// "lazy": a finished turn parks on its lane (KV stays device-resident)
    /// and is swapped to host only when the lane is preempted.
    /// "eager": every finished turn snapshots to host immediately.
    pub swap_policy: String,
    /// Record per-tick phase spans into the flight-recorder journal (the
    /// `trimkv trace` / Chrome-trace export source).  Cheap enough to stay
    /// on in serving; off = the journal records nothing.
    pub trace: bool,
    /// Journal ring capacity in events; the hard memory cap (oldest events
    /// are overwritten, and counted, once it fills).
    pub trace_capacity: usize,
    /// Engine workers behind the session router (`serve` spawns an
    /// `EngineGroup` when > 1; 1 keeps the plain single-engine path).
    pub replicas: usize,
    /// Cross-replica session migration and automatic rebalancing; off
    /// keeps every session pinned to its hash home.
    pub migration: bool,
    /// Shared-prefix KV store: one-shot admissions consult a
    /// longest-cached-prefix index and seed their lane from the stored
    /// slab + frozen retention state, prefilling only the prompt tail.
    pub prefix_enabled: bool,
    /// Prefix-store byte budget; beyond it the least-recently-used entry
    /// no live lane references is evicted.
    pub prefix_max_bytes: usize,
    /// Prefix match/publish granularity in tokens.
    pub prefix_chunk_tokens: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            policy: "trimkv".into(),
            budget: 255,
            batch: 8,
            max_new_tokens: 256,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            queue_capacity: 1024,
            prefill_priority: false,
            chunked_prefill: true,
            mixed_ticks: true,
            tick_token_budget: 0,
            pipeline: true,
            max_sessions: 256,
            swap_policy: "lazy".into(),
            trace: true,
            trace_capacity: 8192,
            replicas: 1,
            migration: true,
            prefix_enabled: false,
            prefix_max_bytes: 64 << 20,
            prefix_chunk_tokens: 64,
        }
    }
}

impl EngineConfig {
    pub fn from_file(path: &Path) -> anyhow::Result<EngineConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {path:?}: {e}"))?;
        Self::from_toml_str(&src)
    }

    pub fn from_toml_str(src: &str) -> anyhow::Result<EngineConfig> {
        let map = tomllite::parse(src)?;
        let mut cfg = EngineConfig::default();
        for (key, val) in &map {
            match key.as_str() {
                "artifacts_dir" => {
                    cfg.artifacts_dir = PathBuf::from(
                        val.as_str().ok_or_else(|| bad(key))?)
                }
                "engine.policy" => {
                    cfg.policy = val.as_str().ok_or_else(|| bad(key))?.into()
                }
                "engine.budget" => cfg.budget = val.as_usize().ok_or_else(|| bad(key))?,
                "engine.batch" => cfg.batch = val.as_usize().ok_or_else(|| bad(key))?,
                "engine.max_new_tokens" => {
                    cfg.max_new_tokens = val.as_usize().ok_or_else(|| bad(key))?
                }
                "engine.temperature" => {
                    cfg.temperature = val.as_f64().ok_or_else(|| bad(key))?
                }
                "engine.top_k" => cfg.top_k = val.as_usize().ok_or_else(|| bad(key))?,
                "engine.seed" => cfg.seed = val.as_usize().ok_or_else(|| bad(key))? as u64,
                "engine.chunked_prefill" => {
                    cfg.chunked_prefill = val.as_bool().ok_or_else(|| bad(key))?
                }
                "scheduler.queue_capacity" => {
                    cfg.queue_capacity = val.as_usize().ok_or_else(|| bad(key))?
                }
                "scheduler.prefill_priority" => {
                    cfg.prefill_priority = val.as_bool().ok_or_else(|| bad(key))?
                }
                "scheduler.mixed_ticks" => {
                    cfg.mixed_ticks = val.as_bool().ok_or_else(|| bad(key))?
                }
                "scheduler.tick_token_budget" => {
                    cfg.tick_token_budget =
                        val.as_usize().ok_or_else(|| bad(key))?
                }
                "scheduler.pipeline" => {
                    cfg.pipeline = val.as_bool().ok_or_else(|| bad(key))?
                }
                "session.max_sessions" => {
                    cfg.max_sessions = val.as_usize().ok_or_else(|| bad(key))?
                }
                "session.swap_policy" => {
                    cfg.swap_policy = val.as_str().ok_or_else(|| bad(key))?.into()
                }
                "obs.trace" => {
                    cfg.trace = val.as_bool().ok_or_else(|| bad(key))?
                }
                "obs.trace_capacity" => {
                    cfg.trace_capacity =
                        val.as_usize().ok_or_else(|| bad(key))?
                }
                "router.replicas" => {
                    cfg.replicas = val.as_usize().ok_or_else(|| bad(key))?
                }
                "router.migration" => {
                    // accepts a bool or the "on"/"off" strings
                    cfg.migration = match (val.as_bool(), val.as_str()) {
                        (Some(b), _) => b,
                        (None, Some("on")) => true,
                        (None, Some("off")) => false,
                        _ => anyhow::bail!(
                            "router.migration must be on|off (got {val:?})"),
                    }
                }
                "prefix.enabled" => {
                    cfg.prefix_enabled = val.as_bool().ok_or_else(|| bad(key))?
                }
                "prefix.max_bytes" => {
                    cfg.prefix_max_bytes =
                        val.as_usize().ok_or_else(|| bad(key))?
                }
                "prefix.chunk_tokens" => {
                    cfg.prefix_chunk_tokens =
                        val.as_usize().ok_or_else(|| bad(key))?
                }
                other => anyhow::bail!("unknown config key `{other}`"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `--policy/--budget/--batch/...` style CLI overrides.
    pub fn apply_cli(&mut self, args: &crate::util::cli::Args) -> anyhow::Result<()> {
        if let Some(v) = args.get("policy") {
            self.policy = v.to_string();
        }
        if let Some(v) = args.get("budget") {
            self.budget = v.parse().map_err(|_| anyhow::anyhow!("bad --budget"))?;
        }
        if let Some(v) = args.get("batch") {
            self.batch = v.parse().map_err(|_| anyhow::anyhow!("bad --batch"))?;
        }
        if let Some(v) = args.get("max-new-tokens") {
            self.max_new_tokens =
                v.parse().map_err(|_| anyhow::anyhow!("bad --max-new-tokens"))?;
        }
        if let Some(v) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("seed") {
            self.seed = v.parse().map_err(|_| anyhow::anyhow!("bad --seed"))?;
        }
        if let Some(v) = args.get("max-sessions") {
            self.max_sessions =
                v.parse().map_err(|_| anyhow::anyhow!("bad --max-sessions"))?;
        }
        if let Some(v) = args.get("swap-policy") {
            self.swap_policy = v.to_string();
        }
        if let Some(v) = args.get("mixed-ticks") {
            self.mixed_ticks = match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                _ => anyhow::bail!("bad --mixed-ticks (true|false)"),
            };
        }
        if let Some(v) = args.get("tick-token-budget") {
            self.tick_token_budget =
                v.parse().map_err(|_| anyhow::anyhow!("bad --tick-token-budget"))?;
        }
        if let Some(v) = args.get("pipeline") {
            self.pipeline = match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                _ => anyhow::bail!("bad --pipeline (true|false)"),
            };
        }
        if args.flag("no-trace") {
            self.trace = false;
        }
        if let Some(v) = args.get("trace-capacity") {
            self.trace_capacity =
                v.parse().map_err(|_| anyhow::anyhow!("bad --trace-capacity"))?;
        }
        if let Some(v) = args.get("replicas") {
            self.replicas =
                v.parse().map_err(|_| anyhow::anyhow!("bad --replicas"))?;
        }
        if let Some(v) = args.get("migration") {
            self.migration = match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                _ => anyhow::bail!("bad --migration (on|off)"),
            };
        }
        if args.flag("prefix-cache") {
            self.prefix_enabled = true;
        }
        if let Some(v) = args.get("prefix-max-bytes") {
            self.prefix_max_bytes =
                v.parse().map_err(|_| anyhow::anyhow!("bad --prefix-max-bytes"))?;
        }
        if let Some(v) = args.get("prefix-chunk") {
            self.prefix_chunk_tokens =
                v.parse().map_err(|_| anyhow::anyhow!("bad --prefix-chunk"))?;
        }
        self.validate()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.budget >= 8, "budget must be >= 8 (got {})", self.budget);
        anyhow::ensure!(self.batch >= 1, "batch must be >= 1");
        anyhow::ensure!(self.temperature >= 0.0, "temperature must be >= 0");
        anyhow::ensure!(
            crate::policy::POLICY_NAMES.contains(&self.policy.as_str()),
            "unknown policy `{}`", self.policy
        );
        anyhow::ensure!(self.max_sessions >= 1, "max_sessions must be >= 1");
        anyhow::ensure!(
            matches!(self.swap_policy.as_str(), "lazy" | "eager"),
            "swap_policy must be `lazy` or `eager` (got `{}`)", self.swap_policy
        );
        anyhow::ensure!(self.trace_capacity >= 1,
                        "trace_capacity must be >= 1");
        anyhow::ensure!(self.replicas >= 1, "replicas must be >= 1");
        anyhow::ensure!(self.prefix_chunk_tokens >= 1,
                        "prefix.chunk_tokens must be >= 1");
        Ok(())
    }
}

fn bad(key: &str) -> anyhow::Error {
    anyhow::anyhow!("config key `{key}` has the wrong type")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        EngineConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_file() {
        let cfg = EngineConfig::from_toml_str(
            r#"
artifacts_dir = "x/y"
[engine]
policy = "h2o"
budget = 128
batch = 1
temperature = 0.7
top_k = 40
[scheduler]
queue_capacity = 9
prefill_priority = true
"#,
        )
        .unwrap();
        assert_eq!(cfg.artifacts_dir, PathBuf::from("x/y"));
        assert_eq!(cfg.policy, "h2o");
        assert_eq!(cfg.budget, 128);
        assert_eq!(cfg.batch, 1);
        assert_eq!(cfg.temperature, 0.7);
        assert_eq!(cfg.queue_capacity, 9);
        assert!(cfg.prefill_priority);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(EngineConfig::from_toml_str("nope = 1").is_err());
        assert!(EngineConfig::from_toml_str("[engine]\npolicy = \"bogus\"").is_err());
        assert!(EngineConfig::from_toml_str("[engine]\nbudget = 2").is_err());
        assert!(EngineConfig::from_toml_str("[engine]\nbudget = \"s\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "[session]\nswap_policy = \"sometimes\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "[session]\nmax_sessions = 0").is_err());
    }

    #[test]
    fn parses_mixed_tick_keys() {
        let cfg = EngineConfig::from_toml_str(
            "[scheduler]\nmixed_ticks = false\ntick_token_budget = 96")
            .unwrap();
        assert!(!cfg.mixed_ticks);
        assert_eq!(cfg.tick_token_budget, 96);
        let d = EngineConfig::default();
        assert!(d.mixed_ticks, "mixed scheduling is the default");
        assert_eq!(d.tick_token_budget, 0);
        assert!(EngineConfig::from_toml_str(
            "[scheduler]\ntick_token_budget = \"lots\"").is_err());
    }

    #[test]
    fn parses_pipeline_key() {
        let cfg = EngineConfig::from_toml_str(
            "[scheduler]\npipeline = false").unwrap();
        assert!(!cfg.pipeline);
        assert!(EngineConfig::default().pipeline,
                "the pipelined loop is the default");
        assert!(EngineConfig::from_toml_str(
            "[scheduler]\npipeline = \"fast\"").is_err());
    }

    #[test]
    fn parses_session_keys() {
        let cfg = EngineConfig::from_toml_str(
            "[session]\nmax_sessions = 9\nswap_policy = \"eager\"").unwrap();
        assert_eq!(cfg.max_sessions, 9);
        assert_eq!(cfg.swap_policy, "eager");
    }

    #[test]
    fn parses_obs_keys() {
        let cfg = EngineConfig::from_toml_str(
            "[obs]\ntrace = false\ntrace_capacity = 64").unwrap();
        assert!(!cfg.trace);
        assert_eq!(cfg.trace_capacity, 64);
        let d = EngineConfig::default();
        assert!(d.trace, "tracing is on by default");
        assert_eq!(d.trace_capacity, 8192);
        assert!(EngineConfig::from_toml_str(
            "[obs]\ntrace_capacity = 0").is_err());
        assert!(EngineConfig::from_toml_str(
            "[obs]\ntrace = \"maybe\"").is_err());
    }

    #[test]
    fn parses_router_keys() {
        let cfg = EngineConfig::from_toml_str(
            "[router]\nreplicas = 4\nmigration = \"off\"").unwrap();
        assert_eq!(cfg.replicas, 4);
        assert!(!cfg.migration);
        // bool spelling works too
        let cfg = EngineConfig::from_toml_str(
            "[router]\nmigration = true").unwrap();
        assert!(cfg.migration);
        let d = EngineConfig::default();
        assert_eq!(d.replicas, 1, "single-engine serving is the default");
        assert!(d.migration, "migration is on by default");
        assert!(EngineConfig::from_toml_str("[router]\nreplicas = 0").is_err());
        assert!(EngineConfig::from_toml_str(
            "[router]\nmigration = \"sometimes\"").is_err());
    }

    #[test]
    fn parses_prefix_keys() {
        let cfg = EngineConfig::from_toml_str(
            "[prefix]\nenabled = true\nmax_bytes = 1024\nchunk_tokens = 32")
            .unwrap();
        assert!(cfg.prefix_enabled);
        assert_eq!(cfg.prefix_max_bytes, 1024);
        assert_eq!(cfg.prefix_chunk_tokens, 32);
        let d = EngineConfig::default();
        assert!(!d.prefix_enabled, "prefix sharing is opt-in");
        assert_eq!(d.prefix_max_bytes, 64 << 20);
        assert_eq!(d.prefix_chunk_tokens, 64);
        assert!(EngineConfig::from_toml_str(
            "[prefix]\nchunk_tokens = 0").is_err());
        assert!(EngineConfig::from_toml_str(
            "[prefix]\nenabled = \"yes\"").is_err());
    }
}
