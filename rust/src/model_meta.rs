//! artifacts/meta.json — the AOT interchange contract with python.
//!
//! Describes the model dimensions, the flat parameter/gate tensor order the
//! HLO graphs expect, and the exported graph variants (batch lanes B, cache
//! slots M, chunk C).  The engine picks the smallest M >= its budget.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub hq: usize,
    pub hkv: usize,
    pub dh: usize,
    pub ffn: usize,
    pub gate_hidden: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub kind: String, // "decode" | "prefill" | "mixed"
    pub b: usize,
    pub m: usize,
    pub c: usize,
    pub file: String,
    pub gate_arch: String, // "mlp" | "linear"
    /// Always "per_lane": the graph takes/returns one kc/vc buffer per
    /// batch lane (O(lane) session swap).  The legacy "monolithic" layout
    /// was removed at the end of its deprecation window; `from_json` bails
    /// on such exports.
    pub cache_layout: String,
    /// The graph's runtime operand names in call order (after params +
    /// gates) — the exported `StepPlan` operand contract.  Empty on
    /// exports that predate the field.
    pub runtime_inputs: Vec<String>,
}

impl ArtifactSpec {
    /// Does this graph take the retrieval inject operands?  Decode graphs
    /// always do; mixed graphs declare them in `runtime_inputs` (the
    /// backend refuses to load a mixed graph without them — the PR-3-era
    /// inject-less exports are past their deprecation window).
    pub fn has_inject(&self) -> bool {
        self.kind == "decode"
            || self.runtime_inputs.iter().any(|s| s == "inject_flag")
    }
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub chunk: usize,
    pub param_order: Vec<TensorSpec>,
    pub gate_order: Vec<TensorSpec>,
    pub decode_outputs: Vec<String>,
    pub prefill_outputs: Vec<String>,
    /// Output order of the fused mixed-step graphs; empty on exports that
    /// predate the `mixed` artifact kind (alternating-tick fallback).
    pub mixed_outputs: Vec<String>,
    pub gate_variants: Vec<String>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> anyhow::Result<ModelMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        Self::from_json(dir, &Json::parse(&text)?)
    }

    pub fn from_json(dir: &Path, j: &Json) -> anyhow::Result<ModelMeta> {
        let m = j.get("model").ok_or_else(|| anyhow::anyhow!("meta: no model"))?;
        let dims = ModelDims {
            vocab: m.usize_field("vocab")?,
            d: m.usize_field("d")?,
            layers: m.usize_field("layers")?,
            hq: m.usize_field("hq")?,
            hkv: m.usize_field("hkv")?,
            dh: m.usize_field("dh")?,
            ffn: m.usize_field("ffn")?,
            gate_hidden: m.usize_field("gate_hidden")?,
        };
        let tensor_list = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("meta: missing {key}"))?
                .iter()
                .map(|e| {
                    Ok(TensorSpec {
                        name: e.str_field("name")?.to_string(),
                        shape: e
                            .get("shape")
                            .and_then(Json::as_arr)
                            .map(|a| a.iter().filter_map(Json::as_usize).collect())
                            .unwrap_or_default(),
                    })
                })
                .collect()
        };
        let str_list = |key: &str| -> Vec<String> {
            j.get(key)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("meta: missing artifacts"))?
            .iter()
            .map(|a| {
                let file = a.str_field("file")?.to_string();
                let cache_layout = a
                    .get("cache_layout")
                    .and_then(Json::as_str)
                    .unwrap_or("monolithic")
                    .to_string();
                anyhow::ensure!(
                    cache_layout == "per_lane",
                    "artifact {file} uses the removed `{cache_layout}` \
                     cache_layout; re-export with python -m compile.aot to \
                     get per-lane residency",
                );
                Ok(ArtifactSpec {
                    kind: a.str_field("kind")?.to_string(),
                    b: a.usize_field("b")?,
                    m: a.usize_field("m")?,
                    c: a.usize_field("c")?,
                    file,
                    gate_arch: a.str_field("gate_arch")?.to_string(),
                    cache_layout,
                    runtime_inputs: a
                        .get("runtime_inputs")
                        .and_then(Json::as_arr)
                        .map(|arr| {
                            arr.iter()
                                .filter_map(|x| x.as_str().map(String::from))
                                .collect()
                        })
                        .unwrap_or_default(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ModelMeta {
            dir: dir.to_path_buf(),
            dims,
            chunk: j.usize_field("chunk")?,
            param_order: tensor_list("param_order")?,
            gate_order: tensor_list("gate_order")?,
            decode_outputs: str_list("decode_outputs"),
            prefill_outputs: str_list("prefill_outputs"),
            mixed_outputs: str_list("mixed_outputs"),
            gate_variants: str_list("gate_variants"),
            artifacts,
        })
    }

    /// Does this export carry a fused mixed-step graph for the given
    /// (batch, slots, gate arch)?  Legacy artifacts return false and the
    /// engine schedules alternating prefill/decode ticks.
    pub fn supports_mixed(&self, b: usize, m: usize, gate_arch: &str) -> bool {
        self.artifacts.iter().any(|a| {
            a.kind == "mixed" && a.b == b && a.m == m && a.gate_arch == gate_arch
        })
    }

    /// Smallest exported variant with b == `b` and m >= `budget`.
    pub fn pick(&self, kind: &str, b: usize, budget: usize,
                gate_arch: &str) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.b == b && a.m >= budget
                        && a.gate_arch == gate_arch)
            .min_by_key(|a| a.m)
    }

    /// All batch-lane counts available for a given kind.
    pub fn available_batches(&self, kind: &str) -> Vec<usize> {
        let mut bs: Vec<usize> =
            self.artifacts.iter().filter(|a| a.kind == kind).map(|a| a.b).collect();
        bs.sort_unstable();
        bs.dedup();
        bs
    }
}

#[cfg(test)]
pub fn test_meta() -> ModelMeta {
    // per-lane mixed graph at b=8: the step-plan operand order with one
    // kc/vc buffer per batch lane in the cache span
    let mut mixed_inputs: Vec<String> =
        ["tokens", "pos", "in_mask", "mode"].map(String::from).to_vec();
    mixed_inputs.extend((0..8).map(|i| format!("kc{i}")));
    mixed_inputs.extend((0..8).map(|i| format!("vc{i}")));
    mixed_inputs.extend(["valid", "write_slots", "inject_flag",
                         "inject_slot", "inject_k", "inject_v"]
        .map(String::from));
    ModelMeta {
        dir: PathBuf::from("artifacts"),
        dims: ModelDims { vocab: 512, d: 128, layers: 4, hq: 4, hkv: 2,
                          dh: 32, ffn: 256, gate_hidden: 48 },
        chunk: 64,
        param_order: vec![],
        gate_order: vec![],
        decode_outputs: vec!["logits".into(), "kc".into(), "vc".into(),
                             "valid".into(), "log_beta".into(), "attn".into(),
                             "k_new".into()],
        prefill_outputs: vec![],
        mixed_outputs: vec![],
        gate_variants: vec!["default".into()],
        artifacts: vec![
            ArtifactSpec { kind: "decode".into(), b: 8, m: 128, c: 1,
                           file: "decode_b8_m128_pl.hlo.txt".into(),
                           gate_arch: "mlp".into(),
                           cache_layout: "per_lane".into(),
                           runtime_inputs: vec![] },
            ArtifactSpec { kind: "decode".into(), b: 8, m: 768, c: 1,
                           file: "decode_b8_m768_pl.hlo.txt".into(),
                           gate_arch: "mlp".into(),
                           cache_layout: "per_lane".into(),
                           runtime_inputs: vec![] },
            ArtifactSpec { kind: "mixed".into(), b: 8, m: 128, c: 64,
                           file: "mixed_b8_m128_pl.hlo.txt".into(),
                           gate_arch: "mlp".into(),
                           cache_layout: "per_lane".into(),
                           runtime_inputs: mixed_inputs },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_chooses_smallest_sufficient_m() {
        let meta = test_meta();
        assert_eq!(meta.pick("decode", 8, 100, "mlp").unwrap().m, 128);
        assert_eq!(meta.pick("decode", 8, 128, "mlp").unwrap().m, 128);
        assert_eq!(meta.pick("decode", 8, 200, "mlp").unwrap().m, 768);
        assert!(meta.pick("decode", 8, 1000, "mlp").is_none());
        assert!(meta.pick("decode", 1, 64, "mlp").is_none());
    }

    #[test]
    fn mixed_capability_is_per_variant_and_defaults_off() {
        let meta = test_meta();
        assert!(meta.supports_mixed(8, 128, "mlp"));
        assert!(!meta.supports_mixed(8, 768, "mlp"), "no mixed graph at m=768");
        assert!(!meta.supports_mixed(1, 128, "mlp"));
        // pick works on the mixed kind like any other
        assert_eq!(meta.pick("mixed", 8, 100, "mlp").unwrap().m, 128);
        assert!(meta.pick("mixed", 8, 500, "mlp").is_none());
    }

    #[test]
    fn inject_capability_follows_runtime_inputs() {
        let meta = test_meta();
        // decode graphs always take the inject operands
        assert!(meta.pick("decode", 8, 100, "mlp").unwrap().has_inject());
        // the test mixed artifact declares the step-plan operand order
        assert!(meta.pick("mixed", 8, 100, "mlp").unwrap().has_inject());
        // a PR-3-era mixed artifact (no runtime_inputs) is not injectable
        let mut legacy = meta.pick("mixed", 8, 100, "mlp").unwrap().clone();
        legacy.runtime_inputs.clear();
        assert!(!legacy.has_inject());
    }

    #[test]
    fn parses_meta_json() {
        let src = r#"{
          "model": {"vocab":512,"d":128,"layers":4,"hq":4,"hkv":2,"dh":32,
                    "ffn":256,"gate_hidden":48,"rope_theta":10000.0},
          "chunk": 64,
          "param_order": [{"name":"embed","shape":[512,128]}],
          "gate_order": [{"name":"g0.w1","shape":[128,48]}],
          "decode_outputs": ["logits"],
          "prefill_outputs": ["logits"],
          "gate_variants": ["default"],
          "artifacts": [{"kind":"decode","b":8,"m":256,"c":1,
                         "file":"decode_b8_m256.hlo.txt","gate_arch":"mlp",
                         "cache_layout":"per_lane"}]
        }"#;
        let meta =
            ModelMeta::from_json(Path::new("x"), &Json::parse(src).unwrap()).unwrap();
        assert_eq!(meta.dims.layers, 4);
        assert_eq!(meta.param_order[0].shape, vec![512, 128]);
        assert_eq!(meta.artifacts.len(), 1);
        assert_eq!(meta.artifacts[0].cache_layout, "per_lane");
        assert_eq!(meta.available_batches("decode"), vec![8]);
        // exports without mixed graphs carry no mixed output order
        assert!(meta.mixed_outputs.is_empty());
        assert!(!meta.supports_mixed(8, 256, "mlp"));
    }

    #[test]
    fn rejects_monolithic_and_layoutless_exports() {
        // pre-refactor exports carry no cache_layout key (implicitly
        // monolithic); both forms are past their deprecation window
        for extra in ["", r#","cache_layout":"monolithic""#] {
            let src = format!(
                r#"{{
                  "model": {{"vocab":512,"d":128,"layers":4,"hq":4,"hkv":2,
                            "dh":32,"ffn":256,"gate_hidden":48}},
                  "chunk": 64,
                  "param_order": [],
                  "gate_order": [],
                  "artifacts": [{{"kind":"decode","b":8,"m":256,"c":1,
                                 "file":"d.hlo.txt","gate_arch":"mlp"{extra}}}]
                }}"#
            );
            let err =
                ModelMeta::from_json(Path::new("x"), &Json::parse(&src).unwrap())
                    .unwrap_err();
            assert!(err.to_string().contains("re-export"), "err: {err}");
        }
    }
}
