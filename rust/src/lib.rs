//! TRIM-KV: learnable token-retention eviction for memory-bounded KV caches
//! (reproduction of Bui et al., 2025), served by a rust coordinator over
//! AOT-compiled JAX/Pallas graphs via PJRT.
//!
//! Layering (see DESIGN.md):
//! - [`util`] — offline substrates (json/toml/cli/rng/stats/proptest/bench)
//! - [`vocab`] / [`model_meta`] — artifact interchange contracts with python
//! - [`runtime`] — PJRT client, HLO loading, the ModelBackend abstraction
//! - [`kvcache`] / [`policy`] — slot cache manager + eviction policies
//! - [`obs`] — observability plane: tick flight recorder, metric samples +
//!   Prometheus-style exposition, retention-score introspection
//! - [`session`] — host-side KV snapshot/swap store for multi-turn serving
//! - [`prefixcache`] — shared-prefix KV store: longest-cached-prefix index
//!   over immutable slab+retention payloads, ref-counted LRU under a byte
//!   budget
//! - [`engine`] / [`scheduler`] / [`server`] — the serving coordinator
//! - [`router`] — N-replica `EngineGroup` + session router (pinning,
//!   load balancing, cross-replica migration)
//! - [`workload`] / [`eval`] — paper benchmark suites and table harnesses

pub mod config;
pub mod engine;
pub mod eval;
pub mod kvcache;
pub mod metrics;
pub mod model_meta;
pub mod obs;
pub mod policy;
pub mod prefixcache;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod util;
pub mod vocab;
pub mod workload;
