//! Slotted KV-cache manager (L3 state behind the paper's eviction policies).
//!
//! The device holds the actual K/V tensors in `[L, B, Hkv, M, dh]` slot
//! arenas; this module owns the *host-side* bookkeeping per (lane, layer,
//! head): which slot is live, each cached token's position/id, its retention
//! score `log beta` (TRIM-KV), accumulated/last attention (H2O/SnapKV/R-KV)
//! and an optional mirror of the key vector (R-KV/KeyDiff/retrieval).
//!
//! Invariants (enforced in debug + property tests):
//!   - `used == live.count_ones()`
//!   - a slot is never double-occupied, the trash slot is never live
//!   - evicting removes exactly one live slot; once evicted a token never
//!     re-enters except through the explicit retrieval `inject` path
//!     (the paper's monotonicity constraint alpha_ti >= alpha_(t+1)i).

use crate::model_meta::ModelDims;

/// Host bookkeeping for one cached token in one head.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SlotEntry {
    pub pos: i64,       // token index i in the sequence
    pub token: u32,     // token id (for retention dumps / debugging)
    pub log_beta: f32,  // retention gate output, <= 0
    pub acc_attn: f32,  // sum of attention received (H2O signal)
    pub ema_attn: f32,  // exponentially-averaged attention (SnapKV signal)
    pub last_attn: f32, // attention received on the latest step
}

/// Host mirror of an evicted token (retrieval baseline re-admission pool;
/// also part of a session snapshot so retrieval state survives a swap).
#[derive(Debug, Clone, PartialEq)]
pub struct MirrorEntry {
    pub entry: SlotEntry,
    pub key: Vec<f32>,
    pub val: Vec<f32>,
}

/// One (layer, head) slot table for one lane.
#[derive(Debug, Clone, PartialEq)]
pub struct HeadState {
    pub entries: Vec<SlotEntry>,
    pub live: Vec<bool>,
    pub used: usize,
    /// key-vector mirror, `slots * dh` (empty unless the policy needs keys)
    pub keys: Vec<f32>,
    /// value-vector mirror (retrieval baseline only)
    pub vals: Vec<f32>,
    pub dh: usize,
    /// smallest non-live slot index in `0..slots-1` (== slots-1 when full);
    /// maintained on insert/evict/clear so `free_slot` is O(1)
    free_hint: usize,
}

impl HeadState {
    pub fn new(slots: usize, dh: usize, mirror_keys: bool) -> HeadState {
        Self::with_mirrors(slots, dh, mirror_keys, false)
    }

    pub fn with_mirrors(slots: usize, dh: usize, mirror_keys: bool,
                        mirror_values: bool) -> HeadState {
        HeadState {
            entries: vec![SlotEntry::default(); slots],
            live: vec![false; slots],
            used: 0,
            keys: if mirror_keys { vec![0.0; slots * dh] } else { Vec::new() },
            vals: if mirror_values { vec![0.0; slots * dh] } else { Vec::new() },
            dh,
            free_hint: 0,
        }
    }

    pub fn slots(&self) -> usize {
        self.live.len()
    }

    /// First free slot, skipping the reserved trash slot (last index).
    /// O(1): `free_hint` always points at the smallest free slot.
    pub fn free_slot(&self) -> Option<usize> {
        debug_assert!(self.free_hint >= self.slots() - 1
                      || !self.live[self.free_hint]);
        (self.free_hint < self.slots() - 1).then_some(self.free_hint)
    }

    pub fn insert(&mut self, slot: usize, entry: SlotEntry, key: Option<&[f32]>) {
        self.insert_kv(slot, entry, key, None)
    }

    pub fn insert_kv(&mut self, slot: usize, entry: SlotEntry,
                     key: Option<&[f32]>, val: Option<&[f32]>) {
        debug_assert!(slot < self.slots() - 1, "insert into trash slot");
        if !self.live[slot] {
            self.used += 1;
            self.live[slot] = true;
            if slot == self.free_hint {
                // advance to the next free slot (amortized O(1): each slot
                // is walked over at most once per occupancy cycle)
                while self.free_hint < self.slots() - 1
                    && self.live[self.free_hint]
                {
                    self.free_hint += 1;
                }
            }
        }
        self.entries[slot] = entry;
        if let (Some(k), false) = (key, self.keys.is_empty()) {
            self.keys[slot * self.dh..(slot + 1) * self.dh].copy_from_slice(k);
        }
        if let (Some(v), false) = (val, self.vals.is_empty()) {
            self.vals[slot * self.dh..(slot + 1) * self.dh].copy_from_slice(v);
        }
    }

    pub fn val(&self, slot: usize) -> &[f32] {
        &self.vals[slot * self.dh..(slot + 1) * self.dh]
    }

    pub fn evict(&mut self, slot: usize) {
        debug_assert!(self.live[slot], "evicting a dead slot");
        self.live[slot] = false;
        self.used -= 1;
        if slot < self.free_hint {
            self.free_hint = slot;
        }
    }

    pub fn clear(&mut self) {
        self.live.iter_mut().for_each(|b| *b = false);
        self.used = 0;
        self.free_hint = 0;
    }

    pub fn live_slots(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.slots()).filter(|&s| self.live[s])
    }

    pub fn key(&self, slot: usize) -> &[f32] {
        &self.keys[slot * self.dh..(slot + 1) * self.dh]
    }

    /// TRIM-KV decayed retention score in log domain:
    /// log(beta_i^(now - i)) = (now - i) * log_beta_i  (paper §4.3).
    pub fn retention_score(&self, slot: usize, now: i64) -> f32 {
        let e = &self.entries[slot];
        ((now - e.pos) as f32) * e.log_beta
    }

    /// Fold this step's attention row into the running statistics.
    /// Hot path (per head per decode step): walks the live bitvec directly,
    /// no temporary slot list.
    pub fn update_attention(&mut self, attn_row: &[f32], ema: f32) {
        for (s, &is_live) in self.live.iter().enumerate() {
            if !is_live {
                continue;
            }
            let a = attn_row[s];
            let e = &mut self.entries[s];
            e.acc_attn += a;
            e.ema_attn = ema * e.ema_attn + (1.0 - ema) * a;
            e.last_attn = a;
        }
    }

    #[cfg(debug_assertions)]
    pub fn check_invariants(&self) {
        assert_eq!(self.used, self.live.iter().filter(|&&b| b).count());
        assert!(!self.live[self.slots() - 1], "trash slot went live");
        assert!(self.free_hint >= self.slots() - 1 || !self.live[self.free_hint],
                "free_hint points at a live slot");
        assert!((0..self.free_hint.min(self.slots() - 1))
                    .all(|s| self.live[s]),
                "free slot below free_hint");
    }
    #[cfg(not(debug_assertions))]
    pub fn check_invariants(&self) {}
}

/// All (layer, head) tables for one batch lane.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneCache {
    pub heads: Vec<HeadState>, // layers * hkv, row-major (l, h)
    pub layers: usize,
    pub hkv: usize,
}

impl LaneCache {
    pub fn new(dims: &ModelDims, slots: usize, mirror_keys: bool) -> LaneCache {
        Self::with_mirrors(dims, slots, mirror_keys, false)
    }

    pub fn with_mirrors(dims: &ModelDims, slots: usize, mirror_keys: bool,
                        mirror_values: bool) -> LaneCache {
        let n = dims.layers * dims.hkv;
        LaneCache {
            heads: (0..n)
                .map(|_| HeadState::with_mirrors(slots, dims.dh, mirror_keys,
                                                 mirror_values))
                .collect(),
            layers: dims.layers,
            hkv: dims.hkv,
        }
    }

    pub fn head(&self, l: usize, h: usize) -> &HeadState {
        &self.heads[l * self.hkv + h]
    }
    pub fn head_mut(&mut self, l: usize, h: usize) -> &mut HeadState {
        &mut self.heads[l * self.hkv + h]
    }

    pub fn clear(&mut self) {
        self.heads.iter_mut().for_each(HeadState::clear);
    }

    /// Total live tokens across heads (diagnostics).
    pub fn total_live(&self) -> usize {
        self.heads.iter().map(|h| h.used).sum()
    }

    /// Write this lane's validity bits into the flat `[L, B, H, M]` buffer
    /// the decode graph consumes.
    pub fn fill_valid(&self, lane: usize, batch: usize, valid: &mut [f32]) {
        let m = self.heads[0].slots();
        for l in 0..self.layers {
            for h in 0..self.hkv {
                let head = self.head(l, h);
                let base = ((l * batch + lane) * self.hkv + h) * m;
                for s in 0..m {
                    valid[base + s] = if head.live[s] { 1.0 } else { 0.0 };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelDims;

    fn dims() -> ModelDims {
        ModelDims { vocab: 512, d: 128, layers: 2, hq: 4, hkv: 2, dh: 4,
                    ffn: 256, gate_hidden: 48 }
    }

    #[test]
    fn insert_evict_lifecycle() {
        let mut h = HeadState::new(8, 4, true);
        assert_eq!(h.free_slot(), Some(0));
        h.insert(0, SlotEntry { pos: 0, token: 5, log_beta: -0.1, ..Default::default() },
                 Some(&[1., 2., 3., 4.]));
        h.insert(1, SlotEntry { pos: 1, token: 6, log_beta: -0.2, ..Default::default() },
                 Some(&[5., 6., 7., 8.]));
        assert_eq!(h.used, 2);
        assert_eq!(h.free_slot(), Some(2));
        assert_eq!(h.key(1), &[5., 6., 7., 8.]);
        h.evict(0);
        assert_eq!(h.used, 1);
        assert_eq!(h.free_slot(), Some(0));
        h.check_invariants();
    }

    #[test]
    fn trash_slot_is_never_offered() {
        let h = HeadState::new(4, 4, false);
        // fill 0..2; slot 3 (trash) must never be returned
        let mut h2 = h.clone();
        for s in 0..3 {
            h2.insert(s, SlotEntry::default(), None);
        }
        assert_eq!(h2.free_slot(), None);
    }

    #[test]
    fn retention_score_decays_with_age() {
        let mut h = HeadState::new(4, 4, false);
        h.insert(0, SlotEntry { pos: 0, log_beta: -0.5, ..Default::default() }, None);
        h.insert(1, SlotEntry { pos: 8, log_beta: -0.5, ..Default::default() }, None);
        // same beta, older token scores lower
        assert!(h.retention_score(0, 10) < h.retention_score(1, 10));
        // higher beta wins at equal age
        h.insert(2, SlotEntry { pos: 8, log_beta: -0.01, ..Default::default() }, None);
        assert!(h.retention_score(2, 10) > h.retention_score(1, 10));
    }

    #[test]
    fn attention_stats_update_only_live() {
        let mut h = HeadState::new(4, 4, false);
        h.insert(0, SlotEntry::default(), None);
        h.insert(2, SlotEntry::default(), None);
        h.update_attention(&[0.5, 9.0, 0.25, 9.0], 0.9);
        assert_eq!(h.entries[0].acc_attn, 0.5);
        assert_eq!(h.entries[1].acc_attn, 0.0); // dead slot untouched
        assert_eq!(h.entries[2].last_attn, 0.25);
        assert!((h.entries[2].ema_attn - 0.025).abs() < 1e-6);
    }

    #[test]
    fn free_hint_tracks_lowest_free_slot() {
        let mut h = HeadState::new(6, 4, false);
        for s in 0..5 {
            assert_eq!(h.free_slot(), Some(s));
            h.insert(s, SlotEntry::default(), None);
            h.check_invariants();
        }
        assert_eq!(h.free_slot(), None);
        // out-of-order evictions: hint must fall back to the smallest hole
        h.evict(3);
        assert_eq!(h.free_slot(), Some(3));
        h.evict(1);
        assert_eq!(h.free_slot(), Some(1));
        h.insert(1, SlotEntry::default(), None);
        assert_eq!(h.free_slot(), Some(3));
        h.check_invariants();
        h.clear();
        assert_eq!(h.free_slot(), Some(0));
        h.check_invariants();
    }

    #[test]
    fn lane_valid_mask_layout() {
        let d = dims();
        let mut lane = LaneCache::new(&d, 4, false);
        lane.head_mut(1, 0).insert(2, SlotEntry::default(), None);
        let batch = 3;
        let mut valid = vec![0.0; d.layers * batch * d.hkv * 4];
        lane.fill_valid(1, batch, &mut valid);
        // index (l=1, lane=1, h=0, s=2)
        let idx = ((1 * batch + 1) * d.hkv + 0) * 4 + 2;
        assert_eq!(valid[idx], 1.0);
        assert_eq!(valid.iter().filter(|&&x| x > 0.0).count(), 1);
        assert_eq!(lane.total_live(), 1);
    }
}
