//! Shared-prefix KV store: serve a fleet's common prompt once.
//!
//! Production traffic is dominated by shared system prompts and few-shot
//! preambles, yet a plain engine re-prefills them from token zero on every
//! admission.  TRIM-KV makes prefix sharing *sound by construction*: the
//! paper's retention scores are assigned at creation time and are
//! query-agnostic, so a prefix's K/V slab **and** its frozen
//! retention-score/slot state are a pure function of the prefix tokens (plus
//! the engine configuration and chunking schedule) — they can be computed
//! once and reused verbatim by every later request that starts with the same
//! tokens.  Attention-proxy schemes whose importance depends on the query
//! cannot do this at all.
//!
//! The store is copy-on-write: a published prefix is an immutable
//! [`PrefixPayload`] behind an `Arc`.  A hitting lane uploads the shared
//! device slab through the ordinary batched `swap_lanes` path and *clones*
//! the host-side slot tables, so its private copy diverges freely while the
//! shared original stays frozen.  The `Arc` doubles as the ref-count: LRU
//! eviction under the `[prefix] max_bytes` budget only considers entries no
//! live lane still references (`strong_count == 1`), so churn can never free
//! state a seated lane depends on — at worst the store temporarily overshoots
//! its budget while every entry is pinned.
//!
//! Matching is longest-cached-prefix over hashed token chunks at a fixed
//! granularity (`[prefix] chunk_tokens`, default 64): the index keys on an
//! FNV-1a hash of (engine fingerprint, first `k * chunk_tokens` tokens) and
//! probes from the deepest eligible boundary down, verifying the stored
//! tokens on a candidate hit so a hash collision degrades to a miss, never a
//! wrong cache.  The match is capped one token short of the prompt so a
//! seeded lane always has a non-empty tail to prefill (the engine needs at
//! least one genuine step to produce first-token logits).
//!
//! One store is shared by every replica of an `EngineGroup` behind a single
//! mutex with short critical sections — a lookup is a hash walk plus an
//! `Arc` clone, so N replicas amortize the same system prompt without
//! copying it N times.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::kvcache::{LaneCache, MirrorEntry, SlotEntry};
use crate::obs::Sample;
use crate::runtime::LaneKv;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn fnv_token(h: u64, token: u32) -> u64 {
    fnv_bytes(h, &token.to_le_bytes())
}

/// Everything that shapes a lane's retention state besides the prefix tokens
/// themselves.  Two engines produce bit-identical prefix state only when all
/// of this matches: the policy and budget drive eviction, `chunked_prefill`
/// selects the per-chunk vs per-token eviction law, the backend chunk width
/// fixes the canonical chunking schedule, and the geometry fixes slab
/// layout.  The fingerprint is folded into every index key, so a mismatched
/// engine simply misses — it can never be served foreign state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefixFingerprint {
    pub policy: String,
    pub budget: usize,
    pub chunked_prefill: bool,
    pub backend_chunk: usize,
    pub slots: usize,
    pub layers: usize,
    pub hkv: usize,
    pub dh: usize,
}

impl PrefixFingerprint {
    /// Hash seed folding every fingerprint field; token hashes extend it.
    fn seed(&self) -> u64 {
        let mut h = fnv_bytes(FNV_OFFSET, self.policy.as_bytes());
        for v in [
            self.budget as u64,
            self.chunked_prefill as u64,
            self.backend_chunk as u64,
            self.slots as u64,
            self.layers as u64,
            self.hkv as u64,
            self.dh as u64,
        ] {
            h = fnv_bytes(h, &v.to_le_bytes());
        }
        h
    }
}

/// The immutable shared state of one published prefix: the device K/V slab
/// plus the frozen host-side retention state a lane needs to continue as if
/// it had prefilled the prefix itself.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixPayload {
    /// The prefix tokens (collision guard + exact-match verification).
    pub tokens: Vec<u32>,
    /// Device K/V slabs at the publish boundary, each flat `[L, H, M, dh]`.
    pub kv: LaneKv,
    /// Per-(layer, head) slot tables with frozen retention scores.
    pub cache: LaneCache,
    /// Retrieval-policy re-admission pool, per (layer * head).
    pub mirror: Vec<Vec<MirrorEntry>>,
    /// Injection plans pending at the boundary, per (layer * head).  Only
    /// non-empty under token-by-token prefill with the retrieval policy,
    /// where a re-admission can be scheduled mid-prompt.
    pub inject: Vec<Option<(usize, MirrorEntry)>>,
    /// The publishing engine's configuration fingerprint.
    pub fp: PrefixFingerprint,
}

impl PrefixPayload {
    /// Prefix length in tokens (== the `fed` a seeded lane resumes at).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Approximate host bytes held (budget accounting), mirroring
    /// `SessionSnapshot::host_bytes`.
    pub fn host_bytes(&self) -> usize {
        let tables: usize = self
            .cache
            .heads
            .iter()
            .map(|h| {
                h.entries.len() * std::mem::size_of::<SlotEntry>()
                    + h.live.len()
                    + (h.keys.len() + h.vals.len()) * 4
            })
            .sum();
        let mirror: usize = self
            .mirror
            .iter()
            .flat_map(|m| m.iter())
            .map(|e| (e.key.len() + e.val.len()) * 4 + 32)
            .sum();
        self.kv.host_bytes() + tables + mirror + self.tokens.len() * 4
    }
}

/// One index entry: the shared payload plus LRU/byte bookkeeping.
struct PrefixEntry {
    payload: Arc<PrefixPayload>,
    bytes: usize,
    last_used: u64,
}

/// Monotonic counters and gauges, readable without parsing exposition text.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCounters {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub prefill_tokens_saved: u64,
    pub bytes: usize,
    pub entries: usize,
}

struct Inner {
    map: BTreeMap<u64, PrefixEntry>,
    clock: u64,
    bytes: usize,
    hits: u64,
    misses: u64,
    inserts: u64,
    evictions: u64,
    tokens_saved: u64,
}

/// The longest-cached-prefix index.  Shared across engines/replicas as an
/// `Arc<PrefixStore>`; every method takes `&self`.
pub struct PrefixStore {
    chunk: usize,
    max_bytes: usize,
    inner: Mutex<Inner>,
}

impl PrefixStore {
    pub fn new(max_bytes: usize, chunk_tokens: usize) -> PrefixStore {
        PrefixStore {
            chunk: chunk_tokens.max(1),
            max_bytes,
            inner: Mutex::new(Inner {
                map: BTreeMap::new(),
                clock: 0,
                bytes: 0,
                hits: 0,
                misses: 0,
                inserts: 0,
                evictions: 0,
                tokens_saved: 0,
            }),
        }
    }

    /// Prefix granularity in tokens: entries exist only at multiples of it.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    /// Index guard; recovers a poisoned mutex.  The store is shared by
    /// every replica of a group, so one panicking engine thread must not
    /// wedge prefix reuse for the rest of the fleet — the map/byte
    /// bookkeeping is consistent at every statement boundary.
    fn locked(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Longest cached prefix of `prompt`, capped one token short of the full
    /// prompt so the seeded lane keeps a non-empty tail.  Counts a hit (plus
    /// the prefill tokens it saves) or — for prompts long enough to have an
    /// eligible boundary at all — a miss.
    pub fn lookup(&self, fp: &PrefixFingerprint, prompt: &[u32])
        -> Option<Arc<PrefixPayload>> {
        let kmax = prompt.len().saturating_sub(1) / self.chunk;
        if kmax == 0 {
            return None; // too short to share: not an eligible lookup
        }
        // one forward hash pass, remembering the key at every boundary
        let mut keys = Vec::with_capacity(kmax);
        let mut h = fp.seed();
        for (i, &tok) in prompt.iter().take(kmax * self.chunk).enumerate() {
            h = fnv_token(h, tok);
            if (i + 1) % self.chunk == 0 {
                keys.push(h);
            }
        }
        let mut g = self.locked();
        for (k, key) in keys.iter().enumerate().rev() {
            let len = (k + 1) * self.chunk;
            let Some(entry) = g.map.get(key) else { continue };
            // collision / fingerprint guard: degrade to a miss, never serve
            // foreign state
            if entry.payload.fp != *fp || entry.payload.tokens != prompt[..len] {
                continue;
            }
            let payload = entry.payload.clone();
            g.clock += 1;
            let stamp = g.clock;
            if let Some(e) = g.map.get_mut(key) {
                e.last_used = stamp;
            }
            g.hits += 1;
            g.tokens_saved += len as u64;
            return Some(payload);
        }
        g.misses += 1;
        None
    }

    /// Whether an exact entry for `tokens` exists (publish-side dedup: a
    /// cheap check before paying the device slab download).  Counts nothing.
    pub fn has(&self, fp: &PrefixFingerprint, tokens: &[u32]) -> bool {
        let mut h = fp.seed();
        for &tok in tokens {
            h = fnv_token(h, tok);
        }
        let g = self.locked();
        g.map
            .get(&h)
            .is_some_and(|e| e.payload.fp == *fp && e.payload.tokens == tokens)
    }

    /// Publish a completed prefix.  Ignores payloads that are not at the
    /// store granularity or already present; then LRU-evicts unreferenced
    /// entries until the byte budget holds (or everything left is pinned).
    pub fn insert(&self, payload: PrefixPayload) {
        let len = payload.len();
        if len == 0 || len % self.chunk != 0 {
            return;
        }
        let mut h = payload.fp.seed();
        for &tok in &payload.tokens {
            h = fnv_token(h, tok);
        }
        let bytes = payload.host_bytes();
        let mut g = self.locked();
        if g.map.contains_key(&h) {
            return; // racing publisher won; keep the established entry
        }
        g.clock += 1;
        let stamp = g.clock;
        g.bytes += bytes;
        g.inserts += 1;
        g.map.insert(h, PrefixEntry {
            payload: Arc::new(payload),
            bytes,
            last_used: stamp,
        });
        while g.bytes > self.max_bytes {
            let victim = g
                .map
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.payload) == 1)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(key) = victim else { break }; // all pinned: overshoot
            let Some(gone) = g.map.remove(&key) else { break };
            g.bytes -= gone.bytes;
            g.evictions += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.locked().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.locked().bytes
    }

    pub fn counters(&self) -> PrefixCounters {
        let g = self.locked();
        PrefixCounters {
            hits: g.hits,
            misses: g.misses,
            inserts: g.inserts,
            evictions: g.evictions,
            prefill_tokens_saved: g.tokens_saved,
            bytes: g.bytes,
            entries: g.map.len(),
        }
    }

    /// Exposition samples (`trimkv_prefix_*_total` plus an entry-count
    /// gauge).  Rendered once per store: by the owning engine when private,
    /// by the `EngineGroup` when shared across replicas.
    pub fn samples(&self) -> Vec<Sample> {
        let c = self.counters();
        vec![
            Sample::counter("trimkv_prefix_hits_total", c.hits as f64),
            Sample::counter("trimkv_prefix_misses_total", c.misses as f64),
            Sample::counter("trimkv_prefix_inserts_total", c.inserts as f64),
            Sample::counter("trimkv_prefix_evictions_total", c.evictions as f64),
            Sample::counter("trimkv_prefix_prefill_tokens_saved_total",
                            c.prefill_tokens_saved as f64),
            Sample::gauge("trimkv_prefix_bytes_total", c.bytes as f64),
            Sample::gauge("trimkv_prefix_entries", c.entries as f64),
        ]
    }
}

impl std::fmt::Debug for PrefixStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let c = self.counters();
        f.debug_struct("PrefixStore")
            .field("chunk", &self.chunk)
            .field("max_bytes", &self.max_bytes)
            .field("counters", &c)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelDims;

    fn dims() -> ModelDims {
        ModelDims { vocab: 512, d: 128, layers: 2, hq: 4, hkv: 2, dh: 4,
                    ffn: 256, gate_hidden: 48 }
    }

    fn fp() -> PrefixFingerprint {
        PrefixFingerprint {
            policy: "trimkv".into(),
            budget: 16,
            chunked_prefill: true,
            backend_chunk: 16,
            slots: 20,
            layers: 2,
            hkv: 2,
            dh: 4,
        }
    }

    fn payload(tokens: Vec<u32>) -> PrefixPayload {
        let d = dims();
        PrefixPayload {
            tokens,
            kv: LaneKv { k: vec![0.5; 2 * 2 * 20 * 4],
                         v: vec![0.25; 2 * 2 * 20 * 4] },
            cache: LaneCache::new(&d, 20, false),
            mirror: vec![Vec::new(); 4],
            inject: vec![None; 4],
            fp: fp(),
        }
    }

    fn toks(tag: u32, n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| 10 + tag * 100 + i % 90).collect()
    }

    #[test]
    fn longest_cached_prefix_wins() {
        let store = PrefixStore::new(usize::MAX, 4);
        let base = toks(1, 12);
        store.insert(payload(base[..4].to_vec()));
        store.insert(payload(base[..8].to_vec()));
        // prompt long enough to probe k=2 first: deepest boundary matches
        let mut prompt = base.clone();
        prompt.push(7);
        let hit = store.lookup(&fp(), &prompt).expect("hit");
        assert_eq!(hit.len(), 8);
        // shorter prompt can only use the 4-token entry
        let hit = store.lookup(&fp(), &base[..7]).expect("hit");
        assert_eq!(hit.len(), 4);
        assert_eq!(store.counters().hits, 2);
        assert_eq!(store.counters().prefill_tokens_saved, 12);
    }

    #[test]
    fn match_is_capped_one_token_short_of_the_prompt() {
        let store = PrefixStore::new(usize::MAX, 4);
        let base = toks(2, 8);
        store.insert(payload(base.clone()));
        // the full prompt equals the stored entry: a full-length match would
        // leave an empty tail, so only the 4-token boundary is probed -- and
        // no 4-token entry exists
        assert!(store.lookup(&fp(), &base).is_none());
        assert_eq!(store.counters().misses, 1);
        // one token longer and the 8-token entry is usable
        let mut longer = base.clone();
        longer.push(9);
        assert_eq!(store.lookup(&fp(), &longer).expect("hit").len(), 8);
    }

    #[test]
    fn short_prompts_are_not_eligible_lookups() {
        let store = PrefixStore::new(usize::MAX, 4);
        assert!(store.lookup(&fp(), &toks(3, 4)).is_none());
        assert_eq!(store.counters().misses, 0); // no boundary to probe
        assert!(store.lookup(&fp(), &toks(3, 5)).is_none());
        assert_eq!(store.counters().misses, 1); // eligible, empty store
    }

    #[test]
    fn fingerprint_mismatch_misses_safely() {
        let store = PrefixStore::new(usize::MAX, 4);
        let base = toks(4, 9);
        store.insert(payload(base[..4].to_vec()));
        let mut other = fp();
        other.budget = 8;
        assert!(store.lookup(&other, &base).is_none());
        assert!(store.lookup(&fp(), &base).is_some());
    }

    #[test]
    fn token_mismatch_misses_even_if_hash_would_collide() {
        let store = PrefixStore::new(usize::MAX, 4);
        store.insert(payload(toks(5, 4)));
        // different tokens, same length: must verify and miss
        assert!(store.lookup(&fp(), &toks(6, 9)).is_none());
    }

    #[test]
    fn off_granularity_inserts_are_rejected() {
        let store = PrefixStore::new(usize::MAX, 4);
        store.insert(payload(toks(7, 6)));
        store.insert(payload(Vec::new()));
        assert!(store.is_empty());
        assert_eq!(store.counters().inserts, 0);
    }

    #[test]
    fn lru_eviction_respects_budget_and_order() {
        let one = payload(toks(8, 4)).host_bytes();
        let store = PrefixStore::new(2 * one, 4);
        store.insert(payload(toks(8, 4)));
        store.insert(payload(toks(9, 4)));
        // touch the first so the second becomes LRU
        assert!(store.lookup(&fp(), &toks(8, 5)).is_some());
        store.insert(payload(toks(10, 4)));
        assert_eq!(store.len(), 2);
        assert_eq!(store.counters().evictions, 1);
        assert!(store.lookup(&fp(), &toks(8, 5)).is_some());
        assert!(store.lookup(&fp(), &toks(9, 5)).is_none());
        assert!(store.lookup(&fp(), &toks(10, 5)).is_some());
        assert!(store.bytes() <= 2 * one);
    }

    #[test]
    fn refcounted_eviction_never_frees_a_live_entry() {
        let one = payload(toks(11, 4)).host_bytes();
        let store = PrefixStore::new(one, 4); // room for exactly one entry
        store.insert(payload(toks(11, 4)));
        let pinned = store.lookup(&fp(), &toks(11, 5)).expect("hit");
        // a live lane holds `pinned`: inserting more must evict around it,
        // overshooting the budget rather than freeing referenced state
        store.insert(payload(toks(12, 4)));
        store.insert(payload(toks(13, 4)));
        assert!(store.lookup(&fp(), &toks(11, 5)).is_some(),
                "pinned entry survived churn");
        assert!(store.bytes() >= one);
        // dropping the pin makes it evictable again
        drop(pinned);
        drop(store.lookup(&fp(), &toks(12, 5)));
        drop(store.lookup(&fp(), &toks(13, 5)));
        store.insert(payload(toks(14, 4)));
        assert_eq!(store.len(), 1);
        assert!(store.bytes() <= one);
    }

    #[test]
    fn duplicate_insert_keeps_established_entry() {
        let store = PrefixStore::new(usize::MAX, 4);
        store.insert(payload(toks(15, 4)));
        store.insert(payload(toks(15, 4)));
        assert_eq!(store.len(), 1);
        assert_eq!(store.counters().inserts, 1);
    }

    #[test]
    fn samples_render_and_parse() {
        let store = PrefixStore::new(usize::MAX, 4);
        store.insert(payload(toks(16, 4)));
        store.lookup(&fp(), &toks(16, 5));
        store.lookup(&fp(), &toks(17, 9));
        let text = crate::obs::render_prometheus(&store.samples());
        crate::obs::assert_prometheus_parses(&text);
        for name in ["trimkv_prefix_hits_total 1",
                     "trimkv_prefix_misses_total 1",
                     "trimkv_prefix_inserts_total 1",
                     "trimkv_prefix_evictions_total 0",
                     "trimkv_prefix_prefill_tokens_saved_total 4"] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        assert!(text.contains("trimkv_prefix_bytes_total"));
    }
}
