//! Serving front-ends.
//!
//! `InProcServer` runs the engine on a dedicated thread behind mpsc
//! channels (the in-process API used by examples and the eval harness when
//! overlap matters).  `tcp` exposes a line-delimited JSON protocol over a
//! std TcpListener — one request per line:
//!   {"id": 1, "prompt": [1, 40, 41], "max_new_tokens": 16}
//! responses stream back as
//!   {"id": 1, "tokens": [...], "finish": "eos", "ttft_us": ..., "e2e_us": ...}

pub mod tcp;

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use crate::engine::Engine;
use crate::runtime::ModelBackend;
use crate::scheduler::{Request, Response};

enum Msg {
    Req(Request),
    CloseSession(String),
    /// reply with the engine's Prometheus-style metrics text
    Stats(Sender<String>),
    /// reply with the flight recorder's Chrome-trace JSON
    Trace(Sender<String>),
    Shutdown,
}

/// Engine on its own thread; submit requests and poll responses from any
/// other thread.
pub struct InProcServer {
    tx: Sender<Msg>,
    rx: Receiver<Response>,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
}

impl InProcServer {
    pub fn spawn<B: ModelBackend + 'static>(mut engine: Engine<B>) -> InProcServer {
        let (tx, req_rx) = channel::<Msg>();
        let (resp_tx, rx) = channel::<Response>();
        let handle = std::thread::spawn(move || -> anyhow::Result<()> {
            let mut shutdown = false;
            loop {
                // drain incoming requests without blocking the decode loop
                loop {
                    match req_rx.try_recv() {
                        Ok(Msg::Req(r)) => {
                            if let Err(e) = engine.submit(r) {
                                log_admit_error(&e);
                            }
                        }
                        Ok(Msg::CloseSession(id)) => engine.close_session(&id),
                        Ok(Msg::Stats(reply)) => {
                            let _ = reply.send(engine.prometheus_text());
                        }
                        Ok(Msg::Trace(reply)) => {
                            let _ = reply.send(engine.chrome_trace_json());
                        }
                        Ok(Msg::Shutdown) => shutdown = true,
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                    }
                }
                let worked = engine.tick()?;
                for resp in engine.take_responses() {
                    let _ = resp_tx.send(resp);
                }
                if shutdown && engine.idle() {
                    return Ok(());
                }
                if !worked && !shutdown {
                    // idle: block until the next request arrives (parked
                    // sessions wait here without burning a core)
                    match req_rx.recv() {
                        Ok(Msg::Req(r)) => {
                            if let Err(e) = engine.submit(r) {
                                log_admit_error(&e);
                            }
                        }
                        Ok(Msg::CloseSession(id)) => engine.close_session(&id),
                        Ok(Msg::Stats(reply)) => {
                            let _ = reply.send(engine.prometheus_text());
                        }
                        Ok(Msg::Trace(reply)) => {
                            let _ = reply.send(engine.chrome_trace_json());
                        }
                        Ok(Msg::Shutdown) => shutdown = true,
                        Err(_) => return Ok(()),
                    }
                }
            }
        });
        InProcServer { tx, rx, handle: Some(handle) }
    }

    pub fn submit(&self, req: Request) {
        let _ = self.tx.send(Msg::Req(req));
    }

    /// Drop a conversation's retained state (host snapshot + parked lane).
    pub fn close_session(&self, id: impl Into<String>) {
        let _ = self.tx.send(Msg::CloseSession(id.into()));
    }

    pub fn try_recv(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }

    /// Live metrics scrape: the engine's Prometheus-style text, rendered on
    /// the engine thread at the next loop turn.  None if the engine thread
    /// is gone.
    pub fn metrics_snapshot(&self) -> Option<String> {
        let (reply_tx, reply_rx) = channel();
        self.tx.send(Msg::Stats(reply_tx)).ok()?;
        reply_rx.recv().ok()
    }

    /// Live flight-recorder snapshot as Chrome-trace JSON.
    pub fn trace_snapshot(&self) -> Option<String> {
        let (reply_tx, reply_rx) = channel();
        self.tx.send(Msg::Trace(reply_tx)).ok()?;
        reply_rx.recv().ok()
    }

    pub fn recv_blocking(&self) -> Option<Response> {
        self.rx.recv().ok()
    }

    /// Finish outstanding work and join the engine thread.
    pub fn shutdown(mut self) -> Vec<Response> {
        let _ = self.tx.send(Msg::Shutdown);
        let mut out = Vec::new();
        while let Ok(r) = self.rx.recv() {
            out.push(r);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        out
    }
}

fn log_admit_error(e: &crate::scheduler::AdmitError) {
    eprintln!("[server] request rejected: {e}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::runtime::MockBackend;

    #[test]
    fn inproc_server_round_trip() {
        let cfg = EngineConfig {
            budget: 16,
            batch: 2,
            chunked_prefill: false,
            ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(2, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        for i in 0..4 {
            srv.submit(Request::new(i, vec![1, 30 + i as u32], 3));
        }
        let responses = srv.shutdown();
        assert_eq!(responses.len(), 4);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn inproc_server_serves_metrics_and_trace_snapshots() {
        let cfg = EngineConfig {
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        srv.submit(Request::new(1, vec![1, 40], 3));
        assert!(srv.recv_blocking().is_some());
        let text = srv.metrics_snapshot().unwrap();
        crate::obs::assert_prometheus_parses(&text);
        assert!(text.contains("trimkv_tokens_decoded_total 3\n"));
        let trace = srv.trace_snapshot().unwrap();
        let doc = crate::util::json::Json::parse(&trace).unwrap();
        assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        srv.shutdown();
    }

    #[test]
    fn inproc_server_session_turns_in_order() {
        let cfg = EngineConfig {
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        srv.submit(Request::new(1, vec![1, 50], 2).with_session("s"));
        srv.submit(Request::new(2, vec![60], 2).with_session("s"));
        srv.close_session("s");
        let responses = srv.shutdown();
        assert_eq!(responses.len(), 2);
        // turn order is preserved within a session, cache carries across
        assert_eq!(responses[0].id, 1);
        assert_eq!(responses[0].tokens, vec![51, 52]);
        assert_eq!(responses[1].id, 2);
        assert_eq!(responses[1].tokens, vec![61, 62]);
    }
}
