//! Serving front-ends.
//!
//! `InProcServer` runs one engine on a dedicated thread behind mpsc
//! channels (the in-process API used by examples and the eval harness when
//! overlap matters).  The same worker loop, spawned with a shared response
//! sink instead of a private channel, is the replica body of
//! [`crate::router::EngineGroup`] — the router drives N of these through
//! the identical `Msg` shape, plus the migration handshake
//! (`TakeSession`/`PutSession`) layered on the engine's
//! `export_session`/`import_session` hooks.
//!
//! `tcp` exposes a line-delimited JSON protocol over a std TcpListener —
//! one request per line:
//!   {"id": 1, "prompt": [1, 40, 41], "max_new_tokens": 16}
//! responses stream back as
//!   {"id": 1, "tokens": [...], "finish": "eos", "ttft_us": ..., "e2e_us": ...}
//! A line of `{"stats": true}` replies `{"metrics": "<prometheus text>"}`
//! (the exposition as one JSON string — the same body `GET /metrics`
//! serves raw), and `{"session": "<id>", "close": true}` drops a
//! conversation's retained state.

pub mod tcp;

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use crate::engine::Engine;
use crate::runtime::ModelBackend;
use crate::scheduler::{Request, Response};
use crate::session::SessionSnapshot;

/// One engine worker's mailbox.  `pub(crate)` so the router can drive
/// replica workers through the same shape the in-process server uses.
pub(crate) enum Msg {
    Req(Request),
    CloseSession(String),
    /// reply with the engine's Prometheus-style metrics text
    Stats(Sender<String>),
    /// reply with the flight recorder's Chrome-trace JSON
    Trace(Sender<String>),
    /// drain the in-flight step and force every parked lane to the host
    /// store, then ack (checkpoint / drain barrier)
    Flush(Sender<()>),
    /// migration source half: drain the session's lane and hand its
    /// snapshot out of the store.  Err(reason) when the session still has
    /// turns in flight (the engine refuses, the worker survives).
    TakeSession(String, Sender<Result<Option<Box<SessionSnapshot>>, String>>),
    /// migration target half: rebind a snapshot into the host store; ack
    /// so the caller can order the session's next turn after the rebind
    PutSession(String, Box<SessionSnapshot>, Sender<()>),
    Shutdown,
}

/// Apply one mailbox message to the engine.  Engine errors on flush
/// propagate (they are tick-loop-fatal, like a failed backend step);
/// per-session migration refusals travel back to the caller instead.
fn handle_msg<B: ModelBackend>(
    engine: &mut Engine<B>,
    msg: Msg,
    shutdown: &mut bool,
) -> anyhow::Result<()> {
    match msg {
        Msg::Req(r) => {
            if let Err(e) = engine.submit(r) {
                log_admit_error(&e);
            }
        }
        Msg::CloseSession(id) => engine.close_session(&id),
        Msg::Stats(reply) => {
            let _ = reply.send(engine.prometheus_text());
        }
        Msg::Trace(reply) => {
            let _ = reply.send(engine.chrome_trace_json());
        }
        Msg::Flush(reply) => {
            engine.flush_sessions()?;
            let _ = reply.send(());
        }
        Msg::TakeSession(id, reply) => {
            let out = engine
                .export_session(&id)
                .map(|snap| snap.map(Box::new))
                .map_err(|e| e.to_string());
            let _ = reply.send(out);
        }
        Msg::PutSession(id, snap, reply) => {
            engine.import_session(&id, *snap);
            let _ = reply.send(());
        }
        Msg::Shutdown => *shutdown = true,
    }
    Ok(())
}

/// Spawn the engine worker loop: drain the mailbox without blocking the
/// decode loop, tick, forward responses into `sink`, and block on the
/// mailbox when idle (parked sessions wait without burning a core).
pub(crate) fn spawn_worker<B, F>(
    mut engine: Engine<B>,
    rx: Receiver<Msg>,
    mut sink: F,
) -> JoinHandle<anyhow::Result<()>>
where
    B: ModelBackend + 'static,
    F: FnMut(Response) + Send + 'static,
{
    std::thread::spawn(move || -> anyhow::Result<()> {
        let mut shutdown = false;
        loop {
            loop {
                match rx.try_recv() {
                    Ok(msg) => handle_msg(&mut engine, msg, &mut shutdown)?,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
            let worked = engine.tick()?;
            for resp in engine.take_responses() {
                sink(resp);
            }
            if shutdown && engine.idle() {
                return Ok(());
            }
            if !worked && !shutdown {
                match rx.recv() {
                    Ok(msg) => handle_msg(&mut engine, msg, &mut shutdown)?,
                    Err(_) => return Ok(()),
                }
            }
        }
    })
}

/// What the TCP front door needs from whatever sits behind it — one
/// engine ([`InProcServer`]) or a routed fleet
/// ([`crate::router::EngineGroup`]).  `serve_connection`/`listen` are
/// generic over this, so the wire protocol is identical at N=1 and N=8.
pub trait Frontend {
    fn submit(&self, req: Request);
    fn close_session(&self, id: &str);
    fn try_recv(&self) -> Option<Response>;
    fn recv_blocking(&self) -> Option<Response>;
    /// Prometheus-style exposition text (the `GET /metrics` body); the
    /// group aggregates per-replica series under a `replica` label.
    fn metrics_snapshot(&self) -> Option<String>;
}

impl Frontend for InProcServer {
    fn submit(&self, req: Request) {
        InProcServer::submit(self, req)
    }
    fn close_session(&self, id: &str) {
        InProcServer::close_session(self, id)
    }
    fn try_recv(&self) -> Option<Response> {
        InProcServer::try_recv(self)
    }
    fn recv_blocking(&self) -> Option<Response> {
        InProcServer::recv_blocking(self)
    }
    fn metrics_snapshot(&self) -> Option<String> {
        InProcServer::metrics_snapshot(self)
    }
}

/// Engine on its own thread; submit requests and poll responses from any
/// other thread.
pub struct InProcServer {
    tx: Sender<Msg>,
    rx: Receiver<Response>,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
}

impl InProcServer {
    pub fn spawn<B: ModelBackend + 'static>(engine: Engine<B>) -> InProcServer {
        let (tx, req_rx) = channel::<Msg>();
        let (resp_tx, rx) = channel::<Response>();
        let handle = spawn_worker(engine, req_rx, move |r| {
            let _ = resp_tx.send(r);
        });
        InProcServer { tx, rx, handle: Some(handle) }
    }

    pub fn submit(&self, req: Request) {
        let _ = self.tx.send(Msg::Req(req));
    }

    /// Drop a conversation's retained state (host snapshot + parked lane).
    pub fn close_session(&self, id: impl Into<String>) {
        let _ = self.tx.send(Msg::CloseSession(id.into()));
    }

    /// Drain in-flight work and force every parked lane to the host store.
    /// Blocks until the engine acks; false if the engine thread is gone.
    pub fn flush_sessions(&self) -> bool {
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(Msg::Flush(reply_tx)).is_err() {
            return false;
        }
        reply_rx.recv().is_ok()
    }

    pub fn try_recv(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }

    /// Live metrics scrape: the engine's Prometheus-style text, rendered on
    /// the engine thread at the next loop turn.  None if the engine thread
    /// is gone.
    pub fn metrics_snapshot(&self) -> Option<String> {
        let (reply_tx, reply_rx) = channel();
        self.tx.send(Msg::Stats(reply_tx)).ok()?;
        reply_rx.recv().ok()
    }

    /// Live flight-recorder snapshot as Chrome-trace JSON.
    pub fn trace_snapshot(&self) -> Option<String> {
        let (reply_tx, reply_rx) = channel();
        self.tx.send(Msg::Trace(reply_tx)).ok()?;
        reply_rx.recv().ok()
    }

    pub fn recv_blocking(&self) -> Option<Response> {
        self.rx.recv().ok()
    }

    /// Finish outstanding work and join the engine thread.
    pub fn shutdown(mut self) -> Vec<Response> {
        let _ = self.tx.send(Msg::Shutdown);
        let mut out = Vec::new();
        while let Ok(r) = self.rx.recv() {
            out.push(r);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        out
    }
}

fn log_admit_error(e: &crate::scheduler::AdmitError) {
    eprintln!("[server] request rejected: {e}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::runtime::MockBackend;

    #[test]
    fn inproc_server_round_trip() {
        let cfg = EngineConfig {
            budget: 16,
            batch: 2,
            chunked_prefill: false,
            ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(2, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        for i in 0..4 {
            srv.submit(Request::new(i, vec![1, 30 + i as u32], 3));
        }
        let responses = srv.shutdown();
        assert_eq!(responses.len(), 4);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn inproc_server_serves_metrics_and_trace_snapshots() {
        let cfg = EngineConfig {
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        srv.submit(Request::new(1, vec![1, 40], 3));
        assert!(srv.recv_blocking().is_some());
        let text = srv.metrics_snapshot().unwrap();
        crate::obs::assert_prometheus_parses(&text);
        assert!(text.contains("trimkv_tokens_decoded_total 3\n"));
        let trace = srv.trace_snapshot().unwrap();
        let doc = crate::util::json::Json::parse(&trace).unwrap();
        assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        srv.shutdown();
    }

    #[test]
    fn inproc_server_session_turns_in_order() {
        let cfg = EngineConfig {
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        srv.submit(Request::new(1, vec![1, 50], 2).with_session("s"));
        srv.submit(Request::new(2, vec![60], 2).with_session("s"));
        srv.close_session("s");
        let responses = srv.shutdown();
        assert_eq!(responses.len(), 2);
        // turn order is preserved within a session, cache carries across
        assert_eq!(responses[0].id, 1);
        assert_eq!(responses[0].tokens, vec![51, 52]);
        assert_eq!(responses[1].id, 2);
        assert_eq!(responses[1].tokens, vec![61, 62]);
    }

    #[test]
    fn inproc_server_flush_parks_sessions_to_store() {
        let cfg = EngineConfig {
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        srv.submit(Request::new(1, vec![1, 50], 2).with_session("s"));
        assert!(srv.recv_blocking().is_some());
        // under the lazy swap policy the finished turn parks on the lane;
        // the flush barrier forces it down to the host store
        assert!(srv.flush_sessions());
        let text = srv.metrics_snapshot().unwrap();
        assert!(text.contains("trimkv_session_store_size 1\n"),
                "flush must land the parked session in the store:\n{text}");
        srv.shutdown();
    }
}
