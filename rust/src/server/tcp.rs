//! Line-delimited JSON TCP front-end (`trimkv serve --port N`).
//!
//! Protocol: each request is one JSON line
//!   {"id": 1, "prompt": [1, 40, 41], "max_new_tokens": 16, "tag": "x"}
//! multi-turn requests add a session id; the engine retains the KV cache
//! between turns (no re-prefill of prior turns):
//!   {"id": 2, "session": "abc", "prompt": [44, 45], "max_new_tokens": 4}
//! a conversation is dropped with a close message (acked with one line):
//!   {"session": "abc", "close": true}
//! a stats message returns the live metrics as one JSON line holding the
//! Prometheus-style exposition text:
//!   {"stats": true}  ->  {"metrics": "trimkv_tokens_decoded_total 42\n..."}
//! plain HTTP scrapers are also served: a connection whose first line is
//! `GET /metrics` receives one `text/plain` exposition and is closed;
//! any other `GET` path (health probes, typos) gets a 404, never a
//! metrics body.
//! each response is one JSON line
//!   {"id": 1, "tag": "x", "session": "abc", "tokens": [...],
//!    "finish": "eos", "ttft_us": 123.0, "e2e_us": 456.0}
//! Closing the connection ends the client; retained sessions survive it and
//! can be resumed from a later connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use crate::scheduler::{FinishReason, Request, Response};
use crate::server::Frontend;
use crate::util::json::Json;

/// One parsed client line.
pub enum ClientMsg {
    Req(Request),
    Close(String),
    /// metrics scrape over the line protocol ({"stats": true})
    Stats,
}

pub fn parse_client_line(line: &str) -> anyhow::Result<ClientMsg> {
    let j = Json::parse(line)?;
    if j.get("close").and_then(Json::as_bool) == Some(true) {
        let sid = j.str_field("session")?;
        return Ok(ClientMsg::Close(sid.to_string()));
    }
    if j.get("stats").and_then(Json::as_bool) == Some(true) {
        return Ok(ClientMsg::Stats);
    }
    request_from_json(&j).map(ClientMsg::Req)
}

pub fn parse_request_line(line: &str) -> anyhow::Result<Request> {
    request_from_json(&Json::parse(line)?)
}

fn request_from_json(j: &Json) -> anyhow::Result<Request> {
    let id = j.usize_field("id")? as u64;
    let prompt: Vec<u32> = j
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing prompt array"))?
        .iter()
        .filter_map(Json::as_usize)
        .map(|x| x as u32)
        .collect();
    let max_new = j.get("max_new_tokens").and_then(Json::as_usize).unwrap_or(64);
    let tag = j
        .get("tag")
        .and_then(Json::as_str)
        .unwrap_or_default()
        .to_string();
    let mut req = Request::new(id, prompt, max_new);
    req.tag = tag;
    req.session = j.get("session").and_then(Json::as_str).map(str::to_string);
    Ok(req)
}

pub fn response_to_json(r: &Response) -> Json {
    let mut pairs = vec![
        ("id", Json::num(r.id as f64)),
        ("tag", Json::str(r.tag.clone())),
        ("tokens", Json::arr_usize(
            &r.tokens.iter().map(|&t| t as usize).collect::<Vec<_>>())),
        ("finish", Json::str(match r.finish {
            FinishReason::Eos => "eos",
            FinishReason::Length => "length",
            FinishReason::Aborted => "aborted",
        })),
        ("prompt_len", Json::num(r.prompt_len as f64)),
        ("ttft_us", Json::num(r.ttft_us)),
        ("e2e_us", Json::num(r.e2e_us)),
    ];
    if let Some(sid) = &r.session {
        pairs.push(("session", Json::str(sid.clone())));
    }
    Json::obj(pairs)
}

/// Serve one client connection: read request lines, stream response lines.
/// Returns when the client closes its write side and all work is done.
pub fn serve_connection<F: Frontend>(stream: TcpStream, srv: &F) -> anyhow::Result<usize> {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut outstanding = 0usize;
    let mut served = 0usize;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // HTTP fast path: plain scrapers (curl, Prometheus) get one
        // response and the connection closes.  Only `GET /metrics` is the
        // exposition; any other path — health probes, typos — is a 404,
        // never a metrics body.
        if let Some(rest) = line.strip_prefix("GET ") {
            let path = rest.split_whitespace().next().unwrap_or("");
            // ignore a query string ("/metrics?ts=..."), match exactly
            if path.split('?').next() == Some("/metrics") {
                let body = srv.metrics_snapshot().unwrap_or_default();
                write!(writer,
                       "HTTP/1.0 200 OK\r\n\
                        Content-Type: text/plain; version=0.0.4\r\n\
                        Content-Length: {}\r\n\
                        Connection: close\r\n\r\n{}",
                       body.len(), body)?;
            } else {
                let body = "not found\n";
                write!(writer,
                       "HTTP/1.0 404 Not Found\r\n\
                        Content-Type: text/plain\r\n\
                        Content-Length: {}\r\n\
                        Connection: close\r\n\r\n{}",
                       body.len(), body)?;
            }
            return Ok(served);
        }
        match parse_client_line(&line) {
            Ok(ClientMsg::Req(req)) => {
                srv.submit(req);
                outstanding += 1;
            }
            Ok(ClientMsg::Close(sid)) => {
                srv.close_session(&sid);
                writeln!(writer, "{}", Json::obj(vec![
                    ("session", Json::str(sid)),
                    ("closed", Json::Bool(true)),
                ]))?;
            }
            Ok(ClientMsg::Stats) => {
                let text = srv.metrics_snapshot().unwrap_or_default();
                writeln!(writer, "{}", Json::obj(vec![
                    ("metrics", Json::str(text)),
                ]))?;
            }
            Err(e) => {
                writeln!(writer, "{}", Json::obj(vec![
                    ("error", Json::str(e.to_string())),
                ]))?;
            }
        }
        // drain any completions that are already available
        while let Some(resp) = srv.try_recv() {
            writeln!(writer, "{}", response_to_json(&resp))?;
            outstanding -= 1;
            served += 1;
        }
    }
    while outstanding > 0 {
        if let Some(resp) = srv.recv_blocking() {
            writeln!(writer, "{}", response_to_json(&resp))?;
            outstanding -= 1;
            served += 1;
        } else {
            break;
        }
    }
    Ok(served)
}

/// Accept loop: one connection at a time (the engine-group frontend still
/// serves all replicas concurrently — routing is cheap; the single accept
/// loop only serializes protocol parsing).
pub fn listen<F: Frontend>(addr: &str, srv: &F) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("[tcp] listening on {addr}");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let peer = s.peer_addr().map(|a| a.to_string()).unwrap_or_default();
                match serve_connection(s, srv) {
                    Ok(n) => eprintln!("[tcp] {peer}: served {n} requests"),
                    Err(e) => eprintln!("[tcp] {peer}: {e}"),
                }
            }
            Err(e) => eprintln!("[tcp] accept error: {e}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::InProcServer;

    #[test]
    fn parses_request_line() {
        let r = parse_request_line(
            r#"{"id": 3, "prompt": [1, 2, 3], "max_new_tokens": 9, "tag": "t"}"#,
        )
        .unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 9);
        assert_eq!(r.tag, "t");
    }

    #[test]
    fn defaults_and_errors() {
        let r = parse_request_line(r#"{"id": 1, "prompt": [5]}"#).unwrap();
        assert_eq!(r.max_new_tokens, 64);
        assert_eq!(r.session, None);
        assert!(parse_request_line("{}").is_err());
        assert!(parse_request_line("not json").is_err());
    }

    #[test]
    fn parses_session_and_close_messages() {
        let m = parse_client_line(
            r#"{"id": 4, "session": "abc", "prompt": [9], "max_new_tokens": 2}"#,
        )
        .unwrap();
        let ClientMsg::Req(r) = m else { panic!("expected request") };
        assert_eq!(r.session.as_deref(), Some("abc"));
        let m = parse_client_line(r#"{"session": "abc", "close": true}"#).unwrap();
        let ClientMsg::Close(sid) = m else { panic!("expected close") };
        assert_eq!(sid, "abc");
        // close without a session id is a protocol error
        assert!(parse_client_line(r#"{"close": true}"#).is_err());
    }

    #[test]
    fn response_json_shape() {
        let mut r = Response {
            id: 9,
            tag: "x".into(),
            session: None,
            prompt_len: 2,
            tokens: vec![7, 8],
            finish: FinishReason::Eos,
            ttft_us: 1.0,
            e2e_us: 2.0,
        };
        let j = response_to_json(&r);
        assert_eq!(j.usize_field("id").unwrap(), 9);
        assert_eq!(j.str_field("finish").unwrap(), "eos");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("session").is_none());
        r.session = Some("abc".into());
        let j = response_to_json(&r);
        assert_eq!(j.str_field("session").unwrap(), "abc");
    }

    #[test]
    fn tcp_end_to_end() {
        use crate::config::EngineConfig;
        use crate::engine::Engine;
        use crate::runtime::MockBackend;
        use std::io::{BufRead, BufReader, Write};

        let cfg = EngineConfig {
            budget: 16, batch: 1, chunked_prefill: false, ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            serve_connection(s, &srv).unwrap()
        });
        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, r#"{{"id": 1, "prompt": [1, 50], "max_new_tokens": 3}}"#)
            .unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let mut line = String::new();
        BufReader::new(&client).read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.usize_field("id").unwrap(), 1);
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(t.join().unwrap(), 1);
    }

    #[test]
    fn tcp_stats_message_returns_metrics_text() {
        use crate::config::EngineConfig;
        use crate::engine::Engine;
        use crate::runtime::MockBackend;
        use std::io::{BufRead, BufReader, Write};

        let cfg = EngineConfig {
            budget: 16, batch: 1, chunked_prefill: false, ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            serve_connection(s, &srv).unwrap()
        });
        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, r#"{{"id": 1, "prompt": [1, 50], "max_new_tokens": 2}}"#)
            .unwrap();
        writeln!(client, r#"{{"stats": true}}"#).unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let mut metrics_text = None;
        for line in BufReader::new(&client).lines() {
            let j = Json::parse(line.unwrap().trim()).unwrap();
            if let Some(m) = j.get("metrics").and_then(Json::as_str) {
                metrics_text = Some(m.to_string());
            }
        }
        let text = metrics_text.expect("stats line answered");
        crate::obs::assert_prometheus_parses(&text);
        assert!(text.contains("trimkv_requests_admitted_total 1\n"));
        t.join().unwrap();
    }

    #[test]
    fn tcp_get_metrics_serves_http_scrape() {
        use crate::config::EngineConfig;
        use crate::engine::Engine;
        use crate::runtime::MockBackend;
        use std::io::{Read, Write};

        let cfg = EngineConfig {
            budget: 16, batch: 1, chunked_prefill: false, ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            serve_connection(s, &srv).unwrap()
        });
        let mut client = TcpStream::connect(addr).unwrap();
        write!(client, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let mut raw = String::new();
        client.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.0 200 OK\r\n"), "got: {raw}");
        assert!(raw.contains("Content-Type: text/plain"));
        let body = raw.split("\r\n\r\n").nth(1).expect("header/body split");
        crate::obs::assert_prometheus_parses(body);
        assert!(body.contains("trimkv_uptime_seconds"));
        t.join().unwrap();
    }

    #[test]
    fn tcp_get_other_paths_answer_404_not_metrics() {
        use crate::config::EngineConfig;
        use crate::engine::Engine;
        use crate::runtime::MockBackend;
        use std::io::{Read, Write};

        let cfg = EngineConfig {
            budget: 16, batch: 1, chunked_prefill: false, ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            // two probes, then a query-string scrape that must still work
            for _ in 0..3 {
                let (s, _) = listener.accept().unwrap();
                serve_connection(s, &srv).unwrap();
            }
        });
        for path in ["/healthz", "/metricsz"] {
            let mut client = TcpStream::connect(addr).unwrap();
            write!(client, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            client.shutdown(std::net::Shutdown::Write).unwrap();
            let mut raw = String::new();
            client.read_to_string(&mut raw).unwrap();
            assert!(raw.starts_with("HTTP/1.0 404 Not Found\r\n"),
                    "{path} must 404, got: {raw}");
            assert!(!raw.contains("trimkv_"), "{path} leaked metrics: {raw}");
        }
        let mut client = TcpStream::connect(addr).unwrap();
        write!(client, "GET /metrics?ts=1 HTTP/1.1\r\n\r\n").unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let mut raw = String::new();
        client.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.0 200 OK\r\n"), "got: {raw}");
        assert!(raw.contains("trimkv_uptime_seconds"));
        t.join().unwrap();
    }

    #[test]
    fn tcp_multi_turn_session_and_close() {
        use crate::config::EngineConfig;
        use crate::engine::Engine;
        use crate::runtime::MockBackend;
        use std::io::{BufRead, BufReader, Write};

        let cfg = EngineConfig {
            budget: 16, batch: 1, chunked_prefill: false, ..Default::default()
        };
        let engine = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        let srv = InProcServer::spawn(engine);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            serve_connection(s, &srv).unwrap()
        });
        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(
            client,
            r#"{{"id": 1, "session": "s", "prompt": [1, 50], "max_new_tokens": 2}}"#
        )
        .unwrap();
        writeln!(
            client,
            r#"{{"id": 2, "session": "s", "prompt": [60], "max_new_tokens": 2}}"#
        )
        .unwrap();
        writeln!(client, r#"{{"session": "s", "close": true}}"#).unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(&client);
        let mut turn_tokens: Vec<Vec<usize>> = Vec::new();
        let mut saw_close_ack = false;
        for line in reader.lines() {
            let j = Json::parse(line.unwrap().trim()).unwrap();
            if j.get("closed").and_then(Json::as_bool) == Some(true) {
                saw_close_ack = true;
            } else {
                assert_eq!(j.str_field("session").unwrap(), "s");
                let toks = j.get("tokens").unwrap().as_arr().unwrap()
                    .iter().filter_map(Json::as_usize).collect();
                turn_tokens.push(toks);
            }
        }
        assert!(saw_close_ack);
        assert_eq!(turn_tokens.len(), 2);
        // mock emits successors; turn 2 continues from the retained cache
        assert_eq!(turn_tokens[0], vec![51, 52]);
        assert_eq!(turn_tokens[1], vec![61, 62]);
        assert_eq!(t.join().unwrap(), 2);
    }
}
