//! Serving metrics: request latencies, token throughput, cache occupancy.
//!
//! Every latency series uses a bounded streaming summary (`StreamSummary`:
//! Welford moments + a fixed reservoir for percentiles) — a serving engine
//! records one sample per event forever, so nothing here may grow with
//! uptime.

use std::time::Instant;

use crate::util::stats::{LatencyHistogram, StreamSummary};

#[derive(Debug)]
pub struct EngineMetrics {
    pub started: Instant,
    pub requests_admitted: u64,
    pub requests_finished: u64,
    pub tokens_prefilled: u64,
    pub tokens_decoded: u64,
    pub evictions: u64,
    pub injections: u64,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    // mixed-tick scheduler (fused decode + chunked prefill)
    pub mixed_steps: u64,                // fused backend steps executed
    pub mixed_decode_lanes: StreamSummary, // decode lanes per mixed step
    pub mixed_chunk_lanes: StreamSummary,  // chunk-fill lanes per mixed step
    pub mixed_chunk_tokens: u64,         // prompt tokens fed via mixed steps
    /// fused steps whose plan carried retrieval re-injections (`Inject`
    /// ops) — nonzero proves the retrieval baseline rides fused ticks
    /// instead of forcing alternating phases
    pub mixed_inject_steps: u64,
    // session subsystem (KV snapshot/swap)
    pub sessions_opened: u64,            // first turn of a new session
    pub sessions_closed: u64,            // explicit client close
    pub sessions_dropped: u64,           // LRU pressure in the host store
    pub swap_outs: u64,                  // lanes preempted to the host store
    pub swap_ins: u64,                   // lanes restored from the host store
    pub swap_batches: u64,               // batched swap_lanes calls executed
    pub preemptions: u64,                // parked lane evicted for new work
    pub resumes_in_place: u64,           // next turn hit its parked lane
    pub ttft_us: LatencyHistogram,       // time to first token
    pub ttft_summary_us: StreamSummary,  // TTFT mean/p95 (stall-bound SLO)
    pub tbt_us: StreamSummary,           // time between a lane's tokens
    /// engine ticks between a lane's consecutive sampled tokens — the
    /// deterministic stall bound (mixed scheduling keeps this at 1 even
    /// while another lane prefills a long prompt)
    pub tbt_ticks: StreamSummary,
    pub e2e_us: LatencyHistogram,        // request end-to-end
    /// backend-step wall time.  Since the step-plan API this covers EVERY
    /// executed plan — decode, prefill and mixed ticks alike (pre-PR-4 it
    /// excluded pure prefill ticks, so long-prompt workloads report higher
    /// means here than older builds; that is a measurement-coverage change,
    /// not an engine regression).
    pub step_us: StreamSummary,
    /// active lanes per executed step (same coverage note as `step_us`)
    pub lane_occupancy: StreamSummary,
    pub swap_out_us: StreamSummary,      // batched swap call incl. evictions
    pub swap_in_us: StreamSummary,       // batched swap call incl. loads
}

impl Default for EngineMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineMetrics {
    pub fn new() -> Self {
        EngineMetrics {
            started: Instant::now(),
            requests_admitted: 0,
            requests_finished: 0,
            tokens_prefilled: 0,
            tokens_decoded: 0,
            evictions: 0,
            injections: 0,
            decode_steps: 0,
            prefill_chunks: 0,
            mixed_steps: 0,
            mixed_decode_lanes: StreamSummary::new(),
            mixed_chunk_lanes: StreamSummary::new(),
            mixed_chunk_tokens: 0,
            mixed_inject_steps: 0,
            sessions_opened: 0,
            sessions_closed: 0,
            sessions_dropped: 0,
            swap_outs: 0,
            swap_ins: 0,
            swap_batches: 0,
            preemptions: 0,
            resumes_in_place: 0,
            ttft_us: LatencyHistogram::new(),
            ttft_summary_us: StreamSummary::new(),
            tbt_us: StreamSummary::new(),
            tbt_ticks: StreamSummary::new(),
            e2e_us: LatencyHistogram::new(),
            step_us: StreamSummary::new(),
            lane_occupancy: StreamSummary::new(),
            swap_out_us: StreamSummary::new(),
            swap_in_us: StreamSummary::new(),
        }
    }

    pub fn decode_throughput_tok_s(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el > 0.0 { self.tokens_decoded as f64 / el } else { 0.0 }
    }

    pub fn summary(&self) -> String {
        format!(
            "requests {}/{} finished | prefill {} tok | decode {} tok \
             ({:.1} tok/s) | steps {} (mean {:.2} ms, p95 {:.2} ms) | \
             evictions {} | ttft p50 {:.1} ms | e2e p50 {:.1} ms | \
             lanes {:.2}",
            self.requests_finished,
            self.requests_admitted,
            self.tokens_prefilled,
            self.tokens_decoded,
            self.decode_throughput_tok_s(),
            self.decode_steps,
            self.step_us.mean() / 1e3,
            self.step_us.pct(95.0) / 1e3,
            self.evictions,
            self.ttft_us.pct_us(50.0) / 1e3,
            self.e2e_us.pct_us(50.0) / 1e3,
            self.lane_occupancy.mean(),
        )
    }

    /// One-line mixed-tick scheduling summary (stall-free serving).
    pub fn scheduling_summary(&self) -> String {
        format!(
            "mixed steps {} (decode lanes {:.2}, chunk lanes {:.2} mean, \
             {} with injects) | chunk tokens {} | ttft mean {:.1} ms p95 \
             {:.1} ms | tbt mean {:.2} ms p95 {:.2} ms | tick gap max {:.0}",
            self.mixed_steps,
            self.mixed_decode_lanes.mean(),
            self.mixed_chunk_lanes.mean(),
            self.mixed_inject_steps,
            self.mixed_chunk_tokens,
            self.ttft_summary_us.mean() / 1e3,
            self.ttft_summary_us.pct(95.0) / 1e3,
            self.tbt_us.mean() / 1e3,
            self.tbt_us.pct(95.0) / 1e3,
            self.tbt_ticks.max(),
        )
    }

    /// One-line session/swap summary (multi-turn serving).
    pub fn session_summary(&self) -> String {
        format!(
            "sessions {} opened / {} closed / {} dropped | swaps {} out \
             (mean {:.1} us, p95 {:.1} us) / {} in (mean {:.1} us, p95 \
             {:.1} us) over {} batched calls | preemptions {} | in-place \
             resumes {}",
            self.sessions_opened,
            self.sessions_closed,
            self.sessions_dropped,
            self.swap_outs,
            self.swap_out_us.mean(),
            self.swap_out_us.pct(95.0),
            self.swap_ins,
            self.swap_in_us.mean(),
            self.swap_in_us.pct(95.0),
            self.swap_batches,
            self.preemptions,
            self.resumes_in_place,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_renders() {
        let mut m = EngineMetrics::new();
        m.requests_admitted = 3;
        m.requests_finished = 2;
        m.tokens_decoded = 100;
        m.decode_steps = 50;
        m.step_us.push(1500.0);
        m.ttft_us.record_us(2000.0);
        m.e2e_us.record_us(9000.0);
        m.lane_occupancy.push(4.0);
        let s = m.summary();
        assert!(s.contains("requests 2/3"));
        assert!(s.contains("decode 100 tok"));
    }

    #[test]
    fn scheduling_summary_renders() {
        let mut m = EngineMetrics::new();
        m.mixed_steps = 4;
        m.mixed_decode_lanes.push(6.0);
        m.mixed_chunk_lanes.push(2.0);
        m.mixed_chunk_tokens = 128;
        m.ttft_summary_us.push(2000.0);
        m.tbt_us.push(900.0);
        m.tbt_ticks.push(1.0);
        let s = m.scheduling_summary();
        assert!(s.contains("mixed steps 4"));
        assert!(s.contains("chunk tokens 128"));
        assert!(s.contains("tick gap max 1"));
    }

    #[test]
    fn session_summary_renders() {
        let mut m = EngineMetrics::new();
        m.sessions_opened = 5;
        m.swap_outs = 3;
        m.swap_ins = 2;
        m.swap_batches = 2;
        m.preemptions = 1;
        let s = m.session_summary();
        assert!(s.contains("sessions 5 opened"));
        assert!(s.contains("swaps 3 out"));
        assert!(s.contains("2 batched calls"));
        assert!(s.contains("preemptions 1"));
    }

    #[test]
    fn latency_series_stay_bounded() {
        // the regression this module guards against: per-event pushes must
        // not grow memory with uptime
        let mut m = EngineMetrics::new();
        for i in 0..100_000 {
            m.step_us.push(i as f64);
            m.swap_out_us.push(i as f64);
        }
        assert_eq!(m.step_us.count(), 100_000);
        assert!(m.step_us.pct(95.0) > m.step_us.pct(5.0));
    }
}
