//! Serving metrics: request latencies, token throughput, cache occupancy.
//!
//! Every latency series uses a bounded streaming summary (`StreamSummary`:
//! Welford moments + a fixed reservoir for percentiles) — a serving engine
//! records one sample per event forever, so nothing here may grow with
//! uptime.
//!
//! Beyond the human-readable `summary()` one-liners, every counter and
//! series is enumerable through [`EngineMetrics::samples`], the machine
//! interface the Prometheus `/metrics` exposition (and any future SLO
//! loadgen) consumes.

use std::time::Instant;

use crate::obs::{self, Sample};
use crate::util::stats::{fmt_opt, LatencyHistogram, StreamSummary};

#[derive(Debug)]
pub struct EngineMetrics {
    pub started: Instant,
    pub requests_admitted: u64,
    pub requests_finished: u64,
    pub tokens_prefilled: u64,
    pub tokens_decoded: u64,
    pub evictions: u64,
    pub injections: u64,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    // mixed-tick scheduler (fused decode + chunked prefill)
    pub mixed_steps: u64,                // fused backend steps executed
    pub mixed_decode_lanes: StreamSummary, // decode lanes per mixed step
    pub mixed_chunk_lanes: StreamSummary,  // chunk-fill lanes per mixed step
    pub mixed_chunk_tokens: u64,         // prompt tokens fed via mixed steps
    /// fused steps whose plan carried retrieval re-injections (`Inject`
    /// ops) — nonzero proves the retrieval baseline rides fused ticks
    /// instead of forcing alternating phases
    pub mixed_inject_steps: u64,
    // session subsystem (KV snapshot/swap)
    pub sessions_opened: u64,            // first turn of a new session
    pub sessions_closed: u64,            // explicit client close
    pub sessions_dropped: u64,           // LRU pressure in the host store
    pub swap_outs: u64,                  // lanes preempted to the host store
    pub swap_ins: u64,                   // lanes restored from the host store
    pub swap_batches: u64,               // batched swap_lanes calls executed
    /// swap batches issued while a step was in flight (pipelined loop):
    /// transfers that rode an overlap window instead of the critical path
    pub swap_batches_overlapped: u64,
    pub preemptions: u64,                // parked lane evicted for new work
    pub resumes_in_place: u64,           // next turn hit its parked lane
    pub ttft_us: LatencyHistogram,       // time to first token
    pub ttft_summary_us: StreamSummary,  // TTFT mean/p95 (stall-bound SLO)
    pub tbt_us: StreamSummary,           // time between a lane's tokens
    /// engine ticks between a lane's consecutive sampled tokens — the
    /// deterministic stall bound (mixed scheduling keeps this at 1 even
    /// while another lane prefills a long prompt)
    pub tbt_ticks: StreamSummary,
    pub e2e_us: LatencyHistogram,        // request end-to-end
    /// backend-step wall time.  Since the step-plan API this covers EVERY
    /// executed plan — decode, prefill and mixed ticks alike (pre-PR-4 it
    /// excluded pure prefill ticks, so long-prompt workloads report higher
    /// means here than older builds; that is a measurement-coverage change,
    /// not an engine regression).
    pub step_us: StreamSummary,
    /// active lanes per executed step (same coverage note as `step_us`)
    pub lane_occupancy: StreamSummary,
    pub swap_out_us: StreamSummary,      // batched swap call incl. evictions
    pub swap_in_us: StreamSummary,       // batched swap call incl. loads
}

impl Default for EngineMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineMetrics {
    pub fn new() -> Self {
        EngineMetrics {
            started: Instant::now(),
            requests_admitted: 0,
            requests_finished: 0,
            tokens_prefilled: 0,
            tokens_decoded: 0,
            evictions: 0,
            injections: 0,
            decode_steps: 0,
            prefill_chunks: 0,
            mixed_steps: 0,
            mixed_decode_lanes: StreamSummary::new(),
            mixed_chunk_lanes: StreamSummary::new(),
            mixed_chunk_tokens: 0,
            mixed_inject_steps: 0,
            sessions_opened: 0,
            sessions_closed: 0,
            sessions_dropped: 0,
            swap_outs: 0,
            swap_ins: 0,
            swap_batches: 0,
            swap_batches_overlapped: 0,
            preemptions: 0,
            resumes_in_place: 0,
            ttft_us: LatencyHistogram::new(),
            ttft_summary_us: StreamSummary::new(),
            tbt_us: StreamSummary::new(),
            tbt_ticks: StreamSummary::new(),
            e2e_us: LatencyHistogram::new(),
            step_us: StreamSummary::new(),
            lane_occupancy: StreamSummary::new(),
            swap_out_us: StreamSummary::new(),
            swap_in_us: StreamSummary::new(),
        }
    }

    /// `None` until the first decoded token: a fresh engine has no
    /// throughput, and rendering must show `-`, not 0.0 or NaN.
    pub fn decode_throughput_tok_s(&self) -> Option<f64> {
        let el = self.started.elapsed().as_secs_f64();
        if self.tokens_decoded == 0 || el <= 0.0 {
            None
        } else {
            Some(self.tokens_decoded as f64 / el)
        }
    }

    pub fn summary(&self) -> String {
        let ms = |v: Option<f64>, d: usize| fmt_opt(v.map(|x| x / 1e3), d);
        format!(
            "requests {}/{} finished | prefill {} tok | decode {} tok \
             ({} tok/s) | steps {} (mean {:.2} ms, p95 {} ms) | \
             evictions {} | ttft p50 {} ms | e2e p50 {} ms | \
             lanes {:.2}",
            self.requests_finished,
            self.requests_admitted,
            self.tokens_prefilled,
            self.tokens_decoded,
            fmt_opt(self.decode_throughput_tok_s(), 1),
            self.decode_steps,
            self.step_us.mean() / 1e3,
            ms(self.step_us.pct(95.0), 2),
            self.evictions,
            ms(self.ttft_us.pct_us(50.0), 1),
            ms(self.e2e_us.pct_us(50.0), 1),
            self.lane_occupancy.mean(),
        )
    }

    /// One-line mixed-tick scheduling summary (stall-free serving).
    pub fn scheduling_summary(&self) -> String {
        let ms = |v: Option<f64>, d: usize| fmt_opt(v.map(|x| x / 1e3), d);
        format!(
            "mixed steps {} (decode lanes {:.2}, chunk lanes {:.2} mean, \
             {} with injects) | chunk tokens {} | ttft mean {:.1} ms p95 \
             {} ms | tbt mean {:.2} ms p95 {} ms | tick gap max {}",
            self.mixed_steps,
            self.mixed_decode_lanes.mean(),
            self.mixed_chunk_lanes.mean(),
            self.mixed_inject_steps,
            self.mixed_chunk_tokens,
            self.ttft_summary_us.mean() / 1e3,
            ms(self.ttft_summary_us.pct(95.0), 1),
            self.tbt_us.mean() / 1e3,
            ms(self.tbt_us.pct(95.0), 2),
            fmt_opt((self.tbt_ticks.count() > 0).then(|| self.tbt_ticks.max()),
                    0),
        )
    }

    /// One-line session/swap summary (multi-turn serving).
    pub fn session_summary(&self) -> String {
        format!(
            "sessions {} opened / {} closed / {} dropped | swaps {} out \
             (mean {:.1} us, p95 {} us) / {} in (mean {:.1} us, p95 \
             {} us) over {} batched calls ({} overlapped) | preemptions {} \
             | in-place resumes {}",
            self.sessions_opened,
            self.sessions_closed,
            self.sessions_dropped,
            self.swap_outs,
            self.swap_out_us.mean(),
            fmt_opt(self.swap_out_us.pct(95.0), 1),
            self.swap_ins,
            self.swap_in_us.mean(),
            fmt_opt(self.swap_in_us.pct(95.0), 1),
            self.swap_batches,
            self.swap_batches_overlapped,
            self.preemptions,
            self.resumes_in_place,
        )
    }

    /// Enumerate every counter and series as [`obs::Sample`]s — the single
    /// source the Prometheus exposition renders.  Counters keep their field
    /// names under a `trimkv_` prefix with the `_total` suffix; summaries
    /// and histograms expand per the Prometheus conventions.
    pub fn samples(&self) -> Vec<Sample> {
        let mut out: Vec<Sample> = [
            ("trimkv_requests_admitted_total", self.requests_admitted),
            ("trimkv_requests_finished_total", self.requests_finished),
            ("trimkv_tokens_prefilled_total", self.tokens_prefilled),
            ("trimkv_tokens_decoded_total", self.tokens_decoded),
            ("trimkv_evictions_total", self.evictions),
            ("trimkv_injections_total", self.injections),
            ("trimkv_decode_steps_total", self.decode_steps),
            ("trimkv_prefill_chunks_total", self.prefill_chunks),
            ("trimkv_mixed_steps_total", self.mixed_steps),
            ("trimkv_mixed_chunk_tokens_total", self.mixed_chunk_tokens),
            ("trimkv_mixed_inject_steps_total", self.mixed_inject_steps),
            ("trimkv_sessions_opened_total", self.sessions_opened),
            ("trimkv_sessions_closed_total", self.sessions_closed),
            ("trimkv_sessions_dropped_total", self.sessions_dropped),
            ("trimkv_swap_outs_total", self.swap_outs),
            ("trimkv_swap_ins_total", self.swap_ins),
            ("trimkv_swap_batches_total", self.swap_batches),
            ("trimkv_swap_batches_overlapped_total",
             self.swap_batches_overlapped),
            ("trimkv_preemptions_total", self.preemptions),
            ("trimkv_resumes_in_place_total", self.resumes_in_place),
        ]
        .into_iter()
        .map(|(name, v)| Sample::counter(name, v as f64))
        .collect();
        out.push(Sample::gauge("trimkv_uptime_seconds",
                               self.started.elapsed().as_secs_f64()));
        for (name, s) in [
            ("trimkv_mixed_decode_lanes", &self.mixed_decode_lanes),
            ("trimkv_mixed_chunk_lanes", &self.mixed_chunk_lanes),
            ("trimkv_ttft_summary_us", &self.ttft_summary_us),
            ("trimkv_tbt_us", &self.tbt_us),
            ("trimkv_tbt_ticks", &self.tbt_ticks),
            ("trimkv_step_us", &self.step_us),
            ("trimkv_lane_occupancy", &self.lane_occupancy),
            ("trimkv_swap_out_us", &self.swap_out_us),
            ("trimkv_swap_in_us", &self.swap_in_us),
        ] {
            out.extend(obs::summary_samples(name, s));
        }
        out.extend(obs::histogram_samples("trimkv_ttft_us", &self.ttft_us));
        out.extend(obs::histogram_samples("trimkv_e2e_us", &self.e2e_us));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_renders() {
        let mut m = EngineMetrics::new();
        m.requests_admitted = 3;
        m.requests_finished = 2;
        m.tokens_decoded = 100;
        m.decode_steps = 50;
        m.step_us.push(1500.0);
        m.ttft_us.record_us(2000.0);
        m.e2e_us.record_us(9000.0);
        m.lane_occupancy.push(4.0);
        let s = m.summary();
        assert!(s.contains("requests 2/3"));
        assert!(s.contains("decode 100 tok"));
    }

    #[test]
    fn empty_series_render_dashes_not_nan() {
        let m = EngineMetrics::new();
        assert_eq!(m.decode_throughput_tok_s(), None);
        for s in [m.summary(), m.scheduling_summary(), m.session_summary()] {
            assert!(!s.contains("NaN") && !s.contains("inf"),
                    "NaN/inf leaked into: {s}");
            assert!(s.contains('-'), "empty series must render `-`: {s}");
        }
        assert!(m.summary().contains("(- tok/s)"));
        assert!(m.summary().contains("ttft p50 - ms"));
    }

    #[test]
    fn scheduling_summary_renders() {
        let mut m = EngineMetrics::new();
        m.mixed_steps = 4;
        m.mixed_decode_lanes.push(6.0);
        m.mixed_chunk_lanes.push(2.0);
        m.mixed_chunk_tokens = 128;
        m.ttft_summary_us.push(2000.0);
        m.tbt_us.push(900.0);
        m.tbt_ticks.push(1.0);
        let s = m.scheduling_summary();
        assert!(s.contains("mixed steps 4"));
        assert!(s.contains("chunk tokens 128"));
        assert!(s.contains("tick gap max 1"));
    }

    #[test]
    fn session_summary_renders() {
        let mut m = EngineMetrics::new();
        m.sessions_opened = 5;
        m.swap_outs = 3;
        m.swap_ins = 2;
        m.swap_batches = 2;
        m.preemptions = 1;
        let s = m.session_summary();
        assert!(s.contains("sessions 5 opened"));
        assert!(s.contains("swaps 3 out"));
        assert!(s.contains("2 batched calls"));
        assert!(s.contains("preemptions 1"));
    }

    #[test]
    fn latency_series_stay_bounded() {
        // the regression this module guards against: per-event pushes must
        // not grow memory with uptime
        let mut m = EngineMetrics::new();
        for i in 0..100_000 {
            m.step_us.push(i as f64);
            m.swap_out_us.push(i as f64);
        }
        assert_eq!(m.step_us.count(), 100_000);
        assert!(m.step_us.pct(95.0).unwrap() > m.step_us.pct(5.0).unwrap());
    }

    #[test]
    fn samples_enumerate_counters_series_and_histograms() {
        let mut m = EngineMetrics::new();
        m.tokens_decoded = 77;
        m.evictions = 5;
        m.step_us.push(1000.0);
        m.ttft_us.record_us(2000.0);
        let samples = m.samples();
        let get = |n: &str| {
            samples
                .iter()
                .find(|s| s.name == n && s.labels.is_empty())
                .unwrap_or_else(|| panic!("missing sample {n}"))
                .value
        };
        assert_eq!(get("trimkv_tokens_decoded_total"), 77.0);
        assert_eq!(get("trimkv_evictions_total"), 5.0);
        assert_eq!(get("trimkv_step_us_count"), 1.0);
        assert_eq!(get("trimkv_ttft_us_count"), 1.0);
        assert_eq!(get("trimkv_requests_admitted_total"), 0.0);
        // every sample renders to a strictly parseable exposition line
        let text = crate::obs::render_prometheus(&samples);
        for line in text.lines() {
            let (name, value) = line.rsplit_once(' ').unwrap();
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "bad value line: {line}");
        }
    }
}
