//! Token sampler: greedy, temperature, and top-k sampling over raw logits.

use crate::util::rng::Rng;

#[derive(Debug)]
pub struct Sampler {
    pub temperature: f64,
    pub top_k: usize,
    rng: Rng,
}

impl Sampler {
    pub fn new(temperature: f64, top_k: usize, seed: u64) -> Sampler {
        Sampler { temperature, top_k, rng: Rng::new(seed ^ 0x5a17) }
    }

    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        // softmax with temperature over the (optionally top-k-truncated) set
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
            idx.truncate(self.top_k);
        }
        let t = self.temperature as f32;
        let mx = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> =
            idx.iter().map(|&i| (((logits[i] - mx) / t) as f64).exp()).collect();
        let total: f64 = weights.iter().sum();
        let mut r = self.rng.f64() * total;
        for (w, &i) in weights.iter().zip(&idx) {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        idx.last().copied().unwrap_or(0)
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(0.0, 0, 1);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0, 2.9]), 1);
    }

    #[test]
    fn temperature_sampling_respects_top_k() {
        let mut s = Sampler::new(1.0, 2, 7);
        let logits = [10.0, 9.5, -50.0, -50.0];
        for _ in 0..100 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut s = Sampler::new(0.05, 0, 3);
        let logits = [1.0, 2.0, 0.0];
        let hits = (0..200).filter(|_| s.sample(&logits) == 1).count();
        assert!(hits > 190, "hits {hits}");
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 1.0, 0.5]), 0);
    }
}
