//! The serving engine: continuous-batching event loop that drives the AOT
//! model graphs and enforces the KV budget through the configured eviction
//! policy (paper Algorithm 1, generalized over all baselines).
//!
//! Every tick is ONE plan-execute-postprocess pipeline:
//!   1. idle lanes admit waiting requests (continuous batching); any lane
//!      residency changes — LRU preemptions of parked sessions and session
//!      swap-ins from the host store — execute as ONE batched
//!      `swap_lanes` backend call (O(lane) per lane moved, never a
//!      round-trip per lane)
//!   2. *plan*: `engine::plan::assign_ops` gives every lane a `LaneOp` —
//!      `Decode` (one token), `Chunk{tokens}` (a Sarathi-budgeted prefill
//!      chunk), `Inject{slots}` (decode + retrieval re-admissions), or
//!      `Idle` — per the tick's scheduling phase (fused mixed ticks by
//!      default; alternating phases when `mixed_ticks` is off)
//!   3. *assemble*: each active lane picks, per (layer, head), the slot(s)
//!      its new token(s) will occupy — free slots (the arena keeps
//!      `slots > budget` so one always exists after the previous tick's
//!      eviction) — into the reusable fused `StepPlan` buffers; the
//!      validity mask is maintained incrementally, not rebuilt per tick
//!   4. *execute*: one `ModelBackend::submit(&StepPlan)` call (KV stays
//!      device-resident; the backend dispatches to the cheapest graph)
//!   5. *postprocess*: ONE shared per-lane helper records the new tokens'
//!      retention scores (gate outputs), folds attention stats, then — if
//!      a head now exceeds the budget — evicts the policy's victims
//!      (provisional-add-then-evict, exactly the paper's rule: the newest
//!      token itself can be evicted), plans retrieval re-injections, and
//!      samples the next token, finishing lanes on EOS / length
//!
//! Pipelined ticks (`scheduler.pipeline`, default on): submit and wait are
//! split across tick boundaries.  `tick` t submits its step and returns;
//! tick t+1 opens an *overlap window* — deferred eager-park snapshots and
//! admission (whose batched `swap_lanes` chains behind the in-flight step
//! on the device timeline) run while the device executes step t — then
//! waits, postprocesses step t, and submits step t+1 from the other side
//! of the double-buffered assembly scratch.  Host work overlaps device
//! execution, so the mean tick approaches max(host, device) instead of
//! their sum; token streams are bit-identical to the serial loop (each
//! lane's stream depends only on its own state, never on when unrelated
//! admission work ran).  `pipeline = off` restores the serial
//! submit-then-wait tick.
//!
//! Prompts run through chunk ops (compress-after-each-chunk, the LocRet
//! protocol used in paper §B.3) or token-by-token through decode ops
//! (`chunked_prefill = false`).
//!
//! Multi-turn serving: a request carrying a `session` id retains its lane
//! state after the turn.  Under the `lazy` swap policy the finished turn
//! *parks* on the lane (KV stays device-resident) and is preempted to the
//! host `SessionStore` only when a new request needs the lane; under
//! `eager` every finished turn snapshots to host immediately.  The next
//! turn of a session resumes in place, or swaps its snapshot back into any
//! free lane — decoding continues from the retained cache with zero
//! re-prefill of prior turns.  The lane state machine itself lives in
//! `engine::lanes`.

pub(crate) mod lanes;
pub(crate) mod plan;
pub mod sampler;

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::config::EngineConfig;
use crate::kvcache::{LaneCache, MirrorEntry, SlotEntry};
use crate::metrics::EngineMetrics;
use crate::model_meta::ModelDims;
use crate::obs::{self, EngineObs, Phase, RetentionObs, SpanHandle,
                 TID_DEVICE};
use crate::policy::Policy;
use crate::prefixcache::{PrefixFingerprint, PrefixPayload, PrefixStore};
use crate::runtime::{LaneKv, LaneOp, ModelBackend, StepOut, StepToken};
use crate::scheduler::{AdmitError, FinishReason, Request, Response, WaitQueue};
use crate::session::{SessionSnapshot, SessionStore};
use lanes::{Lane, LaneAvail, ParkedSession, SeqState, ValidMask};
use plan::{assign_ops, DoubleBufs, TickKind};
use sampler::Sampler;

/// EMA factor for the SnapKV-style attention statistic.
const ATTN_EMA: f32 = 0.9;

/// Full gate/eviction trace of one sequence (inspect tooling, Figs 4/5/11-19).
#[derive(Debug, Clone, Default)]
pub struct SeqRecord {
    /// token id at each position
    pub tokens: Vec<u32>,
    /// per position, per (layer*hkv) head: the gate's log beta
    pub log_betas: Vec<Vec<f32>>,
    /// (head index, evicted token pos, eviction step)
    pub evictions: Vec<(usize, i64, i64)>,
}

/// Bookkeeping for the step currently executing on the device: everything
/// `complete_in_flight` needs to postprocess it, captured at submit time.
struct InFlight {
    token: StepToken,
    /// tick the step was submitted on (stamps its tokens' latency metrics)
    tick_no: u64,
    kind: TickKind,
    kind_label: &'static str,
    /// which side of the double buffer the step was assembled into
    buf: usize,
    /// per lane: (real_c, flat chosen-slot table) — None for lanes that
    /// were inactive (or not yet seated) at submit time
    chunk_info: Vec<Option<(usize, Vec<usize>)>>,
    want_attn: bool,
    want_kv: bool,
    n_active: usize,
    /// submit instant (step_us measures submit -> completion)
    t0: Instant,
    /// open Execute span on the device trace track, closed at wait
    exec_span: SpanHandle,
}

pub struct Engine<B: ModelBackend> {
    backend: B,
    pub cfg: EngineConfig,
    policy: Policy,
    queue: WaitQueue,
    lanes: Vec<Lane>,
    sampler: Sampler,
    eos_token: u32,
    responses: Vec<Response>,
    pub metrics: EngineMetrics,
    /// record per-token gate scores + evictions (inspect tooling)
    pub record_gates: bool,
    /// trace of the most recently finished sequence (when record_gates)
    pub last_record: Option<SeqRecord>,
    /// host-side store of swapped-out sessions (LRU-bounded)
    sessions: SessionStore,
    /// close barriers: (session id, pre-close turns still to drain);
    /// the close applies when the count reaches zero
    pending_closes: Vec<(String, u64)>,
    /// logical clock stamping parked sessions for LRU preemption
    clock: u64,
    /// scheduling ticks executed (stamps token arrivals for the
    /// deterministic time-between-tokens gap metric)
    tick_no: u64,
    /// `[L, B, H, M]` validity mask, incrementally maintained
    valid: ValidMask,
    /// double-buffered fused `StepPlan` operand scratch: the next step
    /// assembles into one side while the in-flight step's postprocess
    /// still reads the other (and no per-step allocation, as before)
    dbufs: DoubleBufs,
    /// the step submitted but not yet waited on (pipelined loop)
    in_flight: Option<InFlight>,
    /// lanes parked under the eager swap policy whose snapshots are
    /// deferred to the next tick's overlap window (pipelined loop)
    chained_parks: Vec<usize>,
    /// shared-prefix KV store: admission consults it, completed cold
    /// prefixes publish back.  None when the feature is off.
    prefix: Option<Arc<PrefixStore>>,
    /// store attached from outside (`EngineGroup` replica sharing): the
    /// group renders the store's samples once, so this engine's own
    /// exposition skips them
    prefix_shared: bool,
    /// the configuration fingerprint folded into every prefix-store key
    prefix_fp: PrefixFingerprint,
    /// observability plane: tick flight recorder + retention histograms
    pub obs: EngineObs,
}

impl<B: ModelBackend> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig, eos_token: u32) -> Result<Engine<B>> {
        let dims = backend.dims();
        let slots = backend.slots();
        let chunk = backend.chunk();
        let needed = if cfg.chunked_prefill {
            cfg.budget + chunk + 1
        } else {
            cfg.budget + 2
        };
        ensure!(
            slots >= needed,
            "arena too small: slots {slots} < budget {} (+ headroom {})",
            cfg.budget, needed - cfg.budget
        );
        let policy = Policy::from_name(&cfg.policy, cfg.budget, cfg.seed)?;
        let b = backend.batch();
        let prefix_fp = PrefixFingerprint {
            policy: cfg.policy.clone(),
            budget: cfg.budget,
            chunked_prefill: cfg.chunked_prefill,
            backend_chunk: chunk,
            slots,
            layers: dims.layers,
            hkv: dims.hkv,
            dh: dims.dh,
        };
        let prefix = cfg.prefix_enabled.then(|| {
            Arc::new(PrefixStore::new(cfg.prefix_max_bytes,
                                      cfg.prefix_chunk_tokens))
        });
        Ok(Engine {
            sampler: Sampler::new(cfg.temperature, cfg.top_k, cfg.seed),
            queue: WaitQueue::new(cfg.queue_capacity),
            lanes: (0..b).map(|_| Lane::Idle).collect(),
            policy,
            backend,
            eos_token,
            responses: Vec::new(),
            metrics: EngineMetrics::new(),
            record_gates: false,
            last_record: None,
            sessions: SessionStore::new(cfg.max_sessions),
            pending_closes: Vec::new(),
            clock: 0,
            tick_no: 0,
            valid: ValidMask::new(&dims, b, slots),
            dbufs: DoubleBufs::new(&dims, b, chunk),
            in_flight: None,
            chained_parks: Vec::new(),
            prefix,
            prefix_shared: false,
            prefix_fp,
            obs: EngineObs::new(cfg.trace_capacity, cfg.trace, dims.layers,
                                dims.hkv),
            cfg,
        })
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Attach an externally owned prefix store (an `EngineGroup` shares one
    /// across its replicas).  The group renders the store's metric samples
    /// once; this engine's own exposition then skips them.
    pub fn set_prefix_store(&mut self, store: Arc<PrefixStore>) {
        self.prefix = Some(store);
        self.prefix_shared = true;
    }

    /// The prefix store this engine consults, when enabled.
    pub fn prefix_store(&self) -> Option<&Arc<PrefixStore>> {
        self.prefix.as_ref()
    }

    /// Tear down the engine and recover the backend (the eval harness
    /// rebuilds engines per policy/budget without recompiling artifacts).
    pub fn into_backend(self) -> B {
        self.backend
    }

    pub fn submit(&mut self, req: Request) -> Result<(), AdmitError> {
        self.metrics.requests_admitted += 1;
        self.queue.admit(req)
    }

    pub fn take_responses(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.responses)
    }

    /// No queued work and no lane decoding.  Parked sessions do not count:
    /// they are passive residents awaiting their next turn.
    pub fn idle(&self) -> bool {
        self.queue.is_empty()
            && self.in_flight.is_none()
            && self.lanes.iter().all(|l| !matches!(l, Lane::Busy(_)))
    }

    /// Host session store (swapped-out conversations).
    pub fn sessions(&self) -> &SessionStore {
        &self.sessions
    }

    /// Mutable store access (checkpoint restore / migration tooling).
    pub fn sessions_mut(&mut self) -> &mut SessionStore {
        &mut self.sessions
    }

    /// Full validity-mask lane rewrites performed so far (diagnostics:
    /// steady-state decode maintains the mask incrementally and should add
    /// none of these per tick).
    pub fn valid_refreshes(&self) -> u64 {
        self.valid.refreshes
    }

    /// Force every parked lane out to the host store (drain / checkpoint)
    /// in one batched swap.  Resolves the in-flight step first: its
    /// finishing turns may park, and those lanes must be in the flush.
    pub fn flush_sessions(&mut self) -> Result<()> {
        self.complete_in_flight()?;
        self.drain_chained_swaps()?;
        let parked: Vec<usize> = self
            .lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| matches!(l, Lane::Parked(_)).then_some(i))
            .collect();
        self.execute_swap(&parked, &[])?;
        Ok(())
    }

    /// Drain a session out of this engine for cross-replica migration:
    /// resolve the in-flight step, force the session's parked lane (if any)
    /// down to the host store, then take the snapshot out of the store.
    /// Returns `Ok(None)` when the engine holds no state for the id.
    /// Refuses while the session has turns decoding or queued — the router
    /// only migrates quiescent sessions, so a refusal is a caller bug.
    pub fn export_session(&mut self, id: &str) -> Result<Option<SessionSnapshot>> {
        self.complete_in_flight()?;
        self.drain_chained_swaps()?;
        let busy = self.lanes.iter().any(|l| {
            matches!(l, Lane::Busy(s) if s.session.as_deref() == Some(id))
        });
        ensure!(
            !busy && !self.queue.has_session(id),
            "session {id} has turns in flight; migration requires quiescence"
        );
        let parked = self.lanes.iter().position(|l| {
            matches!(l, Lane::Parked(p) if p.session_id == id)
        });
        if let Some(lane) = parked {
            self.execute_swap(&[lane], &[])?;
        }
        Ok(self.sessions.take(id))
    }

    /// Rebind a migrated snapshot into this engine's host store (the
    /// target half of a cross-replica handoff).  The session's next turn
    /// swaps it into a lane through the ordinary admission path.  LRU
    /// pressure applies exactly as for a locally parked session.
    pub fn import_session(&mut self, id: &str, snap: SessionSnapshot) {
        let dropped = self.sessions.insert(id.to_string(), snap);
        self.metrics.sessions_dropped += dropped as u64;
    }

    /// Drop a conversation: its host snapshot and its parked lane.  The
    /// close is a *barrier*: turns already decoding or queued at close time
    /// finish normally (with the retained cache), then the state is
    /// dropped; a turn submitted with the same id *after* the close starts
    /// a brand-new conversation.
    pub fn close_session(&mut self, id: &str) {
        let active = self.lanes.iter().filter(|l| {
            matches!(l, Lane::Busy(s) if s.session.as_deref() == Some(id))
        }).count();
        let outstanding = (active + self.queue.session_count(id)) as u64;
        self.pending_closes.push((id.to_string(), outstanding));
        self.process_pending_closes();
    }

    fn process_pending_closes(&mut self) {
        if self.pending_closes.is_empty() {
            return;
        }
        let mut remaining = Vec::new();
        for (id, outstanding) in std::mem::take(&mut self.pending_closes) {
            if outstanding > 0 {
                remaining.push((id, outstanding));
                continue;
            }
            let mut closed = self.sessions.remove(&id);
            for lane in self.lanes.iter_mut() {
                if matches!(lane, Lane::Parked(p) if p.session_id == id) {
                    *lane = Lane::Idle;
                    closed = true;
                }
            }
            self.metrics.sessions_closed += closed as u64;
        }
        self.pending_closes = remaining;
    }

    /// Run until every submitted request has finished; returns all responses.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.idle() {
            self.tick()?;
        }
        Ok(self.take_responses())
    }

    /// Scheduling ticks executed so far.
    pub fn ticks(&self) -> u64 {
        self.tick_no
    }

    /// One scheduling step. Returns false when there was nothing to do
    /// (no backend step was issued — `run_to_completion` must never spin
    /// on no-op ticks).
    pub fn tick(&mut self) -> Result<bool> {
        let t0 = Instant::now();
        if self.in_flight.is_some() {
            // overlap window: every piece of host work that does not
            // depend on the in-flight step's outputs runs while the
            // device executes it — deferred eager-park snapshots, then
            // admission (whose batched `swap_lanes` chains behind the
            // step on the device timeline).  Lanes the window seats are
            // invisible to the in-flight step (its chunk_info was
            // captured at submit), and per-session turn order holds
            // because in-flight turns keep their lanes Busy.
            let w0 = Instant::now();
            self.drain_chained_swaps()?;
            self.admit_waiting()?;
            self.obs.journal.note_overlap(w0.elapsed().as_nanos() as u64);
        }
        self.complete_in_flight()?;
        self.process_pending_closes();
        // late admission pass: lanes freed by the postprocess above
        self.admit_waiting()?;
        self.tick_no += 1;
        let any_prefill = self.lanes.iter().any(|l| match l {
            Lane::Busy(s) => self.cfg.chunked_prefill && s.fed < s.prompt.len(),
            _ => false,
        });
        let any_decode = self.lanes.iter().any(|l| match l {
            Lane::Busy(s) => !self.cfg.chunked_prefill || s.fed >= s.prompt.len(),
            _ => false,
        });
        // Fused tick: when decoders and mid-prefill lanes coexist, plan one
        // mixed step for both — no prefill/decode head-of-line blocking.
        // The backend realizes the plan through whatever graphs it has
        // (fused mixed graph, or per-kind calls on legacy artifacts);
        // retrieval's re-injections ride the plan's inject operands, so no
        // policy forces the alternating phases any more.
        let fuse = self.cfg.mixed_ticks
            && self.cfg.chunked_prefill
            && any_prefill
            && any_decode;
        let worked = if fuse {
            self.submit_tick(TickKind::Fused)?
        } else if any_prefill && (self.cfg.prefill_priority || !any_decode) {
            self.submit_tick(TickKind::Prefill)?
        } else if any_decode || any_prefill {
            self.submit_tick(TickKind::Decode)?
        } else {
            false
        };
        if worked {
            if !self.cfg.pipeline {
                // serial loop: resolve the step before the tick returns
                self.complete_in_flight()?;
                self.process_pending_closes();
            }
        } else {
            // nothing submitted: no later overlap window will flush these
            self.drain_chained_swaps()?;
        }
        // device-idle accounting: a runnable tick that issued no backend
        // step is a host gap (structurally zero on both loop shapes)
        self.obs.journal.note_host_gap(
            any_prefill || any_decode, worked,
            (t0.elapsed().as_secs_f64() * 1e6) as u64);
        Ok(worked)
    }

    /// Session-aware admission, batched.  Plan every placement first —
    /// waiting requests in FIFO order, skipping turns whose session is
    /// already decoding or already planned; per request prefer the lane
    /// where its session is parked (in-place resume), else any idle lane,
    /// else the least-recently-used parked lane — then execute EVERY
    /// residency change (preempt-to-store, load-from-store) as one batched
    /// `swap_lanes` call, and finally seat the requests.  Preempting and
    /// restoring N lanes costs N lane-sized transfers in one backend call.
    /// A turn whose own parked lane was claimed earlier in the same round
    /// chases its snapshot through a second, chained swap instead of
    /// deferring a tick.
    fn admit_waiting(&mut self) -> Result<()> {
        if self.queue.is_empty() {
            return Ok(()); // steady-state decode: stay allocation-free
        }
        // --- plan -------------------------------------------------------
        let mut avail: Vec<LaneAvail> =
            self.lanes.iter().map(LaneAvail::of).collect();
        let mut busy_sessions: Vec<String> = self
            .lanes
            .iter()
            .filter_map(|l| match l {
                Lane::Busy(s) => s.session.clone(),
                _ => None,
            })
            .collect();
        let mut placements: Vec<(usize, usize)> = Vec::new(); // (lane, q idx)
        let mut evict: Vec<usize> = Vec::new();
        let mut chased: Vec<usize> = Vec::new(); // q idxs chasing a snapshot
        for qi in 0..self.queue.len() {
            // staticcheck: allow(panic-path, qi ranges over queue.len() with no removals in the scan)
            let req = self.queue.get(qi).expect("index in range");
            let sid = req.session.clone();
            if let Some(s) = sid.as_deref() {
                // per-session turn order: one in flight at a time
                if busy_sessions.iter().any(|x| x == s) {
                    continue;
                }
            }
            let own_parked = sid.as_deref().and_then(|s| {
                self.lanes.iter().position(
                    |l| matches!(l, Lane::Parked(p) if p.session_id == s))
            });
            // its retained lane was claimed earlier in this plan: the
            // snapshot reaches the host store with this round's batched
            // swap-out, so the turn *chases* it — seat it on another lane
            // and pull the snapshot back in a second, chained swap (this
            // used to defer the turn a full tick)
            let chase = own_parked
                .map_or(false, |i| avail[i] != LaneAvail::Parked);
            let lane_idx = (if chase { None } else { own_parked })
                .or_else(|| avail.iter().position(|&a| a == LaneAvail::Free))
                .or_else(|| self.lru_parked_lane(&avail));
            let Some(lane_idx) = lane_idx else {
                break; // every lane is decoding (head-of-line wait)
            };
            if own_parked != Some(lane_idx)
                && avail[lane_idx] == LaneAvail::Parked
            {
                evict.push(lane_idx);
            }
            avail[lane_idx] = LaneAvail::Claimed;
            if chase {
                chased.push(qi);
            }
            placements.push((lane_idx, qi));
            if let Some(s) = sid {
                busy_sessions.push(s);
            }
        }
        if placements.is_empty() {
            return Ok(());
        }
        // --- execute all residency changes in one batched swap ----------
        let load: Vec<(usize, String)> = placements
            .iter()
            .filter_map(|&(lane, qi)| {
                let sid = self.queue.get(qi)?.session.as_deref()?;
                if matches!(&self.lanes[lane],
                            Lane::Parked(p) if p.session_id == sid)
                {
                    return None; // in-place resume: no transfer at all
                }
                self.sessions.contains(sid).then(|| (lane, sid.to_string()))
            })
            .collect();
        let loaded = self.execute_swap(&evict, &load)?;
        self.metrics.preemptions += evict.len() as u64;
        let mut loaded_by_lane: std::collections::BTreeMap<usize, SessionSnapshot> =
            load.iter().map(|&(lane, _)| lane).zip(loaded).collect();
        // chased turns: their snapshots entered the store with the swap
        // above; pull them back through a second, chained swap.  (Under
        // capacity pressure the store may have LRU-dropped one already —
        // that turn then starts a fresh conversation, the documented drop
        // semantic, so the filter below is load-bearing.)
        if !chased.is_empty() {
            let chase: Vec<(usize, String)> = placements
                .iter()
                .filter(|(_, qi)| chased.contains(qi))
                .filter_map(|&(lane, qi)| {
                    let sid = self.queue.get(qi)?.session.as_deref()?;
                    self.sessions.contains(sid)
                        .then(|| (lane, sid.to_string()))
                })
                .collect();
            let chase_loaded = self.execute_swap(&[], &chase)?;
            loaded_by_lane
                .extend(chase.iter().map(|&(lane, _)| lane).zip(chase_loaded));
        }
        // --- seat the requests ------------------------------------------
        // pop planned requests in descending queue order (indices stay
        // valid), then place
        let mut seats: Vec<(usize, Request)> = Vec::with_capacity(placements.len());
        placements.sort_by_key(|&(_, qi)| std::cmp::Reverse(qi));
        for (lane_idx, qi) in placements {
            // staticcheck: allow(panic-path, placements hold distinct indices popped in descending order)
            let req = self.queue.take(qi).expect("planned index");
            seats.push((lane_idx, req));
        }
        // shared-prefix consult: fresh one-shot placements look up the
        // store, and every matched lane's slab uploads in ONE batched
        // seeding call (session turns resume their own retained state and
        // never consult the store)
        let mut prefix_hits: std::collections::BTreeMap<usize, Arc<PrefixPayload>> =
            std::collections::BTreeMap::new();
        if let Some(store) = self.prefix.clone() {
            for (lane_idx, req) in &seats {
                if req.session.is_none() && !loaded_by_lane.contains_key(lane_idx) {
                    if let Some(p) = store.lookup(&self.prefix_fp, &req.prompt) {
                        prefix_hits.insert(*lane_idx, p);
                    }
                }
            }
            if !prefix_hits.is_empty() {
                let seeds: Vec<(usize, &LaneKv)> = prefix_hits
                    .iter()
                    .map(|(&lane, p)| (lane, &p.kv))
                    .collect();
                self.backend.swap_lanes(&[], &seeds)?;
            }
        }
        for (lane_idx, req) in seats {
            let snap = loaded_by_lane.remove(&lane_idx);
            let hit = prefix_hits.remove(&lane_idx);
            self.place(lane_idx, req, snap, hit)?;
        }
        Ok(())
    }

    /// Least-recently-parked lane still available to the planner
    /// (preemption victim), preferring sessions with no queued turn —
    /// preempting a session that is about to resume would pay a swap-out
    /// plus an immediate swap-in for nothing.
    fn lru_parked_lane(&self, avail: &[LaneAvail]) -> Option<usize> {
        let pick = |idle_only: bool| {
            self.lanes
                .iter()
                .enumerate()
                .filter(|&(i, _)| avail[i] == LaneAvail::Parked)
                .filter_map(|(i, l)| match l {
                    Lane::Parked(p)
                        if !idle_only
                            || self.queue.session_count(&p.session_id) == 0 =>
                    {
                        Some((i, p.snap.last_used))
                    }
                    _ => None,
                })
                .min_by_key(|&(_, t)| t)
                .map(|(i, _)| i)
        };
        pick(true).or_else(|| pick(false))
    }

    /// Execute one batched lane-residency change: snapshot every `evict`ed
    /// parked lane into the host store and load every `(lane, session)` of
    /// `load` out of it, all through a single `ModelBackend::swap_lanes`
    /// call.  Returns the loaded snapshots in `load` order.
    ///
    /// Failure safety: slabs are uploaded from borrowed store snapshots and
    /// only *taken* after the backend call succeeds, and parked lanes are
    /// only vacated after their download is in hand — a backend error
    /// leaves every session exactly where it was.
    fn execute_swap(&mut self, evict: &[usize], load: &[(usize, String)])
        -> Result<Vec<SessionSnapshot>> {
        if evict.is_empty() && load.is_empty() {
            return Ok(Vec::new());
        }
        let span = self.obs.journal.now_us();
        let t0 = Instant::now();
        let downloaded = {
            let Engine { backend, sessions, .. } = self;
            let mut inn: Vec<(usize, &LaneKv)> = Vec::with_capacity(load.len());
            for (lane, sid) in load {
                let snap = sessions
                    .get(sid)
                    .with_context(|| format!("session {sid} not in store"))?;
                inn.push((*lane, &snap.kv));
            }
            backend.swap_lanes(evict, &inn)?
        };
        let us = t0.elapsed().as_secs_f64() * 1e6;
        // commit loads first: take them out of the store before the evicted
        // snapshots are inserted (an insert may LRU-drop the coldest entry)
        let mut loaded = Vec::with_capacity(load.len());
        for (_, sid) in load {
            // staticcheck: allow(panic-path, load list built from sessions present in the store this tick)
            loaded.push(self.sessions.take(sid).expect("present above"));
        }
        for (&lane_idx, kv) in evict.iter().zip(downloaded) {
            let lane = std::mem::replace(&mut self.lanes[lane_idx], Lane::Idle);
            let Lane::Parked(p) = lane else {
                anyhow::bail!("swap-out of lane {lane_idx} which is not parked");
            };
            let ParkedSession { session_id, mut snap } = *p;
            snap.kv = kv;
            let dropped = self.sessions.insert(session_id, snap);
            self.metrics.sessions_dropped += dropped as u64;
        }
        if !evict.is_empty() {
            self.metrics.swap_out_us.push(us);
            self.metrics.swap_outs += evict.len() as u64;
        }
        if !load.is_empty() {
            self.metrics.swap_in_us.push(us);
            self.metrics.swap_ins += load.len() as u64;
        }
        self.metrics.swap_batches += 1;
        // batches issued while a step was in flight rode an overlap
        // window — the deterministic overlap measure the bench gates on
        self.metrics.swap_batches_overlapped += self.in_flight.is_some() as u64;
        self.obs.journal.record(self.tick_no, Phase::Swap, "swap",
                                (evict.len() + load.len()) as u32, span);
        Ok(loaded)
    }

    /// Seat a request on `lane_idx`.  `loaded` carries its session's
    /// snapshot when the batched swap just pulled it from the host store;
    /// otherwise the lane is idle, or parked on the request's own session
    /// (in-place resume).  `prefix` carries a shared-prefix store hit whose
    /// slab the batched seeding call just uploaded to this lane.
    fn place(&mut self, lane_idx: usize, req: Request,
             loaded: Option<SessionSnapshot>,
             prefix: Option<Arc<PrefixPayload>>) -> Result<()> {
        let record_gates = self.record_gates;
        if let Some(snap) = loaded {
            // swapped in from the host store: slabs are already on the
            // lane, the mask region must rebuild from the snapshot's tables
            self.valid.mark_dirty(lane_idx);
            self.lanes[lane_idx] =
                Lane::Busy(Box::new(SeqState::resume(req, snap, record_gates)));
            return Ok(());
        }
        if let Some(sid) = req.session.as_deref() {
            // in-place resume: previous turn still parked on this lane —
            // cache, device slabs AND mask region are all still valid
            if matches!(&self.lanes[lane_idx],
                        Lane::Parked(p) if p.session_id == sid)
            {
                let Lane::Parked(p) =
                    std::mem::replace(&mut self.lanes[lane_idx], Lane::Idle)
                else {
                    // staticcheck: allow(panic-path, the matches! guard above proves this lane is Parked)
                    unreachable!("checked above");
                };
                self.metrics.resumes_in_place += 1;
                self.lanes[lane_idx] = Lane::Busy(Box::new(SeqState::resume(
                    req, p.snap, record_gates,
                )));
                return Ok(());
            }
            self.metrics.sessions_opened += 1;
        }
        // prefix-store hit: the shared slab is already uploaded; clone the
        // frozen slot tables and resume past the prefix — only the prompt
        // tail will prefill
        if let Some(payload) = prefix {
            self.valid.mark_dirty(lane_idx);
            self.lanes[lane_idx] = Lane::Busy(Box::new(
                SeqState::from_prefix(req, payload, record_gates)));
            return Ok(());
        }
        // fresh sequence on a clean slot table (device garbage in dead
        // slots is masked once the lane's mask region refreshes)
        let dims = self.backend.dims();
        let slots = self.backend.slots();
        let cache = LaneCache::with_mirrors(&dims, slots,
                                            self.policy.needs_keys(),
                                            self.policy.is_retrieval());
        self.valid.mark_dirty(lane_idx);
        self.lanes[lane_idx] =
            Lane::Busy(Box::new(SeqState::fresh(req, cache, record_gates)));
        Ok(())
    }

    // -----------------------------------------------------------------
    // the unified step pipeline: plan -> assemble -> submit ... wait ->
    // postprocess (the wait half lives in `complete_in_flight`)
    // -----------------------------------------------------------------
    /// Plan, assemble and SUBMIT one scheduling step of the given kind.
    /// Returns false when no lane had work (no backend call was issued —
    /// `run_to_completion` must never spin on no-op ticks).
    ///
    /// The pipeline is identical for every phase: `plan::assign_ops` gives
    /// each lane a [`LaneOp`], the assembly loop fills the current side of
    /// the double-buffered fused scratch (applying pending retrieval
    /// injections, which upgrades a lane's op to `Inject`), and ONE
    /// `ModelBackend::submit` call enqueues the plan.  The matching wait
    /// and [`postprocess_lane`] sweep run in [`Self::complete_in_flight`] —
    /// immediately on the serial loop, a tick later on the pipelined one.
    fn submit_tick(&mut self, kind: TickKind) -> Result<bool> {
        let dims = self.backend.dims();
        let (l, b, h, m, c) = (dims.layers, self.backend.batch(), dims.hkv,
                               self.backend.slots(), self.backend.chunk());
        let trash = (m - 1) as i32;
        let kind_label = match kind {
            TickKind::Decode => "decode",
            TickKind::Prefill => "chunk",
            TickKind::Fused => "mixed",
        };
        let mut span = self.obs.journal.now_us();

        // --- plan --------------------------------------------------------
        self.dbufs.cur_mut().reset(trash);
        let n_active = {
            let Engine { lanes, dbufs, cfg, .. } = self;
            assign_ops(lanes, kind, cfg.chunked_prefill,
                       cfg.tick_token_budget, c, &mut dbufs.cur_mut().ops)
        };
        if n_active == 0 {
            return Ok(false);
        }
        span = self.obs.journal.record(self.tick_no, Phase::Plan, kind_label,
                                       n_active as u32, span);

        // --- assemble ----------------------------------------------------
        // per lane: (real_c, flat [l*h, real_c] chosen-slot table); decode
        // lanes use real_c = 1 (one flat Vec per lane, not one per head —
        // steady-state decode stays off the allocator's hot path)
        let mut chunk_info: Vec<Option<(usize, Vec<usize>)>> = vec![None; b];
        let mut any_inject = false;
        {
        let Engine { lanes, dbufs, valid, metrics, .. } = self;
        let bufs = dbufs.cur_mut();
        for (lane_idx, lane) in lanes.iter_mut().enumerate() {
            let Lane::Busy(seq) = lane else { continue };
            let op = bufs.ops[lane_idx];
            if !op.is_active() {
                continue;
            }
            // rebuild this lane's mask region only if its occupant changed
            valid.sync(lane_idx, &seq.cache);
            if op.is_decode() {
                bufs.tokens[lane_idx * c] = seq.stream_token(seq.fed) as i32;
                bufs.pos[lane_idx * c] = seq.fed as i32;
                bufs.in_mask[lane_idx * c] = 1.0;
                let mut injected = 0usize;
                let mut per_head = Vec::with_capacity(l * h);
                for li in 0..l {
                    for hi in 0..h {
                        let flat = li * h + hi;
                        let base = (li * b + lane_idx) * h + hi;
                        // apply pending retrieval injections: mark live
                        // *before* the call (the graph writes inject k/v
                        // ahead of attention)
                        if let Some((slot, me)) = seq.inject.plans[flat].take() {
                            bufs.inject_flag[base] = 1.0;
                            bufs.inject_slot[base] = slot as i32;
                            let kb = base * dims.dh;
                            bufs.inject_k[kb..kb + dims.dh]
                                .copy_from_slice(&me.key);
                            bufs.inject_v[kb..kb + dims.dh]
                                .copy_from_slice(&me.val);
                            seq.cache.head_mut(li, hi).insert_kv(
                                slot, me.entry, Some(&me.key), Some(&me.val));
                            valid.set(lane_idx, li, hi, slot, true);
                            injected += 1;
                            metrics.injections += 1;
                        }
                        let head = seq.cache.head(li, hi);
                        let slot = head
                            .free_slot()
                            .context("no free slot (arena invariant broken)")?;
                        bufs.write_slots[base * c] = slot as i32;
                        per_head.push(slot);
                    }
                }
                if injected > 0 {
                    bufs.ops[lane_idx] = LaneOp::Inject { slots: injected };
                    any_inject = true;
                }
                chunk_info[lane_idx] = Some((1, per_head));
            } else if let LaneOp::Chunk { tokens: real_c } = op {
                let start = seq.fed;
                for ci in 0..real_c {
                    bufs.tokens[lane_idx * c + ci] =
                        seq.prompt[start + ci] as i32;
                    bufs.pos[lane_idx * c + ci] = (start + ci) as i32;
                    bufs.in_mask[lane_idx * c + ci] = 1.0;
                }
                let mut per_head = Vec::with_capacity(l * h * real_c);
                for li in 0..l {
                    for hi in 0..h {
                        let head = seq.cache.head(li, hi);
                        // first real_c free slots for this chunk
                        let before = per_head.len();
                        per_head.extend(
                            (0..m - 1).filter(|&s| !head.live[s]).take(real_c));
                        ensure!(per_head.len() - before == real_c,
                                "chunk needs {real_c} free slots, found {}",
                                per_head.len() - before);
                        let base = ((li * b + lane_idx) * h + hi) * c;
                        for ci in 0..real_c {
                            bufs.write_slots[base + ci] =
                                per_head[before + ci] as i32;
                        }
                    }
                }
                chunk_info[lane_idx] = Some((real_c, per_head));
            }
        }
        }

        self.obs.journal.record(self.tick_no, Phase::Assemble, kind_label,
                                n_active as u32, span);

        // --- submit ------------------------------------------------------
        // the backend fully consumes the plan's borrowed buffers before
        // returning (pipelining contract), so the double buffer may flip
        // and host state may mutate while the step runs
        let want_attn = self.policy.needs_attention() || self.record_gates;
        let want_kv = self.policy.needs_keys();
        let t0 = Instant::now();
        let token = {
            let Engine { backend, dbufs, valid, .. } = self;
            let plan = dbufs.cur().as_plan(valid.as_slice(), any_inject,
                                           want_attn, want_kv);
            backend.submit(&plan)?
        };
        let exec_span = self.obs.journal.begin_span(
            self.tick_no, Phase::Execute, kind_label, n_active as u32,
            TID_DEVICE);
        self.metrics.lane_occupancy.push(n_active as f64);
        match kind {
            TickKind::Decode => self.metrics.decode_steps += 1,
            TickKind::Prefill => self.metrics.prefill_chunks += 1,
            TickKind::Fused => {
                let n_dec = self.dbufs.cur().ops.iter()
                    .filter(|o| o.is_decode()).count();
                self.metrics.mixed_steps += 1;
                self.metrics.mixed_decode_lanes.push(n_dec as f64);
                self.metrics.mixed_chunk_lanes
                    .push((n_active - n_dec) as f64);
                self.metrics.mixed_inject_steps += any_inject as u64;
            }
        }
        let buf = self.dbufs.flip();
        self.in_flight = Some(InFlight {
            token,
            tick_no: self.tick_no,
            kind,
            kind_label,
            buf,
            chunk_info,
            want_attn,
            want_kv,
            n_active,
            t0,
            exec_span,
        });
        Ok(true)
    }

    /// The wait half of the step pipeline: block on the in-flight step (a
    /// no-op when none is), close its device Execute span, and run the
    /// shared per-lane postprocess sweep against the retired side of the
    /// double buffer.  Lanes seated after the submit (overlap-window
    /// admission) have no `chunk_info` entry and are skipped untouched.
    fn complete_in_flight(&mut self) -> Result<()> {
        let Some(fl) = self.in_flight.take() else { return Ok(()) };
        let out = self.backend.wait(fl.token)?;
        self.obs.journal.end_span(fl.exec_span);
        self.metrics.step_us.push(fl.t0.elapsed().as_secs_f64() * 1e6);
        let span = self.obs.journal.now_us();

        // --- postprocess (ONE shared per-lane helper) --------------------
        let dims = self.backend.dims();
        let (b, m) = (self.backend.batch(), self.backend.slots());
        let chunk_c = self.backend.chunk();
        let fused = fl.kind == TickKind::Fused;
        let budget = self.cfg.budget;
        let eos_token = self.eos_token;
        let mut chunk_info = fl.chunk_info;
        let mut finished: Vec<usize> = Vec::new();
        let Engine { lanes, policy, valid, metrics, sampler, dbufs, obs, .. } =
            self;
        let bufs = dbufs.get(fl.buf);
        for (lane_idx, lane) in lanes.iter_mut().enumerate() {
            let Lane::Busy(seq) = lane else { continue };
            let Some((real_c, per_head)) = chunk_info[lane_idx].take() else {
                continue;
            };
            let done = postprocess_lane(
                seq, lane_idx, bufs.ops[lane_idx], real_c, &per_head, &out,
                &dims, b, m, budget, chunk_c, fused, fl.want_attn, fl.want_kv,
                policy, valid, metrics, sampler, &mut obs.retention, eos_token,
                fl.tick_no)?;
            if done {
                finished.push(lane_idx);
            }
        }
        obs.journal.record(fl.tick_no, Phase::Postprocess, fl.kind_label,
                           fl.n_active as u32, span);
        // publish completed prefixes before `finish_lanes` vacates any lane
        // that reached a boundary on its final step — and before the next
        // tick submits, so the downloaded slab is exactly the boundary state
        self.publish_prefixes()?;
        self.finish_lanes(finished)?;
        self.process_pending_closes();
        Ok(())
    }

    /// Offer every fresh one-shot lane that just reached a prefix boundary
    /// back to the shared store: the lane's state at `fed` is a pure
    /// function of its first `fed` tokens exactly when the canonical flag
    /// held (full backend chunks from an aligned start — or token-by-token
    /// prefill) and decoding has not started (`fed <= prompt.len()`), so
    /// the frozen tables plus the slab download reproduce it verbatim for
    /// any later prompt sharing those tokens.  All downloads ride one
    /// batched `swap_lanes` call, which never vacates a lane.
    fn publish_prefixes(&mut self) -> Result<()> {
        let Some(store) = self.prefix.clone() else { return Ok(()) };
        let chunk = store.chunk();
        // chunked prefill advances in backend-chunk steps: boundaries are
        // hit exactly only when the store granularity is a multiple of it
        if self.cfg.chunked_prefill && chunk % self.backend.chunk() != 0 {
            return Ok(());
        }
        let mut pull: Vec<usize> = Vec::new();
        for (idx, lane) in self.lanes.iter_mut().enumerate() {
            let Lane::Busy(seq) = lane else { continue };
            if seq.session.is_some() || !seq.prefix_canon {
                continue; // session turns break chunk alignment; see lanes.rs
            }
            let fed = seq.fed;
            if fed == 0 || fed % chunk != 0 || fed > seq.prompt.len()
                || fed <= seq.prefix_published
            {
                continue;
            }
            seq.prefix_published = fed; // this boundary is handled either way
            if store.has(&self.prefix_fp, &seq.prompt[..fed]) {
                continue;
            }
            pull.push(idx);
        }
        if pull.is_empty() {
            return Ok(());
        }
        let slabs = self.backend.swap_lanes(&pull, &[])?;
        for (idx, kv) in pull.into_iter().zip(slabs) {
            let Lane::Busy(seq) = &self.lanes[idx] else { continue };
            store.insert(PrefixPayload {
                tokens: seq.prompt[..seq.fed].to_vec(),
                kv,
                cache: seq.cache.clone(),
                mirror: seq.mirror.clone(),
                inject: seq.inject.plans.clone(),
                fp: self.prefix_fp.clone(),
            });
        }
        Ok(())
    }

    /// Flush deferred eager-park snapshots (queued by `finish_lanes` on
    /// the pipelined loop) in one batched swap.  Lanes whose occupant
    /// changed since parking are skipped — an in-place resume or an
    /// admission preemption already resolved them.
    fn drain_chained_swaps(&mut self) -> Result<()> {
        if self.chained_parks.is_empty() {
            return Ok(());
        }
        let mut parked: Vec<usize> = std::mem::take(&mut self.chained_parks)
            .into_iter()
            .filter(|&i| matches!(self.lanes[i], Lane::Parked(_)))
            .collect();
        parked.sort_unstable();
        parked.dedup();
        self.execute_swap(&parked, &[])?;
        Ok(())
    }

    /// Retire the finished sequence on `lane_idx`.  Returns true when the
    /// lane parked a surviving session turn — the caller batches any eager
    /// swap-outs of a tick into one `execute_swap` call.
    fn finish_lane(&mut self, lane_idx: usize) -> Result<bool> {
        let lane = std::mem::replace(&mut self.lanes[lane_idx], Lane::Idle);
        let Lane::Busy(seq) = lane else { return Ok(false) };
        let mut seq = *seq;
        if let Some(rec) = seq.record.take() {
            self.last_record = Some(rec);
        }
        let e2e = seq.t_submit.elapsed().as_secs_f64() * 1e6;
        self.metrics.e2e_us.record_us(e2e);
        self.metrics.requests_finished += 1;
        let finish = if seq.stop_at_eos
            && seq.generated.last() == Some(&self.eos_token)
        {
            FinishReason::Eos
        } else {
            FinishReason::Length
        };
        self.responses.push(Response {
            id: seq.id,
            tag: seq.tag,
            session: seq.session.clone(),
            prompt_len: seq.prompt.len(),
            // only the session-park branch still needs the tokens; the
            // common one-shot path keeps its zero-copy move
            tokens: if seq.session.is_some() {
                seq.generated.clone()
            } else {
                std::mem::take(&mut seq.generated)
            },
            finish,
            ttft_us: seq.ttft_us.unwrap_or(e2e),
            e2e_us: e2e,
        });
        // a finished turn drains one slot of EVERY close barrier on its id
        // (each barrier counted this turn as outstanding at its close time)
        let mut doomed = false;
        if let Some(sid) = seq.session.as_deref() {
            for entry in self
                .pending_closes
                .iter_mut()
                .filter(|(cid, _)| cid == sid)
            {
                if entry.1 > 0 {
                    entry.1 -= 1;
                }
                doomed |= entry.1 == 0;
            }
            if doomed {
                // the barrier drained: drop the retained state right here
                // instead of parking (and possibly eager-swapping) a doomed
                // session — which could LRU-evict an innocent stored one
                self.pending_closes
                    .retain(|(cid, n)| !(cid == sid && *n == 0));
                self.sessions.remove(sid);
                self.metrics.sessions_closed += 1;
            }
        }
        // a surviving session turn retains its cache for the next turn:
        // park on the lane (lazy; eager callers batch the swap-out)
        if !doomed {
            if let Some(sid) = seq.session {
                // un-executed retrieval injections go back to the mirror pool
                for (flat, plan) in seq.inject.plans.iter_mut().enumerate() {
                    if let Some((_, me)) = plan.take() {
                        seq.mirror[flat].push(me);
                    }
                }
                let mut history = seq.prompt;
                history.extend(&seq.generated);
                self.clock += 1;
                self.lanes[lane_idx] = Lane::Parked(Box::new(ParkedSession {
                    session_id: sid,
                    snap: SessionSnapshot {
                        cache: seq.cache,
                        mirror: seq.mirror,
                        kv: LaneKv::default(), // device-resident until swap-out
                        fed: seq.fed,
                        history,
                        turns: seq.turns + 1,
                        last_used: self.clock,
                    },
                }));
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Retire every lane in `finished`; under the eager swap policy, all
    /// freshly parked lanes snapshot to the host store in ONE batched swap
    /// — immediately on the serial loop, deferred to the next tick's
    /// overlap window on the pipelined one (the snapshot transfer then
    /// rides alongside the next step instead of the critical path).
    fn finish_lanes(&mut self, finished: Vec<usize>) -> Result<()> {
        let mut parked: Vec<usize> = Vec::new();
        for lane_idx in finished {
            if self.finish_lane(lane_idx)? {
                parked.push(lane_idx);
            }
        }
        if self.cfg.swap_policy == "eager" {
            if self.cfg.pipeline {
                self.chained_parks.extend(parked);
            } else {
                self.execute_swap(&parked, &[])?;
            }
        }
        Ok(())
    }

    /// Live cache snapshot of a lane for the retention-inspection tooling
    /// (Figs 4/5/13-19): per (layer, head) the live (pos, token, log_beta).
    /// Covers decoding *and* parked lanes (a parked session's retained set
    /// is exactly what its next turn will attend over).
    pub fn retention_snapshot(&self, lane_idx: usize)
        -> Option<Vec<Vec<(i64, u32, f32)>>> {
        let cache = match &self.lanes[lane_idx] {
            Lane::Idle => return None,
            Lane::Busy(seq) => &seq.cache,
            Lane::Parked(p) => &p.snap.cache,
        };
        Some(
            cache
                .heads
                .iter()
                .map(|head| {
                    head.live_slots()
                        .map(|s| {
                            let e = &head.entries[s];
                            (e.pos, e.token, e.log_beta)
                        })
                        .collect()
                })
                .collect(),
        )
    }

    /// Every metric sample — engine counters/series plus the obs plane's
    /// own health counters — rendered as Prometheus-style text (the
    /// `GET /metrics` payload).
    pub fn prometheus_text(&self) -> String {
        let mut samples = self.metrics.samples();
        // per-direction swap wall time straight off the backend's transfer
        // accounting (the engine's swap_out_us/swap_in_us series time the
        // whole batched call; these split download from upload)
        let t = self.backend.swap_traffic();
        samples.push(obs::Sample::counter("trimkv_swap_lane_out_us_total",
                                          (t.out_ns / 1000) as f64));
        samples.push(obs::Sample::counter("trimkv_swap_lane_in_us_total",
                                          (t.in_ns / 1000) as f64));
        // instantaneous occupancy gauges (the router's per-replica load
        // signals when scraped through the group's labeled aggregation)
        let busy = self.lanes.iter()
            .filter(|l| matches!(l, Lane::Busy(_))).count();
        let parked = self.lanes.iter()
            .filter(|l| matches!(l, Lane::Parked(_))).count();
        samples.push(obs::Sample::gauge("trimkv_lanes_busy", busy as f64));
        samples.push(obs::Sample::gauge("trimkv_lanes_parked",
                                        parked as f64));
        samples.push(obs::Sample::gauge("trimkv_queue_depth",
                                        self.queue.len() as f64));
        samples.push(obs::Sample::gauge("trimkv_session_store_size",
                                        self.sessions.len() as f64));
        samples.push(obs::Sample::gauge("trimkv_session_store_bytes",
                                        self.sessions.host_bytes() as f64));
        // a privately owned prefix store renders here; a store shared
        // across an `EngineGroup` is rendered once by the group instead
        if let Some(store) = &self.prefix {
            if !self.prefix_shared {
                samples.extend(store.samples());
            }
        }
        samples.extend(self.obs.samples());
        obs::render_prometheus(&samples)
    }

    /// The flight-recorder journal exported as Chrome-trace JSON
    /// (loadable in chrome://tracing / Perfetto).
    pub fn chrome_trace_json(&self) -> String {
        self.obs.journal.chrome_trace().to_string()
    }

    /// Per-(layer, head) retention-at-eviction report
    /// (the `trimkv inspect --retention` payload).
    pub fn retention_report(&self) -> String {
        self.obs.retention.report()
    }
}

/// THE shared per-lane postprocess: commit one lane's step results to its
/// host slot tables — used identically by decode, prefill and fused ticks
/// (it replaces the three near-identical copies the tick bodies used to
/// carry).  Inserts the new entries, folds attention, enforces the budget
/// (provisional-add-then-evict at the same `now` the alternating paths
/// used: decode ops evict at the fed position, chunk ops past the chunk),
/// mirrors retrieval evictions, plans re-injections, records gate traces,
/// and samples once the prompt is exhausted.  Returns true when the lane's
/// sequence finished (EOS / length).
#[allow(clippy::too_many_arguments)]
fn postprocess_lane(seq: &mut SeqState, lane_idx: usize, op: LaneOp,
                    real_c: usize, per_head: &[usize], out: &StepOut,
                    dims: &ModelDims, b: usize, m: usize, budget: usize,
                    chunk_c: usize, fused: bool, want_attn: bool,
                    want_kv: bool, policy: &mut Policy, valid: &mut ValidMask,
                    metrics: &mut EngineMetrics, sampler: &mut Sampler,
                    retention: &mut RetentionObs,
                    eos_token: u32, tick_no: u64) -> Result<bool> {
    let (l, h, dh) = (dims.layers, dims.hkv, dims.dh);
    let (vocab, cols) = (dims.vocab, out.cols);
    let is_decode = op.is_decode();
    let retrieval = policy.is_retrieval();
    let start = seq.fed;
    // resolved before the slot tables borrow below (chunk ops read their
    // tokens straight off `seq.prompt`, which stays field-disjoint)
    let dec_token = is_decode.then(|| seq.stream_token(start));
    for li in 0..l {
        for hi in 0..h {
            let base = (li * b + lane_idx) * h + hi;
            let head = seq.cache.head_mut(li, hi);
            if is_decode {
                // decode semantics on chunk column 0: insert, then fold
                // the (mode-fused) [M] attention row
                let cb = base * cols;
                let kb = cb * dh;
                let slot = per_head[li * h + hi];
                let entry = SlotEntry {
                    pos: start as i64,
                    // staticcheck: allow(panic-path, decode ops always carry the sampled token)
                    token: dec_token.expect("decode op"),
                    log_beta: out.log_beta[cb],
                    ..Default::default()
                };
                head.insert_kv(
                    slot, entry,
                    want_kv.then(|| &out.k_chunk[kb..kb + dh]).as_deref(),
                    want_kv.then(|| &out.v_chunk[kb..kb + dh]).as_deref());
                valid.set(lane_idx, li, hi, slot, true);
                if want_attn {
                    let arow = &out.attn_slots[base * m..(base + 1) * m];
                    head.update_attention(arow, ATTN_EMA);
                }
            } else {
                // chunk semantics: resident slots absorb the chunk's
                // attention first, then the chunk inserts
                let arow = &out.attn_slots[base * m..(base + 1) * m];
                head.update_attention(arow, ATTN_EMA);
                for ci in 0..real_c {
                    let slot = per_head[(li * h + hi) * real_c + ci];
                    let cb = base * cols + ci;
                    let kb = cb * dh;
                    let entry = SlotEntry {
                        pos: (start + ci) as i64,
                        token: seq.prompt[start + ci],
                        log_beta: out.log_beta[cb],
                        acc_attn: out.attn_chunk[cb],
                        ema_attn: out.attn_chunk[cb] / real_c as f32,
                        last_attn: out.attn_chunk[cb] / real_c as f32,
                    };
                    head.insert_kv(slot, entry,
                                   Some(&out.k_chunk[kb..kb + dh]),
                                   Some(&out.v_chunk[kb..kb + dh]));
                    valid.set(lane_idx, li, hi, slot, true);
                }
            }
            // budget enforcement: provisional add(s), then evict the
            // policy's victims ("compress after each chunk" on chunk ops)
            let now = if is_decode {
                start as i64
            } else {
                (start + real_c) as i64
            };
            while head.used > budget {
                let Some(victim) = policy.select_victim(head, now) else {
                    break;
                };
                if retrieval {
                    let me = MirrorEntry {
                        entry: head.entries[victim],
                        key: head.key(victim).to_vec(),
                        val: head.val(victim).to_vec(),
                    };
                    seq.mirror[li * h + hi].push(me);
                }
                let vpos = head.entries[victim].pos;
                let vbeta = head.entries[victim].log_beta;
                head.evict(victim);
                valid.set(lane_idx, li, hi, victim, false);
                metrics.evictions += 1;
                retention.record_eviction(li, hi, vbeta, now - vpos);
                if let Some(rec) = seq.record.as_mut() {
                    rec.evictions.push((li * h + hi, vpos, now));
                }
            }
            head.check_invariants();
            // retrieval: schedule a re-admission when a mirrored key
            // matches the current decoding direction better than the
            // weakest resident does (decode ops only — chunk ops keep the
            // LocRet protocol and never inject)
            if retrieval && is_decode {
                let kb = base * cols * dh;
                let q_proxy = &out.k_chunk[kb..kb + dh];
                let head = seq.cache.head(li, hi);
                if let Some(plan) = plan_injection(
                    head, &mut seq.mirror[li * h + hi], q_proxy) {
                    seq.inject.plans[li * h + hi] = Some(plan);
                }
            }
        }
    }

    if let Some(rec) = seq.record.as_mut() {
        for ci in 0..real_c {
            rec.tokens.push(match dec_token {
                Some(tok) => tok, // decode op: real_c == 1
                None => seq.prompt[start + ci],
            });
            let mut row = Vec::with_capacity(l * h);
            for li in 0..l {
                for hi in 0..h {
                    row.push(out.log_beta[((li * b + lane_idx) * h + hi)
                                          * cols + ci]);
                }
            }
            rec.log_betas.push(row);
        }
    }
    seq.fed += real_c;
    // shared-prefix canonicality: a budget-truncated mid-prompt chunk makes
    // the eviction history schedule-dependent (each chunk evicts at its own
    // `now`), so the lane's state stops being a pure function of its prefix
    // and must never publish.  Token-by-token prefill and the final partial
    // chunk of the greedy schedule stay canonical.
    if !is_decode && seq.fed < seq.prompt.len() && seq.fed % chunk_c != 0 {
        seq.prefix_canon = false;
    }
    if is_decode {
        metrics.tokens_prefilled += (seq.fed <= seq.prompt.len()) as u64;
    } else {
        metrics.tokens_prefilled += real_c as u64;
        if fused {
            metrics.mixed_chunk_tokens += real_c as u64;
        }
    }
    // logits at the lane's last real column predict stream[fed]; sample
    // once the prompt is exhausted
    if seq.fed >= seq.prompt.len() {
        let lb = (lane_idx * cols + real_c - 1) * vocab;
        let tok = sampler.sample(&out.logits[lb..lb + vocab]) as u32;
        seq.generated.push(tok);
        metrics.tokens_decoded += 1;
        record_token_latency(metrics, seq, tick_no);
        let hit_eos = seq.stop_at_eos && tok == eos_token;
        if hit_eos || seq.generated.len() >= seq.max_new {
            return Ok(true);
        }
    }
    Ok(false)
}

/// Record the latency streams for a freshly sampled token: TTFT on a
/// lane's first token, time-between-tokens (wall time + deterministic tick
/// gap) on every later one.  Shared by all three tick paths so mixed and
/// alternating scheduling report comparable SLO numbers.
fn record_token_latency(metrics: &mut EngineMetrics, seq: &mut SeqState,
                        tick_no: u64) {
    let now = Instant::now();
    if seq.ttft_us.is_none() {
        let us = seq.t_submit.elapsed().as_secs_f64() * 1e6;
        seq.ttft_us = Some(us);
        metrics.ttft_us.record_us(us);
        metrics.ttft_summary_us.push(us);
    } else if let Some(t0) = seq.last_tok_at {
        metrics.tbt_us.push(now.duration_since(t0).as_secs_f64() * 1e6);
        if let Some(t) = seq.last_tok_tick {
            metrics.tbt_ticks.push(tick_no.saturating_sub(t) as f64);
        }
    }
    seq.last_tok_at = Some(now);
    seq.last_tok_tick = Some(tick_no);
}

/// Retrieval re-admission rule: among mirrored (evicted) tokens, find the
/// one whose key best matches the current key direction; if it beats the
/// weakest resident's match, swap them (evict resident now, inject next
/// tick into the freed slot).
fn plan_injection(head: &crate::kvcache::HeadState,
                  mirror: &mut Vec<MirrorEntry>,
                  q_proxy: &[f32]) -> Option<(usize, MirrorEntry)> {
    if mirror.is_empty() || head.used == 0 {
        return None;
    }
    let cos = |a: &[f32], b: &[f32]| -> f32 {
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        dot / (na * nb)
    };
    let (best_idx, best_sim) = mirror
        .iter()
        .enumerate()
        .map(|(i, me)| (i, cos(&me.key, q_proxy)))
        .max_by(|a, b| a.1.total_cmp(&b.1))?;
    let (worst_slot, worst_sim) = head
        .live_slots()
        .map(|s| (s, cos(head.key(s), q_proxy)))
        .min_by(|a, b| a.1.total_cmp(&b.1))?;
    if best_sim > worst_sim + 0.05 {
        let me = mirror.swap_remove(best_idx);
        Some((worst_slot, me))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockBackend;

    fn engine(policy: &str, budget: usize, batch: usize)
        -> Engine<MockBackend> {
        let mut cfg = EngineConfig {
            policy: policy.into(),
            budget,
            batch,
            max_new_tokens: 8,
            chunked_prefill: false,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let backend = MockBackend::new(batch, budget + 4);
        Engine::new(backend, cfg, 2).unwrap()
    }

    #[test]
    fn generates_mock_successor_tokens() {
        let mut e = engine("trimkv", 16, 2);
        e.submit(Request::new(1, vec![1, 10, 20], 4)).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 1);
        // mock emits successor of last fed token each step: 21, 22, 23, 24
        assert_eq!(rs[0].tokens, vec![21, 22, 23, 24]);
        assert_eq!(rs[0].finish, FinishReason::Length);
        assert_eq!(rs[0].prompt_len, 3);
    }

    #[test]
    fn eos_finishes_early() {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let backend = MockBackend::new(1, 20).with_eos_after(5);
        let mut e = Engine::new(backend, cfg, 2).unwrap();
        e.submit(Request::new(7, vec![1, 3, 5], 50)).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs[0].finish, FinishReason::Eos);
        assert_eq!(*rs[0].tokens.last().unwrap(), 2);
        assert!(rs[0].tokens.len() < 50);
    }

    #[test]
    fn prefix_hit_matches_cold_and_prefills_only_the_tail() {
        let cfg = |enabled: bool| EngineConfig {
            policy: "trimkv".into(),
            budget: 24,
            batch: 1,
            chunked_prefill: true,
            prefix_enabled: enabled,
            prefix_chunk_tokens: 16,
            ..Default::default()
        };
        let shared: Vec<u32> = (0..40).map(|i| 50 + i).collect();
        let p1: Vec<u32> = shared.iter().copied().chain([200, 201, 202]).collect();
        let p2: Vec<u32> = shared.iter().copied().chain([300, 301]).collect();
        // cold reference: p2 from token zero, no store
        let mut cold = Engine::new(MockBackend::new(1, 44), cfg(false), 2).unwrap();
        cold.submit(Request::new(1, p2.clone(), 4)).unwrap();
        let cold_toks = cold.run_to_completion().unwrap().pop().unwrap().tokens;
        assert_eq!(cold.metrics.tokens_prefilled, 42);
        // warm: p1 publishes boundaries 16 and 32, then p2 hits at 32
        let mut warm = Engine::new(MockBackend::new(1, 44), cfg(true), 2).unwrap();
        warm.submit(Request::new(1, p1, 4)).unwrap();
        warm.run_to_completion().unwrap();
        warm.submit(Request::new(2, p2, 4)).unwrap();
        let warm_toks = warm.run_to_completion().unwrap().pop().unwrap().tokens;
        assert_eq!(warm_toks, cold_toks);
        // p1 fed 43 tokens cold; p2 prefilled only its 10-token tail
        assert_eq!(warm.metrics.tokens_prefilled, 43 + 10);
        let c = warm.prefix_store().unwrap().counters();
        assert_eq!((c.hits, c.misses, c.inserts), (1, 1, 2));
        assert_eq!(c.prefill_tokens_saved, 32);
        let text = warm.prometheus_text();
        assert!(text.contains("trimkv_prefix_hits_total 1"));
        assert!(text.contains("trimkv_prefix_prefill_tokens_saved_total 32"));
    }

    #[test]
    fn budget_is_never_exceeded() {
        let mut e = engine("trimkv", 8, 1);
        e.submit(Request::new(1, (0..30).map(|i| 32 + i).collect(), 10)).unwrap();
        while !e.idle() {
            e.tick().unwrap();
            if let Lane::Busy(seq) = &e.lanes[0] {
                for head in &seq.cache.heads {
                    assert!(head.used <= 8, "budget exceeded: {}", head.used);
                }
            }
        }
        assert!(e.metrics.evictions > 0);
    }

    #[test]
    fn valid_mask_refreshes_only_on_occupancy_change() {
        // the incremental-mask win: a full lane rewrite happens exactly once
        // per lane occupancy change, never per decode tick
        let mut e = engine("trimkv", 8, 1);
        e.submit(Request::new(1, (0..30).map(|i| 32 + i).collect(), 10)).unwrap();
        e.run_to_completion().unwrap();
        assert!(e.metrics.evictions > 0);
        assert_eq!(e.valid_refreshes(), 1,
                   "steady-state decode must not rebuild the mask");
        // a second one-shot request reuses the lane: exactly one more
        e.submit(Request::new(2, vec![1, 40], 2)).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.valid_refreshes(), 2);
    }

    #[test]
    fn continuous_batching_fills_lanes() {
        let mut e = engine("streaming_llm", 16, 2);
        for i in 0..5 {
            e.submit(Request::new(i, vec![1, 40 + i as u32], 3)).unwrap();
        }
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 5);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        // with 2 lanes and 5 requests, peak occupancy must reach 2
        assert!(e.metrics.lane_occupancy.max() >= 2.0);
    }

    #[test]
    fn chunked_prefill_path_matches_decode_path_token_count() {
        for chunked in [false, true] {
            let cfg = EngineConfig {
                policy: "h2o".into(),
                budget: 24,
                batch: 1,
                chunked_prefill: chunked,
                ..Default::default()
            };
            let backend = MockBackend::new(1, 24 + 20);
            let mut e = Engine::new(backend, cfg, 2).unwrap();
            let prompt: Vec<u32> = (0..37).map(|i| 32 + i).collect();
            e.submit(Request::new(1, prompt, 5)).unwrap();
            let rs = e.run_to_completion().unwrap();
            assert_eq!(rs[0].tokens.len(), 5, "chunked={chunked}");
            if chunked {
                assert!(e.metrics.prefill_chunks >= 2);
            }
        }
    }

    #[test]
    fn fullkv_never_evicts_and_overflows_gracefully() {
        // fullkv with a big enough arena: no evictions
        let cfg = EngineConfig {
            policy: "fullkv".into(),
            budget: 64,
            batch: 1,
            chunked_prefill: false,
            ..Default::default()
        };
        let backend = MockBackend::new(1, 80);
        let mut e = Engine::new(backend, cfg, 2).unwrap();
        e.submit(Request::new(1, (0..40).map(|i| 32 + i).collect(), 8)).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.evictions, 0);
    }

    #[test]
    fn metrics_track_tokens() {
        let mut e = engine("trimkv", 16, 1);
        e.submit(Request::new(1, vec![1, 2, 3, 4, 5], 6)).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.tokens_decoded, 6);
        assert_eq!(e.metrics.tokens_prefilled, 5);
        assert_eq!(e.metrics.requests_finished, 1);
    }

    #[test]
    fn session_second_turn_skips_history() {
        let mut e = engine("trimkv", 16, 1); // lazy swap policy (default)
        let prompt: Vec<u32> = (0..20).map(|i| 32 + i).collect();
        e.submit(Request::new(1, prompt, 2).with_session("s")).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].session.as_deref(), Some("s"));
        let steps_t1 = e.metrics.decode_steps; // 20 prompt + 1 generation tick
        assert!(e.idle(), "parked lane must not keep the engine busy");
        // second turn: only the retained-cache gap is fed, never the history
        e.submit(Request::new(2, vec![60, 61], 2).with_session("s")).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(e.metrics.resumes_in_place, 1);
        assert_eq!(e.metrics.swap_outs, 0, "lazy: turn stays on its lane");
        // an in-place resume keeps the lane's mask region: exactly the one
        // rewrite from the first placement
        assert_eq!(e.valid_refreshes(), 1);
        let t2 = e.metrics.decode_steps - steps_t1;
        assert!(t2 <= 5, "second turn re-prefilled history: {t2} steps");
        // positions continue across turns: newest cached pos > first turn len
        let snap = e.retention_snapshot(0).unwrap();
        let max_pos = snap[0].iter().map(|&(p, _, _)| p).max().unwrap();
        assert!(max_pos >= 21, "cache does not span both turns: {max_pos}");
    }

    #[test]
    fn parked_sessions_are_preempted_under_lane_pressure() {
        let mut e = engine("trimkv", 16, 2);
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![1, 40 + i as u32], 2)
                     .with_session(format!("s{i}")))
                .unwrap();
        }
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 4);
        // 4 sessions over 2 lanes: the early finishers were pushed to host
        assert_eq!(e.metrics.preemptions, 2);
        assert_eq!(e.metrics.swap_outs, 2);
        // ...through ONE batched swap_lanes call, not one per lane
        assert_eq!(e.metrics.swap_batches, 1,
                   "simultaneous preemptions must batch");
        assert_eq!(e.sessions().len(), 2);
        // a swapped-out session's next turn swaps back into a lane
        e.submit(Request::new(10, vec![50], 1).with_session("s0")).unwrap();
        e.run_to_completion().unwrap();
        assert!(e.metrics.swap_ins >= 1, "s0 should return via swap-in");
    }

    #[test]
    fn preemption_traffic_is_o_lane_in_batch() {
        // the acceptance criterion: swapping one lane moves exactly
        // 2 * lane_kv_len() elements, independent of the batch size
        let mut per_batch = Vec::new();
        for batch in [2usize, 8] {
            let mut e = engine("trimkv", 16, batch);
            e.submit(Request::new(1, vec![1, 40], 1).with_session("s")).unwrap();
            e.run_to_completion().unwrap();
            e.flush_sessions().unwrap();
            let t = e.backend().swap_traffic();
            assert_eq!(t.lanes_out, 1);
            assert_eq!(t.elems_out as usize, 2 * e.backend().lane_kv_len());
            per_batch.push(t.elems_out);
        }
        assert_eq!(per_batch[0], per_batch[1],
                   "swap traffic must not scale with batch size");
    }

    #[test]
    fn eager_swap_policy_snapshots_every_turn() {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            swap_policy: "eager".into(),
            ..Default::default()
        };
        let backend = MockBackend::new(1, 20);
        let mut e = Engine::new(backend, cfg, 2).unwrap();
        e.submit(Request::new(1, vec![1, 40, 41], 2).with_session("s")).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.swap_outs, 1);
        {
            let snap = e.sessions().get("s").unwrap();
            assert_eq!(snap.history.len(), 5); // 3 prompt + 2 generated
            assert_eq!(snap.fed, 4);           // last sample never fed
            assert_eq!(snap.turns, 1);
            assert_eq!(snap.kv.k.len(), 4 * 2 * 20 * 32); // [L, H, M, dh]
            assert!(snap.cache.total_live() > 0);
        }
        e.submit(Request::new(2, vec![50], 2).with_session("s")).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.swap_ins, 1);
        assert_eq!(e.metrics.swap_outs, 2);
        assert_eq!(e.sessions().get("s").unwrap().turns, 2);
    }

    #[test]
    fn close_session_drops_state_everywhere() {
        let mut e = engine("trimkv", 16, 1);
        e.submit(Request::new(1, vec![1, 40], 2).with_session("s")).unwrap();
        e.run_to_completion().unwrap();
        assert!(e.retention_snapshot(0).is_some(), "session parked on lane");
        e.close_session("s");
        assert!(e.retention_snapshot(0).is_none());
        assert_eq!(e.sessions().len(), 0);
        assert_eq!(e.metrics.sessions_closed, 1);
        // the id can be reused as a brand-new conversation
        e.submit(Request::new(2, vec![1, 40], 1).with_session("s")).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.sessions_opened, 2);
    }

    #[test]
    fn close_is_deferred_until_turns_drain() {
        let mut e = engine("trimkv", 16, 1);
        e.submit(Request::new(1, vec![1, 40], 2).with_session("s")).unwrap();
        e.close_session("s"); // turn still queued: must not be dropped
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].session.as_deref(), Some("s"));
        // once the turn drained, the close applied
        assert!(e.retention_snapshot(0).is_none());
        assert_eq!(e.sessions().len(), 0);
        assert_eq!(e.metrics.sessions_closed, 1);
    }

    #[test]
    fn close_is_a_barrier_for_later_turns() {
        let mut e = engine("trimkv", 16, 1);
        e.submit(Request::new(1, vec![1, 50], 2).with_session("s")).unwrap();
        e.close_session("s");
        // submitted AFTER the close: must start a brand-new conversation,
        // not resume the doomed cache
        e.submit(Request::new(2, vec![60], 2).with_session("s")).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[1].prompt_len, 1,
                   "post-close turn inherited the closed session's history");
        assert_eq!(rs[1].tokens, vec![61, 62]);
        assert_eq!(e.metrics.sessions_opened, 2);
        assert_eq!(e.metrics.sessions_closed, 1);
    }

    #[test]
    fn flush_sessions_moves_parked_lanes_to_store() {
        let mut e = engine("trimkv", 16, 2);
        e.submit(Request::new(1, vec![1, 40], 1).with_session("a")).unwrap();
        e.submit(Request::new(2, vec![1, 41], 1).with_session("b")).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.sessions().len(), 0); // both parked on lanes
        e.flush_sessions().unwrap();
        assert_eq!(e.sessions().len(), 2);
        assert!(e.sessions().contains("a") && e.sessions().contains("b"));
        assert_eq!(e.metrics.swap_outs, 2);
        assert_eq!(e.metrics.swap_batches, 1, "flush is one batched swap");
        assert!(e.sessions().host_bytes() > 0);
    }

    #[test]
    fn store_lru_drops_over_capacity() {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget: 16,
            batch: 1,
            chunked_prefill: false,
            swap_policy: "eager".into(),
            max_sessions: 1,
            ..Default::default()
        };
        let mut e = Engine::new(MockBackend::new(1, 20), cfg, 2).unwrap();
        e.submit(Request::new(1, vec![1, 40], 1).with_session("a")).unwrap();
        e.run_to_completion().unwrap();
        e.submit(Request::new(2, vec![1, 41], 1).with_session("b")).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.sessions().len(), 1);
        assert!(e.sessions().contains("b"));
        assert_eq!(e.metrics.sessions_dropped, 1);
    }

    #[test]
    fn session_works_with_chunked_prefill() {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget: 24,
            batch: 1,
            chunked_prefill: true,
            ..Default::default()
        };
        // mock chunk = 16 -> slots must cover budget + chunk + 1
        let mut e = Engine::new(MockBackend::new(1, 24 + 20), cfg, 2).unwrap();
        let t1: Vec<u32> = (0..30).map(|i| 32 + i).collect();
        e.submit(Request::new(1, t1, 2).with_session("s")).unwrap();
        e.run_to_completion().unwrap();
        let chunks_t1 = e.metrics.prefill_chunks;
        assert!(chunks_t1 >= 2);
        // the second turn's 20 tokens prefill in fresh chunks from the
        // retained position; history is not re-chunked
        let t2: Vec<u32> = (0..20).map(|i| 40 + i).collect();
        e.submit(Request::new(2, t2, 2).with_session("s")).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs[0].tokens.len(), 2);
        let chunks_t2 = e.metrics.prefill_chunks - chunks_t1;
        assert!(chunks_t2 <= 2, "history re-chunked: {chunks_t2} chunks");
    }

    fn mixed_engine(batch: usize, budget: usize, mixed: bool,
                    prefill_priority: bool, tick_token_budget: usize)
        -> Engine<MockBackend> {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget,
            batch,
            max_new_tokens: 8,
            chunked_prefill: true,
            mixed_ticks: mixed,
            prefill_priority,
            tick_token_budget,
            ..Default::default()
        };
        // slots must cover budget + chunk (16) + 1
        Engine::new(MockBackend::new(batch, budget + 20), cfg, 2).unwrap()
    }

    #[test]
    fn mixed_tick_fuses_decode_and_prefill() {
        let mut e = mixed_engine(2, 16, true, false, 0);
        // lane 0: short prompt -> decoding from tick 2 on
        e.submit(Request::new(0, vec![1, 40], 6)).unwrap();
        // lane 1: long prompt -> 3 chunks of prefill
        e.submit(Request::new(1, (0..40).map(|i| 32 + i).collect(), 2)).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 2);
        assert!(e.metrics.mixed_steps > 0, "contended ticks must fuse");
        assert_eq!(e.metrics.mixed_steps, e.backend().mixed_calls as u64);
        // fused ticks carried both a decoder and a filling lane
        assert!(e.metrics.mixed_decode_lanes.mean() >= 1.0);
        assert!(e.metrics.mixed_chunk_lanes.mean() >= 1.0);
        assert!(e.backend().mixed_chunk_tokens > 0);
        // every lane produced its full output
        let by_id: std::collections::BTreeMap<u64, usize> =
            rs.iter().map(|r| (r.id, r.tokens.len())).collect();
        assert_eq!(by_id[&0], 6);
        assert_eq!(by_id[&1], 2);
    }

    #[test]
    fn mixed_scheduling_never_stalls_decoders() {
        // the acceptance criterion: admitting one long prompt leaves every
        // decoding lane progressing each tick (token gap == 1 tick), where
        // the alternating scheduler stalls decoders for the whole prefill
        for (mixed, priority) in [(true, false), (false, true)] {
            let mut e = mixed_engine(2, 16, mixed, priority, 0);
            e.submit(Request::new(0, vec![1, 40], 20)).unwrap();
            // let lane 0 reach steady decode
            for _ in 0..3 {
                e.tick().unwrap();
            }
            assert!(e.metrics.tokens_decoded >= 2);
            // admit a 4-chunk prompt while lane 0 decodes
            e.submit(Request::new(1, (0..64).map(|i| 32 + i).collect(), 1))
                .unwrap();
            e.run_to_completion().unwrap();
            let max_gap = e.metrics.tbt_ticks.max();
            if mixed {
                assert_eq!(max_gap, 1.0,
                           "mixed tick stalled a decoder: gap {max_gap}");
                assert!(e.metrics.mixed_steps >= 4,
                        "prefill chunks must ride fused ticks");
            } else {
                assert!(max_gap > 1.0,
                        "alternating+prefill_priority should stall \
                         decoders during the 4-chunk prefill");
            }
        }
    }

    #[test]
    fn mixed_tick_respects_token_budget() {
        // budget 2 with one decoder leaves 1 prompt token per fused tick:
        // prefill slows down, decode never pauses
        let mut e = mixed_engine(2, 16, true, false, 2);
        e.submit(Request::new(0, vec![1, 40], 30)).unwrap();
        for _ in 0..3 {
            e.tick().unwrap();
        }
        e.submit(Request::new(1, (0..20).map(|i| 32 + i).collect(), 1))
            .unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.backend().mixed_chunk_tokens, 20,
                   "every prompt token of the admission rode a fused tick");
        assert!(e.metrics.mixed_steps >= 20,
                "token budget 2 must spread the prompt over >= 20 ticks");
        assert_eq!(e.metrics.tbt_ticks.max(), 1.0);
    }

    #[test]
    fn mixed_equals_alternating_token_streams() {
        // same workload, mixed on/off: bit-identical per-request outputs
        // (TRIM-KV scores at creation time; lanes are independent)
        let prompts: Vec<Vec<u32>> = vec![
            vec![1, 40],
            (0..40).map(|i| 32 + i).collect(),
            (0..23).map(|i| 50 + (i % 20)).collect(),
        ];
        let mut outs: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
        for mixed in [true, false] {
            let mut e = mixed_engine(2, 16, mixed, false, 0);
            for (i, p) in prompts.iter().enumerate() {
                e.submit(Request::new(i as u64, p.clone(), 5)).unwrap();
            }
            let mut rs = e.run_to_completion().unwrap();
            rs.sort_by_key(|r| r.id);
            if mixed {
                assert!(e.metrics.mixed_steps > 0);
            } else {
                assert_eq!(e.metrics.mixed_steps, 0);
            }
            outs.push(rs.into_iter().map(|r| (r.id, r.tokens)).collect());
        }
        assert_eq!(outs[0], outs[1],
                   "mixed scheduling changed a token stream");
    }

    #[test]
    fn tick_true_iff_backend_stepped() {
        // the no-op fix: tick() must report work exactly when a backend
        // step was issued, so run_to_completion can never spin
        let mut e = mixed_engine(2, 16, true, false, 0);
        assert!(!e.tick().unwrap(), "idle engine must report no work");
        e.submit(Request::new(0, vec![1, 40, 41], 4)).unwrap();
        e.submit(Request::new(1, (0..20).map(|i| 32 + i).collect(), 3))
            .unwrap();
        let mut worked = 0usize;
        while !e.idle() {
            worked += e.tick().unwrap() as usize;
        }
        let be = e.backend();
        assert_eq!(worked,
                   be.decode_calls + be.prefill_calls + be.mixed_calls,
                   "worked ticks must equal backend steps");
        assert!(!e.tick().unwrap());
    }

    #[test]
    fn retrieval_policy_rides_fused_ticks() {
        // the restriction the step-plan API lifts: retrieval's KV
        // re-injection used to force alternating ticks; now its injections
        // ride the plan's inject operands and contended ticks still fuse
        let cfg = EngineConfig {
            policy: "retrieval".into(),
            budget: 16,
            batch: 2,
            max_new_tokens: 16,
            chunked_prefill: true,
            mixed_ticks: true,
            ..Default::default()
        };
        let mut e = Engine::new(MockBackend::new(2, 16 + 20), cfg, 2).unwrap();
        e.submit(Request::new(0, vec![1, 40], 16)).unwrap();
        for _ in 0..3 {
            e.tick().unwrap();
        }
        // admit a 3-chunk prompt while lane 0 decodes: ticks must fuse
        e.submit(Request::new(1, (0..40).map(|i| 32 + i).collect(), 2))
            .unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 2);
        assert!(e.metrics.mixed_steps > 0,
                "retrieval must no longer force alternating ticks");
        assert_eq!(e.metrics.tbt_ticks.max(), 1.0,
                   "fused retrieval ticks must not stall decoders");
    }

    #[test]
    fn retrieval_injections_reach_the_backend() {
        // every injection the engine plans is applied by the backend in the
        // same step's plan — exact (layer, head)-entry accounting, through
        // decode-only AND fused ticks
        let cfg = EngineConfig {
            policy: "retrieval".into(),
            budget: 8,
            batch: 2,
            chunked_prefill: true,
            mixed_ticks: true,
            ..Default::default()
        };
        let mut e = Engine::new(MockBackend::new(2, 8 + 20), cfg, 2).unwrap();
        e.submit(Request::new(0, (0..30).map(|i| 32 + i).collect(), 20))
            .unwrap();
        e.submit(Request::new(1, (0..25).map(|i| 64 + i).collect(), 4))
            .unwrap();
        e.run_to_completion().unwrap();
        assert!(e.metrics.evictions > 0, "tight budget must evict");
        assert_eq!(e.metrics.injections, e.backend().injected_entries,
                   "planned injections must all reach the backend");
    }

    #[test]
    fn trace_journal_stays_bounded_over_ten_thousand_ticks() {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget: 8,
            batch: 1,
            chunked_prefill: false,
            trace_capacity: 128,
            ..Default::default()
        };
        let mut e = Engine::new(MockBackend::new(1, 12), cfg, 2).unwrap();
        for i in 0..800u64 {
            e.submit(Request::new(i, vec![1, 40], 12)).unwrap();
            e.run_to_completion().unwrap();
        }
        assert!(e.ticks() >= 10_000, "want a 10k-tick run, got {}", e.ticks());
        // the hard cap held over ~4 events per tick, and the overflow was
        // counted, not grown into
        assert_eq!(e.obs.journal.len(), 128);
        assert!(e.obs.journal.dropped() > 0);
        let ts: Vec<u64> = e.obs.journal.events().map(|ev| ev.ts_us).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]),
                "ring iteration must stay chronological after wrap");
    }

    #[test]
    fn chrome_trace_spans_are_valid_and_monotone_per_track() {
        let mut e = mixed_engine(2, 16, true, false, 0);
        e.submit(Request::new(0, vec![1, 40], 6)).unwrap();
        e.submit(Request::new(1, (0..40).map(|i| 32 + i).collect(), 2))
            .unwrap();
        e.run_to_completion().unwrap();
        let text = e.chrome_trace_json();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        // host and device are separate tracks: Execute spans legitimately
        // overlap the next tick's host spans (that IS the pipelining), but
        // within one track spans must never overlap
        let mut prev_end = std::collections::BTreeMap::new();
        let mut cats = std::collections::BTreeSet::new();
        for ev in evs {
            assert_eq!(ev.str_field("ph").unwrap(), "X");
            let tid = ev.get("tid").unwrap().as_f64().unwrap() as u32;
            let ts = ev.get("ts").unwrap().as_f64().unwrap();
            let dur = ev.get("dur").unwrap().as_f64().unwrap();
            let end = prev_end.get(&tid).copied().unwrap_or(0.0);
            assert!(ts >= end, "tid {tid} spans overlap: ts {ts} < end {end}");
            prev_end.insert(tid, ts + dur);
            cats.insert(ev.str_field("cat").unwrap().to_string());
        }
        assert!(cats.contains("mixed"),
                "fused ticks must be labelled mixed, got {cats:?}");
        assert!(prev_end.contains_key(&crate::obs::TID_HOST)
                    && prev_end.contains_key(&crate::obs::TID_DEVICE),
                "want host + device tracks, got {:?}",
                prev_end.keys().collect::<Vec<_>>());
    }

    #[test]
    fn prometheus_text_matches_engine_counters() {
        let mut e = engine("trimkv", 8, 1);
        e.submit(Request::new(1, (0..20).map(|i| 32 + i).collect(), 10))
            .unwrap();
        e.run_to_completion().unwrap();
        let text = e.prometheus_text();
        crate::obs::assert_prometheus_parses(&text);
        let line = |n: &str, v: u64| format!("{n} {v}\n");
        assert!(text.contains(&line("trimkv_tokens_decoded_total",
                                    e.metrics.tokens_decoded)));
        assert!(text.contains(&line("trimkv_evictions_total",
                                    e.metrics.evictions)));
        assert!(text.contains(&line("trimkv_requests_finished_total",
                                    e.metrics.requests_finished)));
        // the obs plane rides the same exposition, and its eviction counter
        // agrees with the engine's
        assert!(text.contains(&line("trimkv_retention_evictions_total",
                                    e.metrics.evictions)));
        assert!(text.contains(&line("trimkv_swap_batches_overlapped_total",
                                    e.metrics.swap_batches_overlapped)));
        // per-direction swap wall time from the backend traffic counters
        assert!(text.contains("trimkv_swap_lane_out_us_total"));
        assert!(text.contains("trimkv_swap_lane_in_us_total"));
        assert!(text.contains("trimkv_overlap_us_total"));
        assert!(text.contains("trimkv_step_us_count"));
        assert!(text.contains("trimkv_ttft_us_bucket{le=\"+Inf\"}"));
    }

    #[test]
    fn host_gap_is_structurally_zero_on_both_loop_shapes() {
        // availability for the step plan is computed after the in-flight
        // step completes, so neither the pipelined loop (default) nor the
        // serial one can leave runnable work unstepped within a tick
        for pipeline in [true, false] {
            let mut e = mixed_engine(2, 16, true, false, 0);
            e.cfg.pipeline = pipeline;
            e.submit(Request::new(0, vec![1, 40], 8)).unwrap();
            e.submit(Request::new(1, (0..30).map(|i| 32 + i).collect(), 4))
                .unwrap();
            e.run_to_completion().unwrap();
            e.tick().unwrap(); // an idle tick is not a gap either
            assert_eq!(e.obs.journal.host_gap_ticks, 0, "pipeline={pipeline}");
            assert_eq!(e.obs.journal.host_gap_us, 0, "pipeline={pipeline}");
        }
    }

    #[test]
    fn pipelined_loop_overlaps_host_work_and_matches_serial_streams() {
        // session churn over 2 lanes with real (synthetic) device latency:
        // the pipelined loop must emit bit-identical streams, keep the
        // host-gap counter at zero, and actually record overlap windows
        let mut outs = Vec::new();
        for pipeline in [true, false] {
            let cfg = EngineConfig {
                policy: "trimkv".into(),
                budget: 16,
                batch: 2,
                chunked_prefill: true,
                mixed_ticks: true,
                swap_policy: "eager".into(),
                pipeline,
                ..Default::default()
            };
            let backend =
                MockBackend::new(2, 16 + 20).with_synthetic_latency_us(200);
            let mut e = Engine::new(backend, cfg, 2).unwrap();
            for i in 0..5u64 {
                let p: Vec<u32> = (0..(5 + 7 * i as usize))
                    .map(|j| 32 + j as u32)
                    .collect();
                e.submit(Request::new(i, p, 4)
                         .with_session(format!("s{}", i % 3)))
                    .unwrap();
            }
            let mut rs = e.run_to_completion().unwrap();
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs.len(), 5);
            if pipeline {
                assert_eq!(e.obs.journal.host_gap_ticks, 0);
                assert!(e.obs.journal.overlap_ns > 0,
                        "pipelined run must record overlap windows");
            }
            outs.push(rs.into_iter()
                      .map(|r| (r.id, r.tokens))
                      .collect::<Vec<_>>());
        }
        assert_eq!(outs[0], outs[1], "pipelining changed a token stream");
    }

    #[test]
    fn chained_eager_snapshot_rides_the_overlap_window() {
        // an eager park that happens while another lane keeps decoding is
        // deferred into the next overlap window, so its swap-out transfers
        // while a step is in flight instead of stalling the tick
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget: 16,
            batch: 2,
            chunked_prefill: false,
            swap_policy: "eager".into(),
            ..Default::default() // pipeline defaults to on
        };
        let mut e = Engine::new(MockBackend::new(2, 36), cfg, 2).unwrap();
        let long: Vec<u32> = (0..10).map(|i| 32 + i).collect();
        e.submit(Request::new(1, long, 2).with_session("x")).unwrap();
        e.submit(Request::new(2, vec![1, 40], 2).with_session("y")).unwrap();
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(e.metrics.swap_outs, 2, "eager: both turns snapshot");
        assert!(e.metrics.swap_batches_overlapped >= 1,
                "the early finisher's snapshot must ride an overlap window");
        assert_eq!(e.sessions().len(), 2);
        assert!(e.idle(), "chained snapshots must all drain by idle");
    }

    #[test]
    fn same_round_lane_claim_chases_the_snapshot() {
        // regression for the carried admission bug: a turn whose session's
        // parked lane is claimed by an earlier request in the SAME round
        // used to defer a full tick — it must now seat in that round, with
        // its snapshot pulled back through the chained chase swap
        let mut e = engine("trimkv", 16, 2); // lazy swap policy
        e.submit(Request::new(1, vec![1, 40], 2).with_session("a")).unwrap();
        e.run_to_completion().unwrap();
        e.submit(Request::new(2, vec![1, 41], 2).with_session("b")).unwrap();
        e.run_to_completion().unwrap();
        // one round: a fresh request claims "a"'s LRU lane while both
        // sessions have queued turns
        e.submit(Request::new(3, vec![1, 50, 51], 2)).unwrap();
        e.submit(Request::new(4, vec![60], 2).with_session("a")).unwrap();
        e.submit(Request::new(5, vec![70], 2).with_session("b")).unwrap();
        e.tick().unwrap();
        assert!(matches!(&e.lanes[0], Lane::Busy(s) if s.session.is_none()),
                "the fresh request claims the LRU lane");
        assert!(matches!(&e.lanes[1], Lane::Busy(s)
                         if s.session.as_deref() == Some("a")),
                "a's turn must seat in the same round, not defer a tick");
        assert_eq!(e.metrics.swap_outs, 2, "both parked lanes preempted");
        assert_eq!(e.metrics.swap_ins, 1, "a chased its snapshot back");
        assert_eq!(e.metrics.resumes_in_place, 0);
        let rs = e.run_to_completion().unwrap();
        assert_eq!(rs.len(), 3);
        let mut by_id: Vec<(u64, Vec<u32>)> =
            rs.into_iter().map(|r| (r.id, r.tokens)).collect();
        by_id.sort_by_key(|&(id, _)| id);
        // chased history survives: both dialogues continue their streams
        assert_eq!(by_id[1], (4, vec![61, 62]));
        assert_eq!(by_id[2], (5, vec![71, 72]));
        e.flush_sessions().unwrap(); // lazy: lanes still hold the parks
        assert_eq!(e.sessions().get("a").unwrap().history,
                   vec![1, 40, 41, 42, 60, 61, 62]);
    }

    #[test]
    fn flush_sessions_drains_the_in_flight_step_before_snapshotting() {
        let mut e = engine("trimkv", 16, 1); // lazy, pipeline defaults on
        e.submit(Request::new(1, vec![1, 40], 1).with_session("s")).unwrap();
        assert!(e.tick().unwrap());
        assert!(e.tick().unwrap());
        assert!(e.in_flight.is_some(), "a step must be in flight");
        // the in-flight step samples the final token: flush must resolve
        // it (finish + park) before collecting snapshots
        e.flush_sessions().unwrap();
        assert!(e.in_flight.is_none());
        let snap = e.sessions().get("s").expect("session reaches the store");
        assert_eq!(snap.history, vec![1, 40, 41]);
        assert_eq!(e.take_responses().len(), 1);
        assert!(e.idle());
    }

    #[test]
    fn retention_histograms_cover_every_head_at_eviction() {
        let mut e = engine("trimkv", 8, 1);
        e.submit(Request::new(1, (0..30).map(|i| 32 + i).collect(), 10))
            .unwrap();
        e.run_to_completion().unwrap();
        assert!(e.metrics.evictions > 0);
        assert_eq!(e.obs.retention.total_evictions(), e.metrics.evictions);
        // budget pressure applies per (layer, head): every head evicted
        for li in 0..4 {
            for hi in 0..2 {
                assert!(e.obs.retention.head(li, hi).count > 0,
                        "no evictions recorded for ({li}, {hi})");
            }
        }
        let rep = e.retention_report();
        assert!(rep.contains("signature"));
        assert!(rep.lines().count() >= 4 * 2 + 3);
    }

    #[test]
    fn trace_flag_disables_the_journal_but_not_retention() {
        let cfg = EngineConfig {
            policy: "trimkv".into(),
            budget: 8,
            batch: 1,
            chunked_prefill: false,
            trace: false,
            ..Default::default()
        };
        let mut e = Engine::new(MockBackend::new(1, 12), cfg, 2).unwrap();
        e.submit(Request::new(1, (0..20).map(|i| 32 + i).collect(), 4))
            .unwrap();
        e.run_to_completion().unwrap();
        assert!(e.obs.journal.is_empty());
        assert!(e.obs.retention.total_evictions() > 0);
    }

    #[test]
    fn retention_snapshot_exposes_live_tokens() {
        let mut e = engine("trimkv", 16, 1);
        e.submit(Request::new(1, vec![1, 33, 44], 64)).unwrap();
        // run a few ticks but do not finish
        for _ in 0..5 {
            e.tick().unwrap();
        }
        let snap = e.retention_snapshot(0).unwrap();
        assert_eq!(snap.len(), 4 * 2); // layers * hkv
        assert!(!snap[0].is_empty());
        let (pos0, tok0, lb0) = snap[0][0];
        assert_eq!(pos0, 0);
        assert_eq!(tok0, 1);
        assert!(lb0 < 0.0);
    }
}
