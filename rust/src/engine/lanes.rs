//! Lane-residency machinery for the engine: which sequence occupies each
//! batch lane, parked sessions awaiting their next turn, sequence-state
//! construction/resumption, and the incrementally-maintained validity mask
//! the serving graphs consume.
//!
//! The engine's event loop (`engine::mod`) stays in charge of *when* lanes
//! change hands; this module owns *what* a lane can hold and the
//! device-facing bookkeeping that must stay consistent when it does.

use std::sync::Arc;
use std::time::Instant;

use crate::kvcache::{LaneCache, MirrorEntry};
use crate::model_meta::ModelDims;
use crate::prefixcache::PrefixPayload;
use crate::scheduler::Request;
use crate::session::SessionSnapshot;

use super::SeqRecord;

#[derive(Debug, Clone, Default)]
pub(crate) struct PendingInject {
    /// per (l, h): (slot, mirror entry) scheduled for the next decode tick
    pub plans: Vec<Option<(usize, MirrorEntry)>>,
}

pub(crate) struct SeqState {
    pub id: u64,
    pub tag: String,
    /// conversation this turn belongs to (None: one-shot request)
    pub session: Option<String>,
    /// for session turns, `prompt` is the full fed stream: prior turns +
    /// their replies + this turn's new tokens; `fed` starts past history
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    pub max_new: usize,
    pub stop_at_eos: bool,
    /// tokens fed to the model so far (== position of the next input)
    pub fed: usize,
    /// completed prior turns of this session
    pub turns: u64,
    pub cache: LaneCache,
    pub mirror: Vec<Vec<MirrorEntry>>, // per (l*h); retrieval only
    pub inject: PendingInject,
    /// Prefix-store payload this lane was seeded from; the held `Arc` is the
    /// store's ref-count pin (the entry cannot be evicted while we decode).
    pub prefix_pin: Option<Arc<PrefixPayload>>,
    /// Whether this lane's cache state is still a pure function of its fed
    /// prefix under the canonical chunking schedule (full backend chunks
    /// from an aligned start).  A budget-truncated mid-prompt chunk or a
    /// session resume makes the state schedule-dependent and unpublishable.
    pub prefix_canon: bool,
    /// Largest prefix length already offered to the store (publish dedup).
    pub prefix_published: usize,
    pub t_submit: Instant,
    pub ttft_us: Option<f64>,
    /// wall time of the last sampled token (time-between-tokens metric)
    pub last_tok_at: Option<Instant>,
    /// engine tick of the last sampled token (deterministic stall bound:
    /// under mixed scheduling the gap between tokens is one tick)
    pub last_tok_tick: Option<u64>,
    pub record: Option<SeqRecord>,
}

impl SeqState {
    pub fn stream_token(&self, idx: usize) -> u32 {
        if idx < self.prompt.len() {
            self.prompt[idx]
        } else {
            self.generated[idx - self.prompt.len()]
        }
    }

    /// Fresh sequence on a clean slot table (device garbage in dead slots
    /// is masked by the valid bits once the lane's mask region refreshes).
    pub fn fresh(req: Request, cache: LaneCache, record_gates: bool)
        -> SeqState {
        let nheads = cache.layers * cache.hkv;
        SeqState {
            id: req.id,
            tag: req.tag,
            session: req.session,
            prompt: req.prompt,
            generated: Vec::new(),
            max_new: req.max_new_tokens,
            stop_at_eos: req.stop_at_eos,
            fed: 0,
            turns: 0,
            cache,
            mirror: vec![Vec::new(); nheads],
            inject: PendingInject { plans: vec![None; nheads] },
            prefix_pin: None,
            prefix_canon: true,
            prefix_published: 0,
            t_submit: Instant::now(),
            ttft_us: None,
            last_tok_at: None,
            last_tok_tick: None,
            record: record_gates.then(SeqRecord::default),
        }
    }

    /// Fresh sequence seeded from a shared-prefix store hit: the host slot
    /// tables are cloned from the immutable payload (copy-on-write — this
    /// lane's copy diverges freely), `fed` resumes past the shared prefix so
    /// only the prompt tail is prefilled, and the payload `Arc` is held for
    /// the lane's lifetime as the store's eviction pin.  The matching device
    /// slab upload rides the batched `swap_lanes` seeding call.
    pub fn from_prefix(req: Request, payload: Arc<PrefixPayload>,
                       record_gates: bool) -> SeqState {
        let fed = payload.len();
        debug_assert!(fed < req.prompt.len(), "seeded lane needs a tail");
        SeqState {
            id: req.id,
            tag: req.tag,
            session: req.session,
            prompt: req.prompt,
            generated: Vec::new(),
            max_new: req.max_new_tokens,
            stop_at_eos: req.stop_at_eos,
            fed,
            turns: 0,
            cache: payload.cache.clone(),
            mirror: payload.mirror.clone(),
            inject: PendingInject { plans: payload.inject.clone() },
            prefix_canon: true,
            prefix_published: fed,
            prefix_pin: Some(payload),
            t_submit: Instant::now(),
            ttft_us: None,
            last_tok_at: None,
            last_tok_tick: None,
            record: record_gates.then(SeqRecord::default),
        }
    }

    /// Rebuild a decoding sequence from a retained session: `history`
    /// (every token fed or sampled in prior turns) extends with the new
    /// turn's prompt, and `fed` resumes past the retained prefix — zero
    /// re-prefill.
    pub fn resume(req: Request, snap: SessionSnapshot, record_gates: bool)
        -> SeqState {
        let SessionSnapshot { cache, mirror, fed, mut history, turns, .. } = snap;
        let nheads = cache.layers * cache.hkv;
        history.extend(&req.prompt);
        SeqState {
            id: req.id,
            tag: req.tag,
            session: req.session,
            prompt: history,
            generated: Vec::new(),
            max_new: req.max_new_tokens,
            stop_at_eos: req.stop_at_eos,
            fed,
            turns,
            cache,
            mirror,
            inject: PendingInject { plans: vec![None; nheads] },
            prefix_pin: None,
            // resumed state depends on the turn history, not just a prefix
            prefix_canon: false,
            prefix_published: 0,
            t_submit: Instant::now(),
            ttft_us: None,
            last_tok_at: None,
            last_tok_tick: None,
            record: record_gates.then(SeqRecord::default),
        }
    }
}

/// A finished session turn still occupying its lane: the KV slabs remain
/// device-resident so the session's next turn can resume without any host
/// round-trip.  Preempted (snapshotted to the `SessionStore`) on demand.
pub(crate) struct ParkedSession {
    pub session_id: String,
    /// Retained state; `snap.kv` stays empty while the slabs are
    /// device-resident and is filled by the batched swap-out download.
    /// `snap.last_used` holds the engine clock at park time (LRU
    /// preemption order).
    pub snap: SessionSnapshot,
}

pub(crate) enum Lane {
    Idle,
    Busy(Box<SeqState>),
    Parked(Box<ParkedSession>),
}

/// Lane availability during admission planning: a snapshot of each lane's
/// role that the planner mutates as it claims lanes, so one batched swap
/// can execute every preemption/load at once afterwards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum LaneAvail {
    Busy,
    Free,
    Parked,
    Claimed,
}

impl LaneAvail {
    pub fn of(lane: &Lane) -> LaneAvail {
        match lane {
            Lane::Idle => LaneAvail::Free,
            Lane::Busy(_) => LaneAvail::Busy,
            Lane::Parked(_) => LaneAvail::Parked,
        }
    }
}

/// The flat `[L, B, H, M]` validity mask the graphs consume, maintained
/// incrementally: individual bits flip exactly when the host slot tables
/// change (insert / evict / inject), and a whole lane region is rewritten
/// from its slot tables only when the lane's *occupant* changed (fresh
/// placement, session swap-in) — never once per lane per tick as the old
/// zero-then-rebuild did (O(L*H*M) per active lane per step).
///
/// Regions of idle/parked lanes may hold stale bits between occupants;
/// they are never attended on behalf of an active lane (attention is
/// per-lane) and are fully rewritten before the lane decodes again.
#[derive(Debug)]
pub(crate) struct ValidMask {
    buf: Vec<f32>,
    dirty: Vec<bool>,
    batch: usize,
    hkv: usize,
    slots: usize,
    /// full lane-region rewrites performed (diagnostics: steady-state
    /// decode should add none of these per tick)
    pub refreshes: u64,
}

impl ValidMask {
    pub fn new(dims: &ModelDims, batch: usize, slots: usize) -> ValidMask {
        ValidMask {
            buf: vec![0.0; dims.layers * batch * dims.hkv * slots],
            dirty: vec![true; batch],
            batch,
            hkv: dims.hkv,
            slots,
            refreshes: 0,
        }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf
    }

    /// The lane's occupant changed: rewrite its whole region on next sync.
    pub fn mark_dirty(&mut self, lane: usize) {
        self.dirty[lane] = true;
    }

    /// Rewrite the lane's region from its slot tables if marked dirty.
    pub fn sync(&mut self, lane: usize, cache: &LaneCache) {
        if self.dirty[lane] {
            cache.fill_valid(lane, self.batch, &mut self.buf);
            self.dirty[lane] = false;
            self.refreshes += 1;
        }
    }

    /// Flip one (layer, head, slot) liveness bit of `lane`.
    pub fn set(&mut self, lane: usize, l: usize, h: usize, slot: usize,
               live: bool) {
        let idx = ((l * self.batch + lane) * self.hkv + h) * self.slots + slot;
        self.buf[idx] = if live { 1.0 } else { 0.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SlotEntry;

    fn dims() -> ModelDims {
        ModelDims { vocab: 512, d: 128, layers: 2, hq: 4, hkv: 2, dh: 4,
                    ffn: 256, gate_hidden: 48 }
    }

    #[test]
    fn valid_mask_incremental_matches_full_rebuild() {
        let d = dims();
        let (batch, slots) = (3usize, 6usize);
        let mut cache = LaneCache::new(&d, slots, false);
        let mut mask = ValidMask::new(&d, batch, slots);
        mask.sync(1, &cache); // fresh lane: all-zero region
        assert_eq!(mask.refreshes, 1);
        mask.sync(1, &cache); // clean: no rewrite
        assert_eq!(mask.refreshes, 1);
        // incremental path: insert + set must equal a full rebuild
        cache.head_mut(1, 0).insert(2, SlotEntry::default(), None);
        mask.set(1, 1, 0, 2, true);
        let mut full = vec![0.0; d.layers * batch * d.hkv * slots];
        cache.fill_valid(1, batch, &mut full);
        assert_eq!(mask.as_slice(), &full[..]);
        // evict clears the same bit
        cache.head_mut(1, 0).evict(2);
        mask.set(1, 1, 0, 2, false);
        cache.fill_valid(1, batch, &mut full);
        assert_eq!(mask.as_slice(), &full[..]);
    }

    #[test]
    fn valid_mask_dirty_rewrites_whole_lane_region() {
        let d = dims();
        let (batch, slots) = (2usize, 4usize);
        let mut mask = ValidMask::new(&d, batch, slots);
        // lane 0 carries stale bits from a departed occupant
        mask.set(0, 0, 0, 1, true);
        mask.set(0, 1, 1, 3, true);
        let empty = LaneCache::new(&d, slots, false);
        mask.mark_dirty(0);
        mask.sync(0, &empty);
        assert!(mask.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn lane_avail_maps_roles() {
        assert_eq!(LaneAvail::of(&Lane::Idle), LaneAvail::Free);
        let seq = SeqState::fresh(Request::new(1, vec![1], 4),
                                  LaneCache::new(&dims(), 4, false), false);
        assert_eq!(LaneAvail::of(&Lane::Busy(Box::new(seq))), LaneAvail::Busy);
    }

    #[test]
    fn fresh_and_resume_build_consistent_state() {
        let d = dims();
        let cache = LaneCache::new(&d, 6, false);
        let seq = SeqState::fresh(Request::new(7, vec![1, 2, 3], 5), cache,
                                  true);
        assert_eq!(seq.fed, 0);
        assert_eq!(seq.prompt, vec![1, 2, 3]);
        assert!(seq.record.is_some());
        assert_eq!(seq.inject.plans.len(), d.layers * d.hkv);
        // resume extends history with the new turn and keeps `fed`
        let snap = SessionSnapshot {
            cache: LaneCache::new(&d, 6, false),
            mirror: vec![Vec::new(); d.layers * d.hkv],
            kv: Default::default(),
            fed: 4,
            history: vec![1, 2, 3, 4, 9],
            turns: 2,
            last_used: 0,
        };
        let seq = SeqState::resume(
            Request::new(8, vec![40, 41], 5).with_session("s"), snap, false);
        assert_eq!(seq.fed, 4);
        assert_eq!(seq.prompt, vec![1, 2, 3, 4, 9, 40, 41]);
        assert_eq!(seq.turns, 2);
        assert_eq!(seq.session.as_deref(), Some("s"));
    }
}
