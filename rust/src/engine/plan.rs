//! The step planner: ONE place that decides what every lane does in a tick
//! and owns the reusable fused operand buffers behind the [`StepPlan`] the
//! backend executes.
//!
//! The engine's event loop picks a [`TickKind`] (its scheduling policy —
//! fused mixed ticks by default, alternating decode/prefill phases when
//! `mixed_ticks` is off); `assign_ops` turns that into a [`LaneOp`] per
//! lane, Sarathi-style splitting the tick token budget across mid-prefill
//! lanes (decoders reserved first).  The engine then fills the `StepBufs`
//! scratch (tokens, masks, write slots, retrieval injections) and hands the
//! assembled plan to `ModelBackend::submit` — the same pipeline for
//! decode-only, prefill-only, mixed and inject-carrying steps.  [`DoubleBufs`]
//! holds two of them so the pipelined loop can assemble the next tick while
//! the previous one is still in flight.

use crate::model_meta::ModelDims;
use crate::runtime::{LaneOp, StepPlan};

use super::lanes::Lane;

/// Which lanes a tick schedules: the engine's phase choice, not the
/// backend's (any [`StepPlan`] executes through the one `execute` call).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TickKind {
    /// decode-ready lanes only (alternating fallback / no prefill pending)
    Decode,
    /// mid-prefill lanes only, one full chunk each (alternating fallback)
    Prefill,
    /// every busy lane: decoders one token, fillers a budgeted chunk
    Fused,
}

/// Assign a [`LaneOp`] to every lane for this tick; returns the number of
/// active ops.  `Inject` ops are upgraded from `Decode` later, during
/// buffer assembly, when a lane has pending retrieval re-admissions.
pub(crate) fn assign_ops(lanes: &[Lane], kind: TickKind,
                         chunked_prefill: bool, token_budget: usize,
                         chunk: usize, ops: &mut [LaneOp]) -> usize {
    let mut n_decode = 0usize;
    let mut fill_needs: Vec<usize> = Vec::new();
    let mut fill_lanes: Vec<usize> = Vec::new();
    for (i, lane) in lanes.iter().enumerate() {
        let Lane::Busy(seq) = lane else {
            ops[i] = LaneOp::Idle;
            continue;
        };
        let mid_prefill = chunked_prefill && seq.fed < seq.prompt.len();
        ops[i] = match kind {
            TickKind::Decode if !mid_prefill => {
                n_decode += 1;
                LaneOp::Decode
            }
            TickKind::Prefill if mid_prefill => LaneOp::Chunk {
                tokens: chunk.min(seq.prompt.len() - seq.fed),
            },
            TickKind::Fused => {
                if mid_prefill {
                    fill_needs.push(seq.prompt.len() - seq.fed);
                    fill_lanes.push(i);
                    LaneOp::Chunk { tokens: 1 } // granted below
                } else {
                    n_decode += 1;
                    LaneOp::Decode
                }
            }
            _ => LaneOp::Idle,
        };
    }
    if kind == TickKind::Fused {
        let grants = split_prefill_budget(token_budget, n_decode,
                                          &fill_needs, chunk);
        for (i, grant) in fill_lanes.into_iter().zip(grants) {
            ops[i] = LaneOp::Chunk { tokens: grant };
        }
    }
    ops.iter().filter(|o| o.is_active()).count()
}

/// Sarathi-style per-tick token budget split for fused ticks.
///
/// Decoders come first: each decoding lane is reserved one token off the
/// top (their progress is the whole point of mixed ticks).  The remainder
/// divides evenly across the mid-prefill lanes, clamped to the graph's
/// chunk capacity and each lane's remaining prompt — but never below one
/// token, so an over-subscribed budget slows prefill, it cannot stall it.
/// `budget == 0` means unbounded (every filling lane gets a full chunk).
///
/// Returns the chunk length granted to each entry of `needs` (the
/// remaining prompt tokens of each mid-prefill lane, in lane order).
pub(crate) fn split_prefill_budget(budget: usize, n_decode: usize,
                                   needs: &[usize], chunk: usize)
    -> Vec<usize> {
    if needs.is_empty() {
        return Vec::new();
    }
    let share = if budget == 0 {
        chunk
    } else {
        (budget.saturating_sub(n_decode) / needs.len()).clamp(1, chunk)
    };
    needs.iter().map(|&need| share.min(need).min(chunk)).collect()
}

/// Reusable fused operand buffers behind the per-tick [`StepPlan`] — one
/// allocation at engine construction, `reset` per tick, so contended
/// steady state stays off the allocator's hot path.
pub(crate) struct StepBufs {
    pub ops: Vec<LaneOp>,        // [B]
    pub tokens: Vec<i32>,        // [B, C]
    pub pos: Vec<i32>,           // [B, C]
    pub in_mask: Vec<f32>,       // [B, C]
    pub write_slots: Vec<i32>,   // [L, B, H, C]
    pub inject_flag: Vec<f32>,   // [L, B, H]
    pub inject_slot: Vec<i32>,   // [L, B, H]
    pub inject_k: Vec<f32>,      // [L, B, H, dh]
    pub inject_v: Vec<f32>,      // [L, B, H, dh]
}

impl StepBufs {
    pub fn new(dims: &ModelDims, b: usize, c: usize) -> StepBufs {
        let lbh = dims.layers * b * dims.hkv;
        StepBufs {
            ops: vec![LaneOp::Idle; b],
            tokens: vec![0; b * c],
            pos: vec![0; b * c],
            in_mask: vec![0.0; b * c],
            write_slots: vec![0; lbh * c],
            inject_flag: vec![0.0; lbh],
            inject_slot: vec![0; lbh],
            inject_k: vec![0.0; lbh * dims.dh],
            inject_v: vec![0.0; lbh * dims.dh],
        }
    }

    /// Clear to the idle state: zero masks/tokens/injections, every write
    /// pointed at the trash slot.
    pub fn reset(&mut self, trash: i32) {
        self.ops.iter_mut().for_each(|o| *o = LaneOp::Idle);
        self.tokens.iter_mut().for_each(|x| *x = 0);
        self.pos.iter_mut().for_each(|x| *x = 0);
        self.in_mask.iter_mut().for_each(|x| *x = 0.0);
        self.write_slots.iter_mut().for_each(|x| *x = trash);
        self.inject_flag.iter_mut().for_each(|x| *x = 0.0);
        self.inject_slot.iter_mut().for_each(|x| *x = 0);
        self.inject_k.iter_mut().for_each(|x| *x = 0.0);
        self.inject_v.iter_mut().for_each(|x| *x = 0.0);
    }

    /// View the assembled buffers as the backend's [`StepPlan`].
    pub fn as_plan<'a>(&'a self, valid: &'a [f32], any_inject: bool,
                       want_attn: bool, want_kv: bool) -> StepPlan<'a> {
        StepPlan {
            ops: &self.ops,
            tokens: &self.tokens,
            pos: &self.pos,
            in_mask: &self.in_mask,
            valid,
            write_slots: &self.write_slots,
            inject_flag: any_inject.then_some(&self.inject_flag[..]),
            inject_slot: any_inject.then_some(&self.inject_slot[..]),
            inject_k: any_inject.then_some(&self.inject_k[..]),
            inject_v: any_inject.then_some(&self.inject_v[..]),
            want_attn,
            want_kv,
        }
    }
}

/// Two [`StepBufs`] and a cursor: the pipelined engine assembles tick t+1
/// into one buffer while tick t's plan — borrowed from the other at
/// `submit` — is still pinned by the in-flight step's postprocess.  The
/// in-flight bookkeeping records the index `flip` retired, so postprocess
/// reads the exact buffer its step was assembled from.
pub(crate) struct DoubleBufs {
    bufs: [StepBufs; 2],
    cur: usize,
}

impl DoubleBufs {
    pub fn new(dims: &ModelDims, b: usize, c: usize) -> DoubleBufs {
        DoubleBufs {
            bufs: [StepBufs::new(dims, b, c), StepBufs::new(dims, b, c)],
            cur: 0,
        }
    }

    /// The buffer the next tick assembles into.
    pub fn cur(&self) -> &StepBufs {
        &self.bufs[self.cur]
    }

    pub fn cur_mut(&mut self) -> &mut StepBufs {
        &mut self.bufs[self.cur]
    }

    /// Pinned access for an in-flight step's postprocess.
    pub fn get(&self, idx: usize) -> &StepBufs {
        &self.bufs[idx]
    }

    /// Retire the current buffer to its just-submitted step and expose the
    /// other side for the next tick's assembly; returns the retired index.
    pub fn flip(&mut self) -> usize {
        let retired = self.cur;
        self.cur ^= 1;
        retired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::lanes::SeqState;
    use crate::kvcache::LaneCache;
    use crate::scheduler::Request;

    fn dims() -> ModelDims {
        ModelDims { vocab: 512, d: 128, layers: 2, hq: 4, hkv: 2, dh: 4,
                    ffn: 256, gate_hidden: 48 }
    }

    fn busy(prompt_len: usize, fed: usize) -> Lane {
        let prompt: Vec<u32> = (0..prompt_len as u32).map(|i| 32 + i).collect();
        let mut seq = SeqState::fresh(Request::new(1, prompt, 4),
                                      LaneCache::new(&dims(), 6, false), false);
        seq.fed = fed;
        Lane::Busy(Box::new(seq))
    }

    #[test]
    fn budget_split_reserves_decoders_first() {
        // budget 10, 6 decoders -> 4 left over 2 filling lanes = 2 each
        assert_eq!(split_prefill_budget(10, 6, &[30, 30], 16), vec![2, 2]);
        // unbounded: full chunks, clamped by remaining prompt
        assert_eq!(split_prefill_budget(0, 6, &[30, 5], 16), vec![16, 5]);
        // over-subscribed budget still grants one token (no prefill stall)
        assert_eq!(split_prefill_budget(4, 7, &[30, 30, 30], 16),
                   vec![1, 1, 1]);
        // share never exceeds the graph's chunk capacity
        assert_eq!(split_prefill_budget(1000, 0, &[500], 16), vec![16]);
        assert_eq!(split_prefill_budget(8, 0, &[2], 16), vec![2]);
        assert!(split_prefill_budget(10, 2, &[], 16).is_empty());
    }

    #[test]
    fn assign_ops_fused_mixes_decoders_and_grants() {
        let lanes = vec![busy(2, 2), busy(40, 8), Lane::Idle];
        let mut ops = vec![LaneOp::Idle; 3];
        let n = assign_ops(&lanes, TickKind::Fused, true, 0, 16, &mut ops);
        assert_eq!(n, 2);
        assert_eq!(ops[0], LaneOp::Decode);
        assert_eq!(ops[1], LaneOp::Chunk { tokens: 16 });
        assert_eq!(ops[2], LaneOp::Idle);
        // a tight budget shrinks the grant, never below one token
        assign_ops(&lanes, TickKind::Fused, true, 2, 16, &mut ops);
        assert_eq!(ops[1], LaneOp::Chunk { tokens: 1 });
    }

    #[test]
    fn assign_ops_alternating_phases_select_disjoint_lanes() {
        let lanes = vec![busy(2, 2), busy(40, 8)];
        let mut ops = vec![LaneOp::Idle; 2];
        let n = assign_ops(&lanes, TickKind::Decode, true, 0, 16, &mut ops);
        assert_eq!((n, ops[0], ops[1]), (1, LaneOp::Decode, LaneOp::Idle));
        let n = assign_ops(&lanes, TickKind::Prefill, true, 0, 16, &mut ops);
        assert_eq!((n, ops[0]), (1, LaneOp::Idle));
        assert_eq!(ops[1], LaneOp::Chunk { tokens: 16 });
        // without chunked prefill every busy lane decodes (token-by-token
        // prompt feed rides the decode op)
        let n = assign_ops(&lanes, TickKind::Decode, false, 0, 16, &mut ops);
        assert_eq!((n, ops[0], ops[1]), (2, LaneOp::Decode, LaneOp::Decode));
    }

    #[test]
    fn assign_ops_chunk_grant_caps_at_remaining_prompt() {
        let lanes = vec![busy(10, 8)];
        let mut ops = vec![LaneOp::Idle; 1];
        assign_ops(&lanes, TickKind::Prefill, true, 0, 16, &mut ops);
        assert_eq!(ops[0], LaneOp::Chunk { tokens: 2 });
        assign_ops(&lanes, TickKind::Fused, true, 0, 16, &mut ops);
        assert_eq!(ops[0], LaneOp::Chunk { tokens: 2 });
    }

    #[test]
    fn step_bufs_reset_restores_idle_state() {
        let d = dims();
        let mut bufs = StepBufs::new(&d, 2, 4);
        bufs.ops[0] = LaneOp::Decode;
        bufs.tokens[0] = 9;
        bufs.in_mask[0] = 1.0;
        bufs.inject_flag[0] = 1.0;
        bufs.reset(7);
        assert_eq!(bufs.ops[0], LaneOp::Idle);
        assert_eq!(bufs.tokens[0], 0);
        assert_eq!(bufs.in_mask[0], 0.0);
        assert_eq!(bufs.inject_flag[0], 0.0);
        assert!(bufs.write_slots.iter().all(|&x| x == 7));
        let valid = vec![0.0; 2 * 2 * 2 * 6];
        let plan = bufs.as_plan(&valid, false, false, false);
        assert!(plan.inject_flag.is_none());
    }

    #[test]
    fn double_bufs_flip_preserves_the_retired_side() {
        let d = dims();
        let mut db = DoubleBufs::new(&d, 2, 4);
        db.cur_mut().tokens[0] = 41;
        let retired = db.flip();
        assert_eq!(retired, 0);
        // the in-flight side is untouched by writes to the new current side
        db.cur_mut().tokens[0] = 99;
        assert_eq!(db.get(retired).tokens[0], 41);
        assert_eq!(db.cur().tokens[0], 99);
        // flipping again returns to the first side
        assert_eq!(db.flip(), 1);
        assert_eq!(db.cur().tokens[0], 41);
    }
}
