//! Replicated serving: an [`EngineGroup`] of N engine workers behind a
//! [`SessionRouter`].
//!
//! One engine owns one backend and B lanes — the ceiling on concurrent
//! users is one device.  The group runs N replicas (each its own
//! `ModelBackend` + `Engine` on its own thread, driven through the same
//! worker loop `InProcServer` uses) and routes at the request level:
//!
//! - **session turns** are *pinned*: the first turn of a session lands on
//!   `hash(session_id) % N` (a stable FNV-1a hash — the same session finds
//!   the same home replica across process restarts), and every later turn
//!   follows the pin, so the conversation's retained KV cache is always
//!   local to the engine that serves it;
//! - **sessionless requests** load-balance: the router tracks outstanding
//!   turns per replica and picks the replica with the most free lanes,
//!   breaking ties toward the shallowest queue, then the lowest index —
//!   deterministic, so tests and replays see the same placement;
//! - **cross-replica migration** moves a quiescent session: drain the
//!   source replica's in-flight step, force the session's parked lane down
//!   to the host store (`Engine::export_session`), hand the O(budget)
//!   [`crate::session::SessionSnapshot`] to the target store
//!   (`Engine::import_session`), and repin.  The swap/park machinery is
//!   untouched — migration is a store handoff, not a new serialization
//!   format.  TRIM-KV makes this sound by construction: retention scores
//!   are assigned at creation time and are query-agnostic, so the migrated
//!   cache is valid verbatim on the target replica (an attention-proxy
//!   scheme would need the new replica to have seen the query history).
//!   When the pinned replica is saturated and another has free lanes, the
//!   router migrates automatically before routing the turn (*rebalancing*;
//!   `[router] migration = off` disables both forms).
//!
//! `GET /metrics` on the group aggregates every replica's exposition under
//! a `replica="<i>"` label and appends the router's own counters
//! (`trimkv_router_*`).

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

use anyhow::{bail, ensure, Result};

use crate::engine::Engine;
use crate::obs::{self, Sample};
use crate::prefixcache::PrefixStore;
use crate::runtime::ModelBackend;
use crate::scheduler::{Request, Response};
use crate::server::{spawn_worker, Frontend, Msg};

/// Stable 64-bit FNV-1a. The pin hash must not change across processes or
/// rust versions (std's `DefaultHasher` is explicitly unstable), so a
/// session restarted against a fresh group lands on the same home replica
/// and finds its snapshot where an external checkpoint put it.
pub fn session_hash(id: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in id.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// The home replica a session id hashes to in a group of `n`.
pub fn home_replica(id: &str, n: usize) -> usize {
    (session_hash(id) % n.max(1) as u64) as usize
}

/// Router decision/outcome counters (exposed as `trimkv_router_*`).
#[derive(Debug, Default, Clone)]
pub struct RouterMetrics {
    /// requests routed to a replica (sessionful + sessionless)
    pub routed: u64,
    /// sessionless requests placed by load (no pin)
    pub balanced: u64,
    /// successful cross-replica session migrations (incl. rebalances)
    pub migrations: u64,
    /// migrations triggered automatically by a saturated home replica
    pub rebalances: u64,
    /// migration attempts refused (disabled, in-flight turns, bad target)
    pub migrations_rejected: u64,
}

/// Placement state: one mutex'd blob so every routing decision reads a
/// consistent picture.  All counts are router-side accounting (submitted
/// minus responses drained), not engine introspection — deterministic
/// regardless of replica thread timing.
struct RouterState {
    /// session -> replica; absent means "home replica by hash"
    pins: BTreeMap<String, usize>,
    /// outstanding turns per replica (submitted - responses drained)
    inflight: Vec<usize>,
    /// outstanding turns per session (migration requires zero)
    session_inflight: BTreeMap<String, usize>,
    metrics: RouterMetrics,
}

impl RouterState {
    fn free_lanes(&self, replica: usize, batch: usize) -> usize {
        batch.saturating_sub(self.inflight[replica])
    }

    /// The sessionless placement rule: most free lanes, then least
    /// outstanding work (shallowest queue), then lowest index.
    fn best_replica(&self, batch: usize) -> usize {
        (0..self.inflight.len())
            .min_by_key(|&i| {
                (std::cmp::Reverse(self.free_lanes(i, batch)), self.inflight[i], i)
            })
            .unwrap_or(0)
    }
}

/// The placement policy, separable from the worker plumbing so the routing
/// rules unit-test without spawning engine threads.
pub struct SessionRouter {
    n: usize,
    /// lanes per replica (homogeneous fleet)
    batch: usize,
    migration: bool,
    state: Mutex<RouterState>,
}

/// What `SessionRouter::route` decided for one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteDecision {
    /// send to this replica
    To(usize),
    /// migrate the session from `.0` to `.1` first, then send to `.1`
    MigrateThenTo(usize, usize),
}

impl SessionRouter {
    pub fn new(n: usize, batch: usize, migration: bool) -> SessionRouter {
        SessionRouter {
            n: n.max(1),
            batch,
            migration,
            state: Mutex::new(RouterState {
                pins: BTreeMap::new(),
                inflight: vec![0; n.max(1)],
                session_inflight: BTreeMap::new(),
                metrics: RouterMetrics::default(),
            }),
        }
    }

    pub fn replicas(&self) -> usize {
        self.n
    }

    /// Placement-state guard.  A replica thread that panics while the
    /// router is mid-update poisons the mutex; the state is plain
    /// bookkeeping that is consistent at every statement boundary, so
    /// recover the guard instead of cascading the panic into every
    /// subsequent request.
    fn st(&self) -> MutexGuard<'_, RouterState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The replica a session currently resolves to (pin, else hash home).
    pub fn replica_for(&self, session: &str) -> usize {
        let st = self.st();
        st.pins
            .get(session)
            .copied()
            .unwrap_or_else(|| home_replica(session, self.n))
    }

    /// Decide a placement and book the request as outstanding there.  The
    /// caller must act on a `MigrateThenTo` (or fall back to the source on
    /// a failed handoff via [`SessionRouter::repin`]).
    pub fn route(&self, req: &Request) -> RouteDecision {
        let mut st = self.st();
        st.metrics.routed += 1;
        let decision = match &req.session {
            None => {
                st.metrics.balanced += 1;
                RouteDecision::To(st.best_replica(self.batch))
            }
            Some(sid) => {
                let cur = st
                    .pins
                    .get(sid)
                    .copied()
                    .unwrap_or_else(|| home_replica(sid, self.n));
                let quiescent =
                    st.session_inflight.get(sid).copied().unwrap_or(0) == 0;
                let best = st.best_replica(self.batch);
                if self.migration
                    && quiescent
                    && st.free_lanes(cur, self.batch) == 0
                    && st.free_lanes(best, self.batch) > 0
                {
                    // home is saturated, somewhere else has a free lane:
                    // move the session rather than queue behind the hot
                    // replica (skewed hash loads rebalance instead of
                    // starving)
                    st.pins.insert(sid.clone(), best);
                    st.metrics.rebalances += 1;
                    RouteDecision::MigrateThenTo(cur, best)
                } else {
                    st.pins.insert(sid.clone(), cur);
                    RouteDecision::To(cur)
                }
            }
        };
        let target = match decision {
            RouteDecision::To(t) | RouteDecision::MigrateThenTo(_, t) => t,
        };
        st.inflight[target] += 1;
        if let Some(sid) = &req.session {
            *st.session_inflight.entry(sid.clone()).or_insert(0) += 1;
        }
        decision
    }

    /// Book a drained response against its replica and session.
    pub fn note_done(&self, replica: usize, resp: &Response) {
        let mut st = self.st();
        st.inflight[replica] = st.inflight[replica].saturating_sub(1);
        if let Some(sid) = &resp.session {
            if let Some(c) = st.session_inflight.get_mut(sid) {
                *c = c.saturating_sub(1);
                if *c == 0 {
                    st.session_inflight.remove(sid);
                }
            }
        }
    }

    /// Point a session at a replica (migration bookkeeping / fallback).
    pub fn repin(&self, session: &str, replica: usize) {
        let mut st = self.st();
        st.pins.insert(session.to_string(), replica);
    }

    /// Forget a session (close): the next turn with this id re-homes by
    /// hash, exactly like a brand-new conversation.
    pub fn unpin(&self, session: &str) {
        let mut st = self.st();
        st.pins.remove(session);
        st.session_inflight.remove(session);
    }

    /// Preflight an explicit migration: checks the feature gate, target
    /// range and session quiescence, and counts rejections.
    fn check_migration(&self, session: &str, target: usize) -> Result<usize> {
        let mut st = self.st();
        let source = st
            .pins
            .get(session)
            .copied()
            .unwrap_or_else(|| home_replica(session, self.n));
        let ok = (|| {
            ensure!(self.migration, "migration is disabled ([router] migration = off)");
            ensure!(target < self.n, "target replica {target} out of range (n = {})", self.n);
            ensure!(
                st.session_inflight.get(session).copied().unwrap_or(0) == 0,
                "session {session} has turns in flight"
            );
            Ok(())
        })();
        if let Err(e) = ok {
            st.metrics.migrations_rejected += 1;
            return Err(e);
        }
        Ok(source)
    }

    fn count_migration(&self, ok: bool) {
        let mut st = self.st();
        if ok {
            st.metrics.migrations += 1;
        } else {
            st.metrics.migrations_rejected += 1;
        }
    }

    pub fn metrics(&self) -> RouterMetrics {
        self.st().metrics.clone()
    }

    /// Router-plane samples (appended to the aggregated exposition).
    pub fn samples(&self) -> Vec<Sample> {
        let st = self.st();
        let m = &st.metrics;
        let mut out = vec![
            Sample::gauge("trimkv_router_replicas", self.n as f64),
            Sample::counter("trimkv_router_routed_total", m.routed as f64),
            Sample::counter("trimkv_router_balanced_total", m.balanced as f64),
            Sample::counter("trimkv_router_migrations_total",
                            m.migrations as f64),
            Sample::counter("trimkv_router_rebalances_total",
                            m.rebalances as f64),
            Sample::counter("trimkv_router_migrations_rejected_total",
                            m.migrations_rejected as f64),
            Sample::gauge("trimkv_router_pinned_sessions",
                          st.pins.len() as f64),
        ];
        for (i, &inflight) in st.inflight.iter().enumerate() {
            out.push(
                Sample::gauge("trimkv_router_inflight", inflight as f64)
                    .label("replica", i.to_string()),
            );
        }
        out
    }
}

struct Worker {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<Result<()>>>,
}

/// N replica engines behind one request-level router.  Implements
/// [`Frontend`], so the TCP front door (and every example) is identical at
/// N=1 and N=8.
pub struct EngineGroup {
    workers: Vec<Worker>,
    rx: Receiver<(usize, Response)>,
    pub router: SessionRouter,
    /// The fleet-shared prefix store, when one was attached: replicas that
    /// had it injected via `Engine::set_prefix_store` suppress their own
    /// `trimkv_prefix_*` rendering, and the group renders the store's
    /// samples exactly once in the aggregated exposition.
    prefix: Option<Arc<PrefixStore>>,
}

impl EngineGroup {
    /// Spawn `n` replicas; `make_engine(i)` builds replica i's engine (its
    /// own backend — replicas share nothing but the response channel).
    /// The fleet must be homogeneous in lane count: the router's free-lane
    /// arithmetic assumes one `batch` across replicas.
    pub fn spawn<B, F>(n: usize, migration: bool, mut make_engine: F)
        -> Result<EngineGroup>
    where
        B: ModelBackend + 'static,
        F: FnMut(usize) -> Result<Engine<B>>,
    {
        ensure!(n >= 1, "engine group needs at least one replica");
        let (resp_tx, rx) = channel::<(usize, Response)>();
        let mut workers = Vec::with_capacity(n);
        let mut batch = 0usize;
        for i in 0..n {
            let engine = make_engine(i)?;
            let b = engine.backend().batch();
            if i == 0 {
                batch = b;
            } else {
                ensure!(b == batch,
                        "replica {i} has {b} lanes, replica 0 has {batch}: \
                         the group must be homogeneous");
            }
            let (tx, mrx) = channel::<Msg>();
            let sink = resp_tx.clone();
            let handle = spawn_worker(engine, mrx, move |r| {
                let _ = sink.send((i, r));
            });
            workers.push(Worker { tx, handle: Some(handle) });
        }
        drop(resp_tx);
        Ok(EngineGroup {
            workers,
            rx,
            router: SessionRouter::new(n, batch, migration),
            prefix: None,
        })
    }

    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Register the prefix store the replicas share (the same `Arc` each
    /// engine received through `Engine::set_prefix_store`), making the
    /// group the single exposition point for its `trimkv_prefix_*` series.
    pub fn attach_prefix_store(&mut self, store: Arc<PrefixStore>) {
        self.prefix = Some(store);
    }

    pub fn prefix_store(&self) -> Option<&Arc<PrefixStore>> {
        self.prefix.as_ref()
    }

    /// Route and submit one request (the `Frontend` entry point).
    pub fn submit(&self, req: Request) {
        match self.router.route(&req) {
            RouteDecision::To(t) => {
                let _ = self.workers[t].tx.send(Msg::Req(req));
            }
            RouteDecision::MigrateThenTo(src, dst) => {
                let Some(sid) = req.session.clone() else {
                    // route() only rebalances sessionful requests; if that
                    // invariant ever breaks, still serve the turn on the
                    // chosen replica rather than panic the server
                    let _ = self.workers[dst].tx.send(Msg::Req(req));
                    return;
                };
                // best effort: a failed handoff (source still warming the
                // snapshot, store miss) falls back to the source replica —
                // the turn still runs, just on the busy engine
                match self.handoff(&sid, src, dst) {
                    Ok(()) => {
                        self.router.count_migration(true);
                        let _ = self.workers[dst].tx.send(Msg::Req(req));
                    }
                    Err(_) => {
                        self.router.count_migration(false);
                        self.router.repin(&sid, src);
                        let _ = self.workers[src].tx.send(Msg::Req(req));
                    }
                }
            }
        }
    }

    /// Explicitly migrate a session to `target`.  Errors when migration is
    /// disabled, the target is out of range, the session has turns in
    /// flight, or the source handoff fails.  Migrating a session the group
    /// has never seen is a no-op pin (its first turn simply lands there).
    pub fn migrate_session(&self, session: &str, target: usize) -> Result<()> {
        let source = self.router.check_migration(session, target)?;
        if source == target {
            return Ok(());
        }
        match self.handoff(session, source, target) {
            Ok(()) => {
                self.router.count_migration(true);
                self.router.repin(session, target);
                Ok(())
            }
            Err(e) => {
                self.router.count_migration(false);
                Err(e)
            }
        }
    }

    /// The migration handshake: TakeSession out of `src`'s store (the
    /// worker drains its in-flight step and swaps the parked lane down
    /// first), PutSession into `dst`'s, both acked.  A session with no
    /// state on the source (never ran there, or externally dropped) moves
    /// as a pure repin.
    fn handoff(&self, session: &str, src: usize, dst: usize) -> Result<()> {
        let (take_tx, take_rx) = channel();
        if self.workers[src].tx.send(
            Msg::TakeSession(session.to_string(), take_tx)).is_err()
        {
            bail!("replica {src} is gone");
        }
        let snap = match take_rx.recv() {
            Ok(Ok(s)) => s,
            Ok(Err(reason)) => bail!("replica {src} refused: {reason}"),
            Err(_) => bail!("replica {src} dropped the migration reply"),
        };
        let Some(snap) = snap else {
            return Ok(()); // no state to move: repin only
        };
        let (put_tx, put_rx) = channel();
        if self.workers[dst].tx.send(
            Msg::PutSession(session.to_string(), snap, put_tx)).is_err()
        {
            bail!("replica {dst} is gone");
        }
        ensure!(put_rx.recv().is_ok(), "replica {dst} dropped the rebind ack");
        Ok(())
    }

    /// Drop a conversation's retained state on whichever replica holds it,
    /// and forget its pin (a later same-id session re-homes by hash).
    pub fn close_session(&self, id: &str) {
        let replica = self.router.replica_for(id);
        let _ = self.workers[replica].tx.send(Msg::CloseSession(id.to_string()));
        self.router.unpin(id);
    }

    /// Drain every replica's in-flight step and force all parked lanes to
    /// the host stores (group-wide checkpoint barrier).  False if any
    /// replica thread is gone.
    pub fn flush_sessions(&self) -> bool {
        let mut acks = Vec::with_capacity(self.workers.len());
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(Msg::Flush(tx)).is_err() {
                return false;
            }
            acks.push(rx);
        }
        acks.into_iter().all(|rx| rx.recv().is_ok())
    }

    /// Next finished response from any replica, if one is ready.
    pub fn try_recv(&self) -> Option<Response> {
        let (replica, resp) = self.rx.try_recv().ok()?;
        self.router.note_done(replica, &resp);
        Some(resp)
    }

    /// Block for the next finished response from any replica.
    pub fn recv_blocking(&self) -> Option<Response> {
        let (replica, resp) = self.rx.recv().ok()?;
        self.router.note_done(replica, &resp);
        Some(resp)
    }

    /// Aggregated exposition: every replica's samples under a
    /// `replica="<i>"` label, then the router's own `trimkv_router_*`
    /// series.  Replica lines are relabeled textually — the exposition
    /// format is strictly `name value` / `name{labels} value`, so the
    /// injection is mechanical and keeps each engine's rendering code
    /// single-sourced.
    pub fn metrics_snapshot(&self) -> Option<String> {
        let mut out = String::new();
        for (i, w) in self.workers.iter().enumerate() {
            let (tx, rx) = channel();
            w.tx.send(Msg::Stats(tx)).ok()?;
            let text = rx.recv().ok()?;
            out.push_str(&label_replica(&text, i));
        }
        out.push_str(&obs::render_prometheus(&self.router.samples()));
        if let Some(store) = &self.prefix {
            out.push_str(&obs::render_prometheus(&store.samples()));
        }
        Some(out)
    }

    /// One replica's Chrome-trace snapshot (traces stay per-replica: each
    /// engine has its own flight recorder and time origin).
    pub fn trace_snapshot(&self, replica: usize) -> Option<String> {
        let w = self.workers.get(replica)?;
        let (tx, rx) = channel();
        w.tx.send(Msg::Trace(tx)).ok()?;
        rx.recv().ok()
    }

    /// Finish outstanding work on every replica and join the threads.
    pub fn shutdown(mut self) -> Vec<Response> {
        for w in &self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        let mut out = Vec::new();
        while let Ok((replica, resp)) = self.rx.recv() {
            self.router.note_done(replica, &resp);
            out.push(resp);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
        out
    }
}

impl Frontend for EngineGroup {
    fn submit(&self, req: Request) {
        EngineGroup::submit(self, req)
    }
    fn close_session(&self, id: &str) {
        EngineGroup::close_session(self, id)
    }
    fn try_recv(&self) -> Option<Response> {
        EngineGroup::try_recv(self)
    }
    fn recv_blocking(&self) -> Option<Response> {
        EngineGroup::recv_blocking(self)
    }
    fn metrics_snapshot(&self) -> Option<String> {
        EngineGroup::metrics_snapshot(self)
    }
}

/// Inject `replica="<i>"` as the first label of every exposition line.
fn label_replica(text: &str, replica: usize) -> String {
    let mut out = String::with_capacity(text.len() + text.lines().count() * 14);
    for line in text.lines() {
        match line.rsplit_once(' ') {
            Some((name, value)) => {
                match name.split_once('{') {
                    Some((bare, rest)) => {
                        out.push_str(bare);
                        out.push_str(&format!("{{replica=\"{replica}\","));
                        out.push_str(rest);
                    }
                    None => {
                        out.push_str(name);
                        out.push_str(&format!("{{replica=\"{replica}\"}}"));
                    }
                }
                out.push(' ');
                out.push_str(value);
                out.push('\n');
            }
            None => {
                out.push_str(line);
                out.push('\n');
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::runtime::MockBackend;

    fn group(n: usize, batch: usize, migration: bool) -> EngineGroup {
        EngineGroup::spawn(n, migration, |_| {
            let cfg = EngineConfig {
                budget: 16,
                batch,
                chunked_prefill: false,
                ..Default::default()
            };
            Engine::new(MockBackend::new(batch, 20), cfg, 2)
        })
        .unwrap()
    }

    #[test]
    fn hash_pinning_is_stable_across_restarts() {
        // the pin is a pure function of (id, n): a fresh router — a
        // restarted process — maps every session to the same replica
        let ids: Vec<String> = (0..64).map(|i| format!("sess-{i}")).collect();
        let first: Vec<usize> = ids.iter().map(|s| home_replica(s, 4)).collect();
        let again: Vec<usize> = ids.iter().map(|s| home_replica(s, 4)).collect();
        assert_eq!(first, again);
        // spot-check against precomputed FNV-1a values: these are part of
        // the on-disk/cross-restart contract, not an implementation detail
        assert_eq!(session_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(session_hash("a"), 0xaf63_dc4c_8601_ec8c);
        // all replicas reachable over a small id population
        let mut seen = [false; 4];
        for &r in &first {
            seen[r] = true;
        }
        assert!(seen.iter().all(|&s| s), "hash never reaches some replica");
        // a router wrapper agrees with the bare hash for unpinned sessions
        let router = SessionRouter::new(4, 2, true);
        for (id, &home) in ids.iter().zip(&first) {
            assert_eq!(router.replica_for(id), home);
        }
    }

    #[test]
    fn sessionless_requests_prefer_most_free_lanes() {
        let router = SessionRouter::new(3, 2, true);
        let req = |id: u64| Request::new(id, vec![1, 2], 1);
        // empty group: lowest index wins the tie
        assert_eq!(router.route(&req(0)), RouteDecision::To(0));
        // replica 0 now has 1 outstanding -> 1 free lane; 1 and 2 have 2
        assert_eq!(router.route(&req(1)), RouteDecision::To(1));
        assert_eq!(router.route(&req(2)), RouteDecision::To(2));
        // all at 1 outstanding again: round keeps spreading
        assert_eq!(router.route(&req(3)), RouteDecision::To(0));
        assert_eq!(router.route(&req(4)), RouteDecision::To(1));
        assert_eq!(router.route(&req(5)), RouteDecision::To(2));
        // everyone full (0 free lanes): shallowest queue, lowest index
        assert_eq!(router.route(&req(6)), RouteDecision::To(0));
        assert_eq!(router.route(&req(7)), RouteDecision::To(1));
        let m = router.metrics();
        assert_eq!(m.routed, 8);
        assert_eq!(m.balanced, 8);
    }

    #[test]
    fn saturated_home_rebalances_quiescent_session() {
        let router = SessionRouter::new(2, 1, true);
        let sid = "conv";
        let home = home_replica(sid, 2);
        let other = 1 - home;
        // first turn lands on the hash home
        let turn = Request::new(1, vec![1, 2], 1).with_session(sid);
        assert_eq!(router.route(&turn), RouteDecision::To(home));
        let done = Response {
            id: 1, tag: String::new(), session: Some(sid.to_string()),
            prompt_len: 2, tokens: vec![3], finish:
                crate::scheduler::FinishReason::Length,
            ttft_us: 0.0, e2e_us: 0.0,
        };
        router.note_done(home, &done);
        // saturate the home replica with another pinned session's turn
        // (sessionless fillers would spread; a pin targets the lane)
        router.repin("blocker", home);
        let blocker = Request::new(2, vec![1], 1).with_session("blocker");
        assert_eq!(router.route(&blocker), RouteDecision::To(home));
        // the session's next turn rebalances to the free replica
        let turn2 = Request::new(3, vec![4], 1).with_session(sid);
        match router.route(&turn2) {
            RouteDecision::MigrateThenTo(src, dst) => {
                assert_eq!(src, home);
                assert_eq!(dst, other);
            }
            other => panic!("expected rebalance, got {other:?}"),
        }
        assert_eq!(router.metrics().rebalances, 1);
        // and the pin moved: the turn after resolves to the new replica
        assert_eq!(router.replica_for(sid), other);
    }

    #[test]
    fn migration_off_cleanly_rejects() {
        let group = group(2, 1, false);
        let sid = "conv";
        let home = home_replica(sid, 2);
        group.submit(Request::new(1, vec![1, 50], 2).with_session(sid));
        assert!(group.recv_blocking().is_some());
        let err = group.migrate_session(sid, 1 - home).unwrap_err();
        assert!(err.to_string().contains("migration is disabled"),
                "unexpected error: {err}");
        assert_eq!(group.router.metrics().migrations_rejected, 1);
        assert_eq!(group.router.metrics().migrations, 0);
        // the session still serves fine where it is
        group.submit(Request::new(2, vec![60], 2).with_session(sid));
        let r = group.recv_blocking().unwrap();
        assert_eq!(r.tokens, vec![61, 62]);
        group.shutdown();
    }

    #[test]
    fn group_flush_drains_every_replica() {
        let group = group(3, 1, true);
        // one session per replica (pinned by distinct explicit ids that
        // hash apart is fiddly — route enough sessions that each replica
        // holds at least one parked lane)
        let mut turn = 0u64;
        for i in 0..6 {
            turn += 1;
            group.submit(
                Request::new(turn, vec![1, 40 + i], 2)
                    .with_session(format!("s{i}")),
            );
        }
        for _ in 0..6 {
            assert!(group.recv_blocking().is_some());
        }
        assert!(group.flush_sessions());
        let text = group.metrics_snapshot().unwrap();
        // every parked lane went down to its host store: no replica
        // reports parked lanes, and the store sizes sum to 6
        let mut stored = 0.0;
        for line in text.lines() {
            if let Some((name, value)) = line.rsplit_once(' ') {
                if name.starts_with("trimkv_lanes_parked{") {
                    assert_eq!(value, "0", "parked lane survived flush: {line}");
                }
                if name.starts_with("trimkv_session_store_size{") {
                    stored += value.parse::<f64>().unwrap();
                }
            }
        }
        assert_eq!(stored, 6.0);
        group.shutdown();
    }

    #[test]
    fn explicit_migration_moves_session_state() {
        let group = group(2, 1, true);
        let sid = "mover";
        let home = home_replica(sid, 2);
        let target = 1 - home;
        group.submit(Request::new(1, vec![1, 50], 2).with_session(sid));
        let r1 = group.recv_blocking().unwrap();
        assert_eq!(r1.tokens, vec![51, 52]);
        group.migrate_session(sid, target).unwrap();
        assert_eq!(group.router.replica_for(sid), target);
        assert_eq!(group.router.metrics().migrations, 1);
        // the next turn runs on the target replica with the retained
        // cache: the mock emits successors of the full stream, so a
        // re-prefilled (state-lost) session would answer differently
        group.submit(Request::new(2, vec![60], 2).with_session(sid));
        let r2 = group.recv_blocking().unwrap();
        assert_eq!(r2.tokens, vec![61, 62]);
        // and the state genuinely moved: the target's store held it
        let text = group.metrics_snapshot().unwrap();
        let line = format!("trimkv_sessions_opened_total{{replica=\"{home}\"}} 1");
        assert!(text.contains(&line), "home replica lost its open count:\n{text}");
        group.shutdown();
    }

    #[test]
    fn group_round_trip_spreads_sessionless_load() {
        let group = group(2, 2, true);
        for i in 0..8 {
            group.submit(Request::new(i, vec![1, 30 + i as u32], 3));
        }
        // the balanced counter is final at submit time (and `shutdown`
        // consumes the group, router included)
        assert_eq!(group.router.metrics().balanced, 8);
        let responses = group.shutdown();
        assert_eq!(responses.len(), 8);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn shared_prefix_store_spans_replicas_and_renders_once() {
        use crate::prefixcache::PrefixStore;

        let store = Arc::new(PrefixStore::new(1 << 20, 16));
        let mut group = EngineGroup::spawn(2, true, |_| {
            let cfg = EngineConfig {
                budget: 24,
                batch: 1,
                chunked_prefill: false,
                // injection alone activates the path — exactly the wiring
                // `serve` uses for a fleet-shared store
                prefix_enabled: false,
                prefix_chunk_tokens: 16,
                ..Default::default()
            };
            let mut e = Engine::new(MockBackend::new(1, 28), cfg, 2)?;
            e.set_prefix_store(store.clone());
            Ok(e)
        })
        .unwrap();
        group.attach_prefix_store(store.clone());
        let prefix: Vec<u32> = (100..116).collect();
        let with_tail = |tail: &[u32]| {
            let mut p = prefix.clone();
            p.extend_from_slice(tail);
            p
        };
        // cold request warms the store on replica 0 (publishes at fed=16)
        group.submit(Request::new(1, with_tail(&[200, 201, 202, 203]), 2));
        assert_eq!(group.recv_blocking().unwrap().tokens, vec![204, 205]);
        // two concurrent sessionless requests spread across both replicas
        // (most-free-lanes: id 2 -> replica 0, id 3 -> replica 1) and both
        // hit the same store entry
        group.submit(Request::new(2, with_tail(&[300, 301]), 2));
        group.submit(Request::new(3, with_tail(&[400, 401, 402]), 2));
        let mut warm = vec![
            group.recv_blocking().unwrap(),
            group.recv_blocking().unwrap(),
        ];
        warm.sort_by_key(|r| r.id);
        assert_eq!(warm[0].tokens, vec![302, 303]);
        assert_eq!(warm[1].tokens, vec![403, 404]);
        let c = store.counters();
        assert_eq!((c.hits, c.misses, c.inserts), (2, 1, 1));
        assert_eq!(c.prefill_tokens_saved, 32, "two 16-token seeds");
        assert_eq!(c.entries, 1);
        // the group renders the store once, unlabeled; replicas sharing
        // the store suppress their own copy of the series
        let text = group.metrics_snapshot().unwrap();
        crate::obs::assert_prometheus_parses(&text);
        assert!(text.contains("trimkv_prefix_hits_total 2\n"), "{text}");
        assert!(!text.contains("trimkv_prefix_hits_total{replica="),
                "replica-labeled duplicate of a shared series:\n{text}");
        group.shutdown();
    }

    #[test]
    fn label_injection_preserves_exposition_grammar() {
        let text = "trimkv_tokens_total 42\n\
                    trimkv_step_us{quantile=\"0.5\"} 1.5\n";
        let labeled = label_replica(text, 3);
        assert_eq!(labeled,
                   "trimkv_tokens_total{replica=\"3\"} 42\n\
                    trimkv_step_us{replica=\"3\",quantile=\"0.5\"} 1.5\n");
        crate::obs::assert_prometheus_parses(&labeled);
    }

    #[test]
    fn group_metrics_aggregate_with_replica_labels() {
        let group = group(2, 1, true);
        group.submit(Request::new(1, vec![1, 40], 3));
        assert!(group.recv_blocking().is_some());
        let text = group.metrics_snapshot().unwrap();
        crate::obs::assert_prometheus_parses(&text);
        for i in 0..2 {
            let needle = format!("trimkv_uptime_seconds{{replica=\"{i}\"}}");
            assert!(text.contains(&needle), "missing {needle}:\n{text}");
        }
        assert!(text.contains("trimkv_router_replicas 2\n"));
        assert!(text.contains("trimkv_router_routed_total 1\n"));
        assert!(text.contains("trimkv_router_inflight{replica=\"0\"} 0\n"));
        group.shutdown();
    }
}
