//! Session subsystem: conversations that outlive their batch lane.
//!
//! The paper's headline scenario (LongMemEval, §5.2) is long-horizon
//! multi-session dialogue.  The engine has a handful of device lanes; a
//! deployment has thousands of concurrent conversations.  This module holds
//! the host side of that gap: when a turn completes (or the scheduler
//! preempts an idle session under lane pressure) the lane's entire retention
//! state — per-head slot tables with `log beta` scores and attention
//! statistics, the retrieval mirror, and the device-resident K/V slabs —
//! is captured as a [`SessionSnapshot`] and parked in a [`SessionStore`].
//! When the session's next turn arrives the snapshot is swapped back into a
//! free lane and decoding continues from the retained cache: **no re-prefill
//! of prior turns**, and the memory-bounded cache means a snapshot is
//! O(budget), not O(history).
//!
//! The store is LRU-bounded (`EngineConfig::max_sessions`): under pressure
//! the coldest conversation is dropped, exactly the trade the paper's
//! retention gates make per token, lifted to whole dialogues.
//!
//! The snapshot doubles as the unit of **cross-replica migration**
//! (`router`): `SessionStore::take` on the source and `insert` on the
//! target replica move a conversation wholesale — no extra serialization
//! format, and TRIM-KV's creation-time scores keep the moved cache valid
//! verbatim.

use std::collections::BTreeMap;

use crate::kvcache::{LaneCache, MirrorEntry};
use crate::runtime::LaneKv;

/// Everything needed to resume a conversation on any free lane.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    /// Per-(layer, head) slot tables: live bits, token entries, retention
    /// scores, attention statistics, optional key/value mirrors.
    pub cache: LaneCache,
    /// Retrieval-policy re-admission pool, per (layer * head).
    pub mirror: Vec<Vec<MirrorEntry>>,
    /// The lane's K/V slabs, each flat `[L, H, M, dh]`.  Empty while the
    /// session is parked on a lane (slabs still device-resident); filled by
    /// the batched `swap_lanes` download at preemption.
    pub kv: LaneKv,
    /// Tokens already fed through the model (== next position to feed).
    pub fed: usize,
    /// Full token stream so far: all turn prompts plus generated replies.
    /// `history.len() == fed + 1` (the final sampled token is never fed).
    pub history: Vec<u32>,
    /// Completed turns.
    pub turns: u64,
    /// LRU stamp.  Two clock domains use this field and never cross: the
    /// engine stamps lane-parked snapshots with its own clock (preemption
    /// order among parked lanes); the store re-stamps on every insert
    /// (eviction order among stored snapshots).
    pub last_used: u64,
}

impl SessionSnapshot {
    /// Approximate host bytes held by this snapshot (observability).
    pub fn host_bytes(&self) -> usize {
        let slab = self.kv.host_bytes();
        let tables: usize = self
            .cache
            .heads
            .iter()
            .map(|h| {
                h.entries.len() * std::mem::size_of::<crate::kvcache::SlotEntry>()
                    + h.live.len()
                    + (h.keys.len() + h.vals.len()) * 4
            })
            .sum();
        let mirror: usize = self
            .mirror
            .iter()
            .flat_map(|m| m.iter())
            .map(|e| (e.key.len() + e.val.len()) * 4 + 32)
            .sum();
        slab + tables + mirror + self.history.len() * 4
    }
}

/// Host-side store of swapped-out sessions, LRU-bounded.
#[derive(Debug)]
pub struct SessionStore {
    max_sessions: usize,
    clock: u64,
    map: BTreeMap<String, SessionSnapshot>,
}

impl SessionStore {
    pub fn new(max_sessions: usize) -> SessionStore {
        SessionStore { max_sessions: max_sessions.max(1), clock: 0, map: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.max_sessions
    }

    pub fn contains(&self, id: &str) -> bool {
        self.map.contains_key(id)
    }

    pub fn get(&self, id: &str) -> Option<&SessionSnapshot> {
        self.map.get(id)
    }

    pub fn ids(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    /// Total host bytes across stored snapshots.
    pub fn host_bytes(&self) -> usize {
        self.map.values().map(SessionSnapshot::host_bytes).sum()
    }

    /// Remove and return a snapshot (swap-in takes ownership).
    pub fn take(&mut self, id: &str) -> Option<SessionSnapshot> {
        self.map.remove(id)
    }

    /// Drop a session outright (client close). Returns whether it existed.
    pub fn remove(&mut self, id: &str) -> bool {
        self.map.remove(id).is_some()
    }

    /// Insert (or replace) a snapshot, stamping it most-recently-used.
    /// Returns the number of LRU victims dropped to stay under capacity.
    pub fn insert(&mut self, id: String, mut snap: SessionSnapshot) -> usize {
        self.clock += 1;
        snap.last_used = self.clock;
        self.map.insert(id, snap);
        let mut dropped = 0;
        while self.map.len() > self.max_sessions {
            let lru = self
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map over capacity");
            self.map.remove(&lru);
            dropped += 1;
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{LaneCache, SlotEntry};
    use crate::model_meta::ModelDims;

    fn snap(tag: u32) -> SessionSnapshot {
        let dims = ModelDims { vocab: 512, d: 128, layers: 2, hq: 4, hkv: 2,
                               dh: 4, ffn: 256, gate_hidden: 48 };
        let mut cache = LaneCache::new(&dims, 6, true);
        cache.head_mut(0, 0).insert(
            0,
            SlotEntry { pos: 0, token: tag, log_beta: -0.2, ..Default::default() },
            Some(&[tag as f32, 0.0, 0.0, 0.0]),
        );
        SessionSnapshot {
            cache,
            mirror: vec![Vec::new(); 4],
            kv: LaneKv { k: vec![tag as f32; 2 * 2 * 6 * 4],
                         v: vec![tag as f32; 2 * 2 * 6 * 4] },
            fed: 3,
            history: vec![1, tag, tag + 1, tag + 2],
            turns: 1,
            last_used: 0,
        }
    }

    #[test]
    fn insert_take_roundtrip_is_identity() {
        let mut store = SessionStore::new(4);
        let s = snap(40);
        store.insert("a".into(), s.clone());
        assert!(store.contains("a"));
        let mut back = store.take("a").unwrap();
        assert!(!store.contains("a"));
        // last_used is store metadata; everything else must be untouched
        back.last_used = s.last_used;
        assert_eq!(back, s);
    }

    #[test]
    fn lru_eviction_drops_coldest() {
        let mut store = SessionStore::new(2);
        assert_eq!(store.insert("a".into(), snap(1)), 0);
        assert_eq!(store.insert("b".into(), snap(2)), 0);
        // touching "a" (take + reinsert) makes "b" the LRU victim
        let a = store.take("a").unwrap();
        store.insert("a".into(), a);
        assert_eq!(store.insert("c".into(), snap(3)), 1);
        assert_eq!(store.len(), 2);
        assert!(store.contains("a") && store.contains("c"));
        assert!(!store.contains("b"));
    }

    #[test]
    fn remove_and_bytes() {
        let mut store = SessionStore::new(4);
        store.insert("a".into(), snap(9));
        assert!(store.host_bytes() > 0);
        assert!(store.remove("a"));
        assert!(!store.remove("a"));
        assert!(store.is_empty());
        assert_eq!(store.host_bytes(), 0);
    }
}
