//! Observability plane: tick flight recorder, enumerable metric samples
//! with Prometheus-style text exposition, and retention-score introspection.
//!
//! Layering: `obs` sits on [`crate::util`] only.  `engine`, `metrics` and
//! `server` depend on `obs`, never the reverse, so the hot tick loop can
//! record into the journal without an import cycle.
//!
//! The exposition format is deliberately strict: every rendered line is
//! `name value` or `name{label="v",...} value` — no comment or TYPE lines —
//! so scrapers (and the repo's own tests) can parse it with a two-token
//! split.

pub mod retention;
pub mod trace;

pub use retention::{HeadHist, RetentionObs, AGE_BUCKETS, SCORE_BUCKETS};
pub use trace::{Phase, SpanHandle, TraceEvent, TraceJournal, TID_DEVICE,
                TID_HOST};

use crate::util::stats::{LatencyHistogram, StreamSummary};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleKind {
    Counter,
    Gauge,
}

/// One enumerable metric sample: the unit every exposition surface
/// (Prometheus text, tests, future loadgen) consumes.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(&'static str, String)>,
    pub value: f64,
    pub kind: SampleKind,
}

impl Sample {
    pub fn counter(name: impl Into<String>, value: f64) -> Sample {
        Sample { name: name.into(), labels: Vec::new(), value,
                 kind: SampleKind::Counter }
    }

    pub fn gauge(name: impl Into<String>, value: f64) -> Sample {
        Sample { name: name.into(), labels: Vec::new(), value,
                 kind: SampleKind::Gauge }
    }

    pub fn label(mut self, key: &'static str, value: impl Into<String>) -> Sample {
        self.labels.push((key, value.into()));
        self
    }
}

/// Render samples as Prometheus-style text: one `name{labels} value` line
/// per sample, nothing else.
pub fn render_prometheus(samples: &[Sample]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for s in samples {
        out.push_str(&s.name);
        if !s.labels.is_empty() {
            out.push('{');
            for (i, (k, v)) in s.labels.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{k}=\"{v}\"");
            }
            out.push('}');
        }
        let _ = writeln!(out, " {}", s.value);
    }
    out
}

/// Expand a [`StreamSummary`] into quantile samples plus `_count`/`_sum`
/// (the Prometheus summary convention).  Quantiles are emitted only once
/// the series has samples — empty series never render NaN.
pub fn summary_samples(name: &str, s: &StreamSummary) -> Vec<Sample> {
    let mut out = Vec::new();
    for (q, p) in [("0.5", 50.0), ("0.9", 90.0), ("0.99", 99.0)] {
        if let Some(v) = s.pct(p) {
            out.push(Sample::gauge(name, v).label("quantile", q));
        }
    }
    out.push(Sample::counter(format!("{name}_sum"),
                             s.mean() * s.count() as f64));
    out.push(Sample::counter(format!("{name}_count"), s.count() as f64));
    out
}

/// Expand a [`LatencyHistogram`] into cumulative `_bucket{le="..."}` lines
/// plus `_sum`/`_count` (the Prometheus histogram convention).  Bucket
/// boundaries are the histogram's native powers of two, trimmed at the last
/// occupied bucket.
pub fn histogram_samples(name: &str, h: &LatencyHistogram) -> Vec<Sample> {
    let mut out = Vec::new();
    let buckets = h.buckets();
    if let Some(last) = buckets.iter().rposition(|&c| c > 0) {
        let mut acc = 0u64;
        for (i, &c) in buckets.iter().enumerate().take(last + 1) {
            acc += c;
            out.push(Sample::counter(format!("{name}_bucket"), acc as f64)
                .label("le", (1u64 << (i + 1)).to_string()));
        }
    }
    out.push(Sample::counter(format!("{name}_bucket"), h.count() as f64)
        .label("le", "+Inf"));
    out.push(Sample::counter(format!("{name}_sum"),
                             h.mean_us() * h.count() as f64));
    out.push(Sample::counter(format!("{name}_count"), h.count() as f64));
    out
}

/// The engine's observability bundle: one flight-recorder journal plus the
/// retention histograms, constructed once per engine.
#[derive(Debug)]
pub struct EngineObs {
    pub journal: TraceJournal,
    pub retention: RetentionObs,
}

impl EngineObs {
    pub fn new(trace_capacity: usize, trace_enabled: bool, layers: usize,
               heads: usize) -> EngineObs {
        EngineObs {
            journal: TraceJournal::new(trace_capacity, trace_enabled),
            retention: RetentionObs::new(layers, heads),
        }
    }

    /// The obs plane's own samples (journal health + host-gap + retention
    /// totals); the engine appends these to `EngineMetrics::samples()`.
    pub fn samples(&self) -> Vec<Sample> {
        vec![
            Sample::gauge("trimkv_trace_events", self.journal.len() as f64),
            Sample::counter("trimkv_trace_dropped_total",
                            self.journal.dropped() as f64),
            Sample::counter("trimkv_host_gap_ticks_total",
                            self.journal.host_gap_ticks as f64),
            Sample::counter("trimkv_host_gap_us_total",
                            self.journal.host_gap_us as f64),
            Sample::counter("trimkv_overlap_us_total",
                            (self.journal.overlap_ns / 1000) as f64),
            Sample::counter("trimkv_retention_evictions_total",
                            self.retention.total_evictions() as f64),
        ]
    }
}

/// Strict line-shape check shared by the obs, engine and server exposition
/// tests: every line must split into `name{...}` and a float.
#[cfg(test)]
pub fn assert_prometheus_parses(text: &str) {
    for line in text.lines() {
        let (name, value) = line.rsplit_once(' ')
            .unwrap_or_else(|| panic!("unsplittable line: {line}"));
        assert!(!name.is_empty(), "empty name in: {line}");
        assert!(!name.contains(' ') || name.contains('{'),
                "malformed name in: {line}");
        assert!(value.parse::<f64>().is_ok() || value == "+Inf",
                "unparseable value in: {line}");
        if let Some(open) = name.find('{') {
            assert!(name.ends_with('}'), "unclosed labels: {line}");
            let inner = &name[open + 1..name.len() - 1];
            for pair in inner.split(',') {
                let (k, v) = pair.split_once('=').unwrap();
                assert!(!k.is_empty() && v.starts_with('"')
                            && v.ends_with('"'),
                        "bad label `{pair}` in: {line}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_names_labels_values() {
        let samples = vec![
            Sample::counter("trimkv_tokens_total", 42.0),
            Sample::gauge("trimkv_step_us", 1.5)
                .label("quantile", "0.5"),
        ];
        let text = render_prometheus(&samples);
        assert_eq!(text, "trimkv_tokens_total 42\n\
                          trimkv_step_us{quantile=\"0.5\"} 1.5\n");
        assert_prometheus_parses(&text);
    }

    #[test]
    fn summary_samples_skip_quantiles_when_empty() {
        let empty = StreamSummary::new();
        let s = summary_samples("trimkv_tbt_us", &empty);
        assert_eq!(s.len(), 2, "only _sum and _count for an empty series");
        assert!(s.iter().all(|x| x.value == 0.0));
        let mut pop = StreamSummary::new();
        pop.push(5.0);
        pop.push(7.0);
        let s = summary_samples("trimkv_tbt_us", &pop);
        assert_eq!(s.len(), 5);
        let count = s.iter().find(|x| x.name.ends_with("_count")).unwrap();
        assert_eq!(count.value, 2.0);
        let sum = s.iter().find(|x| x.name.ends_with("_sum")).unwrap();
        assert!((sum.value - 12.0).abs() < 1e-9);
        assert_prometheus_parses(&render_prometheus(&s));
    }

    #[test]
    fn histogram_samples_are_cumulative_with_inf_bucket() {
        let mut h = LatencyHistogram::new();
        h.record_us(3.0); // bucket 1 ([2,4))
        h.record_us(3.5);
        h.record_us(100.0); // bucket 6 ([64,128))
        let s = histogram_samples("trimkv_ttft_us", &h);
        let buckets: Vec<&Sample> =
            s.iter().filter(|x| x.name.ends_with("_bucket")).collect();
        // trimmed at the last occupied bucket, plus +Inf
        assert_eq!(buckets.len(), 8);
        let values: Vec<f64> = buckets.iter().map(|x| x.value).collect();
        assert!(values.windows(2).all(|w| w[0] <= w[1]), "not cumulative");
        assert_eq!(buckets.last().unwrap().labels[0].1, "+Inf");
        assert_eq!(buckets.last().unwrap().value, 3.0);
        assert_prometheus_parses(&render_prometheus(&s));
        // empty histogram: just the +Inf bucket and zero _sum/_count
        let s = histogram_samples("x", &LatencyHistogram::new());
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn engine_obs_samples_cover_journal_and_retention() {
        let mut obs = EngineObs::new(8, true, 2, 2);
        let t = obs.journal.now_us();
        obs.journal.record(0, Phase::Execute, "decode", 1, t);
        obs.retention.record_eviction(0, 1, -0.1, 3);
        obs.journal.note_overlap(2_500);
        let s = obs.samples();
        let get = |n: &str| s.iter().find(|x| x.name == n).unwrap().value;
        assert_eq!(get("trimkv_trace_events"), 1.0);
        assert_eq!(get("trimkv_host_gap_ticks_total"), 0.0);
        assert_eq!(get("trimkv_overlap_us_total"), 2.0);
        assert_eq!(get("trimkv_retention_evictions_total"), 1.0);
        assert_prometheus_parses(&render_prometheus(&s));
    }
}
