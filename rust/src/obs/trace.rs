//! Tick flight recorder: a bounded ring-buffer journal of `step_tick` phase
//! spans, exportable as Chrome-trace (`chrome://tracing` / Perfetto) JSON.
//!
//! One [`TraceEvent`] per phase per tick, O(1) memory per event and a hard
//! capacity cap: once the ring is full the oldest events are overwritten
//! (and counted in `dropped`), so the journal can run forever in serving.
//! Phase spans chain through [`TraceJournal::record`] — the returned end
//! timestamp is the next phase's start — which makes the exported spans
//! monotone and non-overlapping by construction.
//!
//! The journal also owns the device-idle accounting ROADMAP item 2 needs:
//! [`TraceJournal::note_host_gap`] counts ticks where runnable work existed
//! but no step executed.  The current engine loop is strictly serial (a
//! runnable tick always executes), so both gap counters are structurally
//! zero today; they arm the moment pipelined execution lands.

use std::time::Instant;

use crate::util::json::Json;

/// One `step_tick` phase (plus the session-swap step around it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Plan,
    Assemble,
    Execute,
    Postprocess,
    Swap,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Plan => "plan",
            Phase::Assemble => "assemble",
            Phase::Execute => "execute",
            Phase::Postprocess => "postprocess",
            Phase::Swap => "swap",
        }
    }
}

/// One recorded phase span.  `Copy` and fixed-size: journal memory is
/// exactly `capacity * size_of::<TraceEvent>()` no matter the uptime.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// span start, microseconds since journal creation
    pub ts_us: u64,
    pub dur_us: u64,
    pub tick: u64,
    pub phase: Phase,
    /// plan-kind label for the tick ("decode" | "chunk" | "mixed" | "swap")
    pub kind: &'static str,
    /// active lanes in the tick's plan (lanes moved, for a swap span)
    pub lanes: u32,
}

/// Bounded ring-buffer trace journal (see module docs).
#[derive(Debug)]
pub struct TraceJournal {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// next write index; once the ring is full this is also the oldest event
    head: usize,
    dropped: u64,
    epoch: Instant,
    enabled: bool,
    /// ticks where runnable work existed but no step executed (serial loop:
    /// always 0; pipelined execution will make this the device-idle metric)
    pub host_gap_ticks: u64,
    /// host-side microseconds accumulated across those gap ticks
    pub host_gap_us: u64,
}

impl TraceJournal {
    pub fn new(cap: usize, enabled: bool) -> TraceJournal {
        TraceJournal {
            buf: Vec::with_capacity(if enabled { cap.min(1024) } else { 0 }),
            cap,
            head: 0,
            dropped: 0,
            epoch: Instant::now(),
            enabled,
            host_gap_ticks: 0,
            host_gap_us: 0,
        }
    }

    /// Microseconds since the journal epoch: the timebase every span uses.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Close the span that started at `start_us` (from [`Self::now_us`] or a
    /// previous `record` return) and return its end timestamp — feed that
    /// into the next phase's `record` so spans never overlap.
    pub fn record(&mut self, tick: u64, phase: Phase, kind: &'static str,
                  lanes: u32, start_us: u64) -> u64 {
        let end = self.now_us();
        if self.enabled && self.cap > 0 {
            let ev = TraceEvent {
                ts_us: start_us,
                dur_us: end.saturating_sub(start_us),
                tick,
                phase,
                kind,
                lanes,
            };
            if self.buf.len() < self.cap {
                self.buf.push(ev);
            } else {
                self.buf[self.head] = ev;
                self.head = (self.head + 1) % self.cap;
                self.dropped += 1;
            }
        }
        end
    }

    /// Device-idle accounting: a tick that had runnable work but executed
    /// no step is a host gap.  The serial loop never produces one.
    pub fn note_host_gap(&mut self, runnable: bool, executed: bool,
                         gap_us: u64) {
        if runnable && !executed {
            self.host_gap_ticks += 1;
            self.host_gap_us += gap_us;
        }
    }

    /// Retained events in chronological order (oldest first).
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        let split = if self.buf.len() == self.cap { self.head } else { 0 };
        let (older, newer) = self.buf.split_at(split);
        newer.iter().chain(older.iter())
    }

    /// Export the retained spans as a Chrome-trace JSON object
    /// (`{"traceEvents": [...]}`), loadable in chrome://tracing / Perfetto.
    pub fn chrome_trace(&self) -> Json {
        let events: Vec<Json> = self
            .events()
            .map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.phase.name())),
                    ("cat", Json::str(e.kind)),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(e.ts_us as f64)),
                    ("dur", Json::num(e.dur_us as f64)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(1.0)),
                    ("args", Json::obj(vec![
                        ("tick", Json::num(e.tick as f64)),
                        ("lanes", Json::num(e.lanes as f64)),
                    ])),
                ])
            })
            .collect();
        Json::obj(vec![("traceEvents", Json::Arr(events))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_never_exceeds_cap_and_counts_drops() {
        let mut j = TraceJournal::new(8, true);
        let mut t = j.now_us();
        for tick in 0..100u64 {
            t = j.record(tick, Phase::Execute, "decode", 1, t);
        }
        assert_eq!(j.len(), 8);
        assert_eq!(j.capacity(), 8);
        assert_eq!(j.dropped(), 92);
        // chronological iteration yields the newest 8 ticks in order
        let ticks: Vec<u64> = j.events().map(|e| e.tick).collect();
        assert_eq!(ticks, (92..100).collect::<Vec<_>>());
    }

    #[test]
    fn disabled_journal_records_nothing_but_still_times() {
        let mut j = TraceJournal::new(8, false);
        let t0 = j.now_us();
        let t1 = j.record(0, Phase::Plan, "decode", 1, t0);
        assert!(t1 >= t0);
        assert!(j.is_empty());
        assert_eq!(j.dropped(), 0);
    }

    #[test]
    fn chained_records_are_monotone_and_non_overlapping() {
        let mut j = TraceJournal::new(64, true);
        let mut t = j.now_us();
        for tick in 0..4u64 {
            for ph in [Phase::Plan, Phase::Assemble, Phase::Execute,
                       Phase::Postprocess] {
                t = j.record(tick, ph, "mixed", 2, t);
            }
        }
        let evs: Vec<&TraceEvent> = j.events().collect();
        assert_eq!(evs.len(), 16);
        for w in evs.windows(2) {
            assert!(w[0].ts_us + w[0].dur_us <= w[1].ts_us,
                    "spans overlap: {:?} then {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_span_fields() {
        let mut j = TraceJournal::new(16, true);
        let t = j.now_us();
        j.record(7, Phase::Execute, "chunk", 3, t);
        let text = j.chrome_trace().to_string();
        let parsed = Json::parse(&text).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].str_field("name").unwrap(), "execute");
        assert_eq!(evs[0].str_field("cat").unwrap(), "chunk");
        assert_eq!(evs[0].str_field("ph").unwrap(), "X");
        assert_eq!(evs[0].path("args.tick").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn host_gap_counts_only_runnable_unexecuted_ticks() {
        let mut j = TraceJournal::new(4, true);
        j.note_host_gap(true, true, 10); // executed: not a gap
        j.note_host_gap(false, false, 10); // idle: not a gap
        assert_eq!(j.host_gap_ticks, 0);
        j.note_host_gap(true, false, 10);
        assert_eq!(j.host_gap_ticks, 1);
        assert_eq!(j.host_gap_us, 10);
    }
}
