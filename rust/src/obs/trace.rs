//! Tick flight recorder: a bounded ring-buffer journal of engine-tick phase
//! spans, exportable as Chrome-trace (`chrome://tracing` / Perfetto) JSON.
//!
//! One [`TraceEvent`] per phase per tick, O(1) memory per event and a hard
//! capacity cap: once the ring is full the oldest events are overwritten
//! (and counted in `dropped`), so the journal can run forever in serving.
//! Host-side phase spans chain through [`TraceJournal::record`] — the
//! returned end timestamp is the next phase's start — which makes the
//! exported spans monotone and non-overlapping per track by construction.
//! Device execution spans are open-ended: [`TraceJournal::begin_span`] at
//! submit, [`TraceJournal::end_span`] at wait patches the duration in
//! place, and the span renders on its own "device" track (tid 2) so the
//! pipelined overlap is directly visible in Perfetto.
//!
//! The journal also owns the device-idle accounting ROADMAP item 2 needed:
//! [`TraceJournal::note_host_gap`] counts ticks where runnable work existed
//! but no step executed — structurally zero on both the serial and the
//! pipelined loop (a runnable tick always submits), and the CI gate that
//! keeps it that way.  [`TraceJournal::note_overlap`] accumulates the host
//! work done while a step was in flight (the pipelined loop's win).

use std::time::Instant;

use crate::util::json::Json;

/// One engine-tick phase (plus the session-swap step around it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Plan,
    Assemble,
    Execute,
    Postprocess,
    Swap,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Plan => "plan",
            Phase::Assemble => "assemble",
            Phase::Execute => "execute",
            Phase::Postprocess => "postprocess",
            Phase::Swap => "swap",
        }
    }
}

/// Chrome-trace track for host-side phase spans (plan/assemble/postprocess
/// and swaps issued from the tick loop).
pub const TID_HOST: u32 = 1;
/// Chrome-trace track for device execution spans (submit → wait): a
/// separate row in Perfetto, so overlap with host work is visible.
pub const TID_DEVICE: u32 = 2;

/// One recorded phase span.  `Copy` and fixed-size: journal memory is
/// exactly `capacity * size_of::<TraceEvent>()` no matter the uptime.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// span start, microseconds since journal creation
    pub ts_us: u64,
    pub dur_us: u64,
    pub tick: u64,
    pub phase: Phase,
    /// plan-kind label for the tick ("decode" | "chunk" | "mixed" | "swap")
    pub kind: &'static str,
    /// active lanes in the tick's plan (lanes moved, for a swap span)
    pub lanes: u32,
    /// Chrome-trace track ([`TID_HOST`] or [`TID_DEVICE`])
    pub tid: u32,
}

/// Handle to an open span begun with [`TraceJournal::begin_span`]: feed it
/// to `end_span` to patch the duration in place.  Carries the record's
/// sequence number so a span overwritten by ring wraparound while open is
/// detected and skipped rather than corrupting an unrelated event.
#[derive(Debug, Clone, Copy)]
pub struct SpanHandle {
    seq: u64,
    start_us: u64,
    live: bool,
}

/// Bounded ring-buffer trace journal (see module docs).
#[derive(Debug)]
pub struct TraceJournal {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// next write index; once the ring is full this is also the oldest event
    head: usize,
    dropped: u64,
    epoch: Instant,
    enabled: bool,
    /// ticks where runnable work existed but no step executed — the
    /// device-idle metric, structurally zero on both loop shapes and
    /// gated so in CI
    pub host_gap_ticks: u64,
    /// host-side microseconds accumulated across those gap ticks
    pub host_gap_us: u64,
    /// host-side nanoseconds of useful work done while a step was in
    /// flight (window admission, chained swaps, completed-tick
    /// postprocess) — exposed as `trimkv_overlap_us_total`
    pub overlap_ns: u64,
}

impl TraceJournal {
    pub fn new(cap: usize, enabled: bool) -> TraceJournal {
        TraceJournal {
            buf: Vec::with_capacity(if enabled { cap.min(1024) } else { 0 }),
            cap,
            head: 0,
            dropped: 0,
            epoch: Instant::now(),
            enabled,
            host_gap_ticks: 0,
            host_gap_us: 0,
            overlap_ns: 0,
        }
    }

    /// Microseconds since the journal epoch: the timebase every span uses.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Close the span that started at `start_us` (from [`Self::now_us`] or a
    /// previous `record` return) and return its end timestamp — feed that
    /// into the next phase's `record` so spans never overlap.
    pub fn record(&mut self, tick: u64, phase: Phase, kind: &'static str,
                  lanes: u32, start_us: u64) -> u64 {
        let end = self.now_us();
        self.push(TraceEvent {
            ts_us: start_us,
            dur_us: end.saturating_sub(start_us),
            tick,
            phase,
            kind,
            lanes,
            tid: TID_HOST,
        });
        end
    }

    /// Append an event to the ring, returning its sequence number (total
    /// records ever made; `u64::MAX` when recording is off).
    fn push(&mut self, ev: TraceEvent) -> u64 {
        if !self.enabled || self.cap == 0 {
            return u64::MAX;
        }
        let seq = self.buf.len() as u64 + self.dropped;
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
        seq
    }

    /// Open a span at the current timestamp on track `tid` — used for the
    /// device execute span, recorded at submit and still open while host
    /// work proceeds.  The event enters the ring now (buffer order stays
    /// chronological by start time); `end_span` patches the duration.
    pub fn begin_span(&mut self, tick: u64, phase: Phase, kind: &'static str,
                      lanes: u32, tid: u32) -> SpanHandle {
        let start_us = self.now_us();
        if !self.enabled || self.cap == 0 {
            return SpanHandle { seq: 0, start_us, live: false };
        }
        let seq = self.push(TraceEvent {
            ts_us: start_us,
            dur_us: 0,
            tick,
            phase,
            kind,
            lanes,
            tid,
        });
        SpanHandle { seq, start_us, live: true }
    }

    /// Close an open span, patching its duration in place.  A span whose
    /// ring slot was overwritten while it was open (journal smaller than
    /// the pipeline depth) is silently skipped.
    pub fn end_span(&mut self, h: SpanHandle) {
        if !h.live {
            return;
        }
        let total = self.buf.len() as u64 + self.dropped;
        if total.saturating_sub(h.seq) <= self.cap as u64 {
            let idx = (h.seq % self.cap as u64) as usize;
            self.buf[idx].dur_us = self.now_us().saturating_sub(h.start_us);
        }
    }

    /// Accumulate host work performed while a step was in flight.
    pub fn note_overlap(&mut self, ns: u64) {
        self.overlap_ns += ns;
    }

    /// Device-idle accounting: a tick that had runnable work but executed
    /// no step is a host gap.  The serial loop never produces one.
    pub fn note_host_gap(&mut self, runnable: bool, executed: bool,
                         gap_us: u64) {
        if runnable && !executed {
            self.host_gap_ticks += 1;
            self.host_gap_us += gap_us;
        }
    }

    /// Retained events in chronological order (oldest first).
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        let split = if self.buf.len() == self.cap { self.head } else { 0 };
        let (older, newer) = self.buf.split_at(split);
        newer.iter().chain(older.iter())
    }

    /// Export the retained spans as a Chrome-trace JSON object
    /// (`{"traceEvents": [...]}`), loadable in chrome://tracing / Perfetto.
    pub fn chrome_trace(&self) -> Json {
        let events: Vec<Json> = self
            .events()
            .map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.phase.name())),
                    ("cat", Json::str(e.kind)),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(e.ts_us as f64)),
                    ("dur", Json::num(e.dur_us as f64)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(e.tid as f64)),
                    ("args", Json::obj(vec![
                        ("tick", Json::num(e.tick as f64)),
                        ("lanes", Json::num(e.lanes as f64)),
                    ])),
                ])
            })
            .collect();
        Json::obj(vec![("traceEvents", Json::Arr(events))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_never_exceeds_cap_and_counts_drops() {
        let mut j = TraceJournal::new(8, true);
        let mut t = j.now_us();
        for tick in 0..100u64 {
            t = j.record(tick, Phase::Execute, "decode", 1, t);
        }
        assert_eq!(j.len(), 8);
        assert_eq!(j.capacity(), 8);
        assert_eq!(j.dropped(), 92);
        // chronological iteration yields the newest 8 ticks in order
        let ticks: Vec<u64> = j.events().map(|e| e.tick).collect();
        assert_eq!(ticks, (92..100).collect::<Vec<_>>());
    }

    #[test]
    fn disabled_journal_records_nothing_but_still_times() {
        let mut j = TraceJournal::new(8, false);
        let t0 = j.now_us();
        let t1 = j.record(0, Phase::Plan, "decode", 1, t0);
        assert!(t1 >= t0);
        assert!(j.is_empty());
        assert_eq!(j.dropped(), 0);
    }

    #[test]
    fn chained_records_are_monotone_and_non_overlapping() {
        let mut j = TraceJournal::new(64, true);
        let mut t = j.now_us();
        for tick in 0..4u64 {
            for ph in [Phase::Plan, Phase::Assemble, Phase::Execute,
                       Phase::Postprocess] {
                t = j.record(tick, ph, "mixed", 2, t);
            }
        }
        let evs: Vec<&TraceEvent> = j.events().collect();
        assert_eq!(evs.len(), 16);
        for w in evs.windows(2) {
            assert!(w[0].ts_us + w[0].dur_us <= w[1].ts_us,
                    "spans overlap: {:?} then {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_span_fields() {
        let mut j = TraceJournal::new(16, true);
        let t = j.now_us();
        j.record(7, Phase::Execute, "chunk", 3, t);
        let text = j.chrome_trace().to_string();
        let parsed = Json::parse(&text).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].str_field("name").unwrap(), "execute");
        assert_eq!(evs[0].str_field("cat").unwrap(), "chunk");
        assert_eq!(evs[0].str_field("ph").unwrap(), "X");
        assert_eq!(evs[0].path("args.tick").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn begin_end_span_patches_duration_in_place() {
        let mut j = TraceJournal::new(16, true);
        let h = j.begin_span(3, Phase::Execute, "decode", 2, TID_DEVICE);
        // host work recorded while the span is open: buffer stays
        // chronological because the open span entered at begin time
        let t = j.now_us();
        j.record(3, Phase::Postprocess, "decode", 2, t);
        std::thread::sleep(std::time::Duration::from_millis(2));
        j.end_span(h);
        let evs: Vec<&TraceEvent> = j.events().collect();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].phase, Phase::Execute);
        assert_eq!(evs[0].tid, TID_DEVICE);
        assert!(evs[0].dur_us >= 2000, "duration not patched: {:?}", evs[0]);
        assert_eq!(evs[1].tid, TID_HOST);
        assert!(evs[0].ts_us <= evs[1].ts_us, "buffer order not chronological");
    }

    #[test]
    fn end_span_skips_slots_overwritten_while_open() {
        let mut j = TraceJournal::new(2, true);
        let h = j.begin_span(0, Phase::Execute, "decode", 1, TID_DEVICE);
        let mut t = j.now_us();
        for tick in 1..5u64 {
            t = j.record(tick, Phase::Plan, "decode", 1, t);
        }
        j.end_span(h); // slot long since recycled: must not corrupt it
        for ev in j.events() {
            assert_eq!(ev.phase, Phase::Plan, "stale end_span hit {ev:?}");
        }
    }

    #[test]
    fn disabled_journal_spans_are_inert_and_overlap_still_counts() {
        let mut j = TraceJournal::new(8, false);
        let h = j.begin_span(0, Phase::Execute, "decode", 1, TID_DEVICE);
        j.end_span(h);
        assert!(j.is_empty());
        j.note_overlap(1500);
        assert_eq!(j.overlap_ns, 1500);
    }

    #[test]
    fn host_gap_counts_only_runnable_unexecuted_ticks() {
        let mut j = TraceJournal::new(4, true);
        j.note_host_gap(true, true, 10); // executed: not a gap
        j.note_host_gap(false, false, 10); // idle: not a gap
        assert_eq!(j.host_gap_ticks, 0);
        j.note_host_gap(true, false, 10);
        assert_eq!(j.host_gap_ticks, 1);
        assert_eq!(j.host_gap_us, 10);
    }
}
