//! Retention-score introspection: bounded per-(layer, head) histograms of
//! the retention score a token carried *at eviction time* and of how old it
//! was when it died.
//!
//! This is the paper's interpretability claim made observable from live
//! serving data: heads that evict only *young* tokens are keeping their old
//! ones (attention-sink behaviour), heads that evict *old, low-score*
//! tokens behave like a sliding window, and heads that evict tokens whose
//! scores are still high are doing selective/gist-style retention where
//! budget pressure — not the gate — forces the kill.  The hook sits in the
//! engine's `postprocess_lane` eviction loop, so every policy (not just
//! trim-kv) produces a comparable report.
//!
//! Memory is fixed: `layers * heads` histograms of
//! `SCORE_BUCKETS + AGE_BUCKETS` u64 buckets, regardless of uptime.

use crate::util::benchkit::Table;

/// Linear buckets over `beta = exp(log_beta)` in [0, 1).
pub const SCORE_BUCKETS: usize = 16;
/// Log2 buckets over eviction age; bucket i covers [2^i, 2^(i+1)) ticks.
pub const AGE_BUCKETS: usize = 16;

/// One (layer, head)'s eviction histograms.
#[derive(Debug, Clone)]
pub struct HeadHist {
    pub score: [u64; SCORE_BUCKETS],
    pub age: [u64; AGE_BUCKETS],
    pub count: u64,
    score_sum: f64,
    age_sum: f64,
}

impl Default for HeadHist {
    fn default() -> Self {
        HeadHist {
            score: [0; SCORE_BUCKETS],
            age: [0; AGE_BUCKETS],
            count: 0,
            score_sum: 0.0,
            age_sum: 0.0,
        }
    }
}

impl HeadHist {
    /// Mean retention score (beta) across this head's evictions.
    pub fn mean_beta(&self) -> Option<f64> {
        if self.count == 0 { None } else { Some(self.score_sum / self.count as f64) }
    }

    pub fn mean_age(&self) -> Option<f64> {
        if self.count == 0 { None } else { Some(self.age_sum / self.count as f64) }
    }

    /// Approximate age percentile from the log2 buckets (upper bound).
    pub fn age_pct(&self, p: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.age.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(1u64 << (i + 1));
            }
        }
        Some(1u64 << AGE_BUCKETS)
    }

    /// Heuristic tag recovering the paper's head-role taxonomy from the
    /// eviction signature alone.  Deliberately coarse — it labels the
    /// report, it does not drive any decision.
    pub fn signature(&self) -> &'static str {
        let (Some(beta), Some(p50)) = (self.mean_beta(), self.age_pct(50.0))
        else {
            return "-";
        };
        if p50 <= 4 {
            // evicted tokens die young: old tokens are being retained
            "sink-like"
        } else if beta < 0.5 {
            // old, low-score victims: gate decay tracks recency
            "sliding-window"
        } else if beta >= 0.75 {
            // victims still scored high: budget pressure, selective churn
            "gist/selective"
        } else {
            "mixed"
        }
    }
}

/// Per-(layer, head) eviction histograms for a whole model.
#[derive(Debug)]
pub struct RetentionObs {
    layers: usize,
    heads: usize,
    hists: Vec<HeadHist>,
}

impl RetentionObs {
    pub fn new(layers: usize, heads: usize) -> RetentionObs {
        RetentionObs {
            layers,
            heads,
            hists: vec![HeadHist::default(); layers * heads],
        }
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head(&self, layer: usize, head: usize) -> &HeadHist {
        &self.hists[layer * self.heads + head]
    }

    pub fn total_evictions(&self) -> u64 {
        self.hists.iter().map(|h| h.count).sum()
    }

    /// Record one eviction: the victim's gate output (`log_beta`) and its
    /// age (current position minus the victim's write position, >= 0).
    pub fn record_eviction(&mut self, layer: usize, head: usize,
                           log_beta: f32, age: i64) {
        let h = &mut self.hists[layer * self.heads + head];
        let beta = (log_beta as f64).exp().clamp(0.0, 1.0);
        let si = ((beta * SCORE_BUCKETS as f64) as usize).min(SCORE_BUCKETS - 1);
        h.score[si] += 1;
        let age = age.max(0) as u64;
        let ai = if age < 2 {
            0
        } else {
            ((age as f64).log2() as usize).min(AGE_BUCKETS - 1)
        };
        h.age[ai] += 1;
        h.count += 1;
        h.score_sum += beta;
        h.age_sum += age as f64;
    }

    /// Human-readable per-head report (the `trimkv inspect --retention`
    /// payload): evictions, mean retention score, age percentiles, and the
    /// heuristic sink / sliding-window / gist signature per (layer, head).
    pub fn report(&self) -> String {
        let mut t = Table::new(&["layer", "head", "evicted", "mean_beta",
                                 "age_p50", "age_p90", "signature"]);
        for li in 0..self.layers {
            for hi in 0..self.heads {
                let h = self.head(li, hi);
                let fmt_opt = |v: Option<u64>| {
                    v.map(|x| x.to_string()).unwrap_or_else(|| "-".into())
                };
                t.row(vec![
                    li.to_string(),
                    hi.to_string(),
                    h.count.to_string(),
                    h.mean_beta()
                        .map(|b| format!("{b:.3}"))
                        .unwrap_or_else(|| "-".into()),
                    fmt_opt(h.age_pct(50.0)),
                    fmt_opt(h.age_pct(90.0)),
                    h.signature().to_string(),
                ]);
            }
        }
        format!("retention at eviction ({} evictions)\n{}",
                self.total_evictions(), t.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_score_and_age() {
        let mut r = RetentionObs::new(2, 2);
        // beta ~= 0.95 -> top score bucket; age 10 -> log2 bucket 3
        r.record_eviction(1, 0, (0.95f32).ln(), 10);
        let h = r.head(1, 0);
        assert_eq!(h.count, 1);
        assert_eq!(h.score[15], 1);
        assert_eq!(h.age[3], 1);
        assert!((h.mean_beta().unwrap() - 0.95).abs() < 1e-3);
        assert_eq!(h.mean_age().unwrap(), 10.0);
        // untouched heads stay empty
        assert_eq!(r.head(0, 0).count, 0);
        assert_eq!(r.total_evictions(), 1);
    }

    #[test]
    fn edge_ages_and_scores_clamp_into_range() {
        let mut r = RetentionObs::new(1, 1);
        r.record_eviction(0, 0, 0.0, 0); // beta = 1.0 clamps to top bucket
        r.record_eviction(0, 0, -100.0, -5); // beta ~ 0, negative age -> 0
        r.record_eviction(0, 0, 0.0, i64::MAX); // huge age -> last bucket
        let h = r.head(0, 0);
        assert_eq!(h.count, 3);
        assert_eq!(h.score[SCORE_BUCKETS - 1], 2);
        assert_eq!(h.score[0], 1);
        assert_eq!(h.age[0], 2);
        assert_eq!(h.age[AGE_BUCKETS - 1], 1);
    }

    #[test]
    fn signatures_follow_the_heuristics() {
        let mut r = RetentionObs::new(1, 4);
        assert_eq!(r.head(0, 3).signature(), "-");
        // head 0: young victims -> sink-like
        for _ in 0..10 {
            r.record_eviction(0, 0, (0.6f32).ln(), 2);
        }
        assert_eq!(r.head(0, 0).signature(), "sink-like");
        // head 1: old low-score victims -> sliding-window
        for _ in 0..10 {
            r.record_eviction(0, 1, (0.2f32).ln(), 100);
        }
        assert_eq!(r.head(0, 1).signature(), "sliding-window");
        // head 2: old high-score victims -> gist/selective
        for _ in 0..10 {
            r.record_eviction(0, 2, (0.9f32).ln(), 100);
        }
        assert_eq!(r.head(0, 2).signature(), "gist/selective");
    }

    #[test]
    fn report_renders_every_head() {
        let mut r = RetentionObs::new(2, 2);
        r.record_eviction(0, 1, (0.8f32).ln(), 7);
        let rep = r.report();
        assert!(rep.contains("signature"));
        // header + rule + 4 head rows + leading summary line
        assert_eq!(rep.trim_end().lines().count(), 7);
        assert!(rep.contains("retention at eviction (1 evictions)"));
    }
}
