//! Eviction policies: TRIM-KV (the paper's contribution) plus every baseline
//! the paper compares against (§5.1): StreamingLLM, H2O, SnapKV, R-KV,
//! KeyDiff, LocRet, random, full-cache, and a SeerAttn-R-style retrieval
//! mode (handled jointly with the engine's inject path).
//!
//! A policy is a victim-selection rule over one head's slot table.  The
//! engine calls `select_victim` whenever a head exceeds its budget; the
//! returned slot is overwritten by the next token (the paper's O(M) scheme:
//! eviction is a mask-bit flip plus slot reuse).

use crate::kvcache::HeadState;
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    /// Paper: evict argmin beta_i^(now-i) — learned intrinsic importance
    /// with exponential decay.
    TrimKv,
    /// Xiao et al. 2023: keep `sinks` initial tokens + the most recent rest.
    StreamingLlm { sinks: usize },
    /// Zhang et al. 2023: keep heavy hitters by accumulated attention,
    /// protecting the `recent` newest tokens.
    H2O { recent: usize },
    /// Li et al. 2024: observation-window attention (EMA adaptation for
    /// long generation), protecting the `recent` newest tokens.
    SnapKv { recent: usize },
    /// Cai et al. 2025: importance + key-diversity (redundant tokens go
    /// first), protecting the `recent` newest tokens.
    RKv { lambda: f32, recent: usize },
    /// Park et al. 2025: key diversity only (query-agnostic).
    KeyDiff,
    /// Huang et al. 2024: trained retaining score without decay + a
    /// hand-crafted recent-window protection.
    LocRet { recent: usize },
    /// Uniform random among live slots.
    RandomEvict,
    /// Never evict (requires slots >= sequence length).
    FullKv,
    /// SeerAttn-R-like learnable retrieval: resident set managed like
    /// SnapKV, but evicted tokens stay in a host mirror and can be
    /// re-admitted via the engine's inject path.
    Retrieval { recent: usize },
}

#[derive(Debug)]
pub struct Policy {
    pub kind: PolicyKind,
    rng: Rng,
}

pub const POLICY_NAMES: &[&str] = &[
    "trimkv", "streaming_llm", "h2o", "snapkv", "rkv", "keydiff", "locret",
    "random", "fullkv", "retrieval",
];

impl Policy {
    pub fn from_name(name: &str, budget: usize, seed: u64) -> anyhow::Result<Policy> {
        // recent-window protection scaled to the budget, as in the baselines'
        // reference implementations (1/8 of budget, >= 4)
        let recent = (budget / 8).max(4);
        let kind = match name {
            "trimkv" => PolicyKind::TrimKv,
            "streaming_llm" => PolicyKind::StreamingLlm { sinks: 4 },
            "h2o" => PolicyKind::H2O { recent },
            "snapkv" => PolicyKind::SnapKv { recent },
            "rkv" => PolicyKind::RKv { lambda: 0.5, recent },
            "keydiff" => PolicyKind::KeyDiff,
            "locret" => PolicyKind::LocRet { recent },
            "random" => PolicyKind::RandomEvict,
            "fullkv" => PolicyKind::FullKv,
            "retrieval" => PolicyKind::Retrieval { recent },
            other => anyhow::bail!("unknown policy `{other}` (expected one of {POLICY_NAMES:?})"),
        };
        Ok(Policy { kind, rng: Rng::new(seed ^ 0x9e37) })
    }

    /// Gate-weight variant this policy expects (LocRet uses its own heads).
    pub fn gate_variant(&self) -> &'static str {
        match self.kind {
            PolicyKind::LocRet { .. } => "locret",
            _ => "default",
        }
    }

    /// Does victim selection consume the per-step attention statistics?
    pub fn needs_attention(&self) -> bool {
        matches!(self.kind,
                 PolicyKind::H2O { .. } | PolicyKind::SnapKv { .. }
                 | PolicyKind::RKv { .. } | PolicyKind::Retrieval { .. })
    }

    pub fn needs_keys(&self) -> bool {
        matches!(self.kind,
                 PolicyKind::RKv { .. } | PolicyKind::KeyDiff
                 | PolicyKind::Retrieval { .. })
    }

    pub fn is_retrieval(&self) -> bool {
        matches!(self.kind, PolicyKind::Retrieval { .. })
    }

    /// Pick the live slot to overwrite; `None` means "do not evict".
    pub fn select_victim(&mut self, head: &HeadState, now: i64) -> Option<usize> {
        if head.used == 0 {
            return None;
        }
        match self.kind {
            PolicyKind::FullKv => None,
            PolicyKind::TrimKv => argmin_live(head, |h, s| h.retention_score(s, now)),
            PolicyKind::StreamingLlm { sinks } => {
                // evict the oldest token that is not one of the first `sinks`
                let min_kept: Vec<i64> = {
                    let mut ps: Vec<i64> =
                        head.live_slots().map(|s| head.entries[s].pos).collect();
                    ps.sort_unstable();
                    ps.into_iter().take(sinks).collect()
                };
                argmin_live_filtered(
                    head,
                    |h, s| h.entries[s].pos as f32,
                    |h, s| !min_kept.contains(&h.entries[s].pos),
                )
                .or_else(|| argmin_live(head, |h, s| h.entries[s].pos as f32))
            }
            PolicyKind::H2O { recent } => protected_argmin(
                head, now, recent, |h, s| h.entries[s].acc_attn),
            PolicyKind::SnapKv { recent } | PolicyKind::Retrieval { recent } => {
                protected_argmin(head, now, recent, |h, s| h.entries[s].ema_attn)
            }
            PolicyKind::RKv { lambda, recent } => {
                let sims = max_key_similarity(head);
                protected_argmin(head, now, recent, |h, s| {
                    lambda * h.entries[s].ema_attn + (1.0 - lambda) * (1.0 - sims[s])
                })
            }
            PolicyKind::KeyDiff => {
                let sims = max_key_similarity(head);
                argmin_live(head, |_, s| 1.0 - sims[s])
            }
            PolicyKind::LocRet { recent } => protected_argmin(
                head, now, recent, |h, s| h.entries[s].log_beta),
            PolicyKind::RandomEvict => {
                let live: Vec<usize> = head.live_slots().collect();
                Some(live[self.rng.below(live.len())])
            }
        }
    }
}

fn argmin_live<F>(head: &HeadState, score: F) -> Option<usize>
where
    F: Fn(&HeadState, usize) -> f32,
{
    argmin_live_filtered(head, score, |_, _| true)
}

fn argmin_live_filtered<F, P>(head: &HeadState, score: F, keep: P) -> Option<usize>
where
    F: Fn(&HeadState, usize) -> f32,
    P: Fn(&HeadState, usize) -> bool,
{
    let mut best: Option<(usize, f32, i64)> = None;
    for s in head.live_slots() {
        if !keep(head, s) {
            continue;
        }
        let sc = score(head, s);
        let pos = head.entries[s].pos;
        // ties break toward the older token (smaller pos)
        let better = match best {
            None => true,
            Some((_, bs, bp)) => sc < bs || (sc == bs && pos < bp),
        };
        if better {
            best = Some((s, sc, pos));
        }
    }
    best.map(|(s, _, _)| s)
}

/// argmin of `score` among live slots older than the protected recent
/// window; falls back to a global argmin when everything is protected.
fn protected_argmin<F>(head: &HeadState, now: i64, recent: usize,
                       score: F) -> Option<usize>
where
    F: Fn(&HeadState, usize) -> f32,
{
    let cutoff = now - recent as i64;
    argmin_live_filtered(head, &score, |h, s| h.entries[s].pos < cutoff)
        .or_else(|| argmin_live(head, &score))
}

/// For each live slot, the max cosine similarity of its key to any *other*
/// live key (R-KV / KeyDiff redundancy signal).  O(live^2 * dh).
fn max_key_similarity(head: &HeadState) -> Vec<f32> {
    let m = head.slots();
    let mut out = vec![0.0f32; m];
    let live: Vec<usize> = head.live_slots().collect();
    if head.keys.is_empty() || live.len() < 2 {
        return out;
    }
    let norms: Vec<f32> = live
        .iter()
        .map(|&s| head.key(s).iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9))
        .collect();
    for (ai, &a) in live.iter().enumerate() {
        let ka = head.key(a);
        let mut best = -1.0f32;
        for (bi, &b) in live.iter().enumerate() {
            if ai == bi {
                continue;
            }
            let kb = head.key(b);
            let dot: f32 = ka.iter().zip(kb).map(|(x, y)| x * y).sum();
            best = best.max(dot / (norms[ai] * norms[bi]));
        }
        out[a] = best;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SlotEntry;

    fn head_with(entries: &[(i64, f32, f32, f32)]) -> HeadState {
        // (pos, log_beta, acc_attn, ema_attn)
        let mut h = HeadState::new(entries.len() + 2, 4, true);
        for (s, &(pos, lb, acc, ema)) in entries.iter().enumerate() {
            h.insert(
                s,
                SlotEntry { pos, token: s as u32, log_beta: lb, acc_attn: acc,
                            ema_attn: ema, last_attn: ema },
                Some(&[s as f32, 1.0, 0.0, 0.0]),
            );
        }
        h
    }

    fn policy(name: &str) -> Policy {
        Policy::from_name(name, 32, 0).unwrap()
    }

    #[test]
    fn trimkv_evicts_lowest_decayed_retention() {
        // old + weak beta decays to the bottom
        let h = head_with(&[(0, -0.5, 0., 0.), (0, -0.01, 0., 0.), (9, -0.5, 0., 0.)]);
        assert_eq!(policy("trimkv").select_victim(&h, 10), Some(0));
        // a fresh token with terrible beta still outranks an ancient one
        let h = head_with(&[(0, -0.2, 0., 0.), (10, -0.9, 0., 0.)]);
        assert_eq!(policy("trimkv").select_victim(&h, 10), Some(0));
    }

    #[test]
    fn streaming_llm_protects_sinks_evicts_oldest() {
        let entries: Vec<(i64, f32, f32, f32)> =
            (0..8).map(|i| (i as i64, -0.1, 0.0, 0.0)).collect();
        let h = head_with(&entries);
        // sinks = 4 -> positions 0..3 protected; oldest evictable is pos 4
        assert_eq!(policy("streaming_llm").select_victim(&h, 8), Some(4));
    }

    #[test]
    fn h2o_evicts_lightest_hitter_outside_recent_window() {
        let h = head_with(&[
            (0, 0.0, 5.0, 0.0),  // heavy
            (1, 0.0, 0.1, 0.0),  // light -> victim
            (98, 0.0, 0.0, 0.0), // recent, protected
            (99, 0.0, 0.0, 0.0), // recent, protected
        ]);
        assert_eq!(policy("h2o").select_victim(&h, 100), Some(1));
    }

    #[test]
    fn h2o_falls_back_when_all_recent() {
        let h = head_with(&[(99, 0.0, 1.0, 0.0), (100, 0.0, 0.5, 0.0)]);
        assert_eq!(policy("h2o").select_victim(&h, 101), Some(1));
    }

    #[test]
    fn snapkv_uses_ema() {
        let h = head_with(&[(0, 0.0, 9.0, 0.01), (1, 0.0, 0.0, 0.9)]);
        assert_eq!(policy("snapkv").select_victim(&h, 100), Some(0));
    }

    #[test]
    fn keydiff_evicts_most_redundant() {
        let mut h = HeadState::new(5, 4, true);
        h.insert(0, SlotEntry { pos: 0, ..Default::default() }, Some(&[1., 0., 0., 0.]));
        h.insert(1, SlotEntry { pos: 1, ..Default::default() }, Some(&[1., 0.01, 0., 0.]));
        h.insert(2, SlotEntry { pos: 2, ..Default::default() }, Some(&[0., 1., 0., 0.]));
        // slots 0 and 1 are near-duplicates; one of them must go (tie -> older)
        assert_eq!(policy("keydiff").select_victim(&h, 3), Some(0));
    }

    #[test]
    fn locret_ignores_decay() {
        // locret ranks by raw beta: the low-beta newer token is the victim
        let h = head_with(&[(0, -0.5, 0., 0.), (90, -2.0, 0., 0.)]);
        assert_eq!(policy("locret").select_victim(&h, 100), Some(1));
        // trimkv at the same state evicts the *older* one (decay dominates:
        // 100 * -0.5 = -50 < 10 * -2.0 = -20)
        assert_eq!(policy("trimkv").select_victim(&h, 100), Some(0));
    }

    #[test]
    fn fullkv_never_evicts_random_always_does() {
        let h = head_with(&[(0, 0.0, 0.0, 0.0), (1, 0.0, 0.0, 0.0)]);
        assert_eq!(policy("fullkv").select_victim(&h, 5), None);
        let v = policy("random").select_victim(&h, 5);
        assert!(matches!(v, Some(0) | Some(1)));
    }

    #[test]
    fn empty_head_yields_none() {
        let h = HeadState::new(4, 4, false);
        assert_eq!(policy("trimkv").select_victim(&h, 0), None);
    }

    #[test]
    fn unknown_policy_rejected() {
        assert!(Policy::from_name("nope", 32, 0).is_err());
        for name in POLICY_NAMES {
            assert!(Policy::from_name(name, 32, 0).is_ok(), "{name}");
        }
    }

    #[test]
    fn needs_keys_only_for_similarity_policies() {
        assert!(policy("rkv").needs_keys());
        assert!(policy("keydiff").needs_keys());
        assert!(policy("retrieval").needs_keys());
        assert!(!policy("trimkv").needs_keys());
        assert!(!policy("h2o").needs_keys());
    }
}
