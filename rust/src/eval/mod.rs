//! Evaluation harness: runs workload suites through the engine under a
//! (policy, budget) grid and renders the paper's tables/figures
//! (DESIGN.md §6 experiment index).  `inspect` holds the retention-trace
//! dumps behind Figs 4/5/11-19.

pub mod bench_support;
pub mod inspect;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::engine::Engine;
use crate::runtime::ModelBackend;
use crate::scheduler::Request;
use crate::util::benchkit::Table;
use crate::util::stats::Percentiles;
use crate::vocab::Vocab;
use crate::workload::suites::Suite;
use crate::workload::{grade, Episode};

/// Aggregate outcome of one (suite, policy, budget) cell.
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub suite: String,
    pub task: String,
    pub policy: String,
    pub budget: usize,
    pub n: usize,
    pub score: f64,          // mean grade in [0, 1]
    pub tok_s: f64,          // decode throughput
    pub decode_ms_p50: f64,  // per-step latency
    pub e2e_ms_p50: f64,
    pub evictions: u64,
    pub wall_s: f64,
}

/// Run one suite through an engine configured for (policy, budget);
/// consumes and returns the backend so artifact compilation is reused
/// across grid cells.
pub fn run_suite<B: ModelBackend>(
    backend: B,
    base_cfg: &EngineConfig,
    vocab: &Vocab,
    policy: &str,
    budget: usize,
    suite: &Suite,
) -> Result<(SuiteResult, B)> {
    let mut cfg = base_cfg.clone();
    cfg.policy = policy.to_string();
    cfg.budget = budget;
    cfg.max_new_tokens = suite.max_new_tokens;
    cfg.validate()?;
    let mut engine = Engine::new(backend, cfg, vocab.eos())?;
    let t0 = std::time::Instant::now();
    for (i, ep) in suite.episodes.iter().enumerate() {
        let mut req = Request::new(i as u64, ep.prompt.clone(),
                                   suite.max_new_tokens);
        req.tag = ep.task.clone();
        engine
            .submit(req)
            .map_err(|e| anyhow::anyhow!("admission failed: {e}"))?;
    }
    let responses = engine.run_to_completion()?;
    let wall_s = t0.elapsed().as_secs_f64();

    let mut score_sum = 0.0;
    let mut e2e = Percentiles::default();
    for resp in &responses {
        let ep: &Episode = &suite.episodes[resp.id as usize];
        score_sum += grade(ep, &resp.tokens, vocab);
        e2e.push(resp.e2e_us / 1e3);
    }
    let n = suite.episodes.len();
    let m = &engine.metrics;
    let task = suite
        .episodes
        .first()
        .map(|e| e.task.clone())
        .unwrap_or_default();
    let result = SuiteResult {
        suite: suite.name.to_string(),
        task,
        policy: policy.to_string(),
        budget,
        n,
        score: if n > 0 { score_sum / n as f64 } else { 0.0 },
        tok_s: m.tokens_decoded as f64 / wall_s.max(1e-9),
        decode_ms_p50: m.step_us.mean() / 1e3,
        e2e_ms_p50: e2e.pct(50.0),
        evictions: m.evictions,
        wall_s,
    };
    Ok((result, engine.into_backend()))
}

/// Generic results table (all paper-table benches pivot from this).
pub fn results_table(results: &[SuiteResult]) -> Table {
    let mut t = Table::new(&[
        "suite", "task", "policy", "budget", "n", "score", "tok/s",
        "step_ms", "e2e_ms_p50", "evictions",
    ]);
    for r in results {
        t.row(vec![
            r.suite.clone(),
            r.task.clone(),
            r.policy.clone(),
            r.budget.to_string(),
            r.n.to_string(),
            format!("{:.3}", r.score),
            format!("{:.1}", r.tok_s),
            format!("{:.2}", r.decode_ms_p50),
            format!("{:.1}", r.e2e_ms_p50),
            r.evictions.to_string(),
        ]);
    }
    t
}

/// Pareto pivot (Fig 3/6/7): rows = policy, columns = budgets, cells = score.
pub fn pareto_table(results: &[SuiteResult], budgets: &[usize]) -> Table {
    let mut header: Vec<String> = vec!["policy".into()];
    header.extend(budgets.iter().map(|b| format!("b={b}")));
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr);
    let mut policies: Vec<String> =
        results.iter().map(|r| r.policy.clone()).collect();
    policies.dedup();
    let mut seen = std::collections::BTreeSet::new();
    for p in policies {
        if !seen.insert(p.clone()) {
            continue;
        }
        let mut row = vec![p.clone()];
        for &b in budgets {
            let cell = results
                .iter()
                .find(|r| r.policy == p && r.budget == b)
                .map(|r| format!("{:.3}", r.score))
                .unwrap_or_else(|| "-".into());
            row.push(cell);
        }
        t.row(row);
    }
    t
}

/// Throughput pivot (Table 6): method rows, throughput + decode-time columns.
pub fn throughput_table(results: &[SuiteResult]) -> Table {
    let mut t = Table::new(&[
        "method", "budget", "ctx", "tok/s", "decode_ms/step", "total_s",
    ]);
    for r in results {
        t.row(vec![
            r.policy.clone(),
            r.budget.to_string(),
            r.task.clone(),
            format!("{:.1}", r.tok_s),
            format!("{:.2}", r.decode_ms_p50),
            format!("{:.2}", r.wall_s),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockBackend;
    use crate::workload::suites;

    #[test]
    fn harness_runs_grid_and_reuses_backend() {
        let vocab = Vocab::builtin();
        let base = EngineConfig {
            batch: 2,
            chunked_prefill: false,
            ..Default::default()
        };
        let mut backend = MockBackend::new(2, 40);
        let suite = suites::math(&vocab, "gsm8k", 4, 3);
        let mut results = Vec::new();
        for policy in ["trimkv", "streaming_llm"] {
            for budget in [16, 32] {
                let (r, be) = run_suite(backend, &base, &vocab, policy,
                                        budget, &suite).unwrap();
                backend = be;
                assert_eq!(r.n, 4);
                assert!(r.score >= 0.0 && r.score <= 1.0);
                results.push(r);
            }
        }
        assert_eq!(results.len(), 4);
        let table = results_table(&results);
        let s = table.render();
        assert!(s.contains("trimkv"));
        assert!(s.contains("streaming_llm"));
        let p = pareto_table(&results, &[16, 32]);
        assert_eq!(p.render().lines().count(), 2 + 2);
        let tt = throughput_table(&results);
        assert!(tt.to_csv().lines().count() == 5);
    }
}
