//! Shared plumbing for the `cargo bench` paper-table harnesses.
//!
//! Benches run against the real AOT artifacts; when `artifacts/` has not
//! been built yet they print SKIPPED and exit 0 so `cargo bench` stays
//! green on a fresh checkout.

use std::path::PathBuf;

use crate::config::EngineConfig;
use crate::model_meta::ModelMeta;
use crate::runtime::PjrtBackend;
use crate::vocab::Vocab;

pub struct BenchCtx {
    pub meta: ModelMeta,
    pub vocab: Vocab,
    pub cfg: EngineConfig,
}

pub fn artifacts_dir() -> PathBuf {
    std::env::var("TRIMKV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Load meta + vocab, or None (with a SKIPPED banner) when absent.
pub fn load_ctx(name: &str) -> Option<BenchCtx> {
    let dir = artifacts_dir();
    if !dir.join("meta.json").exists() {
        println!("bench {name}: SKIPPED (no artifacts; run `make artifacts`)");
        return None;
    }
    let meta = ModelMeta::load(&dir).expect("meta.json parse");
    let vocab = Vocab::load(&dir.join("vocab.json")).expect("vocab.json parse");
    let cfg = EngineConfig { artifacts_dir: dir, ..Default::default() };
    Some(BenchCtx { meta, vocab, cfg })
}

impl BenchCtx {
    /// Backend sized for the largest budget in a sweep.
    pub fn backend(&self, batch: usize, min_slots: usize,
                   gate_variant: &str) -> PjrtBackend {
        let spec = self
            .meta
            .pick("decode", batch, min_slots, "mlp")
            .unwrap_or_else(|| panic!("no artifact for b={batch} m>={min_slots}"));
        PjrtBackend::load(&self.meta, spec.b, spec.m, gate_variant, "mlp", true)
            .expect("backend load")
    }

    /// Largest slot count exported for this batch size.
    pub fn max_slots(&self, batch: usize) -> usize {
        self.meta
            .artifacts
            .iter()
            .filter(|a| a.kind == "decode" && a.b == batch)
            .map(|a| a.m)
            .max()
            .unwrap_or(0)
    }
}

/// Episodes-per-cell for benches; override with TRIMKV_BENCH_N.
pub fn bench_n(default: usize) -> usize {
    std::env::var("TRIMKV_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}
