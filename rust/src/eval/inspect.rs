//! Retention-trace dumps behind the paper's qualitative figures:
//!   Fig 4 / 11 / 12 — per-head retention matrices beta_i^(t-i) and the
//!                      eviction decision matrices alpha_ti
//!   Fig 5a/b        — per-token mean retention + top/bottom token tables
//!   Fig 5c          — layer/head sparsity heatmap
//!   Figs 13-19      — kept-vs-evicted token visualizations per head

use crate::engine::SeqRecord;
use crate::util::benchkit::Table;
use crate::vocab::Vocab;

/// beta_i^(t-i) lower-triangular matrix for one head as CSV (Fig 4 top).
pub fn retention_matrix_csv(rec: &SeqRecord, head: usize) -> String {
    let t_len = rec.tokens.len();
    let mut out = String::new();
    for t in 0..t_len {
        let mut row = Vec::with_capacity(t_len);
        for i in 0..t_len {
            if i > t {
                row.push("0".to_string());
            } else {
                let lb = rec.log_betas[i][head];
                let val = ((t - i) as f32 * lb).exp();
                row.push(format!("{val:.4}"));
            }
        }
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// alpha_ti eviction matrix for one head as CSV (Fig 4 bottom): cell (t, i)
/// is 1 while token i is still cached at step t.
pub fn eviction_matrix_csv(rec: &SeqRecord, head: usize) -> String {
    let t_len = rec.tokens.len();
    // eviction step per position (default: never evicted)
    let mut evicted_at = vec![i64::MAX; t_len];
    for &(h, pos, step) in &rec.evictions {
        if h == head && (pos as usize) < t_len {
            evicted_at[pos as usize] = step;
        }
    }
    let mut out = String::new();
    for t in 0..t_len {
        let mut row = Vec::with_capacity(t_len);
        for i in 0..t_len {
            let alive = i <= t && (t as i64) < evicted_at[i];
            row.push(if alive { "1" } else { "0" }.to_string());
        }
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Fig 5a/b: mean retention score per token (averaged over heads), plus the
/// top/bottom-k token tables.
pub fn token_retention_table(rec: &SeqRecord, vocab: &Vocab, k: usize) -> Table {
    let n_heads = rec.log_betas.first().map(Vec::len).unwrap_or(0);
    let mut scored: Vec<(usize, f32)> = rec
        .log_betas
        .iter()
        .enumerate()
        .map(|(i, lbs)| {
            let beta_mean: f32 =
                lbs.iter().map(|lb| lb.exp()).sum::<f32>() / n_heads.max(1) as f32;
            (i, beta_mean)
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut t = Table::new(&["rank", "pos", "token", "mean beta"]);
    for (rank, &(pos, beta)) in scored.iter().take(k).enumerate() {
        t.row(vec![format!("top{}", rank + 1), pos.to_string(),
                   vocab.name(rec.tokens[pos]), format!("{beta:.4}")]);
    }
    for (rank, &(pos, beta)) in scored.iter().rev().take(k).enumerate() {
        t.row(vec![format!("bot{}", rank + 1), pos.to_string(),
                   vocab.name(rec.tokens[pos]), format!("{beta:.4}")]);
    }
    t
}

/// Fig 5c: per-head sparsity `1 - 2/(T(T+1)) * sum_{i<=t} beta_i^(t-i)`.
pub fn sparsity_table(rec: &SeqRecord, layers: usize, hkv: usize) -> Table {
    let t_len = rec.tokens.len();
    let mut header: Vec<String> = vec!["layer".into()];
    header.extend((0..hkv).map(|h| format!("head{h}")));
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut table = Table::new(&hdr);
    for l in 0..layers {
        let mut row = vec![format!("{l}")];
        for h in 0..hkv {
            let head = l * hkv + h;
            let mut total = 0.0f64;
            for t in 0..t_len {
                for i in 0..=t {
                    total += (((t - i) as f32) * rec.log_betas[i][head]).exp() as f64;
                }
            }
            let denom = (t_len * (t_len + 1)) as f64 / 2.0;
            row.push(format!("{:.3}", 1.0 - total / denom));
        }
        table.row(row);
    }
    table
}

/// Figs 13-19: which prompt tokens survive in a head's cache at the end.
/// `kept` comes from Engine::retention_snapshot.
pub fn kept_tokens_render(rec: &SeqRecord, kept_pos: &[i64],
                          vocab: &Vocab) -> String {
    let kept: std::collections::BTreeSet<i64> = kept_pos.iter().copied().collect();
    rec.tokens
        .iter()
        .enumerate()
        .map(|(i, &tok)| {
            let name = vocab.name(tok);
            if kept.contains(&(i as i64)) {
                format!("[{name}]")
            } else {
                name
            }
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> SeqRecord {
        // 4 tokens, 2 heads; head 0 retains strongly, head 1 decays fast
        SeqRecord {
            tokens: vec![1, 40, 41, 2],
            log_betas: vec![
                vec![-0.01, -2.0],
                vec![-0.02, -1.5],
                vec![-0.01, -2.5],
                vec![-0.03, -1.0],
            ],
            evictions: vec![(1, 0, 2)], // head 1 evicted pos 0 at step 2
        }
    }

    #[test]
    fn retention_matrix_is_lower_triangular_and_decaying() {
        let csv = retention_matrix_csv(&record(), 0);
        let rows: Vec<Vec<f32>> = csv
            .lines()
            .map(|l| l.split(',').map(|x| x.parse().unwrap()).collect())
            .collect();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0][1], 0.0); // upper triangle empty
        assert_eq!(rows[1][1], 1.0); // fresh token at full weight
        assert!(rows[3][0] < rows[1][0]); // older -> decayed
    }

    #[test]
    fn eviction_matrix_respects_monotonicity() {
        let csv = eviction_matrix_csv(&record(), 1);
        let rows: Vec<Vec<u8>> = csv
            .lines()
            .map(|l| l.split(',').map(|x| x.parse().unwrap()).collect())
            .collect();
        // pos 0 alive at steps 0 and 1, evicted from step 2 on
        assert_eq!(rows[0][0], 1);
        assert_eq!(rows[1][0], 1);
        assert_eq!(rows[2][0], 0);
        assert_eq!(rows[3][0], 0);
        // monotone: once dead, stays dead (paper alpha constraint)
        for i in 0..4 {
            for t in 1..4 {
                assert!(rows[t][i] <= rows[t - 1][i] || t <= i);
            }
        }
        // head 0 never evicts
        let csv0 = eviction_matrix_csv(&record(), 0);
        assert!(!csv0.lines().last().unwrap().starts_with('0'));
    }

    #[test]
    fn token_table_ranks_by_mean_beta() {
        let v = Vocab::builtin();
        let t = token_retention_table(&record(), &v, 2);
        let s = t.render();
        assert!(s.contains("top1"));
        assert!(s.contains("bot1"));
    }

    #[test]
    fn sparsity_in_unit_range() {
        let t = sparsity_table(&record(), 1, 2);
        let csv = t.to_csv();
        let line = csv.lines().nth(1).unwrap();
        let cells: Vec<&str> = line.split(',').collect();
        for c in &cells[1..] {
            let x: f64 = c.parse().unwrap();
            assert!((0.0..=1.0).contains(&x), "sparsity {x}");
        }
    }

    #[test]
    fn kept_render_marks_survivors() {
        let v = Vocab::builtin();
        let s = kept_tokens_render(&record(), &[0, 2], &v);
        assert!(s.starts_with("[<bos>]"));
        assert!(s.contains("[s9]")); // token 41 = sym 9 kept
        assert!(s.contains(" s8 ")); // token 40 evicted -> unbracketed
    }
}
