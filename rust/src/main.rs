//! `trimkv` — CLI for the TRIM-KV serving engine.
//!
//! Subcommands:
//!   serve      run the TCP front-end (line-delimited JSON)
//!   generate   run one prompt through the engine and print the tokens
//!   eval       policy x budget accuracy sweep over a paper suite
//!   inspect    retention-trace dumps (Figs 4/5/11-19)
//!   trace      run a workload and export the tick flight recorder as
//!              Chrome-trace JSON (chrome://tracing / Perfetto)
//!   selftest   golden-I/O check of the AOT artifacts vs the python export

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};
use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::eval::{self, inspect};
use trimkv::model_meta::ModelMeta;
use trimkv::policy::Policy;
use trimkv::prefixcache::PrefixStore;
use trimkv::router::EngineGroup;
use trimkv::runtime::PjrtBackend;
use trimkv::scheduler::Request;
use trimkv::server::{tcp, InProcServer};
use trimkv::util::cli::Args;
use trimkv::vocab::Vocab;
use trimkv::workload::suites;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let cmd = argv.get(1).map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = std::iter::once(argv[0].clone())
        .chain(argv.iter().skip(2).cloned())
        .collect();
    match cmd {
        "serve" => serve(&rest),
        "generate" => generate(&rest),
        "eval" => eval_cmd(&rest),
        "inspect" => inspect_cmd(&rest),
        "trace" => trace_cmd(&rest),
        "selftest" => selftest(&rest),
        _ => {
            eprintln!(
                "usage: trimkv <serve|generate|eval|inspect|trace|selftest> \
                 [--help]\n\
                 see README.md for examples"
            );
            Ok(())
        }
    }
}

fn common_spec() -> trimkv::util::cli::SpecBuilder {
    // CLI defaults are derived from `EngineConfig::default()` — one source
    // of truth, so the binary and the library can never quietly diverge
    // (docs/OPERATIONS.md documents a single default column).
    let d = EngineConfig::default();
    Args::spec()
        .opt("artifacts", d.artifacts_dir.display().to_string(),
             "artifact directory (meta.json etc.)")
        .opt("policy", d.policy, "eviction policy")
        .opt("budget", d.budget.to_string(), "live tokens per head")
        .opt("batch", d.batch.to_string(),
             "batch lanes (must match an exported artifact)")
        .opt("max-new-tokens", d.max_new_tokens.to_string(), "generation cap")
        .opt("seed", d.seed.to_string(), "rng seed")
        .opt("max-sessions", d.max_sessions.to_string(),
             "host-side session snapshot store capacity (LRU beyond)")
        .opt("swap-policy", d.swap_policy,
             "session swap policy: lazy (park on lane) | eager (snapshot)")
        .opt("mixed-ticks", d.mixed_ticks.to_string(),
             "fuse decode + chunked prefill into one step plan (legacy \
              artifacts without a mixed graph execute the plan as two \
              per-kind graph calls — still stall-free)")
        .opt("tick-token-budget", d.tick_token_budget.to_string(),
             "token budget per mixed tick, decoders reserved first \
              (Sarathi-style; 0 = unbounded)")
        .opt("pipeline", d.pipeline.to_string(),
             "pipelined tick loop: submit the step async and overlap the \
              next tick's admission/swap host work with device execution \
              (token streams stay bit-identical; false = serial loop)")
        .opt("trace-capacity", d.trace_capacity.to_string(),
             "flight-recorder journal capacity, in events (hard memory cap)")
        .flag("no-trace", "disable the per-tick flight recorder")
        .opt("replicas", d.replicas.to_string(),
             "engine workers behind the session router (serve spawns an \
              EngineGroup when > 1; each replica loads its own backend)")
        .opt("migration", if d.migration { "on" } else { "off" },
             "cross-replica session migration + rebalancing (on|off)")
        .flag("prefix-cache",
              "shared-prefix KV store: admission reuses the cached slab + \
               frozen retention state of a common prompt prefix and \
               prefills only the tail ([prefix] enabled = true)")
        .opt("prefix-max-bytes", d.prefix_max_bytes.to_string(),
             "prefix store byte budget; LRU-evicts unreferenced entries")
        .opt("prefix-chunk", d.prefix_chunk_tokens.to_string(),
             "prefix match/publish granularity in tokens")
}

fn load_engine(args: &Args) -> Result<(Engine<PjrtBackend>, Vocab, ModelMeta)> {
    let mut cfg = EngineConfig::default();
    cfg.apply_cli(args)?;
    let meta = ModelMeta::load(&cfg.artifacts_dir)?;
    let vocab = Vocab::load(&cfg.artifacts_dir.join("vocab.json"))?;
    let policy = Policy::from_name(&cfg.policy, cfg.budget, cfg.seed)?;
    let headroom = if cfg.chunked_prefill { meta.chunk + 1 } else { 2 };
    let spec = meta
        .pick("decode", cfg.batch, cfg.budget + headroom, "mlp")
        .with_context(|| format!(
            "no decode artifact for batch {} budget {}", cfg.batch, cfg.budget))?;
    eprintln!("[trimkv] loading {} (b={} m={})", spec.file, spec.b, spec.m);
    let backend = PjrtBackend::load(&meta, spec.b, spec.m,
                                    policy.gate_variant(), "mlp", true)?;
    let engine = Engine::new(backend, cfg, vocab.eos())?;
    Ok((engine, vocab, meta))
}

fn serve(argv: &[String]) -> Result<()> {
    let args = common_spec()
        .opt("addr", "127.0.0.1:7878", "listen address")
        .parse(argv)?;
    let mut cfg = EngineConfig::default();
    cfg.apply_cli(&args)?;
    let addr = args.get_or("addr", "127.0.0.1:7878");
    if cfg.replicas > 1 {
        // replicated serving: N engines (each its own backend) behind the
        // session router, same wire protocol
        let n = cfg.replicas;
        eprintln!("[trimkv] spawning engine group: {n} replicas");
        // one prefix store for the whole fleet: N replicas amortize the
        // same system prompt instead of each warming a private copy
        let shared = cfg.prefix_enabled.then(|| {
            Arc::new(PrefixStore::new(cfg.prefix_max_bytes,
                                      cfg.prefix_chunk_tokens))
        });
        let mut group = EngineGroup::spawn(n, cfg.migration, |i| {
            let (mut engine, _, _) = load_engine(&args)?;
            if let Some(store) = &shared {
                engine.set_prefix_store(store.clone());
            }
            eprintln!("[trimkv] replica {i} ready");
            Ok(engine)
        })?;
        if let Some(store) = shared {
            group.attach_prefix_store(store);
        }
        return tcp::listen(&addr, &group);
    }
    let (engine, _vocab, _meta) = load_engine(&args)?;
    let srv = InProcServer::spawn(engine);
    tcp::listen(&addr, &srv)
}

fn generate(argv: &[String]) -> Result<()> {
    let args = common_spec()
        .opt("prompt", "", "comma-separated token ids (default: demo recall)")
        .parse(argv)?;
    let (mut engine, vocab, _) = load_engine(&args)?;
    let prompt: Vec<u32> = match args.get("prompt") {
        Some(s) if !s.is_empty() => s
            .split(',')
            .map(|x| x.trim().parse().context("bad token id"))
            .collect::<Result<_>>()?,
        _ => {
            let mut g = trimkv::workload::Gen::new(&vocab, args.u64("seed")?);
            let ep = g.recall(8, 4);
            println!("demo recall episode; expected answer: {}",
                     vocab.name(ep.answer[0]));
            ep.prompt
        }
    };
    println!("prompt ({} tokens): {}", prompt.len(),
             prompt.iter().map(|&t| vocab.name(t)).collect::<Vec<_>>().join(" "));
    engine.submit(Request::new(0, prompt, args.usize("max-new-tokens")?))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let rs = engine.run_to_completion()?;
    let r = &rs[0];
    println!("generated ({:?}): {}", r.finish,
             r.tokens.iter().map(|&t| vocab.name(t)).collect::<Vec<_>>().join(" "));
    println!("{}", engine.metrics.summary());
    Ok(())
}

fn eval_cmd(argv: &[String]) -> Result<()> {
    let args = common_spec()
        .opt("suite", "math", "math|longproc|longmem|scbench|longqa")
        .opt("tier", "gsm8k", "suite tier/task")
        .opt("n", "32", "episodes per cell")
        .opt("budgets", "32,64,127", "comma-separated budgets")
        .opt("policies", "trimkv,snapkv,h2o,streaming_llm", "comma list")
        .parse(argv)?;
    let mut cfg = EngineConfig::default();
    cfg.apply_cli(&args)?;
    let meta = ModelMeta::load(&cfg.artifacts_dir)?;
    let vocab = Vocab::load(&cfg.artifacts_dir.join("vocab.json"))?;
    let budgets = args.usize_list("budgets")?;
    let policies: Vec<String> = args
        .get_or("policies", "trimkv")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let suite = build_suite(&vocab, &args)?;
    let max_budget = *budgets.iter().max().unwrap();
    let spec = meta
        .pick("decode", cfg.batch, max_budget + meta.chunk + 1, "mlp")
        .context("no artifact large enough for the largest budget")?;
    let mut results = Vec::new();
    // policies may need different gate weights (locret) -> backend per variant
    let mut variants: Vec<&str> = policies
        .iter()
        .map(|p| if p == "locret" { "locret" } else { "default" })
        .collect();
    variants.dedup();
    for variant in variants {
        let mut backend = PjrtBackend::load(&meta, spec.b, spec.m, variant,
                                            "mlp", true)?;
        for policy in &policies {
            let needs = if policy == "locret" { "locret" } else { "default" };
            if needs != variant {
                continue;
            }
            for &budget in &budgets {
                eprintln!("[eval] {policy} @ budget {budget}");
                let (r, be) = eval::run_suite(backend, &cfg, &vocab, policy,
                                              budget, &suite)?;
                backend = be;
                results.push(r);
            }
        }
    }
    println!("{}", eval::results_table(&results).render());
    println!("{}", eval::pareto_table(&results, &budgets).render());
    Ok(())
}

fn build_suite(vocab: &Vocab, args: &Args) -> Result<suites::Suite> {
    let n = args.usize("n")?;
    let seed = args.u64("seed")?;
    let tier = args.get_or("tier", "gsm8k");
    Ok(match args.get_or("suite", "math").as_str() {
        "math" => suites::math(vocab, &tier, n, seed),
        "longproc" => suites::longproc(vocab, &tier, 1, n, seed),
        "longmem" => suites::longmem(vocab, &tier, n, seed),
        "scbench" => suites::scbench(vocab, &tier, n, seed),
        "longqa" => suites::longqa(vocab, n, seed),
        other => anyhow::bail!("unknown suite {other}"),
    })
}

fn inspect_cmd(argv: &[String]) -> Result<()> {
    let args = common_spec()
        .opt("layer", "0", "layer for matrix dumps")
        .opt("head", "0", "kv head for matrix dumps")
        .opt("out", "figures", "output directory")
        .flag("matrices", "dump retention + eviction matrices (Fig 4/11/12)")
        .flag("tokens", "per-token retention table (Fig 5a/b)")
        .flag("sparsity", "layer/head sparsity (Fig 5c)")
        .flag("kept", "kept-token rendering (Figs 13-19)")
        .flag("retention",
              "per-(layer, head) retention-at-eviction histograms and \
               sink/sliding-window/gist signatures")
        .parse(argv)?;
    let (mut engine, vocab, meta) = load_engine(&args)?;
    engine.record_gates = true;
    let mut g = trimkv::workload::Gen::new(&vocab, args.u64("seed")?);
    let ep = g.chain(10, 3, 4); // AIME-like episode, as in the paper's Fig 4
    let kept_before_finish = {
        engine.submit(Request::new(0, ep.prompt.clone(), 48))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        // run until one step before completion to snapshot the live cache
        let mut snap = None;
        while !engine.idle() {
            engine.tick()?;
            if let Some(s) = engine.retention_snapshot(0) {
                snap = Some(s);
            }
        }
        snap
    };
    let rec = engine.last_record.clone().context("no record (run too short?)")?;
    let out_dir = args.get_or("out", "figures");
    std::fs::create_dir_all(&out_dir)?;
    let dims = meta.dims;
    let l = args.usize("layer")?;
    let h = args.usize("head")?;
    let head = l * dims.hkv + h;
    if args.flag("matrices") {
        std::fs::write(format!("{out_dir}/retention_l{l}h{h}.csv"),
                       inspect::retention_matrix_csv(&rec, head))?;
        std::fs::write(format!("{out_dir}/eviction_l{l}h{h}.csv"),
                       inspect::eviction_matrix_csv(&rec, head))?;
        println!("wrote {out_dir}/retention_l{l}h{h}.csv and eviction_l{l}h{h}.csv");
    }
    if args.flag("tokens") {
        println!("{}", inspect::token_retention_table(&rec, &vocab, 10).render());
    }
    if args.flag("sparsity") {
        println!("{}", inspect::sparsity_table(&rec, dims.layers, dims.hkv).render());
    }
    if args.flag("kept") {
        if let Some(snap) = kept_before_finish {
            let kept: Vec<i64> = snap[head].iter().map(|&(p, _, _)| p).collect();
            println!("{}", inspect::kept_tokens_render(&rec, &kept, &vocab));
        }
    }
    if args.flag("retention") {
        println!("{}", engine.retention_report());
    }
    Ok(())
}

/// Run a workload through the engine, then export the flight recorder as
/// Chrome-trace JSON (and print the scheduling summary).  The engine traces
/// by default, so `serve` users can also scrape the same journal live over
/// the TCP stats protocol.
fn trace_cmd(argv: &[String]) -> Result<()> {
    let args = common_spec()
        .opt("prompt", "", "comma-separated token ids (default: demo recall)")
        .opt("out", "trace.json", "Chrome-trace output path")
        .parse(argv)?;
    let (mut engine, vocab, _) = load_engine(&args)?;
    let prompt: Vec<u32> = match args.get("prompt") {
        Some(s) if !s.is_empty() => s
            .split(',')
            .map(|x| x.trim().parse().context("bad token id"))
            .collect::<Result<_>>()?,
        _ => {
            let mut g = trimkv::workload::Gen::new(&vocab, args.u64("seed")?);
            g.recall(8, 4).prompt
        }
    };
    engine.submit(Request::new(0, prompt, args.usize("max-new-tokens")?))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    engine.run_to_completion()?;
    let out = args.get_or("out", "trace.json");
    std::fs::write(&out, engine.chrome_trace_json())?;
    println!("wrote {out}: {} spans over {} ticks ({} overwritten)",
             engine.obs.journal.len(), engine.ticks(),
             engine.obs.journal.dropped());
    println!("{}", engine.metrics.scheduling_summary());
    Ok(())
}

/// Golden test: execute the exported decode/prefill/mixed graphs on the
/// I/O pairs the python side dumped, compare outputs elementwise.  With
/// `--structural`, verify the artifact contract without executing HLO
/// (meta/artifact/golden inventories + shapes + the StepPlan operand
/// order each graph declares in `runtime_inputs`) — the mode CI runs
/// against the vendored PJRT stub.
fn selftest(argv: &[String]) -> Result<()> {
    let args = common_spec()
        .flag("structural",
              "contract-only check (no HLO execution; works on the stub)")
        .parse(argv)?;
    let dir = args.get_or("artifacts", "artifacts");
    let dir = Path::new(&dir);
    let report = if args.flag("structural") {
        trimkv::runtime::golden::verify_structural(dir)?
    } else {
        trimkv::runtime::golden::run_goldens(dir)?
    };
    println!("{report}");
    Ok(())
}
