//! Benchmark suites mirroring the paper's evaluation section — each suite
//! fixes the workload mix and difficulty tiers for one paper exhibit
//! (DESIGN.md §6 maps suite -> table/figure -> bench target).

use crate::vocab::Vocab;

use super::{Episode, Gen};

/// A named, seeded collection of episodes.
pub struct Suite {
    pub name: &'static str,
    pub episodes: Vec<Episode>,
    /// generation budget per request
    pub max_new_tokens: usize,
}

/// Math suite (Fig. 3/6/7 analog): three difficulty tiers standing in for
/// GSM8K / MATH-500 / AIME24.
pub fn math(v: &Vocab, tier: &str, n: usize, seed: u64) -> Suite {
    let mut g = Gen::new(v, seed ^ 0x11);
    let mut eps = Vec::with_capacity(n);
    for _ in 0..n {
        // tiers sit at the trained backbone's capability frontier
        // (DESIGN.md §2: contexts <= ~150 tokens, 1-2 retrievable facts)
        let ep = match tier {
            // gsm8k analog: single fact, light filler
            "gsm8k" => {
                if g.rng.bool(0.6) {
                    g.recall(1, 45)
                } else {
                    let hay = g.rng.range(35, 60);
                    g.niah(hay)
                }
            }
            // math500 analog: two facts / mid haystack
            "math500" => {
                if g.rng.bool(0.5) {
                    g.recall(2, 20)
                } else {
                    let hay = g.rng.range(50, 90);
                    g.niah(hay)
                }
            }
            // aime analog: long haystack near the context frontier
            "aime" => {
                if g.rng.bool(0.4) {
                    g.recall(2, 40)
                } else {
                    let hay = g.rng.range(90, 140);
                    g.niah(hay)
                }
            }
            other => panic!("unknown math tier {other}"),
        };
        eps.push(ep);
    }
    Suite { name: "math", episodes: eps, max_new_tokens: 6 }
}

/// LongProc suite (Tables 1/7 analog): per-task, with an output-length tier.
pub fn longproc(v: &Vocab, task: &str, tier: usize, n: usize, seed: u64) -> Suite {
    let mut g = Gen::new(v, seed ^ 0x22);
    let mut eps = Vec::with_capacity(n);
    let mut max_new = 64;
    for _ in 0..n {
        let ep = match task {
            "table" => {
                // tier scales rows to extract (output length driver)
                let rows = 3 + 2 * tier;
                let extract = (1 + tier).min(rows);
                max_new = extract * 5 + 12;
                g.proc_table(rows, 2, extract)
            }
            "countdown" => {
                let steps = 2 + 2 * tier;
                max_new = steps * 4 + 10;
                g.countdown(steps)
            }
            "copy" => {
                let len = 6 + 10 * tier;
                max_new = len + 6;
                g.copy(len)
            }
            other => panic!("unknown longproc task {other}"),
        };
        eps.push(ep);
    }
    Suite { name: "longproc", episodes: eps, max_new_tokens: max_new }
}

/// LongMemEval suite (Tables 3/8 analog) with per-question-type splits.
pub fn longmem(v: &Vocab, qtype: &str, n: usize, seed: u64) -> Suite {
    let mut g = Gen::new(v, seed ^ 0x33);
    let eps = (0..n)
        .map(|_| {
            let sessions = g.rng.range(2, 4);
            g.multi_session(sessions, 1, 12, qtype)
        })
        .collect();
    Suite { name: "longmem", episodes: eps, max_new_tokens: 6 }
}

/// SCBench suite (Table 2 analog): one entry per task family.
pub fn scbench(v: &Vocab, task: &str, n: usize, seed: u64) -> Suite {
    let mut g = Gen::new(v, seed ^ 0x44);
    let mut eps = Vec::with_capacity(n);
    let mut max_new = 8;
    for _ in 0..n {
        let ep = match task {
            "retr_kv" => {
                let hay = g.rng.range(60, 130);
                g.niah(hay)
            }
            "manyshot" => {
                let shots = g.rng.range(10, 20);
                g.manyshot(3, shots)
            }
            "math_find" => {
                let n = g.rng.range(20, 45);
                g.find_minmax(n)
            }
            "multi_session" => g.multi_session(2, 1, 10, "single"),
            "summary" => {
                max_new = 40;
                let rows = g.rng.range(6, 10);
                g.proc_table(rows, 2, 4)
            }
            other => panic!("unknown scbench task {other}"),
        };
        eps.push(ep);
    }
    Suite { name: "scbench", episodes: eps, max_new_tokens: max_new }
}

/// Long-prompt QA for the chunked-prefill comparison (Tables 4/9/10 analog):
/// prompts long enough to span several prefill chunks.
pub fn longqa(v: &Vocab, n: usize, seed: u64) -> Suite {
    let mut g = Gen::new(v, seed ^ 0x55);
    let eps = (0..n)
        .map(|_| {
            if g.rng.bool(0.6) {
                let hay = g.rng.range(90, 140);
                g.niah(hay)
            } else {
                let sessions = g.rng.range(2, 4);
                g.multi_session(sessions, 1, 14, "single")
            }
        })
        .collect();
    Suite { name: "longqa", episodes: eps, max_new_tokens: 8 }
}

/// Throughput workload (Table 6 analog): fixed context and generation
/// lengths, content irrelevant.
pub fn throughput(v: &Vocab, ctx: usize, n: usize, seed: u64) -> Suite {
    let mut g = Gen::new(v, seed ^ 0x66);
    let eps = (0..n)
        .map(|_| {
            let mut ep = g.niah(ctx.saturating_sub(8).max(4));
            ep.task = "throughput".into();
            ep
        })
        .collect();
    Suite { name: "throughput", episodes: eps, max_new_tokens: 64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_generate_requested_sizes() {
        let v = Vocab::builtin();
        for tier in ["gsm8k", "math500", "aime"] {
            let s = math(&v, tier, 5, 1);
            assert_eq!(s.episodes.len(), 5);
        }
        for task in ["table", "countdown", "copy"] {
            for tier in 0..3 {
                let s = longproc(&v, task, tier, 3, 1);
                assert_eq!(s.episodes.len(), 3);
                assert!(s.max_new_tokens >= 8);
            }
        }
        for q in ["single", "update"] {
            assert_eq!(longmem(&v, q, 4, 1).episodes.len(), 4);
        }
        for t in ["retr_kv", "manyshot", "math_find", "multi_session", "summary"] {
            assert_eq!(scbench(&v, t, 3, 1).episodes.len(), 3);
        }
        assert_eq!(longqa(&v, 3, 1).episodes.len(), 3);
        assert_eq!(throughput(&v, 128, 2, 1).episodes.len(), 2);
    }

    #[test]
    fn tiers_scale_difficulty() {
        let v = Vocab::builtin();
        let easy: usize = math(&v, "gsm8k", 20, 7).episodes.iter()
            .map(|e| e.prompt.len()).sum();
        let hard: usize = math(&v, "aime", 20, 7).episodes.iter()
            .map(|e| e.prompt.len()).sum();
        assert!(hard > 2 * easy, "hard {hard} vs easy {easy}");
    }

    #[test]
    fn throughput_prompts_near_requested_ctx() {
        let v = Vocab::builtin();
        let s = throughput(&v, 200, 4, 3);
        for ep in &s.episodes {
            assert!((ep.prompt.len() as i64 - 200).abs() < 20,
                    "len {}", ep.prompt.len());
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let v = Vocab::builtin();
        let a = math(&v, "gsm8k", 3, 9).episodes;
        let b = math(&v, "gsm8k", 3, 9).episodes;
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }
}
