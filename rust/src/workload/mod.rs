//! Serving-time workload generators + graders — the rust mirror of
//! python/compile/tasks.py (same vocabulary grammar; the python goldens in
//! artifacts/golden_episodes.jsonl are parsed and graded by this module as
//! the cross-language parity check).
//!
//! Each generator produces an `Episode`: the prompt fed to the engine, the
//! expected answer, and the grading rule.  DESIGN.md §2 maps each task to
//! the paper benchmark it stands in for.

pub mod suites;

use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::vocab::Vocab;

#[derive(Debug, Clone)]
pub struct Episode {
    pub task: String,
    pub prompt: Vec<u32>,
    pub answer: Vec<u32>,
    pub grade: GradeRule,
}

/// How generated tokens are scored against the answer.
#[derive(Debug, Clone, PartialEq)]
pub enum GradeRule {
    /// generated must start with `answer` (ignoring anything after)
    ExactPrefix,
    /// the tokens right after the first `<ans>` in the generation must
    /// match `answer` (chain-of-thought tasks generate think tokens first)
    AfterAns,
    /// row-level F1 over `<row> tag v...` groups (LongProc HTML->TSV analog)
    RowF1 { row_width: usize },
}

/// Score a generation in [0, 1].
pub fn grade(ep: &Episode, generated: &[u32], vocab: &Vocab) -> f64 {
    match ep.grade {
        GradeRule::ExactPrefix => {
            let ok = generated.len() >= ep.answer.len()
                && generated[..ep.answer.len()] == ep.answer[..];
            ok as u8 as f64
        }
        GradeRule::AfterAns => {
            let Some(p) = generated.iter().position(|&t| t == vocab.ans())
            else { return 0.0 };
            let tail = &generated[p + 1..];
            let ok = tail.len() >= ep.answer.len()
                && tail[..ep.answer.len()] == ep.answer[..];
            ok as u8 as f64
        }
        GradeRule::RowF1 { row_width } => {
            let want = parse_rows(&ep.answer, vocab, row_width);
            let got = parse_rows(generated, vocab, row_width);
            if want.is_empty() {
                return 0.0;
            }
            let hit = got.iter().filter(|r| want.contains(r)).count() as f64;
            let prec = if got.is_empty() { 0.0 } else { hit / got.len() as f64 };
            let rec = hit / want.len() as f64;
            if prec + rec == 0.0 { 0.0 } else { 2.0 * prec * rec / (prec + rec) }
        }
    }
}

fn parse_rows(tokens: &[u32], vocab: &Vocab, row_width: usize) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if tokens[i] == vocab.row() {
            let row: Vec<u32> = tokens[i + 1..]
                .iter()
                .take(row_width + 1)
                .copied()
                .collect();
            if row.len() == row_width + 1 {
                out.push(row);
            }
            i += row_width + 2;
        } else {
            i += 1;
        }
    }
    out
}

/// Keys/values come from a reduced pool — must match python tasks.SYM_POOL.
pub const SYM_POOL: u32 = 64;

pub struct Gen<'a> {
    pub v: &'a Vocab,
    pub rng: Rng,
}

impl<'a> Gen<'a> {
    pub fn new(v: &'a Vocab, seed: u64) -> Gen<'a> {
        Gen { v, rng: Rng::new(seed) }
    }

    fn sym(&mut self) -> u32 {
        self.v.sym(self.rng.below(SYM_POOL as usize) as u32)
    }
    fn filler(&mut self, n: usize) -> Vec<u32> {
        (0..n)
            .map(|_| self.v.word(self.rng.below(self.v.num_words as usize) as u32))
            .collect()
    }
    fn distinct_syms(&mut self, n: usize) -> Vec<u32> {
        self.rng
            .sample_indices(SYM_POOL as usize, n)
            .into_iter()
            .map(|i| self.v.sym(i as u32))
            .collect()
    }

    /// recall (GSM8K/MATH analog): facts `<key> k v`, filler, final query.
    pub fn recall(&mut self, n_pairs: usize, filler: usize) -> Episode {
        let keys = self.distinct_syms(n_pairs);
        let vals: Vec<u32> = (0..n_pairs).map(|_| self.sym()).collect();
        let mut p = vec![self.v.bos()];
        for (k, v) in keys.iter().zip(&vals) {
            p.extend([self.v.key(), *k, *v]);
            let f = self.rng.below(filler + 1);
            p.extend(self.filler(f));
        }
        let qi = self.rng.below(n_pairs);
        p.extend([self.v.query(), keys[qi]]);
        Episode {
            task: "recall".into(),
            prompt: p,
            answer: vec![vals[qi]],
            grade: GradeRule::ExactPrefix,
        }
    }

    /// copy (LongProc copy analog): replay a span after `<sep>`.
    pub fn copy(&mut self, n: usize) -> Episode {
        let syms: Vec<u32> = (0..n).map(|_| self.sym()).collect();
        let mut p = vec![self.v.bos()];
        p.extend(&syms);
        p.push(self.v.sep());
        Episode {
            task: "copy".into(),
            prompt: p,
            answer: syms,
            grade: GradeRule::ExactPrefix,
        }
    }

    /// chain (AIME analog): multi-hop pointer chase with CoT generation.
    pub fn chain(&mut self, n_pairs: usize, hops: usize, filler: usize) -> Episode {
        let syms = self.distinct_syms(n_pairs + hops + 1);
        let chain: Vec<u32> = syms[..hops + 1].to_vec();
        let distract: Vec<u32> = syms[hops + 1..].to_vec();
        let mut pairs: Vec<(u32, u32)> =
            (0..hops).map(|i| (chain[i], chain[i + 1])).collect();
        for &d in &distract {
            pairs.push((d, distract[self.rng.below(distract.len())]));
        }
        self.rng.shuffle(&mut pairs);
        let mut p = vec![self.v.bos()];
        for (a, b) in pairs {
            p.extend([self.v.key(), a, b]);
            let f = self.rng.below(filler + 1);
            p.extend(self.filler(f));
        }
        p.extend([self.v.query(), chain[0], self.v.hop(),
                  self.v.digit(hops as u32), self.v.think()]);
        Episode {
            task: "chain".into(),
            prompt: p,
            answer: vec![chain[hops]],
            grade: GradeRule::AfterAns,
        }
    }

    /// proc_table (LongProc HTML->TSV analog), graded by row-F1.
    pub fn proc_table(&mut self, n_rows: usize, row_width: usize,
                      n_extract: usize) -> Episode {
        let tags = self.distinct_syms(n_rows);
        let rows: Vec<Vec<u32>> = (0..n_rows)
            .map(|_| (0..row_width).map(|_| self.sym()).collect())
            .collect();
        let mut p = vec![self.v.bos()];
        for (t, row) in tags.iter().zip(&rows) {
            p.extend([self.v.row(), *t]);
            p.extend(row);
            let f = self.rng.below(3);
            p.extend(self.filler(f));
        }
        let want = self.rng.sample_indices(n_rows, n_extract);
        p.push(self.v.exec_tok());
        for &w in &want {
            p.push(tags[w]);
        }
        p.push(self.v.ans());
        let mut answer = Vec::new();
        for &w in &want {
            answer.push(self.v.row());
            answer.push(tags[w]);
            answer.extend(&rows[w]);
        }
        Episode {
            task: "proc_table".into(),
            prompt: p,
            answer,
            grade: GradeRule::RowF1 { row_width },
        }
    }

    /// countdown (LongProc Countdown analog): digit-arithmetic trace.
    pub fn countdown(&mut self, n_steps: usize) -> Episode {
        let start = self.rng.below(10) as u32;
        let mut cur = start;
        let mut p = vec![self.v.bos(), self.v.count(), self.v.digit(start),
                         self.v.sep()];
        for _ in 0..n_steps {
            let plus = self.rng.bool(0.5);
            let operand = self.rng.range(1, 10) as u32;
            cur = if plus { (cur + operand) % 10 } else { (cur + 10 - operand) % 10 };
            p.extend([if plus { self.v.plus() } else { self.v.minus() },
                      self.v.digit(operand)]);
        }
        p.push(self.v.think());
        Episode {
            task: "countdown".into(),
            prompt: p,
            answer: vec![self.v.digit(cur)],
            grade: GradeRule::AfterAns,
        }
    }

    /// manyshot (SCBench ICL.ManyShot analog).
    pub fn manyshot(&mut self, domain: usize, n_shots: usize) -> Episode {
        let dom = self.distinct_syms(domain);
        let map: Vec<u32> = (0..domain).map(|_| self.sym()).collect();
        let mut p = vec![self.v.bos()];
        for _ in 0..n_shots {
            let i = self.rng.below(domain);
            p.extend([self.v.shot(), dom[i], map[i]]);
        }
        let qi = self.rng.below(domain);
        p.extend([self.v.query(), dom[qi]]);
        Episode {
            task: "manyshot".into(),
            prompt: p,
            answer: vec![map[qi]],
            grade: GradeRule::ExactPrefix,
        }
    }

    /// find_minmax (SCBench Math.Find analog).
    pub fn find_minmax(&mut self, n: usize) -> Episode {
        let xs: Vec<u32> = (0..n).map(|_| self.rng.below(10) as u32).collect();
        let want_max = self.rng.bool(0.5);
        let mut p = vec![self.v.bos(),
                         if want_max { self.v.find_max() } else { self.v.find_min() }];
        p.extend(xs.iter().map(|&x| self.v.digit(x)));
        p.push(self.v.ans());
        let res = if want_max {
            *xs.iter().max().unwrap()
        } else {
            *xs.iter().min().unwrap()
        };
        Episode {
            task: "find_minmax".into(),
            prompt: p,
            answer: vec![self.v.digit(res)],
            grade: GradeRule::ExactPrefix,
        }
    }

    /// multi_session (LongMemEval analog). `qtype`: "single" | "update".
    pub fn multi_session(&mut self, n_sessions: usize, facts_per: usize,
                         filler: usize, qtype: &str) -> Episode {
        let mut store: Vec<(u32, u32)> = Vec::new(); // (key, latest value)
        let mut updated: Vec<usize> = Vec::new();
        let mut p = vec![self.v.bos()];
        for s in 0..n_sessions {
            p.extend([self.v.session(), self.v.digit((s % 10) as u32)]);
            for _ in 0..facts_per {
                if qtype == "update" && !store.is_empty() && self.rng.bool(0.4) {
                    let i = self.rng.below(store.len());
                    let v = self.sym();
                    p.extend([self.v.update(), store[i].0, v]);
                    store[i].1 = v;
                    updated.push(i);
                } else {
                    let mut k = self.sym();
                    while store.iter().any(|&(sk, _)| sk == k) {
                        k = self.sym();
                    }
                    let v = self.sym();
                    p.extend([self.v.key(), k, v]);
                    store.push((k, v));
                }
            }
            let f1 = self.rng.below(filler + 1);
            p.push(self.v.user());
            p.extend(self.filler(f1));
            let f2 = self.rng.below(filler + 1);
            p.push(self.v.assistant());
            p.extend(self.filler(f2));
        }
        let qi = if qtype == "update" && !updated.is_empty() {
            updated[self.rng.below(updated.len())]
        } else {
            self.rng.below(store.len())
        };
        p.extend([self.v.sep(), self.v.query(), store[qi].0]);
        Episode {
            task: format!("multi_session_{qtype}"),
            prompt: p,
            answer: vec![store[qi].1],
            grade: GradeRule::ExactPrefix,
        }
    }

    /// niah (SCBench Retr.KV analog): one needle in a filler haystack.
    pub fn niah(&mut self, haystack: usize) -> Episode {
        let k = self.sym();
        let v = self.sym();
        let pos = self.rng.below(haystack.max(2) - 1);
        let mut p = vec![self.v.bos()];
        p.extend(self.filler(pos));
        p.extend([self.v.niah(), k, v]);
        p.extend(self.filler(haystack - pos));
        p.extend([self.v.query(), k]);
        Episode {
            task: "niah".into(),
            prompt: p,
            answer: vec![v],
            grade: GradeRule::ExactPrefix,
        }
    }
}

/// One scheduled arrival in a replicated-serving workload: a turn of a
/// (possibly skewed-popularity) conversation, or a sessionless one-shot.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub id: u64,
    pub session: Option<String>,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Zipf-like popularity weights: item `i` gets `1 / (i+1)^skew` (skew = 0
/// uniform, ~1 realistic hot-item traffic).
fn zipf_weights(n: usize, skew: f64) -> Vec<f64> {
    (0..n.max(1)).map(|i| 1.0 / ((i + 1) as f64).powf(skew)).collect()
}

/// Draw an index proportionally to `weights` (one `rng.f64()` consumed).
fn weighted_pick(rng: &mut Rng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    let mut x = rng.f64() * total;
    let mut pick = weights.len() - 1;
    for (i, w) in weights.iter().enumerate() {
        if x < *w {
            pick = i;
            break;
        }
        x -= w;
    }
    pick
}

/// Deterministic session-mix schedule for the engine-group bench and
/// router tests: `n_turns` arrivals spread over `n_sessions` conversations
/// with Zipf-like popularity (`skew` = 0 uniform, ~1 realistic hot-session
/// traffic), plus a `sessionless_frac` of one-shot requests.  Pure
/// function of the seed — every run, bench arm and replica count sees the
/// identical arrival sequence.
pub fn session_mix(seed: u64, n_sessions: usize, n_turns: usize,
                   sessionless_frac: f64, skew: f64) -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    let weights = zipf_weights(n_sessions, skew);
    let mut out = Vec::with_capacity(n_turns);
    for t in 0..n_turns {
        let session = if rng.bool(sessionless_frac) {
            None
        } else {
            let pick = weighted_pick(&mut rng, &weights);
            Some(format!("conv-{pick}"))
        };
        let len = rng.range(2, 10);
        let prompt = (0..len).map(|_| 32 + rng.below(64) as u32).collect();
        out.push(Arrival {
            id: t as u64,
            session,
            prompt,
            max_new: rng.range(2, 6),
        });
    }
    out
}

/// Deterministic shared-prefix schedule for the prefix-store bench and
/// tests: every arrival is a sessionless one-shot whose prompt opens with
/// one of `n_prefixes` fixed "system prompts" (`prefix_tokens` tokens
/// each, drawn once from the seed), picked with Zipf-like popularity, then
/// a short unique tail.  Mirrors a fleet serving a handful of agent
/// templates: a warm prefix store prefills only the tails.  Like
/// [`session_mix`], a pure function of the seed.
pub fn shared_prefix_mix(seed: u64, n_prefixes: usize, prefix_tokens: usize,
                         n_requests: usize, skew: f64) -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    let prefixes: Vec<Vec<u32>> = (0..n_prefixes.max(1))
        .map(|_| (0..prefix_tokens).map(|_| 32 + rng.below(64) as u32).collect())
        .collect();
    let weights = zipf_weights(prefixes.len(), skew);
    let mut out = Vec::with_capacity(n_requests);
    for t in 0..n_requests {
        let mut prompt = prefixes[weighted_pick(&mut rng, &weights)].clone();
        let tail = rng.range(8, 24);
        prompt.extend((0..tail).map(|_| 32 + rng.below(64) as u32));
        out.push(Arrival {
            id: t as u64,
            session: None,
            prompt,
            max_new: rng.range(2, 6),
        });
    }
    out
}

/// Parse one line of artifacts/golden_episodes.jsonl (cross-language parity:
/// python-generated episodes must be gradeable by the rust rules).
pub fn parse_golden_line(line: &str)
    -> anyhow::Result<(String, Vec<u32>, usize, Vec<u32>)> {
    let j = Json::parse(line)?;
    let to_tokens = |key: &str| -> anyhow::Result<Vec<u32>> {
        Ok(j.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing {key}"))?
            .iter()
            .filter_map(Json::as_usize)
            .map(|x| x as u32)
            .collect())
    };
    Ok((
        j.str_field("task")?.to_string(),
        to_tokens("tokens")?,
        j.usize_field("prompt_end")?,
        to_tokens("answer")?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> (Vocab, Gen<'static>) {
        let v: &'static Vocab = Box::leak(Box::new(Vocab::builtin()));
        (v.clone(), Gen::new(v, 42))
    }

    #[test]
    fn recall_answer_follows_queried_key() {
        let (v, mut g) = gen();
        for _ in 0..30 {
            let ep = g.recall(6, 4);
            let q = *ep.prompt.last().unwrap();
            let idx = ep
                .prompt
                .windows(2)
                .position(|w| w[0] == v.key() && w[1] == q)
                .unwrap();
            assert_eq!(ep.prompt[idx + 2], ep.answer[0]);
        }
    }

    #[test]
    fn chain_answer_reachable() {
        let (v, mut g) = gen();
        for _ in 0..20 {
            let ep = g.chain(6, 3, 2);
            let mut map = std::collections::BTreeMap::new();
            let toks = &ep.prompt;
            for i in 0..toks.len() - 2 {
                if toks[i] == v.key() {
                    map.insert(toks[i + 1], toks[i + 2]);
                }
            }
            let qpos = toks.iter().position(|&t| t == v.query()).unwrap();
            let mut cur = toks[qpos + 1];
            for _ in 0..3 {
                cur = map[&cur];
            }
            assert_eq!(cur, ep.answer[0]);
        }
    }

    #[test]
    fn countdown_answer_matches_ops() {
        let (v, mut g) = gen();
        for _ in 0..20 {
            let ep = g.countdown(4);
            let toks = &ep.prompt;
            let mut cur = toks[2] - v.digit(0);
            let mut i = 4;
            while toks[i] != v.think() {
                let operand = toks[i + 1] - v.digit(0);
                cur = if toks[i] == v.plus() {
                    (cur + operand) % 10
                } else {
                    (cur + 10 - operand) % 10
                };
                i += 2;
            }
            assert_eq!(v.digit(cur), ep.answer[0]);
        }
    }

    #[test]
    fn multi_session_update_wins() {
        let (v, mut g) = gen();
        for _ in 0..30 {
            let ep = g.multi_session(3, 3, 4, "update");
            let toks = &ep.prompt;
            let q = *toks.last().unwrap();
            let mut latest = None;
            for i in 0..toks.len() - 2 {
                if (toks[i] == v.key() || toks[i] == v.update()) && toks[i + 1] == q {
                    latest = Some(toks[i + 2]);
                }
            }
            assert_eq!(latest, Some(ep.answer[0]));
        }
    }

    #[test]
    fn grade_exact_prefix() {
        let (v, mut g) = gen();
        let ep = g.recall(4, 2);
        let mut gen_ok = ep.answer.clone();
        gen_ok.push(v.eos());
        assert_eq!(grade(&ep, &gen_ok, &v), 1.0);
        assert_eq!(grade(&ep, &[499], &v), 0.0);
        assert_eq!(grade(&ep, &[], &v), 0.0);
    }

    #[test]
    fn grade_after_ans() {
        let (v, mut g) = gen();
        let ep = g.chain(5, 2, 2);
        let gen_toks = vec![v.sym(1), v.sym(2), v.end_think(), v.ans(),
                            ep.answer[0], v.eos()];
        assert_eq!(grade(&ep, &gen_toks, &v), 1.0);
        let bad = vec![v.ans(), ep.answer[0] + 1];
        assert_eq!(grade(&ep, &bad, &v), 0.0);
        assert_eq!(grade(&ep, &[v.eos()], &v), 0.0); // no <ans> at all
    }

    #[test]
    fn grade_row_f1_partial_credit() {
        let (v, mut g) = gen();
        let ep = g.proc_table(5, 2, 2);
        assert_eq!(grade(&ep, &ep.answer, &v), 1.0);
        // half the rows -> F1 = 2 * 0.5 / 1.5
        let half = &ep.answer[..4];
        let f1 = grade(&ep, half, &v);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-9, "f1 {f1}");
        assert_eq!(grade(&ep, &[], &v), 0.0);
    }

    #[test]
    fn prompts_are_bounded_and_clean() {
        let (v, mut g) = gen();
        for _ in 0..50 {
            let ep = g.multi_session(4, 3, 6, "single");
            assert!(ep.prompt.len() < 400);
            assert_eq!(ep.prompt[0], v.bos());
            assert!(ep.prompt.iter().all(|&t| (t as usize) < v.size));
        }
    }

    #[test]
    fn parse_golden_line_works() {
        let line = r#"{"task": "recall", "tokens": [1, 6, 40, 41, 2],
                       "prompt_end": 3, "answer_start": 3, "answer": [41]}"#;
        let (task, tokens, pe, ans) = parse_golden_line(line).unwrap();
        assert_eq!(task, "recall");
        assert_eq!(tokens.len(), 5);
        assert_eq!(pe, 3);
        assert_eq!(ans, vec![41]);
    }

    #[test]
    fn session_mix_is_deterministic_and_skewed() {
        let a = session_mix(7, 8, 200, 0.25, 1.0);
        let b = session_mix(7, 8, 200, 0.25, 1.0);
        assert_eq!(a.len(), 200);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.session, y.session);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
        }
        // skew makes conv-0 the hottest session, and one-shots appear
        let count = |sid: &str| {
            a.iter().filter(|t| t.session.as_deref() == Some(sid)).count()
        };
        assert!(count("conv-0") > count("conv-7"),
                "skew 1.0 must favor the first session");
        assert!(a.iter().any(|t| t.session.is_none()));
        // zero skew with no one-shots: every session gets traffic
        let u = session_mix(7, 4, 400, 0.0, 0.0);
        for i in 0..4 {
            let want = format!("conv-{i}");
            assert!(u.iter().any(
                |t| t.session.as_deref() == Some(want.as_str())));
        }
    }

    #[test]
    fn shared_prefix_mix_reuses_a_small_prefix_pool() {
        let a = shared_prefix_mix(9, 4, 64, 100, 1.0);
        let b = shared_prefix_mix(9, 4, 64, 100, 1.0);
        assert_eq!(a.len(), 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
            assert!(x.session.is_none(), "shared-prefix traffic is one-shot");
            assert!(x.prompt.len() >= 64 + 8 && x.prompt.len() < 64 + 24,
                    "prefix + short tail, got {}", x.prompt.len());
        }
        // heads come from the fixed pool; tails keep full prompts distinct
        let mut head_counts = std::collections::BTreeMap::new();
        for t in &a {
            *head_counts.entry(t.prompt[..64].to_vec()).or_insert(0usize) += 1;
        }
        assert!(head_counts.len() <= 4, "more heads than the pool");
        assert!(head_counts.len() >= 2, "pool collapsed to one prefix");
        let hottest = *head_counts.values().max().unwrap();
        assert!(hottest > 100 / 4, "zipf skew must concentrate traffic");
        let full: std::collections::BTreeSet<&Vec<u32>> =
            a.iter().map(|t| &t.prompt).collect();
        assert!(full.len() > 90, "tails should make prompts unique");
    }
}
