//! ModelBackend: the engine's interface to the AOT-compiled model graphs.
//!
//! `PjrtBackend` executes the HLO artifacts on the PJRT CPU client with the
//! KV caches held device-resident (only logits / gate scores / attention
//! stats cross the device boundary each step — the paper's O(M) decode).
//! `MockBackend` is a deterministic stand-in used by unit/property tests so
//! the scheduler, cache manager and policies are testable without artifacts.

use anyhow::{ensure, Context, Result};

use crate::model_meta::{ModelDims, ModelMeta};

/// One decode step over all B lanes.  Layouts are row-major flat slices:
/// valid `[L,B,H,M]`, write_slot `[L,B,H]`, inject_k/v `[L,B,H,dh]`.
pub struct DecodeIn<'a> {
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub valid: &'a [f32],
    pub write_slot: &'a [i32],
    pub inject_flag: Option<&'a [f32]>,
    pub inject_slot: Option<&'a [i32]>,
    pub inject_k: Option<&'a [f32]>,
    pub inject_v: Option<&'a [f32]>,
    /// download the attention stats (H2O/SnapKV/R-KV/retrieval only)
    pub want_attn: bool,
    /// download k_new/v_new (key-similarity + retrieval policies only)
    pub want_kv: bool,
}

#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>,   // [B, vocab]
    pub log_beta: Vec<f32>, // [L, B, H]
    pub attn: Vec<f32>,     // [L, B, H, M]
    pub k_new: Vec<f32>,    // [L, B, H, dh]
    pub v_new: Vec<f32>,    // [L, B, H, dh]
}

/// One prefill chunk of C tokens per lane.
pub struct PrefillIn<'a> {
    pub tokens: &'a [i32],      // [B, C]
    pub pos: &'a [i32],         // [B, C]
    pub in_mask: &'a [f32],     // [B, C]
    pub valid: &'a [f32],       // [L, B, H, M]
    pub write_slots: &'a [i32], // [L, B, H, C]
}

#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub logits: Vec<f32>,     // [B, C, vocab]
    pub log_beta: Vec<f32>,   // [L, B, H, C]
    pub attn_slots: Vec<f32>, // [L, B, H, M]
    pub attn_chunk: Vec<f32>, // [L, B, H, C]
    pub k_chunk: Vec<f32>,    // [L, B, H, C, dh]
    pub v_chunk: Vec<f32>,    // [L, B, H, C, dh]
}

pub trait ModelBackend: Send {
    fn dims(&self) -> ModelDims;
    fn batch(&self) -> usize;
    fn slots(&self) -> usize;
    fn chunk(&self) -> usize;
    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut>;
    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut>;
    /// Zero the device-resident KV caches (new evaluation run).
    fn reset_cache(&mut self) -> Result<()>;

    /// Download one lane's K/V slabs to the host as two flat `[L, H, M, dh]`
    /// row-major buffers (session swap-out).
    fn download_lane_kv(&mut self, lane: usize) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Upload host `[L, H, M, dh]` slabs into one lane of the device K/V
    /// cache, leaving every other lane untouched (session swap-in).
    fn upload_lane_kv(&mut self, lane: usize, k: &[f32], v: &[f32])
        -> Result<()>;

    /// Elements in one lane's `[L, H, M, dh]` slab (sizing for swap buffers).
    fn lane_kv_len(&self) -> usize {
        let d = self.dims();
        d.layers * d.hkv * self.slots() * d.dh
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

pub struct PjrtBackend {
    client: xla::PjRtClient,
    decode_exe: xla::PjRtLoadedExecutable,
    prefill_exe: Option<xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>, // params ++ gates, device-resident
    kc: xla::PjRtBuffer,
    vc: xla::PjRtBuffer,
    dims: ModelDims,
    b: usize,
    m: usize,
    c: usize,
}

impl PjrtBackend {
    /// Load artifacts for batch `b` and budget->slot count `m` (exact match
    /// against an exported variant chosen by the caller via `meta.pick`).
    pub fn load(meta: &ModelMeta, b: usize, m: usize, gate_variant: &str,
                gate_arch: &str, with_prefill: bool) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()?;
        let dec = meta
            .pick("decode", b, m, gate_arch)
            .with_context(|| format!("no decode artifact for b={b} m>={m}"))?;
        ensure!(dec.m == m, "caller must pass an exported slot count");
        let decode_exe = compile_hlo(&client, &meta.dir.join(&dec.file))?;
        let prefill_exe = if with_prefill {
            let pre = meta
                .pick("prefill", b, m, gate_arch)
                .with_context(|| format!("no prefill artifact for b={b} m={m}"))?;
            ensure!(pre.m == m, "prefill/decode slot mismatch");
            Some(compile_hlo(&client, &meta.dir.join(&pre.file))?)
        } else {
            None
        };

        // upload weights once, in the flat order the graphs expect
        let weights = super::weights::read_weights(&meta.dir.join("weights.bin"))?;
        let gates = super::weights::read_weights(
            &meta.dir.join(format!("gates_{gate_variant}.bin")))?;
        let gate_order: Vec<String> = if gate_arch == "linear" {
            gates.keys().cloned().collect() // BTreeMap order == gN.{b1,w1}
        } else {
            meta.gate_order.iter().map(|t| t.name.clone()).collect()
        };
        let mut weight_bufs = Vec::new();
        for spec in &meta.param_order {
            let t = weights
                .get(&spec.name)
                .with_context(|| format!("weights.bin missing {}", spec.name))?;
            ensure!(t.shape == spec.shape, "shape mismatch for {}", spec.name);
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }
        for name in &gate_order {
            let t = gates
                .get(name)
                .with_context(|| format!("gates bin missing {name}"))?;
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }

        let dims = meta.dims;
        let cache_shape = [dims.layers, b, dims.hkv, m, dims.dh];
        let zeros = vec![0.0f32; cache_shape.iter().product()];
        let kc = client.buffer_from_host_buffer(&zeros, &cache_shape, None)?;
        let vc = client.buffer_from_host_buffer(&zeros, &cache_shape, None)?;
        Ok(PjrtBackend {
            client,
            decode_exe,
            prefill_exe,
            weight_bufs,
            kc,
            vc,
            dims,
            b,
            m,
            c: meta.chunk,
        })
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn lbh(&self) -> (usize, usize, usize) {
        (self.dims.layers, self.b, self.dims.hkv)
    }
}

/// Gather one lane's `[L, H, M, dh]` rows out of a flat `[L, B, H, M, dh]`
/// cache (`stride` = H * M * dh).
fn gather_lane(cache: &[f32], lane: usize, l: usize, b: usize,
               stride: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(l * stride);
    for li in 0..l {
        let off = (li * b + lane) * stride;
        out.extend_from_slice(&cache[off..off + stride]);
    }
    out
}

/// Scatter one lane's `[L, H, M, dh]` rows back into a flat
/// `[L, B, H, M, dh]` cache, leaving other lanes untouched.
fn scatter_lane(cache: &mut [f32], lane: usize, l: usize, b: usize,
                stride: usize, src: &[f32]) {
    for li in 0..l {
        let off = (li * b + lane) * stride;
        cache[off..off + stride]
            .copy_from_slice(&src[li * stride..(li + 1) * stride]);
    }
}

pub fn compile_hlo(client: &xla::PjRtClient,
                   path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn to_host(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
}

impl ModelBackend for PjrtBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut> {
        let (l, b, h) = self.lbh();
        let (m, dh) = (self.m, self.dims.dh);
        ensure!(ins.tokens.len() == b && ins.pos.len() == b, "bad lane count");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slot.len() == l * b * h, "bad write_slot len");

        let zero_f = vec![0.0f32; l * b * h];
        let zero_i = vec![0i32; l * b * h];
        let zero_k = vec![0.0f32; l * b * h * dh];
        let token_b = self.upload_i32(ins.tokens, &[b])?;
        let pos_b = self.upload_i32(ins.pos, &[b])?;
        let valid_b = self.upload_f32(ins.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(ins.write_slot, &[l, b, h])?;
        let if_b = self.upload_f32(ins.inject_flag.unwrap_or(&zero_f), &[l, b, h])?;
        let is_b = self.upload_i32(ins.inject_slot.unwrap_or(&zero_i), &[l, b, h])?;
        let ik_b = self.upload_f32(ins.inject_k.unwrap_or(&zero_k), &[l, b, h, dh])?;
        let iv_b = self.upload_f32(ins.inject_v.unwrap_or(&zero_k), &[l, b, h, dh])?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&token_b, &pos_b, &self.kc, &self.vc, &valid_b, &ws_b,
                     &if_b, &is_b, &ik_b, &iv_b]);
        let mut outs = self.decode_exe.execute_b(&args)?;
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 8, "decode graph returned {} outputs", outs.len());
        // order: logits, kc, vc, valid, log_beta, attn, k_new, v_new
        // (perf: skip device->host transfers the policy will not consume)
        let out = DecodeOut {
            logits: to_host(&outs[0])?,
            log_beta: to_host(&outs[4])?,
            attn: if ins.want_attn { to_host(&outs[5])? } else { Vec::new() },
            k_new: if ins.want_kv { to_host(&outs[6])? } else { Vec::new() },
            v_new: if ins.want_kv { to_host(&outs[7])? } else { Vec::new() },
        };
        self.vc = outs.swap_remove(2);
        self.kc = outs.swap_remove(1);
        Ok(out)
    }

    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut> {
        let (l, b, h) = self.lbh();
        let (m, c) = (self.m, self.c);
        let exe = self
            .prefill_exe
            .as_ref()
            .context("backend loaded without prefill graph")?;
        ensure!(ins.tokens.len() == b * c, "bad tokens len");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slots.len() == l * b * h * c, "bad write_slots len");

        let tok_b = self.upload_i32(ins.tokens, &[b, c])?;
        let pos_b = self.upload_i32(ins.pos, &[b, c])?;
        let mask_b = self.upload_f32(ins.in_mask, &[b, c])?;
        let valid_b = self.upload_f32(ins.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(ins.write_slots, &[l, b, h, c])?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&tok_b, &pos_b, &mask_b, &self.kc, &self.vc, &valid_b, &ws_b]);
        let mut outs = exe.execute_b(&args)?;
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 9, "prefill graph returned {} outputs", outs.len());
        // order: logits, kc, vc, valid, log_beta, attn_slots, attn_chunk,
        //        k_chunk, v_chunk
        let out = PrefillOut {
            logits: to_host(&outs[0])?,
            log_beta: to_host(&outs[4])?,
            attn_slots: to_host(&outs[5])?,
            attn_chunk: to_host(&outs[6])?,
            k_chunk: to_host(&outs[7])?,
            v_chunk: to_host(&outs[8])?,
        };
        self.vc = outs.swap_remove(2);
        self.kc = outs.swap_remove(1);
        Ok(out)
    }

    fn reset_cache(&mut self) -> Result<()> {
        let (l, b, h) = self.lbh();
        let shape = [l, b, h, self.m, self.dims.dh];
        let zeros = vec![0.0f32; shape.iter().product()];
        self.kc = self.client.buffer_from_host_buffer(&zeros, &shape, None)?;
        self.vc = self.client.buffer_from_host_buffer(&zeros, &shape, None)?;
        Ok(())
    }

    fn download_lane_kv(&mut self, lane: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let (l, b, h) = self.lbh();
        ensure!(lane < b, "lane {lane} out of range (batch {b})");
        // PJRT CPU exposes no partial-buffer reads/writes, and the graphs
        // take kc/vc as single buffers, so a lane swap round-trips the full
        // [L,B,H,M,dh] cache (see ROADMAP: per-lane cache buffers or a
        // batched swap API would make this O(lane)).
        let kc = to_host(&self.kc)?;
        let vc = to_host(&self.vc)?;
        let stride = h * self.m * self.dims.dh;
        Ok((gather_lane(&kc, lane, l, b, stride),
            gather_lane(&vc, lane, l, b, stride)))
    }

    fn upload_lane_kv(&mut self, lane: usize, k: &[f32], v: &[f32])
        -> Result<()> {
        let (l, b, h) = self.lbh();
        ensure!(lane < b, "lane {lane} out of range (batch {b})");
        let stride = h * self.m * self.dims.dh;
        ensure!(k.len() == l * stride && v.len() == l * stride,
                "lane kv slab has {} elems, expected {}", k.len(), l * stride);
        let mut kc = to_host(&self.kc)?;
        let mut vc = to_host(&self.vc)?;
        scatter_lane(&mut kc, lane, l, b, stride, k);
        scatter_lane(&mut vc, lane, l, b, stride, v);
        let shape = [l, b, h, self.m, self.dims.dh];
        self.kc = self.client.buffer_from_host_buffer(&kc, &shape, None)?;
        self.vc = self.client.buffer_from_host_buffer(&vc, &shape, None)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Mock backend (tests)
// ---------------------------------------------------------------------------

/// Deterministic fake model: the next-token distribution peaks at
/// `(token + 1) % vocab` until `eos_after` tokens have been produced on a
/// lane, then at EOS (id 2).  Gate scores depend only on (layer, head,
/// token) so TRIM-KV evictions are reproducible in tests.
pub struct MockBackend {
    pub dims: ModelDims,
    pub b: usize,
    pub m: usize,
    pub c: usize,
    pub eos_after: usize,
    pub decoded_per_lane: Vec<usize>,
    pub decode_calls: usize,
    pub prefill_calls: usize,
    /// Host mirror of the device K/V slot arenas, `[L, B, H, M, dh]` —
    /// written exactly where the real graphs would scatter, so the session
    /// swap path (download/upload of lane slabs) is testable end-to-end.
    pub kc: Vec<f32>,
    pub vc: Vec<f32>,
}

impl MockBackend {
    pub fn new(b: usize, m: usize) -> MockBackend {
        let dims = ModelDims { vocab: 512, d: 128, layers: 4, hq: 4, hkv: 2,
                               dh: 32, ffn: 256, gate_hidden: 48 };
        let cache = dims.layers * b * dims.hkv * m * dims.dh;
        MockBackend {
            dims,
            b,
            m,
            c: 16,
            eos_after: usize::MAX,
            decoded_per_lane: vec![0; b],
            decode_calls: 0,
            prefill_calls: 0,
            kc: vec![0.0; cache],
            vc: vec![0.0; cache],
        }
    }

    pub fn with_eos_after(mut self, n: usize) -> Self {
        self.eos_after = n;
        self
    }

    /// Deterministic per-token gate score in (0, 1): higher for sym tokens,
    /// low for word (filler) tokens — crude mirror of the trained gates.
    pub fn mock_log_beta(l: usize, hh: usize, token: i32) -> f32 {
        let t = token as u32;
        let hash = t
            .wrapping_mul(2654435761)
            .wrapping_add((l as u32) << 8)
            .wrapping_add(hh as u32)
            % 1000;
        let base = if (32..288).contains(&t) { 0.999 } else { 0.95 };
        let beta = base - (hash as f32) / 40_000.0;
        beta.ln()
    }
}

impl ModelBackend for MockBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut> {
        self.decode_calls += 1;
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v) = (self.m, self.dims.dh, self.dims.vocab);
        let mut logits = vec![0.0f32; b * v];
        for lane in 0..b {
            let tok = ins.tokens[lane];
            self.decoded_per_lane[lane] += 1;
            let next = if self.decoded_per_lane[lane] >= self.eos_after {
                2 // EOS
            } else {
                ((tok + 1) as usize) % v
            };
            logits[lane * v + next] = 10.0;
        }
        let mut log_beta = vec![0.0f32; l * b * h];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    log_beta[(li * b + lane) * h + hh] =
                        Self::mock_log_beta(li, hh, ins.tokens[lane]);
                }
            }
        }
        // uniform attention over live slots
        let mut attn = vec![0.0f32; l * b * h * m];
        for i in 0..l * b * h {
            let row = &ins.valid[i * m..(i + 1) * m];
            let live: f32 = row.iter().sum();
            if live > 0.0 {
                for s in 0..m {
                    attn[i * m + s] = row[s] / live;
                }
            }
        }
        let mut k_new = vec![0.0f32; l * b * h * dh];
        for (i, x) in k_new.iter_mut().enumerate() {
            *x = ((i % 7) as f32) * 0.1 + ins.tokens[(i / dh / h) % b] as f32 * 1e-3;
        }
        let v_new = k_new.clone();
        // scatter into the mock K/V arenas exactly as the decode graph
        // would: pending injects first, then the step's write_slot
        for base in 0..l * b * h {
            if let (Some(flag), Some(islot)) = (ins.inject_flag, ins.inject_slot) {
                if flag[base] > 0.0 {
                    let s = islot[base] as usize;
                    let dst = (base * m + s) * dh;
                    if let (Some(ik), Some(iv)) = (ins.inject_k, ins.inject_v) {
                        self.kc[dst..dst + dh]
                            .copy_from_slice(&ik[base * dh..(base + 1) * dh]);
                        self.vc[dst..dst + dh]
                            .copy_from_slice(&iv[base * dh..(base + 1) * dh]);
                    }
                }
            }
            let s = ins.write_slot[base] as usize;
            let dst = (base * m + s) * dh;
            self.kc[dst..dst + dh]
                .copy_from_slice(&k_new[base * dh..(base + 1) * dh]);
            self.vc[dst..dst + dh]
                .copy_from_slice(&v_new[base * dh..(base + 1) * dh]);
        }
        Ok(DecodeOut { logits, log_beta, attn, k_new, v_new })
    }

    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut> {
        self.prefill_calls += 1;
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v, c) = (self.m, self.dims.dh, self.dims.vocab, self.c);
        let mut logits = vec![0.0f32; b * c * v];
        for lane in 0..b {
            for ci in 0..c {
                let tok = ins.tokens[lane * c + ci];
                logits[(lane * c + ci) * v + ((tok + 1) as usize) % v] = 10.0;
            }
        }
        let mut log_beta = vec![0.0f32; l * b * h * c];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    for ci in 0..c {
                        log_beta[((li * b + lane) * h + hh) * c + ci] =
                            Self::mock_log_beta(li, hh, ins.tokens[lane * c + ci]);
                    }
                }
            }
        }
        let attn_slots = vec![1.0 / m as f32; l * b * h * m];
        let attn_chunk = vec![1.0 / c as f32; l * b * h * c];
        // token-dependent chunk K/V (same formula as decode) so swapped
        // slabs carry distinguishable content in tests
        let mut k_chunk = vec![0.0f32; l * b * h * c * dh];
        for (i, x) in k_chunk.iter_mut().enumerate() {
            let lane = (i / (h * c * dh)) % b;
            let ci = (i / dh) % c;
            *x = ((i % 7) as f32) * 0.1
                + ins.tokens[lane * c + ci] as f32 * 1e-3;
        }
        let v_chunk = k_chunk.clone();
        // scatter the chunk into the mock arenas at the planned write slots
        for base in 0..l * b * h {
            let lane = (base / h) % b;
            for ci in 0..c {
                if ins.in_mask[lane * c + ci] <= 0.0 {
                    continue;
                }
                let s = ins.write_slots[base * c + ci] as usize;
                let dst = (base * m + s) * dh;
                let src = (base * c + ci) * dh;
                self.kc[dst..dst + dh].copy_from_slice(&k_chunk[src..src + dh]);
                self.vc[dst..dst + dh].copy_from_slice(&v_chunk[src..src + dh]);
            }
        }
        Ok(PrefillOut { logits, log_beta, attn_slots, attn_chunk, k_chunk, v_chunk })
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.decoded_per_lane = vec![0; self.b];
        self.kc.iter_mut().for_each(|x| *x = 0.0);
        self.vc.iter_mut().for_each(|x| *x = 0.0);
        Ok(())
    }

    fn download_lane_kv(&mut self, lane: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        ensure!(lane < b, "lane {lane} out of range (batch {b})");
        let stride = h * self.m * self.dims.dh;
        Ok((gather_lane(&self.kc, lane, l, b, stride),
            gather_lane(&self.vc, lane, l, b, stride)))
    }

    fn upload_lane_kv(&mut self, lane: usize, k: &[f32], v: &[f32])
        -> Result<()> {
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        ensure!(lane < b, "lane {lane} out of range (batch {b})");
        let stride = h * self.m * self.dims.dh;
        ensure!(k.len() == l * stride && v.len() == l * stride,
                "lane kv slab has {} elems, expected {}", k.len(), l * stride);
        scatter_lane(&mut self.kc, lane, l, b, stride, k);
        scatter_lane(&mut self.vc, lane, l, b, stride, v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_decode_emits_successor_then_eos() {
        let mut mb = MockBackend::new(2, 8).with_eos_after(3);
        let valid = vec![0.0f32; 4 * 2 * 2 * 8];
        let ws = vec![0i32; 4 * 2 * 2];
        for step in 0..4 {
            let out = mb
                .decode(&DecodeIn {
                    tokens: &[10, 20],
                    pos: &[step, step],
                    valid: &valid,
                    write_slot: &ws,
                    inject_flag: None,
                    inject_slot: None,
                    inject_k: None,
                    inject_v: None,
                    want_attn: true,
                    want_kv: true,
                })
                .unwrap();
            let argmax = |lane: usize| {
                (0..512)
                    .max_by(|&a, &b| {
                        out.logits[lane * 512 + a]
                            .partial_cmp(&out.logits[lane * 512 + b])
                            .unwrap()
                    })
                    .unwrap()
            };
            if step < 2 {
                assert_eq!(argmax(0), 11);
                assert_eq!(argmax(1), 21);
            } else {
                assert_eq!(argmax(0), 2);
            }
        }
    }

    #[test]
    fn mock_log_beta_prefers_syms() {
        let sym = MockBackend::mock_log_beta(0, 0, 40);
        let word = MockBackend::mock_log_beta(0, 0, 300);
        assert!(sym > word);
        assert!(sym < 0.0);
    }

    #[test]
    fn mock_lane_kv_download_upload_roundtrip() {
        let mut mb = MockBackend::new(2, 8);
        let valid = vec![0.0f32; 4 * 2 * 2 * 8];
        // decode writes lane 0 into slot 1, lane 1 into slot 3
        let mut ws = vec![0i32; 4 * 2 * 2];
        for li in 0..4 {
            for hh in 0..2 {
                ws[(li * 2) * 2 + hh] = 1;
                ws[(li * 2 + 1) * 2 + hh] = 3;
            }
        }
        mb.decode(&DecodeIn {
            tokens: &[10, 77],
            pos: &[0, 0],
            valid: &valid,
            write_slot: &ws,
            inject_flag: None,
            inject_slot: None,
            inject_k: None,
            inject_v: None,
            want_attn: false,
            want_kv: true,
        })
        .unwrap();
        let (k0, v0) = mb.download_lane_kv(0).unwrap();
        let (k1, _) = mb.download_lane_kv(1).unwrap();
        assert_eq!(k0.len(), mb.lane_kv_len());
        assert_ne!(k0, k1, "lanes with different tokens share a slab");
        // roundtrip: upload lane 0's slab into lane 1, download, compare
        let k0c = k0.clone();
        let v0c = v0.clone();
        mb.upload_lane_kv(1, &k0c, &v0c).unwrap();
        let (k1b, v1b) = mb.download_lane_kv(1).unwrap();
        assert_eq!(k1b, k0);
        assert_eq!(v1b, v0);
        // lane 0 untouched by the lane-1 upload
        let (k0b, _) = mb.download_lane_kv(0).unwrap();
        assert_eq!(k0b, k0);
        assert!(mb.upload_lane_kv(1, &k0c[1..], &v0c).is_err());
        assert!(mb.download_lane_kv(9).is_err());
    }

    #[test]
    fn mock_attention_is_uniform_over_live() {
        let mut mb = MockBackend::new(1, 4);
        let mut valid = vec![0.0f32; 4 * 1 * 2 * 4];
        valid[0] = 1.0;
        valid[1] = 1.0;
        let out = mb
            .decode(&DecodeIn {
                tokens: &[1],
                pos: &[0],
                valid: &valid,
                write_slot: &[0; 8],
                inject_flag: None,
                inject_slot: None,
                inject_k: None,
                inject_v: None,
                want_attn: true,
                want_kv: true,
            })
            .unwrap();
        assert_eq!(out.attn[0], 0.5);
        assert_eq!(out.attn[1], 0.5);
        assert_eq!(out.attn[2], 0.0);
    }
}
