//! ModelBackend: the engine's interface to the AOT-compiled model graphs.
//!
//! `PjrtBackend` executes the HLO artifacts on the PJRT CPU client with the
//! KV caches held device-resident (only logits / gate scores / attention
//! stats cross the device boundary each step — the paper's O(M) decode).
//! Cache residency is owned by [`DeviceKvCache`]: per-lane buffer pairs for
//! `cache_layout = "per_lane"` artifacts (O(lane) session swap) or a single
//! monolithic pair with a staged host shadow for legacy artifacts.
//! `MockBackend` is a deterministic stand-in used by unit/property tests so
//! the scheduler, cache manager and policies are testable without artifacts.

use anyhow::{ensure, Context, Result};

use super::devcache::{CacheShape, DeviceKvCache, HostLaneArena, LaneKv,
                      SwapTraffic};
use crate::model_meta::{ModelDims, ModelMeta};

/// One decode step over all B lanes.  Layouts are row-major flat slices:
/// valid `[L,B,H,M]`, write_slot `[L,B,H]`, inject_k/v `[L,B,H,dh]`.
pub struct DecodeIn<'a> {
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub valid: &'a [f32],
    pub write_slot: &'a [i32],
    pub inject_flag: Option<&'a [f32]>,
    pub inject_slot: Option<&'a [i32]>,
    pub inject_k: Option<&'a [f32]>,
    pub inject_v: Option<&'a [f32]>,
    /// download the attention stats (H2O/SnapKV/R-KV/retrieval only)
    pub want_attn: bool,
    /// download k_new/v_new (key-similarity + retrieval policies only)
    pub want_kv: bool,
}

#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>,   // [B, vocab]
    pub log_beta: Vec<f32>, // [L, B, H]
    pub attn: Vec<f32>,     // [L, B, H, M]
    pub k_new: Vec<f32>,    // [L, B, H, dh]
    pub v_new: Vec<f32>,    // [L, B, H, dh]
}

/// One prefill chunk of C tokens per lane.
pub struct PrefillIn<'a> {
    pub tokens: &'a [i32],      // [B, C]
    pub pos: &'a [i32],         // [B, C]
    pub in_mask: &'a [f32],     // [B, C]
    pub valid: &'a [f32],       // [L, B, H, M]
    pub write_slots: &'a [i32], // [L, B, H, C]
}

#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub logits: Vec<f32>,     // [B, C, vocab]
    pub log_beta: Vec<f32>,   // [L, B, H, C]
    pub attn_slots: Vec<f32>, // [L, B, H, M]
    pub attn_chunk: Vec<f32>, // [L, B, H, C]
    pub k_chunk: Vec<f32>,    // [L, B, H, C, dh]
    pub v_chunk: Vec<f32>,    // [L, B, H, C, dh]
}

/// One fused *mixed tick* over all B lanes: decoding lanes advance by one
/// token (a 1-token chunk in column 0), mid-prefill lanes by a budgeted
/// chunk — a single backend step, so a long prompt admission never stalls
/// the decode stream.  Layouts match `PrefillIn` plus the per-lane `mode`.
pub struct MixedIn<'a> {
    pub tokens: &'a [i32],      // [B, C]
    pub pos: &'a [i32],         // [B, C]
    pub in_mask: &'a [f32],     // [B, C]
    /// per lane: 1.0 = decode lane (column 0 holds its token), 0.0 =
    /// chunk-fill lane.  Idle lanes are chunk-fill with an all-zero mask.
    pub mode: &'a [f32],        // [B]
    pub valid: &'a [f32],       // [L, B, H, M]
    pub write_slots: &'a [i32], // [L, B, H, C]
}

/// Mixed-tick outputs: the prefill tuple, with `attn_slots` mode-fused —
/// for decode lanes the new token's self-attention mass is folded into its
/// write slot, so each decode lane reads one `[M]` row exactly like
/// `DecodeOut::attn`.
#[derive(Debug, Clone)]
pub struct MixedOut {
    pub logits: Vec<f32>,     // [B, C, vocab]
    pub log_beta: Vec<f32>,   // [L, B, H, C]
    pub attn_slots: Vec<f32>, // [L, B, H, M]
    pub attn_chunk: Vec<f32>, // [L, B, H, C]
    pub k_chunk: Vec<f32>,    // [L, B, H, C, dh]
    pub v_chunk: Vec<f32>,    // [L, B, H, C, dh]
}

pub trait ModelBackend: Send {
    fn dims(&self) -> ModelDims;
    fn batch(&self) -> usize;
    fn slots(&self) -> usize;
    fn chunk(&self) -> usize;
    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut>;
    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut>;

    /// Does this backend carry a fused mixed-step graph?  When false the
    /// engine falls back to today's alternating prefill/decode ticks
    /// (legacy artifacts exported before the `mixed` kind).
    fn supports_mixed(&self) -> bool {
        false
    }

    /// One fused mixed tick (see [`MixedIn`]).  Implementations must keep
    /// exact per-lane token accounting: every `in_mask == 1` position of a
    /// lane advances that lane by exactly one token, decode and chunk-fill
    /// lanes alike, in the one call.
    fn step_mixed(&mut self, _ins: &MixedIn) -> Result<MixedOut> {
        anyhow::bail!("backend has no fused mixed-step graph \
                       (re-export artifacts with `python -m compile.aot`)")
    }

    /// Zero the device-resident KV caches (new evaluation run).
    fn reset_cache(&mut self) -> Result<()>;

    /// Batched lane-level session swap: download the current `[L, H, M, dh]`
    /// K/V slabs of every lane in `out` (returned in `out` order), then
    /// upload the `inn` slabs into their lanes, leaving every other lane
    /// untouched.  Downloads happen before uploads, so a lane may appear in
    /// both — preempting it and installing another session in one step.
    ///
    /// Cost contract: swapping N lanes moves O(N * lane_kv_len()) elements
    /// on per-lane residency; a monolithic fallback may stage through one
    /// full-cache round-trip per *call* (never per lane).
    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>>;

    /// Cumulative transfer accounting for `swap_lanes` (tests/benches
    /// assert the O(lane) property on these counters).
    fn swap_traffic(&self) -> SwapTraffic;

    /// Elements in one lane's `[L, H, M, dh]` slab (sizing for swap buffers).
    fn lane_kv_len(&self) -> usize {
        let d = self.dims();
        d.layers * d.hkv * self.slots() * d.dh
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

pub struct PjrtBackend {
    client: xla::PjRtClient,
    decode_exe: xla::PjRtLoadedExecutable,
    prefill_exe: Option<xla::PjRtLoadedExecutable>,
    /// fused mixed-step graph; `None` on artifacts exported before the
    /// `mixed` kind — the engine then alternates prefill/decode ticks
    mixed_exe: Option<xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>, // params ++ gates, device-resident
    cache: DeviceKvCache,
    dims: ModelDims,
    b: usize,
    m: usize,
    c: usize,
}

impl PjrtBackend {
    /// Load artifacts for batch `b` and budget->slot count `m` (exact match
    /// against an exported variant chosen by the caller via `meta.pick`).
    pub fn load(meta: &ModelMeta, b: usize, m: usize, gate_variant: &str,
                gate_arch: &str, with_prefill: bool) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()?;
        let dec = meta
            .pick("decode", b, m, gate_arch)
            .with_context(|| format!("no decode artifact for b={b} m>={m}"))?;
        ensure!(dec.m == m, "caller must pass an exported slot count");
        let decode_exe = compile_hlo(&client, &meta.dir.join(&dec.file))?;
        let prefill_exe = if with_prefill {
            // the prefill graph must share the decode graph's cache layout:
            // both operate on the same resident buffers
            let pre = meta
                .artifacts
                .iter()
                .find(|a| a.kind == "prefill" && a.b == b && a.m == m
                          && a.gate_arch == gate_arch
                          && a.cache_layout == dec.cache_layout)
                .with_context(|| format!(
                    "no prefill artifact for b={b} m={m} layout={}",
                    dec.cache_layout))?;
            Some(compile_hlo(&client, &meta.dir.join(&pre.file))?)
        } else {
            None
        };
        // the fused mixed-step graph is optional (absent on legacy
        // exports); like prefill it must share the decode graph's layout
        let mixed_exe = match meta.artifacts.iter().find(|a| {
            a.kind == "mixed" && a.b == b && a.m == m
                && a.gate_arch == gate_arch
                && a.cache_layout == dec.cache_layout
        }) {
            Some(mx) if with_prefill => {
                Some(compile_hlo(&client, &meta.dir.join(&mx.file))?)
            }
            _ => None,
        };

        // upload weights once, in the flat order the graphs expect
        let weights = super::weights::read_weights(&meta.dir.join("weights.bin"))?;
        let gates = super::weights::read_weights(
            &meta.dir.join(format!("gates_{gate_variant}.bin")))?;
        let gate_order: Vec<String> = if gate_arch == "linear" {
            gates.keys().cloned().collect() // BTreeMap order == gN.{b1,w1}
        } else {
            meta.gate_order.iter().map(|t| t.name.clone()).collect()
        };
        let mut weight_bufs = Vec::new();
        for spec in &meta.param_order {
            let t = weights
                .get(&spec.name)
                .with_context(|| format!("weights.bin missing {}", spec.name))?;
            ensure!(t.shape == spec.shape, "shape mismatch for {}", spec.name);
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }
        for name in &gate_order {
            let t = gates
                .get(name)
                .with_context(|| format!("gates bin missing {name}"))?;
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }

        let dims = meta.dims;
        let shape = CacheShape { layers: dims.layers, batch: b, hkv: dims.hkv,
                                 slots: m, dh: dims.dh };
        let cache = DeviceKvCache::new_zeroed(&client, shape,
                                             dec.cache_layout == "per_lane")?;
        Ok(PjrtBackend {
            client,
            decode_exe,
            prefill_exe,
            mixed_exe,
            weight_bufs,
            cache,
            dims,
            b,
            m,
            c: meta.chunk,
        })
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn lbh(&self) -> (usize, usize, usize) {
        (self.dims.layers, self.b, self.dims.hkv)
    }
}

pub fn compile_hlo(client: &xla::PjRtClient,
                   path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn to_host(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
}

impl ModelBackend for PjrtBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut> {
        let (l, b, h) = self.lbh();
        let (m, dh) = (self.m, self.dims.dh);
        ensure!(ins.tokens.len() == b && ins.pos.len() == b, "bad lane count");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slot.len() == l * b * h, "bad write_slot len");

        let zero_f = vec![0.0f32; l * b * h];
        let zero_i = vec![0i32; l * b * h];
        let zero_k = vec![0.0f32; l * b * h * dh];
        let token_b = self.upload_i32(ins.tokens, &[b])?;
        let pos_b = self.upload_i32(ins.pos, &[b])?;
        let valid_b = self.upload_f32(ins.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(ins.write_slot, &[l, b, h])?;
        let if_b = self.upload_f32(ins.inject_flag.unwrap_or(&zero_f), &[l, b, h])?;
        let is_b = self.upload_i32(ins.inject_slot.unwrap_or(&zero_i), &[l, b, h])?;
        let ik_b = self.upload_f32(ins.inject_k.unwrap_or(&zero_k), &[l, b, h, dh])?;
        let iv_b = self.upload_f32(ins.inject_v.unwrap_or(&zero_k), &[l, b, h, dh])?;

        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&token_b, &pos_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b, &if_b, &is_b, &ik_b, &iv_b]);
        let mut outs = self.decode_exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 6 + ncache,
                "decode graph returned {} outputs, expected {}", outs.len(),
                6 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn, k_new, v_new
        // (perf: skip device->host transfers the policy will not consume)
        let iv = 1 + ncache; // index of the (unused) valid output
        let out = DecodeOut {
            logits: to_host(&outs[0])?,
            log_beta: to_host(&outs[iv + 1])?,
            attn: if ins.want_attn { to_host(&outs[iv + 2])? } else { Vec::new() },
            k_new: if ins.want_kv { to_host(&outs[iv + 3])? } else { Vec::new() },
            v_new: if ins.want_kv { to_host(&outs[iv + 4])? } else { Vec::new() },
        };
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        Ok(out)
    }

    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut> {
        let (l, b, h) = self.lbh();
        let (m, c) = (self.m, self.c);
        ensure!(ins.tokens.len() == b * c, "bad tokens len");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slots.len() == l * b * h * c, "bad write_slots len");

        let tok_b = self.upload_i32(ins.tokens, &[b, c])?;
        let pos_b = self.upload_i32(ins.pos, &[b, c])?;
        let mask_b = self.upload_f32(ins.in_mask, &[b, c])?;
        let valid_b = self.upload_f32(ins.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(ins.write_slots, &[l, b, h, c])?;

        let exe = self
            .prefill_exe
            .as_ref()
            .context("backend loaded without prefill graph")?;
        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&tok_b, &pos_b, &mask_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b]);
        let mut outs = exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 7 + ncache,
                "prefill graph returned {} outputs, expected {}", outs.len(),
                7 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn_slots,
        //        attn_chunk, k_chunk, v_chunk
        let iv = 1 + ncache;
        let out = PrefillOut {
            logits: to_host(&outs[0])?,
            log_beta: to_host(&outs[iv + 1])?,
            attn_slots: to_host(&outs[iv + 2])?,
            attn_chunk: to_host(&outs[iv + 3])?,
            k_chunk: to_host(&outs[iv + 4])?,
            v_chunk: to_host(&outs[iv + 5])?,
        };
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        Ok(out)
    }

    fn supports_mixed(&self) -> bool {
        self.mixed_exe.is_some()
    }

    fn step_mixed(&mut self, ins: &MixedIn) -> Result<MixedOut> {
        let (l, b, h) = self.lbh();
        let (m, c) = (self.m, self.c);
        ensure!(ins.tokens.len() == b * c, "bad tokens len");
        ensure!(ins.mode.len() == b, "bad mode len");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slots.len() == l * b * h * c, "bad write_slots len");

        let tok_b = self.upload_i32(ins.tokens, &[b, c])?;
        let pos_b = self.upload_i32(ins.pos, &[b, c])?;
        let mask_b = self.upload_f32(ins.in_mask, &[b, c])?;
        let mode_b = self.upload_f32(ins.mode, &[b])?;
        let valid_b = self.upload_f32(ins.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(ins.write_slots, &[l, b, h, c])?;

        let exe = self
            .mixed_exe
            .as_ref()
            .context("backend loaded without mixed-step graph")?;
        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&tok_b, &pos_b, &mask_b, &mode_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b]);
        let mut outs = exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 7 + ncache,
                "mixed graph returned {} outputs, expected {}", outs.len(),
                7 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn_slots,
        //        attn_chunk, k_chunk, v_chunk (attn_slots mode-fused)
        let iv = 1 + ncache;
        let out = MixedOut {
            logits: to_host(&outs[0])?,
            log_beta: to_host(&outs[iv + 1])?,
            attn_slots: to_host(&outs[iv + 2])?,
            attn_chunk: to_host(&outs[iv + 3])?,
            k_chunk: to_host(&outs[iv + 4])?,
            v_chunk: to_host(&outs[iv + 5])?,
        };
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        Ok(out)
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.cache.reset(&self.client)
    }

    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>> {
        self.cache.swap_lanes(&self.client, out, inn)
    }

    fn swap_traffic(&self) -> SwapTraffic {
        self.cache.traffic
    }
}

// ---------------------------------------------------------------------------
// Mock backend (tests)
// ---------------------------------------------------------------------------

/// Deterministic fake model: the next-token distribution peaks at
/// `(token + 1) % vocab` until `eos_after` tokens have been produced on a
/// lane, then at EOS (id 2).  Gate scores depend only on (layer, head,
/// token), and the fake K/V content only on (layer, head, position-in-lane,
/// token) — never on the lane index or batch size — so TRIM-KV evictions
/// and swapped lane slabs are reproducible across engine shapes in tests.
pub struct MockBackend {
    pub dims: ModelDims,
    pub b: usize,
    pub m: usize,
    pub c: usize,
    /// EOS trigger for tests.  Semantics differ slightly by path — an
    /// artifact of `decode` receiving no activity mask: `decode` bumps
    /// every lane's counter per call (idle lanes included), `step_mixed`
    /// bumps only mode=1 lanes.  Tests combining a finite `eos_after`
    /// with cross-scheduling equivalence would diverge for that reason;
    /// keep eos_after at the usize::MAX default there.
    pub eos_after: usize,
    pub decoded_per_lane: Vec<usize>,
    pub decode_calls: usize,
    pub prefill_calls: usize,
    pub mixed_calls: usize,
    /// decode tokens advanced through `step_mixed` (one per mode=1 lane
    /// per call) — exact accounting for the fused path
    pub mixed_decode_tokens: u64,
    /// prompt tokens advanced through `step_mixed` (sum of live `in_mask`
    /// positions on chunk-fill lanes)
    pub mixed_chunk_tokens: u64,
    /// per lane: total tokens (decode + chunk) fed through `step_mixed`
    pub mixed_tokens_per_lane: Vec<u64>,
    /// Host twin of the per-lane device K/V arenas — written exactly where
    /// the real graphs would scatter, so the batched session-swap path is
    /// testable end-to-end with exact transfer accounting.
    pub arena: HostLaneArena,
}

impl MockBackend {
    pub fn new(b: usize, m: usize) -> MockBackend {
        let dims = ModelDims { vocab: 512, d: 128, layers: 4, hq: 4, hkv: 2,
                               dh: 32, ffn: 256, gate_hidden: 48 };
        let lane_len = dims.layers * dims.hkv * m * dims.dh;
        MockBackend {
            dims,
            b,
            m,
            c: 16,
            eos_after: usize::MAX,
            decoded_per_lane: vec![0; b],
            decode_calls: 0,
            prefill_calls: 0,
            mixed_calls: 0,
            mixed_decode_tokens: 0,
            mixed_chunk_tokens: 0,
            mixed_tokens_per_lane: vec![0; b],
            arena: HostLaneArena::new(b, lane_len),
        }
    }

    pub fn with_eos_after(mut self, n: usize) -> Self {
        self.eos_after = n;
        self
    }

    /// Deterministic per-token gate score in (0, 1): higher for sym tokens,
    /// low for word (filler) tokens — crude mirror of the trained gates.
    pub fn mock_log_beta(l: usize, hh: usize, token: i32) -> f32 {
        let t = token as u32;
        let hash = t
            .wrapping_mul(2654435761)
            .wrapping_add((l as u32) << 8)
            .wrapping_add(hh as u32)
            % 1000;
        let base = if (32..288).contains(&t) { 0.999 } else { 0.95 };
        let beta = base - (hash as f32) / 40_000.0;
        beta.ln()
    }

    /// Fake K/V element for head-dim position `d` of `(layer, head, token)`
    /// (+ chunk offset `ci` on the prefill path).  Deliberately independent
    /// of lane index and batch size.
    fn mock_kv(li: usize, hh: usize, hkv: usize, ci: usize, c: usize,
               d: usize, dh: usize, token: i32) -> f32 {
        let j = (((li * hkv + hh) * c + ci) * dh) + d;
        ((j % 7) as f32) * 0.1 + token as f32 * 1e-3
    }
}

impl ModelBackend for MockBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut> {
        self.decode_calls += 1;
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v) = (self.m, self.dims.dh, self.dims.vocab);
        let mut logits = vec![0.0f32; b * v];
        for lane in 0..b {
            let tok = ins.tokens[lane];
            self.decoded_per_lane[lane] += 1;
            let next = if self.decoded_per_lane[lane] >= self.eos_after {
                2 // EOS
            } else {
                ((tok + 1) as usize) % v
            };
            logits[lane * v + next] = 10.0;
        }
        let mut log_beta = vec![0.0f32; l * b * h];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    log_beta[(li * b + lane) * h + hh] =
                        Self::mock_log_beta(li, hh, ins.tokens[lane]);
                }
            }
        }
        // uniform attention over live slots
        let mut attn = vec![0.0f32; l * b * h * m];
        for i in 0..l * b * h {
            let row = &ins.valid[i * m..(i + 1) * m];
            let live: f32 = row.iter().sum();
            if live > 0.0 {
                for s in 0..m {
                    attn[i * m + s] = row[s] / live;
                }
            }
        }
        let mut k_new = vec![0.0f32; l * b * h * dh];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh;
                    for d in 0..dh {
                        k_new[base * dh + d] = Self::mock_kv(
                            li, hh, h, 0, 1, d, dh, ins.tokens[lane]);
                    }
                }
            }
        }
        let v_new = k_new.clone();
        // scatter into the per-lane K/V arenas exactly as the decode graph
        // would: pending injects first, then the step's write_slot
        for lane in 0..b {
            let slab = self.arena.lane_mut(lane);
            for li in 0..l {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh; // flat [L,B,H] index
                    let row = (li * h + hh) * m;         // in-lane [L,H,M] row
                    if let (Some(flag), Some(islot)) =
                        (ins.inject_flag, ins.inject_slot)
                    {
                        if flag[base] > 0.0 {
                            let s = islot[base] as usize;
                            let dst = (row + s) * dh;
                            if let (Some(ik), Some(ivv)) =
                                (ins.inject_k, ins.inject_v)
                            {
                                slab.k[dst..dst + dh].copy_from_slice(
                                    &ik[base * dh..(base + 1) * dh]);
                                slab.v[dst..dst + dh].copy_from_slice(
                                    &ivv[base * dh..(base + 1) * dh]);
                            }
                        }
                    }
                    let s = ins.write_slot[base] as usize;
                    let dst = (row + s) * dh;
                    slab.k[dst..dst + dh]
                        .copy_from_slice(&k_new[base * dh..(base + 1) * dh]);
                    slab.v[dst..dst + dh]
                        .copy_from_slice(&v_new[base * dh..(base + 1) * dh]);
                }
            }
        }
        Ok(DecodeOut { logits, log_beta, attn, k_new, v_new })
    }

    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut> {
        self.prefill_calls += 1;
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v, c) = (self.m, self.dims.dh, self.dims.vocab, self.c);
        let mut logits = vec![0.0f32; b * c * v];
        for lane in 0..b {
            for ci in 0..c {
                let tok = ins.tokens[lane * c + ci];
                logits[(lane * c + ci) * v + ((tok + 1) as usize) % v] = 10.0;
            }
        }
        let mut log_beta = vec![0.0f32; l * b * h * c];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    for ci in 0..c {
                        log_beta[((li * b + lane) * h + hh) * c + ci] =
                            Self::mock_log_beta(li, hh, ins.tokens[lane * c + ci]);
                    }
                }
            }
        }
        let attn_slots = vec![1.0 / m as f32; l * b * h * m];
        let attn_chunk = vec![1.0 / c as f32; l * b * h * c];
        // token-dependent chunk K/V (lane-invariant, like decode) so swapped
        // slabs carry distinguishable content in tests
        let mut k_chunk = vec![0.0f32; l * b * h * c * dh];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    for ci in 0..c {
                        let cb = ((li * b + lane) * h + hh) * c + ci;
                        for d in 0..dh {
                            k_chunk[cb * dh + d] = Self::mock_kv(
                                li, hh, h, ci, c, d, dh,
                                ins.tokens[lane * c + ci]);
                        }
                    }
                }
            }
        }
        let v_chunk = k_chunk.clone();
        // scatter the chunk into the per-lane arenas at the planned slots
        for lane in 0..b {
            let slab = self.arena.lane_mut(lane);
            for li in 0..l {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh;
                    let row = (li * h + hh) * m;
                    for ci in 0..c {
                        if ins.in_mask[lane * c + ci] <= 0.0 {
                            continue;
                        }
                        let s = ins.write_slots[base * c + ci] as usize;
                        let dst = (row + s) * dh;
                        let src = (base * c + ci) * dh;
                        slab.k[dst..dst + dh]
                            .copy_from_slice(&k_chunk[src..src + dh]);
                        slab.v[dst..dst + dh]
                            .copy_from_slice(&v_chunk[src..src + dh]);
                    }
                }
            }
        }
        Ok(PrefillOut { logits, log_beta, attn_slots, attn_chunk, k_chunk, v_chunk })
    }

    fn supports_mixed(&self) -> bool {
        true
    }

    /// Fused mixed tick: per lane, exactly the numbers `decode` (mode=1;
    /// chunk column 0) or `prefill` (mode=0) would produce, in one call —
    /// the engine's mixed scheduling is therefore token-equivalent to the
    /// alternating paths whenever chunk boundaries align.
    fn step_mixed(&mut self, ins: &MixedIn) -> Result<MixedOut> {
        self.mixed_calls += 1;
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v, c) = (self.m, self.dims.dh, self.dims.vocab, self.c);
        ensure!(ins.tokens.len() == b * c, "bad tokens len");
        ensure!(ins.mode.len() == b, "bad mode len");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slots.len() == l * b * h * c, "bad write_slots len");

        let mut logits = vec![0.0f32; b * c * v];
        let mut log_beta = vec![0.0f32; l * b * h * c];
        let mut attn_slots = vec![0.0f32; l * b * h * m];
        let attn_chunk = vec![1.0 / c as f32; l * b * h * c];
        let mut k_chunk = vec![0.0f32; l * b * h * c * dh];
        for lane in 0..b {
            let decode_lane = ins.mode[lane] > 0.5;
            if decode_lane {
                // column 0 is the lane's decode token; same successor/EOS
                // rule and same per-lane generation counter as `decode`
                let tok = ins.tokens[lane * c];
                self.decoded_per_lane[lane] += 1;
                self.mixed_decode_tokens += 1;
                self.mixed_tokens_per_lane[lane] += 1;
                let next = if self.decoded_per_lane[lane] >= self.eos_after {
                    2 // EOS
                } else {
                    ((tok + 1) as usize) % v
                };
                logits[lane * c * v + next] = 10.0;
            } else {
                for ci in 0..c {
                    if ins.in_mask[lane * c + ci] <= 0.0 {
                        continue;
                    }
                    let tok = ins.tokens[lane * c + ci];
                    self.mixed_chunk_tokens += 1;
                    self.mixed_tokens_per_lane[lane] += 1;
                    logits[(lane * c + ci) * v + ((tok + 1) as usize) % v] = 10.0;
                }
            }
            for li in 0..l {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh;
                    // attention: decode lanes mirror `decode` (uniform over
                    // the lane's live slots), chunk lanes mirror `prefill`
                    if decode_lane {
                        let row = &ins.valid[base * m..(base + 1) * m];
                        let live: f32 = row.iter().sum();
                        if live > 0.0 {
                            for s in 0..m {
                                attn_slots[base * m + s] = row[s] / live;
                            }
                        }
                    } else {
                        for s in 0..m {
                            attn_slots[base * m + s] = 1.0 / m as f32;
                        }
                    }
                    for ci in 0..c {
                        if ins.in_mask[lane * c + ci] <= 0.0 {
                            continue;
                        }
                        let tok = ins.tokens[lane * c + ci];
                        let cb = base * c + ci;
                        log_beta[cb] = Self::mock_log_beta(li, hh, tok);
                        for d in 0..dh {
                            // decode lanes use the 1-token-chunk K/V law so
                            // the slab matches `decode`'s k_new exactly
                            k_chunk[cb * dh + d] = if decode_lane {
                                Self::mock_kv(li, hh, h, 0, 1, d, dh, tok)
                            } else {
                                Self::mock_kv(li, hh, h, ci, c, d, dh, tok)
                            };
                        }
                    }
                }
            }
        }
        let v_chunk = k_chunk.clone();
        // scatter live positions into the per-lane arenas, like the graphs
        for lane in 0..b {
            let slab = self.arena.lane_mut(lane);
            for li in 0..l {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh;
                    let row = (li * h + hh) * m;
                    for ci in 0..c {
                        if ins.in_mask[lane * c + ci] <= 0.0 {
                            continue;
                        }
                        let s = ins.write_slots[base * c + ci] as usize;
                        ensure!(s < m, "write slot {s} out of range");
                        let dst = (row + s) * dh;
                        let src = (base * c + ci) * dh;
                        slab.k[dst..dst + dh]
                            .copy_from_slice(&k_chunk[src..src + dh]);
                        slab.v[dst..dst + dh]
                            .copy_from_slice(&v_chunk[src..src + dh]);
                    }
                }
            }
        }
        Ok(MixedOut { logits, log_beta, attn_slots, attn_chunk, k_chunk, v_chunk })
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.decoded_per_lane = vec![0; self.b];
        self.arena.reset();
        Ok(())
    }

    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>> {
        self.arena.swap_lanes(out, inn)
    }

    fn swap_traffic(&self) -> SwapTraffic {
        self.arena.traffic
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_decode_emits_successor_then_eos() {
        let mut mb = MockBackend::new(2, 8).with_eos_after(3);
        let valid = vec![0.0f32; 4 * 2 * 2 * 8];
        let ws = vec![0i32; 4 * 2 * 2];
        for step in 0..4 {
            let out = mb
                .decode(&DecodeIn {
                    tokens: &[10, 20],
                    pos: &[step, step],
                    valid: &valid,
                    write_slot: &ws,
                    inject_flag: None,
                    inject_slot: None,
                    inject_k: None,
                    inject_v: None,
                    want_attn: true,
                    want_kv: true,
                })
                .unwrap();
            let argmax = |lane: usize| {
                (0..512)
                    .max_by(|&a, &b| {
                        out.logits[lane * 512 + a]
                            .partial_cmp(&out.logits[lane * 512 + b])
                            .unwrap()
                    })
                    .unwrap()
            };
            if step < 2 {
                assert_eq!(argmax(0), 11);
                assert_eq!(argmax(1), 21);
            } else {
                assert_eq!(argmax(0), 2);
            }
        }
    }

    #[test]
    fn mock_log_beta_prefers_syms() {
        let sym = MockBackend::mock_log_beta(0, 0, 40);
        let word = MockBackend::mock_log_beta(0, 0, 300);
        assert!(sym > word);
        assert!(sym < 0.0);
    }

    fn decode_write(mb: &mut MockBackend, tokens: &[i32], slots: &[usize]) {
        let (l, b, h, m) = (mb.dims.layers, mb.b, mb.dims.hkv, mb.m);
        let valid = vec![0.0f32; l * b * h * m];
        let pos = vec![0i32; b];
        let mut ws = vec![0i32; l * b * h];
        for li in 0..l {
            for (lane, &slot) in slots.iter().enumerate() {
                for hh in 0..h {
                    ws[(li * b + lane) * h + hh] = slot as i32;
                }
            }
        }
        mb.decode(&DecodeIn {
            tokens,
            pos: &pos,
            valid: &valid,
            write_slot: &ws,
            inject_flag: None,
            inject_slot: None,
            inject_k: None,
            inject_v: None,
            want_attn: false,
            want_kv: true,
        })
        .unwrap();
    }

    #[test]
    fn mock_batched_lane_swap_roundtrip() {
        let mut mb = MockBackend::new(2, 8);
        // decode writes lane 0 into slot 1, lane 1 into slot 3
        decode_write(&mut mb, &[10, 77], &[1, 3]);
        let down = mb.swap_lanes(&[0, 1], &[]).unwrap();
        assert_eq!(down[0].k.len(), mb.lane_kv_len());
        assert_ne!(down[0].k, down[1].k,
                   "lanes with different tokens share a slab");
        // mixed call: lane 1 is downloaded *and* overwritten by lane 0's
        // slab — the preempt-and-restore-in-one-step case
        let prev = mb.swap_lanes(&[1], &[(1, &down[0])]).unwrap();
        assert_eq!(prev[0], down[1], "mixed swap must download before upload");
        let now = mb.swap_lanes(&[0, 1], &[]).unwrap();
        assert_eq!(now[1], down[0]);
        assert_eq!(now[0], down[0], "lane 0 clobbered by the lane-1 upload");
        // size/range validation
        let short = LaneKv { k: down[0].k[1..].to_vec(), v: down[0].v.clone() };
        assert!(mb.swap_lanes(&[], &[(1, &short)]).is_err());
        assert!(mb.swap_lanes(&[9], &[]).is_err());
    }

    #[test]
    fn swap_traffic_is_o_lane_not_o_batch() {
        // swapping 1 lane moves exactly 2 * lane_kv_len() elements no
        // matter how many lanes the batch has (the acceptance criterion)
        let mut per_batch = Vec::new();
        for b in [2usize, 4, 8] {
            let mut mb = MockBackend::new(b, 8);
            let down = mb.swap_lanes(&[0], &[]).unwrap();
            assert_eq!(down[0].k.len(), mb.lane_kv_len());
            let t = mb.swap_traffic();
            assert_eq!(t.elems_out as usize, 2 * mb.lane_kv_len());
            assert_eq!(t.lanes_out, 1);
            per_batch.push(t.elems_out);
        }
        assert!(per_batch.windows(2).all(|w| w[0] == w[1]),
                "swap traffic grew with batch size: {per_batch:?}");
    }

    #[test]
    fn mock_kv_content_is_lane_and_batch_invariant() {
        // the same token written to the same slot must produce an identical
        // slab through any lane of any batch size (cross-shape swap tests
        // rely on this)
        let mut a = MockBackend::new(1, 8);
        decode_write(&mut a, &[42], &[2]);
        let mut b = MockBackend::new(3, 8);
        decode_write(&mut b, &[7, 42, 9], &[2, 2, 2]);
        let la = a.swap_lanes(&[0], &[]).unwrap();
        let lb = b.swap_lanes(&[1], &[]).unwrap();
        assert_eq!(la[0], lb[0],
                   "lane content depends on lane index or batch size");
    }

    #[test]
    fn mock_mixed_step_matches_decode_and_prefill_lanes() {
        // lane 0 decodes token 10 in chunk column 0; lane 1 prefills 3
        // tokens — each side must reproduce the dedicated graph exactly
        let (l, h, m) = (4usize, 2usize, 8usize);
        let mut mb = MockBackend::new(2, m);
        let c = mb.c;
        let (dh, v) = (mb.dims.dh, mb.dims.vocab);
        let mut valid = vec![0.0f32; l * 2 * h * m];
        for li in 0..l {
            for hh in 0..h {
                let base = (li * 2) * h + hh; // lane 0 rows
                valid[base * m] = 1.0;
                valid[base * m + 1] = 1.0;
            }
        }
        let mut tokens = vec![0i32; 2 * c];
        tokens[0] = 10;
        for ci in 0..3 {
            tokens[c + ci] = 40 + ci as i32;
        }
        let mut in_mask = vec![0.0f32; 2 * c];
        in_mask[0] = 1.0;
        in_mask[c..c + 3].fill(1.0);
        let pos = vec![0i32; 2 * c];
        let mut ws = vec![(m - 1) as i32; l * 2 * h * c];
        for li in 0..l {
            for hh in 0..h {
                ws[((li * 2) * h + hh) * c] = 2; // lane 0 writes slot 2
                for ci in 0..3 {
                    ws[((li * 2 + 1) * h + hh) * c + ci] = ci as i32;
                }
            }
        }
        let out = mb
            .step_mixed(&MixedIn {
                tokens: &tokens,
                pos: &pos,
                in_mask: &in_mask,
                mode: &[1.0, 0.0],
                valid: &valid,
                write_slots: &ws,
            })
            .unwrap();
        assert_eq!(mb.mixed_calls, 1);
        assert_eq!(mb.mixed_decode_tokens, 1);
        assert_eq!(mb.mixed_chunk_tokens, 3);
        assert_eq!(mb.mixed_tokens_per_lane, vec![1, 3]);

        // decode reference for lane 0
        let mut dref = MockBackend::new(2, m);
        let mut dws = vec![0i32; l * 2 * h];
        for li in 0..l {
            for hh in 0..h {
                dws[(li * 2) * h + hh] = 2;
            }
        }
        let dout = dref
            .decode(&DecodeIn {
                tokens: &[10, 0],
                pos: &[0, 0],
                valid: &valid,
                write_slot: &dws,
                inject_flag: None,
                inject_slot: None,
                inject_k: None,
                inject_v: None,
                want_attn: true,
                want_kv: true,
            })
            .unwrap();
        assert_eq!(out.logits[..v], dout.logits[..v], "decode-lane logits");
        for li in 0..l {
            for hh in 0..h {
                let base = (li * 2) * h + hh;
                assert_eq!(out.log_beta[base * c], dout.log_beta[base]);
                assert_eq!(out.attn_slots[base * m..(base + 1) * m],
                           dout.attn[base * m..(base + 1) * m]);
                assert_eq!(out.k_chunk[base * c * dh..base * c * dh + dh],
                           dout.k_new[base * dh..(base + 1) * dh]);
            }
        }

        // prefill reference for lane 1 (same fused buffers)
        let mut pref = MockBackend::new(2, m);
        let pout = pref
            .prefill(&PrefillIn {
                tokens: &tokens,
                pos: &pos,
                in_mask: &in_mask,
                valid: &valid,
                write_slots: &ws,
            })
            .unwrap();
        for ci in 0..3 {
            let col = (c + ci) * v;
            assert_eq!(out.logits[col..col + v], pout.logits[col..col + v]);
        }
        for li in 0..l {
            for hh in 0..h {
                let base = (li * 2 + 1) * h + hh;
                for ci in 0..3 {
                    let cb = base * c + ci;
                    assert_eq!(out.log_beta[cb], pout.log_beta[cb]);
                    assert_eq!(out.attn_chunk[cb], pout.attn_chunk[cb]);
                    assert_eq!(out.k_chunk[cb * dh..(cb + 1) * dh],
                               pout.k_chunk[cb * dh..(cb + 1) * dh]);
                }
                assert_eq!(out.attn_slots[base * m..(base + 1) * m],
                           pout.attn_slots[base * m..(base + 1) * m]);
            }
        }
        // lane slabs: the fused write equals the dedicated graphs' writes
        let mixed_slabs = mb.swap_lanes(&[0, 1], &[]).unwrap();
        let d_slab = dref.swap_lanes(&[0], &[]).unwrap();
        let p_slab = pref.swap_lanes(&[1], &[]).unwrap();
        assert_eq!(mixed_slabs[0], d_slab[0], "decode-lane slab");
        assert_eq!(mixed_slabs[1], p_slab[0], "chunk-lane slab");
    }

    #[test]
    fn mock_attention_is_uniform_over_live() {
        let mut mb = MockBackend::new(1, 4);
        let mut valid = vec![0.0f32; 4 * 1 * 2 * 4];
        valid[0] = 1.0;
        valid[1] = 1.0;
        let out = mb
            .decode(&DecodeIn {
                tokens: &[1],
                pos: &[0],
                valid: &valid,
                write_slot: &[0; 8],
                inject_flag: None,
                inject_slot: None,
                inject_k: None,
                inject_v: None,
                want_attn: true,
                want_kv: true,
            })
            .unwrap();
        assert_eq!(out.attn[0], 0.5);
        assert_eq!(out.attn[1], 0.5);
        assert_eq!(out.attn[2], 0.0);
    }
}
