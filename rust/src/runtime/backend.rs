//! ModelBackend: the engine's interface to the AOT-compiled model graphs.
//!
//! `PjrtBackend` executes the HLO artifacts on the PJRT CPU client with the
//! KV caches held device-resident (only logits / gate scores / attention
//! stats cross the device boundary each step — the paper's O(M) decode).
//! Cache residency is owned by [`DeviceKvCache`]: per-lane buffer pairs for
//! `cache_layout = "per_lane"` artifacts (O(lane) session swap) or a single
//! monolithic pair with a staged host shadow for legacy artifacts.
//! `MockBackend` is a deterministic stand-in used by unit/property tests so
//! the scheduler, cache manager and policies are testable without artifacts.

use anyhow::{ensure, Context, Result};

use super::devcache::{CacheShape, DeviceKvCache, HostLaneArena, LaneKv,
                      SwapTraffic};
use crate::model_meta::{ModelDims, ModelMeta};

/// One decode step over all B lanes.  Layouts are row-major flat slices:
/// valid `[L,B,H,M]`, write_slot `[L,B,H]`, inject_k/v `[L,B,H,dh]`.
pub struct DecodeIn<'a> {
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub valid: &'a [f32],
    pub write_slot: &'a [i32],
    pub inject_flag: Option<&'a [f32]>,
    pub inject_slot: Option<&'a [i32]>,
    pub inject_k: Option<&'a [f32]>,
    pub inject_v: Option<&'a [f32]>,
    /// download the attention stats (H2O/SnapKV/R-KV/retrieval only)
    pub want_attn: bool,
    /// download k_new/v_new (key-similarity + retrieval policies only)
    pub want_kv: bool,
}

#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>,   // [B, vocab]
    pub log_beta: Vec<f32>, // [L, B, H]
    pub attn: Vec<f32>,     // [L, B, H, M]
    pub k_new: Vec<f32>,    // [L, B, H, dh]
    pub v_new: Vec<f32>,    // [L, B, H, dh]
}

/// One prefill chunk of C tokens per lane.
pub struct PrefillIn<'a> {
    pub tokens: &'a [i32],      // [B, C]
    pub pos: &'a [i32],         // [B, C]
    pub in_mask: &'a [f32],     // [B, C]
    pub valid: &'a [f32],       // [L, B, H, M]
    pub write_slots: &'a [i32], // [L, B, H, C]
}

#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub logits: Vec<f32>,     // [B, C, vocab]
    pub log_beta: Vec<f32>,   // [L, B, H, C]
    pub attn_slots: Vec<f32>, // [L, B, H, M]
    pub attn_chunk: Vec<f32>, // [L, B, H, C]
    pub k_chunk: Vec<f32>,    // [L, B, H, C, dh]
    pub v_chunk: Vec<f32>,    // [L, B, H, C, dh]
}

pub trait ModelBackend: Send {
    fn dims(&self) -> ModelDims;
    fn batch(&self) -> usize;
    fn slots(&self) -> usize;
    fn chunk(&self) -> usize;
    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut>;
    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut>;
    /// Zero the device-resident KV caches (new evaluation run).
    fn reset_cache(&mut self) -> Result<()>;

    /// Batched lane-level session swap: download the current `[L, H, M, dh]`
    /// K/V slabs of every lane in `out` (returned in `out` order), then
    /// upload the `inn` slabs into their lanes, leaving every other lane
    /// untouched.  Downloads happen before uploads, so a lane may appear in
    /// both — preempting it and installing another session in one step.
    ///
    /// Cost contract: swapping N lanes moves O(N * lane_kv_len()) elements
    /// on per-lane residency; a monolithic fallback may stage through one
    /// full-cache round-trip per *call* (never per lane).
    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>>;

    /// Cumulative transfer accounting for `swap_lanes` (tests/benches
    /// assert the O(lane) property on these counters).
    fn swap_traffic(&self) -> SwapTraffic;

    /// Elements in one lane's `[L, H, M, dh]` slab (sizing for swap buffers).
    fn lane_kv_len(&self) -> usize {
        let d = self.dims();
        d.layers * d.hkv * self.slots() * d.dh
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

pub struct PjrtBackend {
    client: xla::PjRtClient,
    decode_exe: xla::PjRtLoadedExecutable,
    prefill_exe: Option<xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>, // params ++ gates, device-resident
    cache: DeviceKvCache,
    dims: ModelDims,
    b: usize,
    m: usize,
    c: usize,
}

impl PjrtBackend {
    /// Load artifacts for batch `b` and budget->slot count `m` (exact match
    /// against an exported variant chosen by the caller via `meta.pick`).
    pub fn load(meta: &ModelMeta, b: usize, m: usize, gate_variant: &str,
                gate_arch: &str, with_prefill: bool) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()?;
        let dec = meta
            .pick("decode", b, m, gate_arch)
            .with_context(|| format!("no decode artifact for b={b} m>={m}"))?;
        ensure!(dec.m == m, "caller must pass an exported slot count");
        let decode_exe = compile_hlo(&client, &meta.dir.join(&dec.file))?;
        let prefill_exe = if with_prefill {
            // the prefill graph must share the decode graph's cache layout:
            // both operate on the same resident buffers
            let pre = meta
                .artifacts
                .iter()
                .find(|a| a.kind == "prefill" && a.b == b && a.m == m
                          && a.gate_arch == gate_arch
                          && a.cache_layout == dec.cache_layout)
                .with_context(|| format!(
                    "no prefill artifact for b={b} m={m} layout={}",
                    dec.cache_layout))?;
            Some(compile_hlo(&client, &meta.dir.join(&pre.file))?)
        } else {
            None
        };

        // upload weights once, in the flat order the graphs expect
        let weights = super::weights::read_weights(&meta.dir.join("weights.bin"))?;
        let gates = super::weights::read_weights(
            &meta.dir.join(format!("gates_{gate_variant}.bin")))?;
        let gate_order: Vec<String> = if gate_arch == "linear" {
            gates.keys().cloned().collect() // BTreeMap order == gN.{b1,w1}
        } else {
            meta.gate_order.iter().map(|t| t.name.clone()).collect()
        };
        let mut weight_bufs = Vec::new();
        for spec in &meta.param_order {
            let t = weights
                .get(&spec.name)
                .with_context(|| format!("weights.bin missing {}", spec.name))?;
            ensure!(t.shape == spec.shape, "shape mismatch for {}", spec.name);
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }
        for name in &gate_order {
            let t = gates
                .get(name)
                .with_context(|| format!("gates bin missing {name}"))?;
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }

        let dims = meta.dims;
        let shape = CacheShape { layers: dims.layers, batch: b, hkv: dims.hkv,
                                 slots: m, dh: dims.dh };
        let cache = DeviceKvCache::new_zeroed(&client, shape,
                                             dec.cache_layout == "per_lane")?;
        Ok(PjrtBackend {
            client,
            decode_exe,
            prefill_exe,
            weight_bufs,
            cache,
            dims,
            b,
            m,
            c: meta.chunk,
        })
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn lbh(&self) -> (usize, usize, usize) {
        (self.dims.layers, self.b, self.dims.hkv)
    }
}

pub fn compile_hlo(client: &xla::PjRtClient,
                   path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn to_host(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
}

impl ModelBackend for PjrtBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut> {
        let (l, b, h) = self.lbh();
        let (m, dh) = (self.m, self.dims.dh);
        ensure!(ins.tokens.len() == b && ins.pos.len() == b, "bad lane count");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slot.len() == l * b * h, "bad write_slot len");

        let zero_f = vec![0.0f32; l * b * h];
        let zero_i = vec![0i32; l * b * h];
        let zero_k = vec![0.0f32; l * b * h * dh];
        let token_b = self.upload_i32(ins.tokens, &[b])?;
        let pos_b = self.upload_i32(ins.pos, &[b])?;
        let valid_b = self.upload_f32(ins.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(ins.write_slot, &[l, b, h])?;
        let if_b = self.upload_f32(ins.inject_flag.unwrap_or(&zero_f), &[l, b, h])?;
        let is_b = self.upload_i32(ins.inject_slot.unwrap_or(&zero_i), &[l, b, h])?;
        let ik_b = self.upload_f32(ins.inject_k.unwrap_or(&zero_k), &[l, b, h, dh])?;
        let iv_b = self.upload_f32(ins.inject_v.unwrap_or(&zero_k), &[l, b, h, dh])?;

        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&token_b, &pos_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b, &if_b, &is_b, &ik_b, &iv_b]);
        let mut outs = self.decode_exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 6 + ncache,
                "decode graph returned {} outputs, expected {}", outs.len(),
                6 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn, k_new, v_new
        // (perf: skip device->host transfers the policy will not consume)
        let iv = 1 + ncache; // index of the (unused) valid output
        let out = DecodeOut {
            logits: to_host(&outs[0])?,
            log_beta: to_host(&outs[iv + 1])?,
            attn: if ins.want_attn { to_host(&outs[iv + 2])? } else { Vec::new() },
            k_new: if ins.want_kv { to_host(&outs[iv + 3])? } else { Vec::new() },
            v_new: if ins.want_kv { to_host(&outs[iv + 4])? } else { Vec::new() },
        };
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        Ok(out)
    }

    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut> {
        let (l, b, h) = self.lbh();
        let (m, c) = (self.m, self.c);
        ensure!(ins.tokens.len() == b * c, "bad tokens len");
        ensure!(ins.valid.len() == l * b * h * m, "bad valid len");
        ensure!(ins.write_slots.len() == l * b * h * c, "bad write_slots len");

        let tok_b = self.upload_i32(ins.tokens, &[b, c])?;
        let pos_b = self.upload_i32(ins.pos, &[b, c])?;
        let mask_b = self.upload_f32(ins.in_mask, &[b, c])?;
        let valid_b = self.upload_f32(ins.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(ins.write_slots, &[l, b, h, c])?;

        let exe = self
            .prefill_exe
            .as_ref()
            .context("backend loaded without prefill graph")?;
        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&tok_b, &pos_b, &mask_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b]);
        let mut outs = exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 7 + ncache,
                "prefill graph returned {} outputs, expected {}", outs.len(),
                7 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn_slots,
        //        attn_chunk, k_chunk, v_chunk
        let iv = 1 + ncache;
        let out = PrefillOut {
            logits: to_host(&outs[0])?,
            log_beta: to_host(&outs[iv + 1])?,
            attn_slots: to_host(&outs[iv + 2])?,
            attn_chunk: to_host(&outs[iv + 3])?,
            k_chunk: to_host(&outs[iv + 4])?,
            v_chunk: to_host(&outs[iv + 5])?,
        };
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        Ok(out)
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.cache.reset(&self.client)
    }

    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>> {
        self.cache.swap_lanes(&self.client, out, inn)
    }

    fn swap_traffic(&self) -> SwapTraffic {
        self.cache.traffic
    }
}

// ---------------------------------------------------------------------------
// Mock backend (tests)
// ---------------------------------------------------------------------------

/// Deterministic fake model: the next-token distribution peaks at
/// `(token + 1) % vocab` until `eos_after` tokens have been produced on a
/// lane, then at EOS (id 2).  Gate scores depend only on (layer, head,
/// token), and the fake K/V content only on (layer, head, position-in-lane,
/// token) — never on the lane index or batch size — so TRIM-KV evictions
/// and swapped lane slabs are reproducible across engine shapes in tests.
pub struct MockBackend {
    pub dims: ModelDims,
    pub b: usize,
    pub m: usize,
    pub c: usize,
    pub eos_after: usize,
    pub decoded_per_lane: Vec<usize>,
    pub decode_calls: usize,
    pub prefill_calls: usize,
    /// Host twin of the per-lane device K/V arenas — written exactly where
    /// the real graphs would scatter, so the batched session-swap path is
    /// testable end-to-end with exact transfer accounting.
    pub arena: HostLaneArena,
}

impl MockBackend {
    pub fn new(b: usize, m: usize) -> MockBackend {
        let dims = ModelDims { vocab: 512, d: 128, layers: 4, hq: 4, hkv: 2,
                               dh: 32, ffn: 256, gate_hidden: 48 };
        let lane_len = dims.layers * dims.hkv * m * dims.dh;
        MockBackend {
            dims,
            b,
            m,
            c: 16,
            eos_after: usize::MAX,
            decoded_per_lane: vec![0; b],
            decode_calls: 0,
            prefill_calls: 0,
            arena: HostLaneArena::new(b, lane_len),
        }
    }

    pub fn with_eos_after(mut self, n: usize) -> Self {
        self.eos_after = n;
        self
    }

    /// Deterministic per-token gate score in (0, 1): higher for sym tokens,
    /// low for word (filler) tokens — crude mirror of the trained gates.
    pub fn mock_log_beta(l: usize, hh: usize, token: i32) -> f32 {
        let t = token as u32;
        let hash = t
            .wrapping_mul(2654435761)
            .wrapping_add((l as u32) << 8)
            .wrapping_add(hh as u32)
            % 1000;
        let base = if (32..288).contains(&t) { 0.999 } else { 0.95 };
        let beta = base - (hash as f32) / 40_000.0;
        beta.ln()
    }

    /// Fake K/V element for head-dim position `d` of `(layer, head, token)`
    /// (+ chunk offset `ci` on the prefill path).  Deliberately independent
    /// of lane index and batch size.
    fn mock_kv(li: usize, hh: usize, hkv: usize, ci: usize, c: usize,
               d: usize, dh: usize, token: i32) -> f32 {
        let j = (((li * hkv + hh) * c + ci) * dh) + d;
        ((j % 7) as f32) * 0.1 + token as f32 * 1e-3
    }
}

impl ModelBackend for MockBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    fn decode(&mut self, ins: &DecodeIn) -> Result<DecodeOut> {
        self.decode_calls += 1;
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v) = (self.m, self.dims.dh, self.dims.vocab);
        let mut logits = vec![0.0f32; b * v];
        for lane in 0..b {
            let tok = ins.tokens[lane];
            self.decoded_per_lane[lane] += 1;
            let next = if self.decoded_per_lane[lane] >= self.eos_after {
                2 // EOS
            } else {
                ((tok + 1) as usize) % v
            };
            logits[lane * v + next] = 10.0;
        }
        let mut log_beta = vec![0.0f32; l * b * h];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    log_beta[(li * b + lane) * h + hh] =
                        Self::mock_log_beta(li, hh, ins.tokens[lane]);
                }
            }
        }
        // uniform attention over live slots
        let mut attn = vec![0.0f32; l * b * h * m];
        for i in 0..l * b * h {
            let row = &ins.valid[i * m..(i + 1) * m];
            let live: f32 = row.iter().sum();
            if live > 0.0 {
                for s in 0..m {
                    attn[i * m + s] = row[s] / live;
                }
            }
        }
        let mut k_new = vec![0.0f32; l * b * h * dh];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh;
                    for d in 0..dh {
                        k_new[base * dh + d] = Self::mock_kv(
                            li, hh, h, 0, 1, d, dh, ins.tokens[lane]);
                    }
                }
            }
        }
        let v_new = k_new.clone();
        // scatter into the per-lane K/V arenas exactly as the decode graph
        // would: pending injects first, then the step's write_slot
        for lane in 0..b {
            let slab = self.arena.lane_mut(lane);
            for li in 0..l {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh; // flat [L,B,H] index
                    let row = (li * h + hh) * m;         // in-lane [L,H,M] row
                    if let (Some(flag), Some(islot)) =
                        (ins.inject_flag, ins.inject_slot)
                    {
                        if flag[base] > 0.0 {
                            let s = islot[base] as usize;
                            let dst = (row + s) * dh;
                            if let (Some(ik), Some(ivv)) =
                                (ins.inject_k, ins.inject_v)
                            {
                                slab.k[dst..dst + dh].copy_from_slice(
                                    &ik[base * dh..(base + 1) * dh]);
                                slab.v[dst..dst + dh].copy_from_slice(
                                    &ivv[base * dh..(base + 1) * dh]);
                            }
                        }
                    }
                    let s = ins.write_slot[base] as usize;
                    let dst = (row + s) * dh;
                    slab.k[dst..dst + dh]
                        .copy_from_slice(&k_new[base * dh..(base + 1) * dh]);
                    slab.v[dst..dst + dh]
                        .copy_from_slice(&v_new[base * dh..(base + 1) * dh]);
                }
            }
        }
        Ok(DecodeOut { logits, log_beta, attn, k_new, v_new })
    }

    fn prefill(&mut self, ins: &PrefillIn) -> Result<PrefillOut> {
        self.prefill_calls += 1;
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v, c) = (self.m, self.dims.dh, self.dims.vocab, self.c);
        let mut logits = vec![0.0f32; b * c * v];
        for lane in 0..b {
            for ci in 0..c {
                let tok = ins.tokens[lane * c + ci];
                logits[(lane * c + ci) * v + ((tok + 1) as usize) % v] = 10.0;
            }
        }
        let mut log_beta = vec![0.0f32; l * b * h * c];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    for ci in 0..c {
                        log_beta[((li * b + lane) * h + hh) * c + ci] =
                            Self::mock_log_beta(li, hh, ins.tokens[lane * c + ci]);
                    }
                }
            }
        }
        let attn_slots = vec![1.0 / m as f32; l * b * h * m];
        let attn_chunk = vec![1.0 / c as f32; l * b * h * c];
        // token-dependent chunk K/V (lane-invariant, like decode) so swapped
        // slabs carry distinguishable content in tests
        let mut k_chunk = vec![0.0f32; l * b * h * c * dh];
        for li in 0..l {
            for lane in 0..b {
                for hh in 0..h {
                    for ci in 0..c {
                        let cb = ((li * b + lane) * h + hh) * c + ci;
                        for d in 0..dh {
                            k_chunk[cb * dh + d] = Self::mock_kv(
                                li, hh, h, ci, c, d, dh,
                                ins.tokens[lane * c + ci]);
                        }
                    }
                }
            }
        }
        let v_chunk = k_chunk.clone();
        // scatter the chunk into the per-lane arenas at the planned slots
        for lane in 0..b {
            let slab = self.arena.lane_mut(lane);
            for li in 0..l {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh;
                    let row = (li * h + hh) * m;
                    for ci in 0..c {
                        if ins.in_mask[lane * c + ci] <= 0.0 {
                            continue;
                        }
                        let s = ins.write_slots[base * c + ci] as usize;
                        let dst = (row + s) * dh;
                        let src = (base * c + ci) * dh;
                        slab.k[dst..dst + dh]
                            .copy_from_slice(&k_chunk[src..src + dh]);
                        slab.v[dst..dst + dh]
                            .copy_from_slice(&v_chunk[src..src + dh]);
                    }
                }
            }
        }
        Ok(PrefillOut { logits, log_beta, attn_slots, attn_chunk, k_chunk, v_chunk })
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.decoded_per_lane = vec![0; self.b];
        self.arena.reset();
        Ok(())
    }

    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>> {
        self.arena.swap_lanes(out, inn)
    }

    fn swap_traffic(&self) -> SwapTraffic {
        self.arena.traffic
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_decode_emits_successor_then_eos() {
        let mut mb = MockBackend::new(2, 8).with_eos_after(3);
        let valid = vec![0.0f32; 4 * 2 * 2 * 8];
        let ws = vec![0i32; 4 * 2 * 2];
        for step in 0..4 {
            let out = mb
                .decode(&DecodeIn {
                    tokens: &[10, 20],
                    pos: &[step, step],
                    valid: &valid,
                    write_slot: &ws,
                    inject_flag: None,
                    inject_slot: None,
                    inject_k: None,
                    inject_v: None,
                    want_attn: true,
                    want_kv: true,
                })
                .unwrap();
            let argmax = |lane: usize| {
                (0..512)
                    .max_by(|&a, &b| {
                        out.logits[lane * 512 + a]
                            .partial_cmp(&out.logits[lane * 512 + b])
                            .unwrap()
                    })
                    .unwrap()
            };
            if step < 2 {
                assert_eq!(argmax(0), 11);
                assert_eq!(argmax(1), 21);
            } else {
                assert_eq!(argmax(0), 2);
            }
        }
    }

    #[test]
    fn mock_log_beta_prefers_syms() {
        let sym = MockBackend::mock_log_beta(0, 0, 40);
        let word = MockBackend::mock_log_beta(0, 0, 300);
        assert!(sym > word);
        assert!(sym < 0.0);
    }

    fn decode_write(mb: &mut MockBackend, tokens: &[i32], slots: &[usize]) {
        let (l, b, h, m) = (mb.dims.layers, mb.b, mb.dims.hkv, mb.m);
        let valid = vec![0.0f32; l * b * h * m];
        let pos = vec![0i32; b];
        let mut ws = vec![0i32; l * b * h];
        for li in 0..l {
            for (lane, &slot) in slots.iter().enumerate() {
                for hh in 0..h {
                    ws[(li * b + lane) * h + hh] = slot as i32;
                }
            }
        }
        mb.decode(&DecodeIn {
            tokens,
            pos: &pos,
            valid: &valid,
            write_slot: &ws,
            inject_flag: None,
            inject_slot: None,
            inject_k: None,
            inject_v: None,
            want_attn: false,
            want_kv: true,
        })
        .unwrap();
    }

    #[test]
    fn mock_batched_lane_swap_roundtrip() {
        let mut mb = MockBackend::new(2, 8);
        // decode writes lane 0 into slot 1, lane 1 into slot 3
        decode_write(&mut mb, &[10, 77], &[1, 3]);
        let down = mb.swap_lanes(&[0, 1], &[]).unwrap();
        assert_eq!(down[0].k.len(), mb.lane_kv_len());
        assert_ne!(down[0].k, down[1].k,
                   "lanes with different tokens share a slab");
        // mixed call: lane 1 is downloaded *and* overwritten by lane 0's
        // slab — the preempt-and-restore-in-one-step case
        let prev = mb.swap_lanes(&[1], &[(1, &down[0])]).unwrap();
        assert_eq!(prev[0], down[1], "mixed swap must download before upload");
        let now = mb.swap_lanes(&[0, 1], &[]).unwrap();
        assert_eq!(now[1], down[0]);
        assert_eq!(now[0], down[0], "lane 0 clobbered by the lane-1 upload");
        // size/range validation
        let short = LaneKv { k: down[0].k[1..].to_vec(), v: down[0].v.clone() };
        assert!(mb.swap_lanes(&[], &[(1, &short)]).is_err());
        assert!(mb.swap_lanes(&[9], &[]).is_err());
    }

    #[test]
    fn swap_traffic_is_o_lane_not_o_batch() {
        // swapping 1 lane moves exactly 2 * lane_kv_len() elements no
        // matter how many lanes the batch has (the acceptance criterion)
        let mut per_batch = Vec::new();
        for b in [2usize, 4, 8] {
            let mut mb = MockBackend::new(b, 8);
            let down = mb.swap_lanes(&[0], &[]).unwrap();
            assert_eq!(down[0].k.len(), mb.lane_kv_len());
            let t = mb.swap_traffic();
            assert_eq!(t.elems_out as usize, 2 * mb.lane_kv_len());
            assert_eq!(t.lanes_out, 1);
            per_batch.push(t.elems_out);
        }
        assert!(per_batch.windows(2).all(|w| w[0] == w[1]),
                "swap traffic grew with batch size: {per_batch:?}");
    }

    #[test]
    fn mock_kv_content_is_lane_and_batch_invariant() {
        // the same token written to the same slot must produce an identical
        // slab through any lane of any batch size (cross-shape swap tests
        // rely on this)
        let mut a = MockBackend::new(1, 8);
        decode_write(&mut a, &[42], &[2]);
        let mut b = MockBackend::new(3, 8);
        decode_write(&mut b, &[7, 42, 9], &[2, 2, 2]);
        let la = a.swap_lanes(&[0], &[]).unwrap();
        let lb = b.swap_lanes(&[1], &[]).unwrap();
        assert_eq!(la[0], lb[0],
                   "lane content depends on lane index or batch size");
    }

    #[test]
    fn mock_attention_is_uniform_over_live() {
        let mut mb = MockBackend::new(1, 4);
        let mut valid = vec![0.0f32; 4 * 1 * 2 * 4];
        valid[0] = 1.0;
        valid[1] = 1.0;
        let out = mb
            .decode(&DecodeIn {
                tokens: &[1],
                pos: &[0],
                valid: &valid,
                write_slot: &[0; 8],
                inject_flag: None,
                inject_slot: None,
                inject_k: None,
                inject_v: None,
                want_attn: true,
                want_kv: true,
            })
            .unwrap();
        assert_eq!(out.attn[0], 0.5);
        assert_eq!(out.attn[1], 0.5);
        assert_eq!(out.attn[2], 0.0);
    }
}
