//! ModelBackend: the engine's interface to the AOT-compiled model graphs.
//!
//! The contract is ONE declarative step: the engine assembles a [`StepPlan`]
//! — a [`LaneOp`] per batch lane plus the fused flat operand buffers — and
//! the backend executes it through whatever graph is cheapest.  Execution
//! is asynchronous: `submit` enqueues the plan and returns a [`StepToken`],
//! `wait` blocks for the outputs — so the engine can overlap next-tick
//! assembly, last-tick postprocess and chained `swap_lanes` transfers with
//! the step in flight.  `execute` remains as the serial submit+wait
//! convenience for callers that do not pipeline.
//!
//! `PjrtBackend` executes the HLO artifacts on the PJRT CPU client with the
//! KV caches held device-resident (only logits / gate scores / attention
//! stats cross the device boundary each step — the paper's O(M) decode).
//! A pure-decode plan dispatches to the decode graph, a pure-chunk plan to
//! the prefill graph, and a mixed plan to the fused mixed-step graph;
//! artifacts exported without any mixed graph degrade to one decode-graph
//! + one prefill-graph call behind the same `execute` entrypoint.  Cache
//! residency is owned by [`DeviceKvCache`]: per-lane buffer pairs (O(lane)
//! session swap) — the only supported `cache_layout`.  `MockBackend` is a
//! deterministic stand-in used by unit/property tests so the scheduler,
//! cache manager and policies are testable without artifacts.

use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use super::devcache::{CacheShape, DeviceKvCache, HostLaneArena, LaneKv,
                      SwapTraffic};
use crate::model_meta::{ModelDims, ModelMeta};

/// What one batch lane does in a step plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LaneOp {
    /// No work this step (idle/parked lane): its plan columns are padding
    /// (zero mask, writes pointed at the trash slot).
    #[default]
    Idle,
    /// Advance one decode token, carried in chunk column 0.
    Decode,
    /// Feed a budgeted prefill chunk of this many prompt tokens
    /// (1 <= tokens <= chunk capacity; the planner grants the budget).
    Chunk { tokens: usize },
    /// Decode one token AND re-inject `slots` previously evicted KV entries
    /// first (retrieval baseline; at most one injection per (layer, head),
    /// described by the plan's `inject_*` operands).
    Inject { slots: usize },
}

impl LaneOp {
    /// Decode-like: advances exactly one token through chunk column 0.
    pub fn is_decode(self) -> bool {
        matches!(self, LaneOp::Decode | LaneOp::Inject { .. })
    }

    pub fn is_chunk(self) -> bool {
        matches!(self, LaneOp::Chunk { .. })
    }

    pub fn is_active(self) -> bool {
        self != LaneOp::Idle
    }

    /// Chunk columns this op occupies in the plan's fused buffers.
    pub fn cols(self) -> usize {
        match self {
            LaneOp::Idle => 0,
            LaneOp::Decode | LaneOp::Inject { .. } => 1,
            LaneOp::Chunk { tokens } => tokens,
        }
    }
}

/// Which graph family a plan needs (derived, not stored).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    Empty,
    Decode,
    Chunk,
    Mixed,
}

/// One declarative engine step over all B lanes: a [`LaneOp`] per lane plus
/// the fused flat operand buffers every graph family consumes.  Layouts are
/// row-major flat slices at the backend's chunk capacity C:
/// tokens/pos/in_mask `[B, C]`, valid `[L, B, H, M]`, write_slots
/// `[L, B, H, C]`, inject_flag/inject_slot `[L, B, H]`, inject_k/v
/// `[L, B, H, dh]`.  Decode lanes live in chunk column 0; idle lanes carry
/// a zero mask and trash-slot writes.
#[derive(Clone, Copy)]
pub struct StepPlan<'a> {
    pub ops: &'a [LaneOp],
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub in_mask: &'a [f32],
    pub valid: &'a [f32],
    pub write_slots: &'a [i32],
    /// Retrieval re-injection operands; `Some` only when an `Inject` op is
    /// present (applied before attention, exactly the decode graph's rule).
    pub inject_flag: Option<&'a [f32]>,
    pub inject_slot: Option<&'a [i32]>,
    pub inject_k: Option<&'a [f32]>,
    pub inject_v: Option<&'a [f32]>,
    /// download the attention stats (H2O/SnapKV/R-KV/retrieval only)
    pub want_attn: bool,
    /// download the new-token K/V (key-similarity + retrieval policies only)
    pub want_kv: bool,
}

impl StepPlan<'_> {
    pub fn n_decode(&self) -> usize {
        self.ops.iter().filter(|o| o.is_decode()).count()
    }

    pub fn n_chunk(&self) -> usize {
        self.ops.iter().filter(|o| o.is_chunk()).count()
    }

    pub fn has_inject(&self) -> bool {
        self.ops.iter().any(|o| matches!(o, LaneOp::Inject { .. }))
    }

    pub fn kind(&self) -> PlanKind {
        match (self.n_decode(), self.n_chunk()) {
            (0, 0) => PlanKind::Empty,
            (_, 0) => PlanKind::Decode,
            (0, _) => PlanKind::Chunk,
            _ => PlanKind::Mixed,
        }
    }

    /// Shape-check against a backend's dims (every implementation calls
    /// this first so contract violations fail loudly, not numerically).
    pub fn validate(&self, l: usize, b: usize, h: usize, m: usize, c: usize,
                    dh: usize) -> Result<()> {
        ensure!(self.ops.len() == b, "bad ops len");
        ensure!(self.tokens.len() == b * c && self.pos.len() == b * c
                    && self.in_mask.len() == b * c,
                "bad token/pos/mask len");
        ensure!(self.valid.len() == l * b * h * m, "bad valid len");
        ensure!(self.write_slots.len() == l * b * h * c, "bad write_slots len");
        for op in self.ops {
            if let LaneOp::Chunk { tokens } = op {
                ensure!(*tokens >= 1 && *tokens <= c,
                        "chunk op of {tokens} tokens exceeds capacity {c}");
            }
        }
        let inj = [self.inject_flag.is_some(), self.inject_slot.is_some(),
                   self.inject_k.is_some(), self.inject_v.is_some()];
        ensure!(inj.iter().all(|&x| x) || inj.iter().all(|&x| !x),
                "inject operands must be all-present or all-absent");
        ensure!(!self.has_inject() || self.inject_flag.is_some(),
                "plan has Inject ops but no inject operands");
        if let (Some(flag), Some(slot), Some(ik), Some(iv)) =
            (self.inject_flag, self.inject_slot, self.inject_k, self.inject_v)
        {
            ensure!(flag.len() == l * b * h, "bad inject_flag len");
            ensure!(slot.len() == l * b * h, "bad inject_slot len");
            ensure!(ik.len() == l * b * h * dh && iv.len() == l * b * h * dh,
                    "bad inject_k/v len");
        }
        Ok(())
    }
}

/// Unified step outputs in the chunk formulation.  `cols` is the chunk
/// stride of this step's outputs: 1 when the step ran through the pure
/// decode graph (the cheapest dispatch — decode lanes read column 0 either
/// way), the backend's chunk capacity otherwise.
///
/// For decode lanes `attn_slots` is mode-fused: the new token's
/// self-attention mass is folded into its write slot, so each decode lane
/// reads one `[M]` row.  `attn_chunk` is empty on pure-decode dispatch
/// (decode post-processing never reads it); `attn_slots`/`k_chunk`/
/// `v_chunk` may be empty when the plan did not request them AND no chunk
/// lane forced them.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub cols: usize,
    pub logits: Vec<f32>,     // [B, cols, vocab]
    pub log_beta: Vec<f32>,   // [L, B, H, cols]
    pub attn_slots: Vec<f32>, // [L, B, H, M]
    pub attn_chunk: Vec<f32>, // [L, B, H, cols]
    pub k_chunk: Vec<f32>,    // [L, B, H, cols, dh]
    pub v_chunk: Vec<f32>,    // [L, B, H, cols, dh]
}

/// Handle to a submitted, not-yet-waited step (see [`ModelBackend::submit`]).
/// Single-use and backend-scoped: passing a stale or foreign token to
/// `wait` is an error, never silent data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepToken(u64);

pub trait ModelBackend: Send {
    fn dims(&self) -> ModelDims;
    fn batch(&self) -> usize;
    fn slots(&self) -> usize;
    fn chunk(&self) -> usize;

    /// THE step entrypoint, async half 1: validate and enqueue one
    /// declarative [`StepPlan`], returning a [`StepToken`] for `wait`.
    /// Implementations must keep exact per-lane token accounting — every
    /// `in_mask == 1` position of an active lane advances that lane by
    /// exactly one token, decode and chunk lanes alike, in the one call —
    /// and are free to dispatch to whichever graph(s) realize the plan
    /// cheapest, as long as the result is lane-for-lane equivalent to the
    /// fused semantics.
    ///
    /// Pipelining contract: the plan's borrowed buffers are fully consumed
    /// by the time `submit` returns — the caller may immediately reuse or
    /// mutate them (double-buffered assembly) and may issue `swap_lanes`
    /// while the step is in flight; such chained work observes the
    /// post-step cache state (in-order queue semantics).  At most one step
    /// may be in flight per backend; a second `submit` is an error.
    fn submit(&mut self, plan: &StepPlan) -> Result<StepToken>;

    /// Async half 2: block until the in-flight step completes and download
    /// its outputs.  The token must be the one the matching `submit`
    /// returned — stale/foreign tokens and double waits are errors.
    fn wait(&mut self, token: StepToken) -> Result<StepOut>;

    /// Serial convenience composing the async pair.  Callers that do not
    /// pipeline (tests, benches, `pipeline = off`) need nothing else, and
    /// implementations get it for free from `submit`/`wait`.
    fn execute(&mut self, plan: &StepPlan) -> Result<StepOut> {
        let token = self.submit(plan)?;
        self.wait(token)
    }

    /// Zero the device-resident KV caches (new evaluation run).
    fn reset_cache(&mut self) -> Result<()>;

    /// Batched lane-level session swap: download the current `[L, H, M, dh]`
    /// K/V slabs of every lane in `out` (returned in `out` order), then
    /// upload the `inn` slabs into their lanes, leaving every other lane
    /// untouched.  Downloads happen before uploads, so a lane may appear in
    /// both — preempting it and installing another session in one step.
    ///
    /// Cost contract: swapping N lanes moves O(N * lane_kv_len()) elements.
    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>>;

    /// Cumulative transfer accounting for `swap_lanes` (tests/benches
    /// assert the O(lane) property on these counters).
    fn swap_traffic(&self) -> SwapTraffic;

    /// Elements in one lane's `[L, H, M, dh]` slab (sizing for swap buffers).
    fn lane_kv_len(&self) -> usize {
        let d = self.dims();
        d.layers * d.hkv * self.slots() * d.dh
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Step outputs still device-resident between `submit` and `wait`: only
/// the buffers the plan's want flags kept are held, and nothing crosses
/// the device boundary until the engine asks for it.
struct DeviceStepOut {
    cols: usize,
    logits: xla::PjRtBuffer,
    log_beta: xla::PjRtBuffer,
    attn_slots: Option<xla::PjRtBuffer>,
    attn_chunk: Option<xla::PjRtBuffer>,
    k_chunk: Option<xla::PjRtBuffer>,
    v_chunk: Option<xla::PjRtBuffer>,
}

impl DeviceStepOut {
    fn download(self) -> Result<StepOut> {
        fn opt(buf: &Option<xla::PjRtBuffer>) -> Result<Vec<f32>> {
            buf.as_ref().map_or(Ok(Vec::new()), to_host)
        }
        Ok(StepOut {
            cols: self.cols,
            logits: to_host(&self.logits)?,
            log_beta: to_host(&self.log_beta)?,
            attn_slots: opt(&self.attn_slots)?,
            attn_chunk: opt(&self.attn_chunk)?,
            k_chunk: opt(&self.k_chunk)?,
            v_chunk: opt(&self.v_chunk)?,
        })
    }
}

/// What `PjrtBackend::submit` parks for `wait`: device buffers on the
/// graph paths, an already-host tuple on the split-dispatch degrade path
/// (which merges per-kind host outputs and is synchronous by nature).
enum PendingOut {
    Device(DeviceStepOut),
    Host(StepOut),
}

pub struct PjrtBackend {
    client: xla::PjRtClient,
    decode_exe: xla::PjRtLoadedExecutable,
    prefill_exe: Option<xla::PjRtLoadedExecutable>,
    /// fused mixed-step graph; `None` on artifacts exported before the
    /// `mixed` kind — mixed plans then degrade to per-kind graph calls
    mixed_exe: Option<xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>, // params ++ gates, device-resident
    cache: DeviceKvCache,
    dims: ModelDims,
    b: usize,
    m: usize,
    c: usize,
    next_token: u64,
    pending: Option<(StepToken, PendingOut)>,
}

impl PjrtBackend {
    /// Load artifacts for batch `b` and budget->slot count `m` (exact match
    /// against an exported variant chosen by the caller via `meta.pick`).
    pub fn load(meta: &ModelMeta, b: usize, m: usize, gate_variant: &str,
                gate_arch: &str, with_prefill: bool) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()?;
        let dec = meta
            .pick("decode", b, m, gate_arch)
            .with_context(|| format!("no decode artifact for b={b} m>={m}"))?;
        ensure!(dec.m == m, "caller must pass an exported slot count");
        let decode_exe = compile_hlo(&client, &meta.dir.join(&dec.file))?;
        let prefill_exe = if with_prefill {
            // the prefill graph must share the decode graph's cache layout:
            // both operate on the same resident buffers
            let pre = meta
                .artifacts
                .iter()
                .find(|a| a.kind == "prefill" && a.b == b && a.m == m
                          && a.gate_arch == gate_arch
                          && a.cache_layout == dec.cache_layout)
                .with_context(|| format!(
                    "no prefill artifact for b={b} m={m} layout={}",
                    dec.cache_layout))?;
            Some(compile_hlo(&client, &meta.dir.join(&pre.file))?)
        } else {
            None
        };
        // the fused mixed-step graph is optional (absent on legacy
        // exports); like prefill it must share the decode graph's layout.
        // When present it must carry the retrieval inject operands — the
        // pre-unified-API mixed exports without them are no longer loaded.
        let mixed_spec = meta.artifacts.iter().find(|a| {
            a.kind == "mixed" && a.b == b && a.m == m
                && a.gate_arch == gate_arch
                && a.cache_layout == dec.cache_layout
        });
        let mixed_exe = match mixed_spec {
            Some(mx) if with_prefill => {
                ensure!(mx.has_inject(),
                        "mixed artifact {} lacks inject operands; re-export \
                         with python -m compile.aot",
                        mx.file);
                Some(compile_hlo(&client, &meta.dir.join(&mx.file))?)
            }
            _ => None,
        };

        // upload weights once, in the flat order the graphs expect
        let weights = super::weights::read_weights(&meta.dir.join("weights.bin"))?;
        let gates = super::weights::read_weights(
            &meta.dir.join(format!("gates_{gate_variant}.bin")))?;
        let gate_order: Vec<String> = if gate_arch == "linear" {
            gates.keys().cloned().collect() // BTreeMap order == gN.{b1,w1}
        } else {
            meta.gate_order.iter().map(|t| t.name.clone()).collect()
        };
        let mut weight_bufs = Vec::new();
        for spec in &meta.param_order {
            let t = weights
                .get(&spec.name)
                .with_context(|| format!("weights.bin missing {}", spec.name))?;
            ensure!(t.shape == spec.shape, "shape mismatch for {}", spec.name);
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }
        for name in &gate_order {
            let t = gates
                .get(name)
                .with_context(|| format!("gates bin missing {name}"))?;
            weight_bufs.push(client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
        }

        let dims = meta.dims;
        let shape = CacheShape { layers: dims.layers, batch: b, hkv: dims.hkv,
                                 slots: m, dh: dims.dh };
        let cache = DeviceKvCache::new_zeroed(&client, shape)?;
        Ok(PjrtBackend {
            client,
            decode_exe,
            prefill_exe,
            mixed_exe,
            weight_bufs,
            cache,
            dims,
            b,
            m,
            c: meta.chunk,
            next_token: 0,
            pending: None,
        })
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn lbh(&self) -> (usize, usize, usize) {
        (self.dims.layers, self.b, self.dims.hkv)
    }

    /// Pure-decode dispatch: gather column 0 of the plan into the decode
    /// graph's `[B]`/`[L,B,H]` operands and return cols=1 outputs.
    fn exec_decode(&mut self, plan: &StepPlan) -> Result<DeviceStepOut> {
        let (l, b, h) = self.lbh();
        let (c, dh) = (self.c, self.dims.dh);
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for lane in 0..b {
            tokens[lane] = plan.tokens[lane * c];
            pos[lane] = plan.pos[lane * c];
        }
        let mut ws = vec![0i32; l * b * h];
        for (i, slot) in ws.iter_mut().enumerate() {
            *slot = plan.write_slots[i * c];
        }

        let zero_f = vec![0.0f32; l * b * h];
        let zero_i = vec![0i32; l * b * h];
        let zero_k = vec![0.0f32; l * b * h * dh];
        let token_b = self.upload_i32(&tokens, &[b])?;
        let pos_b = self.upload_i32(&pos, &[b])?;
        let valid_b = self.upload_f32(plan.valid, &[l, b, h, self.m])?;
        let ws_b = self.upload_i32(&ws, &[l, b, h])?;
        let if_b = self.upload_f32(plan.inject_flag.unwrap_or(&zero_f), &[l, b, h])?;
        let is_b = self.upload_i32(plan.inject_slot.unwrap_or(&zero_i), &[l, b, h])?;
        let ik_b = self.upload_f32(plan.inject_k.unwrap_or(&zero_k), &[l, b, h, dh])?;
        let iv_b = self.upload_f32(plan.inject_v.unwrap_or(&zero_k), &[l, b, h, dh])?;

        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&token_b, &pos_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b, &if_b, &is_b, &ik_b, &iv_b]);
        let mut outs = self.decode_exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 6 + ncache,
                "decode graph returned {} outputs, expected {}", outs.len(),
                6 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn, k_new, v_new.
        // Install the updated cache buffers immediately (the device queue
        // is in order, so chained swaps observe the post-step cache); the
        // rest stays device-resident until `wait`, and the want flags
        // decide at submit which buffers survive to be downloaded at all.
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        let mut outs = outs.into_iter();
        let logits = outs.next().context("missing logits output")?;
        let _valid = outs.next();
        let log_beta = outs.next().context("missing log_beta output")?;
        let attn = outs.next().context("missing attn output")?;
        let k_new = outs.next().context("missing k_new output")?;
        let v_new = outs.next().context("missing v_new output")?;
        Ok(DeviceStepOut {
            cols: 1,
            logits,
            log_beta,
            attn_slots: plan.want_attn.then_some(attn),
            attn_chunk: None,
            k_chunk: plan.want_kv.then_some(k_new),
            v_chunk: plan.want_kv.then_some(v_new),
        })
    }

    /// Pure-chunk dispatch: the plan's fused buffers ARE the prefill
    /// graph's operands.  `tokens`/`in_mask`/`write_slots` may be the
    /// caller-modified copies of the degraded mixed path.
    fn exec_prefill(&mut self, tokens: &[i32], pos: &[i32], in_mask: &[f32],
                    valid: &[f32], write_slots: &[i32])
        -> Result<DeviceStepOut> {
        let (l, b, h) = self.lbh();
        let (m, c) = (self.m, self.c);
        let tok_b = self.upload_i32(tokens, &[b, c])?;
        let pos_b = self.upload_i32(pos, &[b, c])?;
        let mask_b = self.upload_f32(in_mask, &[b, c])?;
        let valid_b = self.upload_f32(valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(write_slots, &[l, b, h, c])?;

        let exe = self
            .prefill_exe
            .as_ref()
            .context("backend loaded without prefill graph")?;
        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&tok_b, &pos_b, &mask_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b]);
        let mut outs = exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 7 + ncache,
                "prefill graph returned {} outputs, expected {}", outs.len(),
                7 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn_slots,
        //        attn_chunk, k_chunk, v_chunk
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        let mut outs = outs.into_iter();
        let logits = outs.next().context("missing logits output")?;
        let _valid = outs.next();
        let log_beta = outs.next().context("missing log_beta output")?;
        let attn_slots = outs.next().context("missing attn_slots output")?;
        let attn_chunk = outs.next().context("missing attn_chunk output")?;
        let k_chunk = outs.next().context("missing k_chunk output")?;
        let v_chunk = outs.next().context("missing v_chunk output")?;
        Ok(DeviceStepOut {
            cols: c,
            logits,
            log_beta,
            attn_slots: Some(attn_slots),
            attn_chunk: Some(attn_chunk),
            k_chunk: Some(k_chunk),
            v_chunk: Some(v_chunk),
        })
    }

    /// Mixed dispatch through the fused graph (one execution for decode AND
    /// chunk lanes).  The retrieval inject operands are always appended —
    /// zeros when the plan carries none.
    fn exec_mixed(&mut self, plan: &StepPlan) -> Result<DeviceStepOut> {
        let (l, b, h) = self.lbh();
        let (m, c, dh) = (self.m, self.c, self.dims.dh);
        let mut mode = vec![0.0f32; b];
        for (lane, op) in plan.ops.iter().enumerate() {
            if op.is_decode() {
                mode[lane] = 1.0;
            }
        }
        let tok_b = self.upload_i32(plan.tokens, &[b, c])?;
        let pos_b = self.upload_i32(plan.pos, &[b, c])?;
        let mask_b = self.upload_f32(plan.in_mask, &[b, c])?;
        let mode_b = self.upload_f32(&mode, &[b])?;
        let valid_b = self.upload_f32(plan.valid, &[l, b, h, m])?;
        let ws_b = self.upload_i32(plan.write_slots, &[l, b, h, c])?;
        let zero_f = vec![0.0f32; l * b * h];
        let zero_i = vec![0i32; l * b * h];
        let zero_k = vec![0.0f32; l * b * h * dh];
        let if_b = self.upload_f32(plan.inject_flag.unwrap_or(&zero_f), &[l, b, h])?;
        let is_b = self.upload_i32(plan.inject_slot.unwrap_or(&zero_i), &[l, b, h])?;
        let ik_b = self.upload_f32(plan.inject_k.unwrap_or(&zero_k), &[l, b, h, dh])?;
        let iv_b = self.upload_f32(plan.inject_v.unwrap_or(&zero_k), &[l, b, h, dh])?;

        let exe = self
            .mixed_exe
            .as_ref()
            .context("backend loaded without mixed-step graph")?;
        let ncache = self.cache.num_operands();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&tok_b, &pos_b, &mask_b, &mode_b]);
        args.extend(self.cache.arg_refs());
        args.extend([&valid_b, &ws_b, &if_b, &is_b, &ik_b, &iv_b]);
        let mut outs = exe.execute_b(&args)?;
        drop(args);
        let mut outs = outs.swap_remove(0);
        ensure!(outs.len() == 7 + ncache,
                "mixed graph returned {} outputs, expected {}", outs.len(),
                7 + ncache);
        // order: logits, kc.., vc.., valid, log_beta, attn_slots,
        //        attn_chunk, k_chunk, v_chunk (attn_slots mode-fused)
        let cache_bufs: Vec<xla::PjRtBuffer> = outs.drain(1..1 + ncache).collect();
        self.cache.update_from_outputs(cache_bufs)?;
        let mut outs = outs.into_iter();
        let logits = outs.next().context("missing logits output")?;
        let _valid = outs.next();
        let log_beta = outs.next().context("missing log_beta output")?;
        let attn_slots = outs.next().context("missing attn_slots output")?;
        let attn_chunk = outs.next().context("missing attn_chunk output")?;
        let k_chunk = outs.next().context("missing k_chunk output")?;
        let v_chunk = outs.next().context("missing v_chunk output")?;
        Ok(DeviceStepOut {
            cols: c,
            logits,
            log_beta,
            attn_slots: Some(attn_slots),
            attn_chunk: Some(attn_chunk),
            k_chunk: Some(k_chunk),
            v_chunk: Some(v_chunk),
        })
    }

    /// Degraded mixed dispatch for artifacts exported without any mixed
    /// graph: one decode-graph call advances the decode lanes (chunk lanes
    /// idled behind trash writes), one prefill-graph call feeds the chunk
    /// lanes (decode lanes masked out), and the outputs merge into the
    /// fused cols=C layout.  Lane semantics are identical to the fused
    /// graph — lanes only ever attend to their own rows — at the price of
    /// two graph executions for the one plan.
    fn exec_split(&mut self, plan: &StepPlan) -> Result<StepOut> {
        let (l, b, h) = self.lbh();
        let (m, c, dh, v) = (self.m, self.c, self.dims.dh, self.dims.vocab);
        let trash = (m - 1) as i32;

        // --- decode-graph call over the decode lanes --------------------
        let mut dec_tokens = vec![0i32; b * c];
        let mut dec_pos = vec![0i32; b * c];
        let mut dec_mask = vec![0.0f32; b * c];
        let mut dec_ws = vec![trash; l * b * h * c];
        for lane in 0..b {
            if !plan.ops[lane].is_decode() {
                continue;
            }
            dec_tokens[lane * c] = plan.tokens[lane * c];
            dec_pos[lane * c] = plan.pos[lane * c];
            dec_mask[lane * c] = plan.in_mask[lane * c];
            for li in 0..l {
                for hh in 0..h {
                    let base = ((li * b + lane) * h + hh) * c;
                    dec_ws[base] = plan.write_slots[base];
                }
            }
        }
        // chunk lanes get their attention rows from the prefill call; the
        // decode call honours the plan's own want flags
        let dec_plan = StepPlan {
            tokens: &dec_tokens,
            pos: &dec_pos,
            in_mask: &dec_mask,
            write_slots: &dec_ws,
            ..*plan
        };
        let dec = self.exec_decode(&dec_plan)?.download()?;

        // --- prefill-graph call over the chunk lanes --------------------
        let mut pre_tokens = vec![0i32; b * c];
        let mut pre_pos = vec![0i32; b * c];
        let mut pre_mask = vec![0.0f32; b * c];
        let mut pre_ws = vec![trash; l * b * h * c];
        for lane in 0..b {
            if !plan.ops[lane].is_chunk() {
                continue;
            }
            let col = lane * c;
            pre_tokens[col..col + c].copy_from_slice(&plan.tokens[col..col + c]);
            pre_pos[col..col + c].copy_from_slice(&plan.pos[col..col + c]);
            pre_mask[col..col + c].copy_from_slice(&plan.in_mask[col..col + c]);
            for li in 0..l {
                for hh in 0..h {
                    let base = ((li * b + lane) * h + hh) * c;
                    pre_ws[base..base + c]
                        .copy_from_slice(&plan.write_slots[base..base + c]);
                }
            }
        }
        let pre = self.exec_prefill(&pre_tokens, &pre_pos, &pre_mask,
                                    plan.valid, &pre_ws)?.download()?;

        // --- merge into the fused cols=C layout -------------------------
        let mut out = StepOut {
            cols: c,
            logits: vec![0.0f32; b * c * v],
            log_beta: vec![0.0f32; l * b * h * c],
            attn_slots: vec![0.0f32; l * b * h * m],
            attn_chunk: vec![0.0f32; l * b * h * c],
            k_chunk: vec![0.0f32; l * b * h * c * dh],
            v_chunk: vec![0.0f32; l * b * h * c * dh],
        };
        for lane in 0..b {
            let op = plan.ops[lane];
            if op.is_decode() {
                out.logits[lane * c * v..lane * c * v + v]
                    .copy_from_slice(&dec.logits[lane * v..(lane + 1) * v]);
                for li in 0..l {
                    for hh in 0..h {
                        let base = (li * b + lane) * h + hh;
                        out.log_beta[base * c] = dec.log_beta[base];
                        if plan.want_attn {
                            out.attn_slots[base * m..(base + 1) * m]
                                .copy_from_slice(
                                    &dec.attn_slots[base * m..(base + 1) * m]);
                        }
                        if plan.want_kv {
                            out.k_chunk[base * c * dh..base * c * dh + dh]
                                .copy_from_slice(
                                    &dec.k_chunk[base * dh..(base + 1) * dh]);
                            out.v_chunk[base * c * dh..base * c * dh + dh]
                                .copy_from_slice(
                                    &dec.v_chunk[base * dh..(base + 1) * dh]);
                        }
                    }
                }
            } else if op.is_chunk() {
                let col = lane * c * v;
                out.logits[col..col + c * v]
                    .copy_from_slice(&pre.logits[col..col + c * v]);
                for li in 0..l {
                    for hh in 0..h {
                        let base = (li * b + lane) * h + hh;
                        out.log_beta[base * c..(base + 1) * c]
                            .copy_from_slice(&pre.log_beta[base * c..(base + 1) * c]);
                        out.attn_slots[base * m..(base + 1) * m]
                            .copy_from_slice(&pre.attn_slots[base * m..(base + 1) * m]);
                        out.attn_chunk[base * c..(base + 1) * c]
                            .copy_from_slice(&pre.attn_chunk[base * c..(base + 1) * c]);
                        out.k_chunk[base * c * dh..(base + 1) * c * dh]
                            .copy_from_slice(
                                &pre.k_chunk[base * c * dh..(base + 1) * c * dh]);
                        out.v_chunk[base * c * dh..(base + 1) * c * dh]
                            .copy_from_slice(
                                &pre.v_chunk[base * c * dh..(base + 1) * c * dh]);
                    }
                }
            }
        }
        Ok(out)
    }
}

pub fn compile_hlo(client: &xla::PjRtClient,
                   path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn to_host(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
}

impl ModelBackend for PjrtBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    fn submit(&mut self, plan: &StepPlan) -> Result<StepToken> {
        ensure!(self.pending.is_none(),
                "step already in flight (one submit per wait)");
        let (l, b, h) = self.lbh();
        plan.validate(l, b, h, self.m, self.c, self.dims.dh)?;
        // dispatch now: operand uploads and the graph execution are
        // enqueued on the in-order device stream, downloads wait for
        // `wait` — the plan's borrowed buffers are dead once this returns
        let out = match plan.kind() {
            PlanKind::Empty | PlanKind::Decode => {
                PendingOut::Device(self.exec_decode(plan)?)
            }
            PlanKind::Chunk => PendingOut::Device(self.exec_prefill(
                plan.tokens, plan.pos, plan.in_mask, plan.valid,
                plan.write_slots)?),
            PlanKind::Mixed => {
                if self.mixed_exe.is_some() {
                    PendingOut::Device(self.exec_mixed(plan)?)
                } else {
                    PendingOut::Host(self.exec_split(plan)?)
                }
            }
        };
        let token = StepToken(self.next_token);
        self.next_token += 1;
        self.pending = Some((token, out));
        Ok(token)
    }

    fn wait(&mut self, token: StepToken) -> Result<StepOut> {
        match &self.pending {
            Some((t, _)) if *t == token => {}
            Some((t, _)) => anyhow::bail!(
                "wait token mismatch: in flight {t:?}, got {token:?}"),
            None => anyhow::bail!("wait with no step in flight"),
        }
        match self.pending.take().expect("checked above").1 {
            PendingOut::Device(dev) => dev.download(),
            PendingOut::Host(out) => Ok(out),
        }
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.cache.reset(&self.client)
    }

    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>> {
        self.cache.swap_lanes(&self.client, out, inn)
    }

    fn swap_traffic(&self) -> SwapTraffic {
        self.cache.traffic
    }
}

// ---------------------------------------------------------------------------
// Mock backend (tests)
// ---------------------------------------------------------------------------

/// Deterministic fake model: the next-token distribution peaks at
/// `(token + 1) % vocab` until `eos_after` tokens have been produced on a
/// lane, then at EOS (id 2).  Gate scores depend only on (layer, head,
/// token), and the fake K/V content only on (layer, head, position-in-lane,
/// token) — never on the lane index, the batch size, or the plan's op mix —
/// so TRIM-KV evictions, swapped lane slabs and cross-scheduling runs are
/// reproducible bit-exactly across engine shapes in tests.
pub struct MockBackend {
    pub dims: ModelDims,
    pub b: usize,
    pub m: usize,
    pub c: usize,
    /// EOS trigger for tests: a lane's distribution flips to EOS once its
    /// counter of decode-op tokens reaches this.
    pub eos_after: usize,
    /// Synthetic device-execution latency in microseconds, paid in `wait`
    /// and never in `submit` (net of host time already elapsed since the
    /// submit): models a device that computes while the host does other
    /// work, so host/device overlap is measurable in CI without hardware.
    pub synthetic_execute_us: u64,
    pub decoded_per_lane: Vec<usize>,
    /// executed plans by dispatch kind (mirrors `PjrtBackend`'s graph
    /// choice: pure-decode / pure-chunk / mixed)
    pub decode_calls: usize,
    pub prefill_calls: usize,
    pub mixed_calls: usize,
    /// decode tokens advanced through *mixed* plans (one per decode lane
    /// per call) — exact accounting for the fused path
    pub mixed_decode_tokens: u64,
    /// prompt tokens advanced through *mixed* plans (sum of live `in_mask`
    /// positions on chunk lanes)
    pub mixed_chunk_tokens: u64,
    /// per lane: total tokens (decode + chunk) fed through mixed plans
    pub mixed_tokens_per_lane: Vec<u64>,
    /// retrieval re-injections applied ((layer, head) entries written)
    pub injected_entries: u64,
    /// Host twin of the per-lane device K/V arenas — written exactly where
    /// the real graphs would scatter, so the batched session-swap path is
    /// testable end-to-end with exact transfer accounting.
    pub arena: HostLaneArena,
    next_token: u64,
    pending: Option<(StepToken, StepOut, Instant)>,
}

impl MockBackend {
    pub fn new(b: usize, m: usize) -> MockBackend {
        let dims = ModelDims { vocab: 512, d: 128, layers: 4, hq: 4, hkv: 2,
                               dh: 32, ffn: 256, gate_hidden: 48 };
        let lane_len = dims.layers * dims.hkv * m * dims.dh;
        MockBackend {
            dims,
            b,
            m,
            c: 16,
            eos_after: usize::MAX,
            synthetic_execute_us: 0,
            decoded_per_lane: vec![0; b],
            decode_calls: 0,
            prefill_calls: 0,
            mixed_calls: 0,
            mixed_decode_tokens: 0,
            mixed_chunk_tokens: 0,
            mixed_tokens_per_lane: vec![0; b],
            injected_entries: 0,
            arena: HostLaneArena::new(b, lane_len),
            next_token: 0,
            pending: None,
        }
    }

    pub fn with_eos_after(mut self, n: usize) -> Self {
        self.eos_after = n;
        self
    }

    /// Builder for the synthetic device latency (see
    /// [`MockBackend::synthetic_execute_us`]).
    pub fn with_synthetic_latency_us(mut self, us: u64) -> Self {
        self.synthetic_execute_us = us;
        self
    }

    /// Deterministic per-token gate score in (0, 1): higher for sym tokens,
    /// low for word (filler) tokens — crude mirror of the trained gates.
    pub fn mock_log_beta(l: usize, hh: usize, token: i32) -> f32 {
        let t = token as u32;
        let hash = t
            .wrapping_mul(2654435761)
            .wrapping_add((l as u32) << 8)
            .wrapping_add(hh as u32)
            % 1000;
        let base = if (32..288).contains(&t) { 0.999 } else { 0.95 };
        let beta = base - (hash as f32) / 40_000.0;
        beta.ln()
    }

    /// Fake K/V element for head-dim position `d` of `(layer, head, token)`
    /// (+ chunk offset `ci` on the chunk path).  Deliberately independent
    /// of lane index and batch size.  Decode-op tokens use the 1-token
    /// chunk law `(ci=0, c=1)` in every dispatch, so a token's slab content
    /// never depends on how the scheduler batched it.
    fn mock_kv(li: usize, hh: usize, hkv: usize, ci: usize, c: usize,
               d: usize, dh: usize, token: i32) -> f32 {
        let j = (((li * hkv + hh) * c + ci) * dh) + d;
        ((j % 7) as f32) * 0.1 + token as f32 * 1e-3
    }

    /// One plan-execute step, mirroring `PjrtBackend`'s dispatch: a
    /// pure-decode plan returns compact cols=1 outputs (and honours
    /// `want_attn`/`want_kv` by leaving those tensors empty), any plan with
    /// chunk lanes returns the full cols=C tuple.  Per lane the numbers are
    /// exactly what the dedicated decode/prefill laws produce, so the
    /// engine's fused scheduling is token-equivalent to alternating ticks.
    /// Runs eagerly inside `submit`; `wait` just pays the synthetic latency.
    fn compute(&mut self, plan: &StepPlan) -> Result<StepOut> {
        let (l, b, h) = (self.dims.layers, self.b, self.dims.hkv);
        let (m, dh, v, c) = (self.m, self.dims.dh, self.dims.vocab, self.c);
        plan.validate(l, b, h, m, c, dh)?;
        let n_dec = plan.n_decode();
        let n_chunk = plan.n_chunk();
        let pure_decode = n_chunk == 0;
        let fused = n_dec > 0 && n_chunk > 0;
        let cols = if pure_decode { 1 } else { c };
        if pure_decode {
            self.decode_calls += 1;
        } else if n_dec == 0 {
            self.prefill_calls += 1;
        } else {
            self.mixed_calls += 1;
        }

        let mut logits = vec![0.0f32; b * cols * v];
        let mut log_beta = vec![0.0f32; l * b * h * cols];
        let mut attn_slots = vec![0.0f32; l * b * h * m];
        let attn_chunk = if pure_decode {
            Vec::new()
        } else {
            vec![1.0 / c as f32; l * b * h * cols]
        };
        let mut k_chunk = vec![0.0f32; l * b * h * cols * dh];

        for lane in 0..b {
            let op = plan.ops[lane];
            match op {
                LaneOp::Idle => continue,
                LaneOp::Decode | LaneOp::Inject { .. } => {
                    // column 0 is the lane's decode token; successor/EOS
                    // rule on the lane's own generation counter
                    let tok = plan.tokens[lane * c];
                    self.decoded_per_lane[lane] += 1;
                    if fused {
                        self.mixed_decode_tokens += 1;
                        self.mixed_tokens_per_lane[lane] += 1;
                    }
                    let next = if self.decoded_per_lane[lane] >= self.eos_after {
                        2 // EOS
                    } else {
                        ((tok + 1) as usize) % v
                    };
                    logits[lane * cols * v + next] = 10.0;
                    for li in 0..l {
                        for hh in 0..h {
                            let base = (li * b + lane) * h + hh;
                            let cb = base * cols;
                            log_beta[cb] = Self::mock_log_beta(li, hh, tok);
                            // attention: uniform over the lane's live slots
                            let row = &plan.valid[base * m..(base + 1) * m];
                            let live: f32 = row.iter().sum();
                            if live > 0.0 {
                                for s in 0..m {
                                    attn_slots[base * m + s] = row[s] / live;
                                }
                            }
                            for d in 0..dh {
                                k_chunk[cb * dh + d] =
                                    Self::mock_kv(li, hh, h, 0, 1, d, dh, tok);
                            }
                        }
                    }
                }
                LaneOp::Chunk { .. } => {
                    for li in 0..l {
                        for hh in 0..h {
                            let base = (li * b + lane) * h + hh;
                            for s in 0..m {
                                attn_slots[base * m + s] = 1.0 / m as f32;
                            }
                            for ci in 0..cols {
                                if plan.in_mask[lane * c + ci] <= 0.0 {
                                    continue;
                                }
                                let tok = plan.tokens[lane * c + ci];
                                let cb = base * cols + ci;
                                log_beta[cb] = Self::mock_log_beta(li, hh, tok);
                                for d in 0..dh {
                                    k_chunk[cb * dh + d] = Self::mock_kv(
                                        li, hh, h, ci, c, d, dh, tok);
                                }
                            }
                        }
                    }
                    for ci in 0..cols {
                        if plan.in_mask[lane * c + ci] <= 0.0 {
                            continue;
                        }
                        let tok = plan.tokens[lane * c + ci];
                        if fused {
                            self.mixed_chunk_tokens += 1;
                            self.mixed_tokens_per_lane[lane] += 1;
                        }
                        logits[(lane * cols + ci) * v + ((tok + 1) as usize) % v] =
                            10.0;
                    }
                }
            }
        }
        let v_chunk = k_chunk.clone();

        // scatter into the per-lane K/V arenas exactly as the real graphs
        // would: pending injects first, then the live chunk positions
        for lane in 0..b {
            let op = plan.ops[lane];
            if !op.is_active() {
                continue;
            }
            let mut injected = 0u64;
            let slab = self.arena.lane_mut(lane);
            for li in 0..l {
                for hh in 0..h {
                    let base = (li * b + lane) * h + hh;
                    let row = (li * h + hh) * m;
                    if op.is_decode() {
                        if let (Some(flag), Some(islot), Some(ik), Some(ivv)) =
                            (plan.inject_flag, plan.inject_slot,
                             plan.inject_k, plan.inject_v)
                        {
                            if flag[base] > 0.0 {
                                let s = islot[base] as usize;
                                ensure!(s < m, "inject slot {s} out of range");
                                let dst = (row + s) * dh;
                                slab.k[dst..dst + dh].copy_from_slice(
                                    &ik[base * dh..(base + 1) * dh]);
                                slab.v[dst..dst + dh].copy_from_slice(
                                    &ivv[base * dh..(base + 1) * dh]);
                                injected += 1;
                            }
                        }
                    }
                    for ci in 0..cols {
                        if plan.in_mask[lane * c + ci] <= 0.0 {
                            continue;
                        }
                        let s = plan.write_slots[base * c + ci] as usize;
                        ensure!(s < m, "write slot {s} out of range");
                        let dst = (row + s) * dh;
                        let src = (base * cols + ci) * dh;
                        slab.k[dst..dst + dh]
                            .copy_from_slice(&k_chunk[src..src + dh]);
                        slab.v[dst..dst + dh]
                            .copy_from_slice(&v_chunk[src..src + dh]);
                    }
                }
            }
            self.injected_entries += injected;
        }

        // PjrtBackend parity: a pure-decode dispatch only downloads what
        // the plan asked for — leave the rest empty so an engine that reads
        // un-requested tensors fails in tests, not just on hardware
        let (attn_slots, k_chunk, v_chunk) = if pure_decode {
            (
                if plan.want_attn { attn_slots } else { Vec::new() },
                if plan.want_kv { k_chunk } else { Vec::new() },
                if plan.want_kv { v_chunk } else { Vec::new() },
            )
        } else {
            (attn_slots, k_chunk, v_chunk)
        };
        Ok(StepOut { cols, logits, log_beta, attn_slots, attn_chunk, k_chunk,
                     v_chunk })
    }
}

impl ModelBackend for MockBackend {
    fn dims(&self) -> ModelDims {
        self.dims
    }
    fn batch(&self) -> usize {
        self.b
    }
    fn slots(&self) -> usize {
        self.m
    }
    fn chunk(&self) -> usize {
        self.c
    }

    /// All state mutations happen eagerly at submit — in-order device-queue
    /// semantics: work chained between `submit` and `wait` (e.g. a batched
    /// `swap_lanes`) observes the post-step arenas, exactly as it would
    /// against hardware with an in-order stream.
    fn submit(&mut self, plan: &StepPlan) -> Result<StepToken> {
        ensure!(self.pending.is_none(),
                "step already in flight (one submit per wait)");
        let out = self.compute(plan)?;
        let token = StepToken(self.next_token);
        self.next_token += 1;
        self.pending = Some((token, out, Instant::now()));
        Ok(token)
    }

    fn wait(&mut self, token: StepToken) -> Result<StepOut> {
        match &self.pending {
            Some((t, ..)) if *t == token => {}
            Some((t, ..)) => anyhow::bail!(
                "wait token mismatch: in flight {t:?}, got {token:?}"),
            None => anyhow::bail!("wait with no step in flight"),
        }
        let (_, out, submitted) = self.pending.take().expect("checked above");
        // the synthetic device "finishes" synthetic_execute_us after the
        // submit, regardless of what the host did in between
        let target = Duration::from_micros(self.synthetic_execute_us);
        let left = target.saturating_sub(submitted.elapsed());
        if !left.is_zero() {
            std::thread::sleep(left);
        }
        Ok(out)
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.decoded_per_lane = vec![0; self.b];
        self.arena.reset();
        Ok(())
    }

    fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>> {
        self.arena.swap_lanes(out, inn)
    }

    fn swap_traffic(&self) -> SwapTraffic {
        self.arena.traffic
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Owned buffers backing a hand-built StepPlan (test scaffolding).
    struct PlanBufs {
        ops: Vec<LaneOp>,
        tokens: Vec<i32>,
        pos: Vec<i32>,
        in_mask: Vec<f32>,
        valid: Vec<f32>,
        write_slots: Vec<i32>,
    }

    impl PlanBufs {
        fn new(mb: &MockBackend) -> PlanBufs {
            let (l, b, h) = (mb.dims.layers, mb.b, mb.dims.hkv);
            let (m, c) = (mb.m, mb.c);
            PlanBufs {
                ops: vec![LaneOp::Idle; b],
                tokens: vec![0; b * c],
                pos: vec![0; b * c],
                in_mask: vec![0.0; b * c],
                valid: vec![0.0; l * b * h * m],
                write_slots: vec![(m - 1) as i32; l * b * h * c],
            }
        }

        /// Mark `lane` as a decode op of `token` writing `slot` everywhere.
        fn decode_lane(&mut self, mb: &MockBackend, lane: usize, token: i32,
                       slot: usize) {
            let (l, b, h, c) = (mb.dims.layers, mb.b, mb.dims.hkv, mb.c);
            self.ops[lane] = LaneOp::Decode;
            self.tokens[lane * c] = token;
            self.in_mask[lane * c] = 1.0;
            for li in 0..l {
                for hh in 0..h {
                    self.write_slots[((li * b + lane) * h + hh) * c] = slot as i32;
                }
            }
        }

        fn plan(&self, want_attn: bool, want_kv: bool) -> StepPlan<'_> {
            StepPlan {
                ops: &self.ops,
                tokens: &self.tokens,
                pos: &self.pos,
                in_mask: &self.in_mask,
                valid: &self.valid,
                write_slots: &self.write_slots,
                inject_flag: None,
                inject_slot: None,
                inject_k: None,
                inject_v: None,
                want_attn,
                want_kv,
            }
        }
    }

    fn decode_write(mb: &mut MockBackend, tokens: &[i32], slots: &[usize]) {
        let mut bufs = PlanBufs::new(mb);
        for (lane, (&t, &s)) in tokens.iter().zip(slots).enumerate() {
            bufs.decode_lane(mb, lane, t, s);
        }
        mb.execute(&bufs.plan(false, true)).unwrap();
    }

    #[test]
    fn lane_op_classification() {
        assert!(LaneOp::Decode.is_decode());
        assert!(LaneOp::Inject { slots: 3 }.is_decode());
        assert!(!LaneOp::Chunk { tokens: 4 }.is_decode());
        assert!(LaneOp::Chunk { tokens: 4 }.is_chunk());
        assert!(!LaneOp::Idle.is_active());
        assert_eq!(LaneOp::Idle.cols(), 0);
        assert_eq!(LaneOp::Decode.cols(), 1);
        assert_eq!(LaneOp::Chunk { tokens: 5 }.cols(), 5);
    }

    #[test]
    fn plan_kind_follows_op_mix() {
        let mb = MockBackend::new(2, 8);
        let mut bufs = PlanBufs::new(&mb);
        assert_eq!(bufs.plan(false, false).kind(), PlanKind::Empty);
        bufs.ops[0] = LaneOp::Decode;
        assert_eq!(bufs.plan(false, false).kind(), PlanKind::Decode);
        bufs.ops[1] = LaneOp::Chunk { tokens: 3 };
        assert_eq!(bufs.plan(false, false).kind(), PlanKind::Mixed);
        bufs.ops[0] = LaneOp::Idle;
        assert_eq!(bufs.plan(false, false).kind(), PlanKind::Chunk);
        bufs.ops[0] = LaneOp::Inject { slots: 1 };
        assert!(bufs.plan(false, false).has_inject());
    }

    #[test]
    fn plan_validation_rejects_bad_shapes() {
        let mut mb = MockBackend::new(2, 8);
        let mut bufs = PlanBufs::new(&mb);
        bufs.ops[0] = LaneOp::Chunk { tokens: 99 }; // beyond chunk capacity
        assert!(mb.execute(&bufs.plan(false, false)).is_err());
        bufs.ops[0] = LaneOp::Decode;
        bufs.tokens.pop();
        assert!(mb.execute(&bufs.plan(false, false)).is_err());
    }

    #[test]
    fn mock_decode_plan_emits_successor_then_eos() {
        let mut mb = MockBackend::new(2, 8).with_eos_after(3);
        for step in 0..4 {
            let mut bufs = PlanBufs::new(&mb);
            bufs.decode_lane(&mb, 0, 10, 0);
            bufs.decode_lane(&mb, 1, 20, 0);
            let out = mb.execute(&bufs.plan(true, true)).unwrap();
            assert_eq!(out.cols, 1, "pure-decode dispatch is compact");
            let argmax = |lane: usize| {
                (0..512)
                    .max_by(|&a, &b| {
                        out.logits[lane * 512 + a]
                            .partial_cmp(&out.logits[lane * 512 + b])
                            .unwrap()
                    })
                    .unwrap()
            };
            if step < 2 {
                assert_eq!(argmax(0), 11);
                assert_eq!(argmax(1), 21);
            } else {
                assert_eq!(argmax(0), 2);
            }
        }
        assert_eq!(mb.decode_calls, 4);
        assert_eq!(mb.prefill_calls + mb.mixed_calls, 0);
    }

    #[test]
    fn mock_log_beta_prefers_syms() {
        let sym = MockBackend::mock_log_beta(0, 0, 40);
        let word = MockBackend::mock_log_beta(0, 0, 300);
        assert!(sym > word);
        assert!(sym < 0.0);
    }

    #[test]
    fn pure_decode_dispatch_honours_want_flags() {
        let mut mb = MockBackend::new(1, 8);
        let mut bufs = PlanBufs::new(&mb);
        bufs.decode_lane(&mb, 0, 10, 0);
        let out = mb.execute(&bufs.plan(false, false)).unwrap();
        assert!(out.attn_slots.is_empty() && out.k_chunk.is_empty(),
                "un-requested tensors must come back empty (PJRT parity)");
        assert!(out.attn_chunk.is_empty(), "decode dispatch has no chunk row");
        let out = mb.execute(&bufs.plan(true, true)).unwrap();
        assert!(!out.attn_slots.is_empty() && !out.k_chunk.is_empty());
    }

    #[test]
    fn mock_batched_lane_swap_roundtrip() {
        let mut mb = MockBackend::new(2, 8);
        // decode writes lane 0 into slot 1, lane 1 into slot 3
        decode_write(&mut mb, &[10, 77], &[1, 3]);
        let down = mb.swap_lanes(&[0, 1], &[]).unwrap();
        assert_eq!(down[0].k.len(), mb.lane_kv_len());
        assert_ne!(down[0].k, down[1].k,
                   "lanes with different tokens share a slab");
        // mixed call: lane 1 is downloaded *and* overwritten by lane 0's
        // slab — the preempt-and-restore-in-one-step case
        let prev = mb.swap_lanes(&[1], &[(1, &down[0])]).unwrap();
        assert_eq!(prev[0], down[1], "mixed swap must download before upload");
        let now = mb.swap_lanes(&[0, 1], &[]).unwrap();
        assert_eq!(now[1], down[0]);
        assert_eq!(now[0], down[0], "lane 0 clobbered by the lane-1 upload");
        // size/range validation
        let short = LaneKv { k: down[0].k[1..].to_vec(), v: down[0].v.clone() };
        assert!(mb.swap_lanes(&[], &[(1, &short)]).is_err());
        assert!(mb.swap_lanes(&[9], &[]).is_err());
    }

    #[test]
    fn swap_traffic_is_o_lane_not_o_batch() {
        // swapping 1 lane moves exactly 2 * lane_kv_len() elements no
        // matter how many lanes the batch has (the acceptance criterion)
        let mut per_batch = Vec::new();
        for b in [2usize, 4, 8] {
            let mut mb = MockBackend::new(b, 8);
            let down = mb.swap_lanes(&[0], &[]).unwrap();
            assert_eq!(down[0].k.len(), mb.lane_kv_len());
            let t = mb.swap_traffic();
            assert_eq!(t.elems_out as usize, 2 * mb.lane_kv_len());
            assert_eq!(t.lanes_out, 1);
            per_batch.push(t.elems_out);
        }
        assert!(per_batch.windows(2).all(|w| w[0] == w[1]),
                "swap traffic grew with batch size: {per_batch:?}");
    }

    #[test]
    fn mock_kv_content_is_lane_and_batch_invariant() {
        // the same token written to the same slot must produce an identical
        // slab through any lane of any batch size (cross-shape swap tests
        // rely on this)
        let mut a = MockBackend::new(1, 8);
        decode_write(&mut a, &[42], &[2]);
        let mut b = MockBackend::new(3, 8);
        decode_write(&mut b, &[7, 42, 9], &[2, 2, 2]);
        let la = a.swap_lanes(&[0], &[]).unwrap();
        let lb = b.swap_lanes(&[1], &[]).unwrap();
        assert_eq!(la[0], lb[0],
                   "lane content depends on lane index or batch size");
    }

    #[test]
    fn mixed_plan_matches_decode_and_chunk_dispatches() {
        // lane 0 decodes token 10 in chunk column 0; lane 1 prefills 3
        // tokens — the one mixed plan must reproduce each dedicated
        // dispatch exactly (logits, gate scores, attention, lane slabs)
        let (l, h, m) = (4usize, 2usize, 8usize);
        let mut mb = MockBackend::new(2, m);
        let c = mb.c;
        let (dh, v) = (mb.dims.dh, mb.dims.vocab);
        let mut bufs = PlanBufs::new(&mb);
        for li in 0..l {
            for hh in 0..h {
                let base = (li * 2) * h + hh; // lane 0 rows
                bufs.valid[base * m] = 1.0;
                bufs.valid[base * m + 1] = 1.0;
            }
        }
        bufs.ops[0] = LaneOp::Decode;
        bufs.ops[1] = LaneOp::Chunk { tokens: 3 };
        bufs.tokens[0] = 10;
        bufs.in_mask[0] = 1.0;
        for ci in 0..3 {
            bufs.tokens[c + ci] = 40 + ci as i32;
            bufs.in_mask[c + ci] = 1.0;
        }
        for li in 0..l {
            for hh in 0..h {
                bufs.write_slots[((li * 2) * h + hh) * c] = 2; // lane 0: slot 2
                for ci in 0..3 {
                    bufs.write_slots[((li * 2 + 1) * h + hh) * c + ci] = ci as i32;
                }
            }
        }
        let out = mb.execute(&bufs.plan(true, true)).unwrap();
        assert_eq!(out.cols, c);
        assert_eq!(mb.mixed_calls, 1);
        assert_eq!(mb.mixed_decode_tokens, 1);
        assert_eq!(mb.mixed_chunk_tokens, 3);
        assert_eq!(mb.mixed_tokens_per_lane, vec![1, 3]);

        // pure-decode reference for lane 0 (same valid rows, same slot)
        let mut dref = MockBackend::new(2, m);
        let mut dbufs = PlanBufs::new(&dref);
        dbufs.valid.copy_from_slice(&bufs.valid);
        dbufs.decode_lane(&dref, 0, 10, 2);
        let dout = dref.execute(&dbufs.plan(true, true)).unwrap();
        assert_eq!(dout.cols, 1);
        assert_eq!(out.logits[..v], dout.logits[..v], "decode-lane logits");
        for li in 0..l {
            for hh in 0..h {
                let base = (li * 2) * h + hh;
                assert_eq!(out.log_beta[base * c], dout.log_beta[base]);
                assert_eq!(out.attn_slots[base * m..(base + 1) * m],
                           dout.attn_slots[base * m..(base + 1) * m]);
                assert_eq!(out.k_chunk[base * c * dh..base * c * dh + dh],
                           dout.k_chunk[base * dh..(base + 1) * dh]);
            }
        }

        // pure-chunk reference for lane 1 (same fused buffers, lane 0 idle)
        let mut pref = MockBackend::new(2, m);
        let mut pbufs = PlanBufs::new(&pref);
        pbufs.valid.copy_from_slice(&bufs.valid);
        pbufs.ops[1] = LaneOp::Chunk { tokens: 3 };
        pbufs.tokens.copy_from_slice(&bufs.tokens);
        for ci in 0..3 {
            pbufs.in_mask[c + ci] = 1.0;
        }
        pbufs.write_slots.copy_from_slice(&bufs.write_slots);
        // neutralize lane 0's decode columns for the chunk-only run
        pbufs.in_mask[0] = 0.0;
        let pout = pref.execute(&pbufs.plan(true, true)).unwrap();
        assert_eq!(pref.prefill_calls, 1);
        for ci in 0..3 {
            let col = (c + ci) * v;
            assert_eq!(out.logits[col..col + v], pout.logits[col..col + v]);
        }
        for li in 0..l {
            for hh in 0..h {
                let base = (li * 2 + 1) * h + hh;
                for ci in 0..3 {
                    let cb = base * c + ci;
                    assert_eq!(out.log_beta[cb], pout.log_beta[cb]);
                    assert_eq!(out.attn_chunk[cb], pout.attn_chunk[cb]);
                    assert_eq!(out.k_chunk[cb * dh..(cb + 1) * dh],
                               pout.k_chunk[cb * dh..(cb + 1) * dh]);
                }
                assert_eq!(out.attn_slots[base * m..(base + 1) * m],
                           pout.attn_slots[base * m..(base + 1) * m]);
            }
        }
        // lane slabs: the fused write equals the dedicated dispatch writes
        let mixed_slabs = mb.swap_lanes(&[0, 1], &[]).unwrap();
        let d_slab = dref.swap_lanes(&[0], &[]).unwrap();
        let p_slab = pref.swap_lanes(&[1], &[]).unwrap();
        assert_eq!(mixed_slabs[0], d_slab[0], "decode-lane slab");
        assert_eq!(mixed_slabs[1], p_slab[0], "chunk-lane slab");
    }

    #[test]
    fn inject_op_scatters_before_the_write() {
        // a retrieval inject writes the mirrored K/V into its slot ahead of
        // the decode token's own write — and the counter accounts per-head
        let (l, h, m) = (4usize, 2usize, 8usize);
        let mut mb = MockBackend::new(1, m);
        let c = mb.c;
        let dh = mb.dims.dh;
        let mut bufs = PlanBufs::new(&mb);
        bufs.decode_lane(&mb, 0, 10, 2);
        bufs.ops[0] = LaneOp::Inject { slots: l * h };
        let inj_flag = vec![1.0f32; l * h];
        let inj_slot = vec![5i32; l * h];
        let inj_k = vec![7.25f32; l * h * dh];
        let inj_v = vec![-7.25f32; l * h * dh];
        let plan = StepPlan {
            inject_flag: Some(&inj_flag),
            inject_slot: Some(&inj_slot),
            inject_k: Some(&inj_k),
            inject_v: Some(&inj_v),
            ..bufs.plan(false, true)
        };
        mb.execute(&plan).unwrap();
        assert_eq!(mb.injected_entries, (l * h) as u64);
        let slab = mb.swap_lanes(&[0], &[]).unwrap().remove(0);
        for li in 0..l {
            for hh in 0..h {
                let row = (li * h + hh) * m;
                assert_eq!(slab.k[(row + 5) * dh], 7.25, "inject slot content");
                assert_eq!(slab.v[(row + 5) * dh], -7.25);
                assert_ne!(slab.k[(row + 2) * dh], 0.0, "decode write present");
            }
        }
    }

    #[test]
    fn submit_wait_enforces_one_in_flight_and_token_identity() {
        let mut mb = MockBackend::new(1, 8);
        let mut bufs = PlanBufs::new(&mb);
        bufs.decode_lane(&mb, 0, 10, 0);
        let plan = bufs.plan(false, false);
        let tok = mb.submit(&plan).unwrap();
        assert!(mb.submit(&plan).is_err(), "second submit while in flight");
        assert!(mb.wait(StepToken(tok.0 + 7)).is_err(),
                "foreign token accepted");
        let out = mb.wait(tok).unwrap();
        assert_eq!(out.cols, 1);
        assert!(mb.wait(tok).is_err(), "double wait accepted");
        // tokens are never reused across steps
        let tok2 = mb.submit(&plan).unwrap();
        assert_ne!(tok, tok2);
        mb.wait(tok2).unwrap();
    }

    #[test]
    fn synthetic_latency_is_paid_in_wait_net_of_host_work() {
        let mut mb = MockBackend::new(1, 8).with_synthetic_latency_us(40_000);
        let mut bufs = PlanBufs::new(&mb);
        bufs.decode_lane(&mb, 0, 10, 0);
        let plan = bufs.plan(false, false);
        // serial: the full latency lands on the submit+wait pair
        let t0 = Instant::now();
        let tok = mb.submit(&plan).unwrap();
        let submit_us = t0.elapsed().as_micros();
        mb.wait(tok).unwrap();
        assert!(t0.elapsed().as_micros() >= 40_000, "latency not paid");
        assert!(submit_us < 20_000, "submit blocked for {submit_us}us");
        // overlapped: host work between submit and wait is credited
        let tok = mb.submit(&plan).unwrap();
        std::thread::sleep(Duration::from_micros(45_000));
        let w0 = Instant::now();
        mb.wait(tok).unwrap();
        assert!(w0.elapsed().as_micros() < 20_000,
                "wait re-paid latency already covered by host work");
    }

    #[test]
    fn chained_swaps_between_submit_and_wait_see_post_step_state() {
        let mut mb = MockBackend::new(1, 8);
        let dh = mb.dims.dh;
        let mut bufs = PlanBufs::new(&mb);
        bufs.decode_lane(&mb, 0, 42, 3);
        let plan = bufs.plan(false, true);
        let tok = mb.submit(&plan).unwrap();
        // in-order queue semantics: a swap chained behind the in-flight
        // step downloads the slab that step wrote
        let slab = mb.swap_lanes(&[0], &[]).unwrap().remove(0);
        assert_ne!(slab.k[3 * dh], 0.0, "chained swap missed the step write");
        mb.wait(tok).unwrap();
    }

    #[test]
    fn mock_attention_is_uniform_over_live() {
        let mut mb = MockBackend::new(1, 4);
        let mut bufs = PlanBufs::new(&mb);
        bufs.decode_lane(&mb, 0, 1, 0);
        bufs.valid[0] = 1.0;
        bufs.valid[1] = 1.0;
        let out = mb.execute(&bufs.plan(true, true)).unwrap();
        assert_eq!(out.attn_slots[0], 0.5);
        assert_eq!(out.attn_slots[1], 0.5);
        assert_eq!(out.attn_slots[2], 0.0);
    }
}
