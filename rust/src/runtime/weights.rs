//! TKVW weight-blob reader (written by python/compile/model.py::save_weights_bin).
//!
//! Format (little-endian):
//!   magic "TKVW" | n:u32 | n x { name_len:u32, name, ndim:u32, dims:u32*,
//!                                 f32 data }

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

pub fn read_weights(path: &Path) -> anyhow::Result<BTreeMap<String, HostTensor>> {
    let data = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
    parse_weights(&data).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))
}

pub fn parse_weights(data: &[u8]) -> anyhow::Result<BTreeMap<String, HostTensor>> {
    let mut off = 0usize;
    let take = |off: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
        let s = data
            .get(*off..*off + n)
            .ok_or_else(|| anyhow::anyhow!("truncated at byte {off}"))?;
        *off += n;
        Ok(s)
    };
    let u32le = |off: &mut usize| -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(take(off, 4)?.try_into().unwrap()))
    };
    anyhow::ensure!(take(&mut off, 4)? == b"TKVW", "bad magic");
    let n = u32le(&mut off)? as usize;
    anyhow::ensure!(n < 100_000, "implausible tensor count {n}");
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = u32le(&mut off)? as usize;
        let name = String::from_utf8(take(&mut off, name_len)?.to_vec())
            .map_err(|_| anyhow::anyhow!("non-utf8 tensor name"))?;
        let ndim = u32le(&mut off)? as usize;
        anyhow::ensure!(ndim <= 8, "implausible rank {ndim}");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32le(&mut off)? as usize);
        }
        let count: usize = shape.iter().product();
        let bytes = take(&mut off, count * 4)?;
        let data_f32: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.insert(name, HostTensor { shape, data: data_f32 });
    }
    anyhow::ensure!(off == data.len(), "{} trailing bytes", data.len() - off);
    Ok(out)
}

#[cfg(test)]
pub fn write_weights(tensors: &[(&str, &HostTensor)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"TKVW");
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &x in &t.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let a = HostTensor { shape: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] };
        let b = HostTensor { shape: vec![], data: vec![7.0] };
        let blob = write_weights(&[("w.a", &a), ("b", &b)]);
        let back = parse_weights(&blob).unwrap();
        assert_eq!(back["w.a"], a);
        assert_eq!(back["b"].data, vec![7.0]);
    }

    #[test]
    fn rejects_corruption() {
        let a = HostTensor { shape: vec![4], data: vec![0.; 4] };
        let blob = write_weights(&[("x", &a)]);
        assert!(parse_weights(&blob[..blob.len() - 2]).is_err()); // truncated
        let mut bad = blob.clone();
        bad[0] = b'X';
        assert!(parse_weights(&bad).is_err()); // magic
        let mut extra = blob;
        extra.push(0);
        assert!(parse_weights(&extra).is_err()); // trailing
    }
}
