//! Runtime layer: PJRT client wiring, HLO artifact loading, weight blobs and
//! the ModelBackend abstraction the engine drives.

pub mod backend;
pub mod devcache;
pub mod golden;
pub mod weights;

pub use backend::{compile_hlo, LaneOp, MockBackend, ModelBackend,
                  PjrtBackend, PlanKind, StepOut, StepPlan, StepToken};
pub use devcache::{CacheShape, DeviceKvCache, HostLaneArena, LaneKv,
                   SwapTraffic};
pub use weights::{read_weights, HostTensor};
