//! Device cache residency: who owns the K/V slot arenas and how lane-sized
//! pieces of them cross the host/device boundary.
//!
//! The serving graphs attend over `[L, B, Hkv, M, dh]` K/V slot arenas.  A
//! session swap moves exactly one lane's `[L, Hkv, M, dh]` slice of them.
//! Artifacts take (and return) one kc/vc buffer *per batch lane*, so
//! [`DeviceKvCache`] holds B independent buffer pairs and a swap touches
//! only the buffers of the swapped lanes — O(lane), the cost model the
//! paper's memory-bounded serving story needs.  (The legacy monolithic
//! single-buffer residency and its staged host-shadow swap fallback were
//! removed at the end of their deprecation window; `gather_lane` /
//! `scatter_lane` survive as the flat-layout helpers the golden harness
//! uses to expand per-lane goldens.)
//!
//! [`HostLaneArena`] is the host-memory twin used by `MockBackend`: the same
//! per-lane layout and the same batched-swap semantics, plus exact transfer
//! accounting ([`SwapTraffic`]) so tests can assert the O(lane) property.

use anyhow::{ensure, Result};

/// One lane's K/V slabs on the host, flat `[L, Hkv, M, dh]` row-major.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LaneKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl LaneKv {
    pub fn zeros(lane_len: usize) -> LaneKv {
        LaneKv { k: vec![0.0; lane_len], v: vec![0.0; lane_len] }
    }

    /// Total f32 elements across both slabs.
    pub fn elems(&self) -> usize {
        self.k.len() + self.v.len()
    }

    pub fn host_bytes(&self) -> usize {
        self.elems() * std::mem::size_of::<f32>()
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty() && self.v.is_empty()
    }
}

/// Cumulative transfer accounting for swap operations.  `elems_*` count f32
/// elements that crossed the host/device boundary (both K and V), which is
/// what the O(lane) acceptance tests assert on: swapping one lane must move
/// `2 * lane_kv_len()` elements regardless of batch size.  `out_ns`/`in_ns`
/// accumulate per-direction wall time (nanoseconds — one lane slab can
/// transfer in well under a microsecond on the mock arena), so the swap
/// cost the pipelined engine hides is visible per direction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SwapTraffic {
    /// batched `swap_lanes` calls
    pub swap_calls: u64,
    /// lanes downloaded (device -> host)
    pub lanes_out: u64,
    /// lanes uploaded (host -> device)
    pub lanes_in: u64,
    /// f32 elements moved device -> host by swaps
    pub elems_out: u64,
    /// f32 elements moved host -> device by swaps
    pub elems_in: u64,
    /// wall time spent in the download phase of swap calls
    pub out_ns: u64,
    /// wall time spent in the upload phase of swap calls
    pub in_ns: u64,
}

/// Validate a batched swap request against lane count and slab sizes.
fn check_swap_args(batch: usize, lane_len: usize, out: &[usize],
                   inn: &[(usize, &LaneKv)]) -> Result<()> {
    for &lane in out {
        ensure!(lane < batch, "swap-out lane {lane} out of range (batch {batch})");
    }
    for (lane, kv) in inn {
        ensure!(*lane < batch, "swap-in lane {lane} out of range (batch {batch})");
        ensure!(kv.k.len() == lane_len && kv.v.len() == lane_len,
                "lane kv slab has {}+{} elems, expected {lane_len} each",
                kv.k.len(), kv.v.len());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Host arena (MockBackend storage)
// ---------------------------------------------------------------------------

/// Per-lane K/V arenas in host memory.  `MockBackend` writes its fake model
/// scatter directly into these; the engine's swap path exercises the exact
/// same batched semantics as the device residency manager.
#[derive(Debug, Clone)]
pub struct HostLaneArena {
    lanes: Vec<LaneKv>,
    lane_len: usize,
    pub traffic: SwapTraffic,
}

impl HostLaneArena {
    pub fn new(batch: usize, lane_len: usize) -> HostLaneArena {
        HostLaneArena {
            lanes: (0..batch).map(|_| LaneKv::zeros(lane_len)).collect(),
            lane_len,
            traffic: SwapTraffic::default(),
        }
    }

    pub fn batch(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane_len(&self) -> usize {
        self.lane_len
    }

    pub fn lane(&self, lane: usize) -> &LaneKv {
        &self.lanes[lane]
    }

    pub fn lane_mut(&mut self, lane: usize) -> &mut LaneKv {
        &mut self.lanes[lane]
    }

    /// Zero every lane (cache reset); transfer accounting is preserved.
    pub fn reset(&mut self) {
        for kv in &mut self.lanes {
            kv.k.iter_mut().for_each(|x| *x = 0.0);
            kv.v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Batched lane swap: download every `out` lane's current slabs (in
    /// order), then upload the `inn` slabs.  A lane may appear in both —
    /// its pre-swap content is downloaded before the upload overwrites it.
    pub fn swap_lanes(&mut self, out: &[usize], inn: &[(usize, &LaneKv)])
        -> Result<Vec<LaneKv>> {
        check_swap_args(self.batch(), self.lane_len, out, inn)?;
        let t0 = std::time::Instant::now();
        let downloaded: Vec<LaneKv> =
            out.iter().map(|&lane| self.lanes[lane].clone()).collect();
        let t1 = std::time::Instant::now();
        for (lane, kv) in inn {
            self.lanes[*lane] = (*kv).clone();
        }
        // per-direction wall time, attributed only when the direction did
        // work (an empty phase must not smear timer noise into its counter)
        if !out.is_empty() {
            self.traffic.out_ns += (t1 - t0).as_nanos() as u64;
        }
        if !inn.is_empty() {
            self.traffic.in_ns += t1.elapsed().as_nanos() as u64;
        }
        self.traffic.swap_calls += 1;
        self.traffic.lanes_out += out.len() as u64;
        self.traffic.lanes_in += inn.len() as u64;
        self.traffic.elems_out += (out.len() * 2 * self.lane_len) as u64;
        self.traffic.elems_in += (inn.len() * 2 * self.lane_len) as u64;
        Ok(downloaded)
    }
}

// ---------------------------------------------------------------------------
// Device residency manager (PjrtBackend storage)
// ---------------------------------------------------------------------------

/// Shape of the device cache.
#[derive(Debug, Clone, Copy)]
pub struct CacheShape {
    pub layers: usize,
    pub batch: usize,
    pub hkv: usize,
    pub slots: usize,
    pub dh: usize,
}

impl CacheShape {
    /// Elements in one lane's `[L, Hkv, M, dh]` slab.
    pub fn lane_len(&self) -> usize {
        self.layers * self.hkv * self.slots * self.dh
    }

    fn lane_dims(&self) -> [usize; 4] {
        [self.layers, self.hkv, self.slots, self.dh]
    }
}

/// Gather one lane's `[L, Hkv, M, dh]` rows out of a flat
/// `[L, B, Hkv, M, dh]` cache.
pub fn gather_lane(cache: &[f32], lane: usize, layers: usize, batch: usize,
                   stride: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(layers * stride);
    for li in 0..layers {
        let off = (li * batch + lane) * stride;
        out.extend_from_slice(&cache[off..off + stride]);
    }
    out
}

/// Scatter one lane's `[L, Hkv, M, dh]` rows back into a flat
/// `[L, B, Hkv, M, dh]` cache, leaving other lanes untouched.
pub fn scatter_lane(cache: &mut [f32], lane: usize, layers: usize,
                    batch: usize, stride: usize, src: &[f32]) {
    for li in 0..layers {
        let off = (li * batch + lane) * stride;
        cache[off..off + stride]
            .copy_from_slice(&src[li * stride..(li + 1) * stride]);
    }
}

/// Owner of the device-resident K/V arenas for `PjrtBackend`: one device
/// buffer pair per batch lane, each `[L, Hkv, M, dh]`.
pub struct DeviceKvCache {
    shape: CacheShape,
    kc: Vec<xla::PjRtBuffer>,
    vc: Vec<xla::PjRtBuffer>,
    pub traffic: SwapTraffic,
}

fn to_host(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
}

impl DeviceKvCache {
    /// Allocate zeroed per-lane device arenas.
    pub fn new_zeroed(client: &xla::PjRtClient, shape: CacheShape)
        -> Result<DeviceKvCache> {
        let zeros = vec![0.0f32; shape.lane_len()];
        let dims = shape.lane_dims();
        let mut kc = Vec::with_capacity(shape.batch);
        let mut vc = Vec::with_capacity(shape.batch);
        for _ in 0..shape.batch {
            kc.push(client.buffer_from_host_buffer(&zeros, &dims, None)?);
            vc.push(client.buffer_from_host_buffer(&zeros, &dims, None)?);
        }
        Ok(DeviceKvCache { shape, kc, vc, traffic: SwapTraffic::default() })
    }

    pub fn shape(&self) -> CacheShape {
        self.shape
    }

    /// Number of cache operands the graph takes (and returns): 2 per lane.
    pub fn num_operands(&self) -> usize {
        2 * self.shape.batch
    }

    /// Cache operands in graph order: all kc buffers, then all vc buffers.
    pub fn arg_refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.kc.iter().chain(self.vc.iter()).collect()
    }

    /// Adopt the updated cache buffers a graph execution returned (same
    /// order as `arg_refs`, length `num_operands`).
    pub fn update_from_outputs(&mut self, bufs: Vec<xla::PjRtBuffer>)
        -> Result<()> {
        ensure!(bufs.len() == self.num_operands(),
                "graph returned {} cache buffers, expected {}", bufs.len(),
                self.num_operands());
        let mut it = bufs.into_iter();
        for buf in self.kc.iter_mut() {
            *buf = it.next().expect("length checked");
        }
        for buf in self.vc.iter_mut() {
            *buf = it.next().expect("length checked");
        }
        Ok(())
    }

    /// Re-zero the arenas (new evaluation run).
    pub fn reset(&mut self, client: &xla::PjRtClient) -> Result<()> {
        let traffic = self.traffic;
        *self = DeviceKvCache::new_zeroed(client, self.shape)?;
        self.traffic = traffic;
        Ok(())
    }

    /// Batched lane swap (session preempt/restore).  Downloads every `out`
    /// lane first, then uploads the `inn` slabs, touching only the swapped
    /// lanes' buffers: O(lane) per lane moved.
    pub fn swap_lanes(&mut self, client: &xla::PjRtClient, out: &[usize],
                      inn: &[(usize, &LaneKv)]) -> Result<Vec<LaneKv>> {
        let shape = self.shape;
        check_swap_args(shape.batch, shape.lane_len(), out, inn)?;
        self.traffic.swap_calls += 1;
        self.traffic.lanes_out += out.len() as u64;
        self.traffic.lanes_in += inn.len() as u64;
        let t0 = std::time::Instant::now();
        let mut downloaded = Vec::with_capacity(out.len());
        for &lane in out {
            let kv = LaneKv { k: to_host(&self.kc[lane])?,
                              v: to_host(&self.vc[lane])? };
            self.traffic.elems_out += kv.elems() as u64;
            downloaded.push(kv);
        }
        if !out.is_empty() {
            self.traffic.out_ns += t0.elapsed().as_nanos() as u64;
        }
        // stage every upload before installing any: a mid-call allocation
        // failure must leave the device cache exactly as it was (the engine
        // keeps sessions parked on error)
        let dims = shape.lane_dims();
        let t0 = std::time::Instant::now();
        let mut staged = Vec::with_capacity(inn.len());
        for (lane, kv) in inn {
            staged.push((
                *lane,
                client.buffer_from_host_buffer(&kv.k, &dims, None)?,
                client.buffer_from_host_buffer(&kv.v, &dims, None)?,
                kv.elems() as u64,
            ));
        }
        for (lane, k_buf, v_buf, elems) in staged {
            self.kc[lane] = k_buf;
            self.vc[lane] = v_buf;
            self.traffic.elems_in += elems;
        }
        if !inn.is_empty() {
            self.traffic.in_ns += t0.elapsed().as_nanos() as u64;
        }
        Ok(downloaded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(arena: &mut HostLaneArena, lane: usize, tag: f32) {
        let kv = arena.lane_mut(lane);
        kv.k.iter_mut().enumerate().for_each(|(i, x)| *x = tag + i as f32);
        kv.v.iter_mut().enumerate().for_each(|(i, x)| *x = -tag - i as f32);
    }

    #[test]
    fn arena_swap_roundtrip_and_traffic() {
        let mut a = HostLaneArena::new(3, 8);
        fill(&mut a, 0, 100.0);
        fill(&mut a, 1, 200.0);
        fill(&mut a, 2, 300.0);
        let lane1 = a.lane(1).clone();
        // download lanes 0 and 2 in one call
        let down = a.swap_lanes(&[0, 2], &[]).unwrap();
        assert_eq!(down.len(), 2);
        assert_eq!(down[0].k[0], 100.0);
        assert_eq!(down[1].k[0], 300.0);
        assert_eq!(a.traffic.swap_calls, 1);
        assert_eq!(a.traffic.lanes_out, 2);
        assert_eq!(a.traffic.elems_out, 2 * 2 * 8);
        assert_eq!(a.traffic.elems_in, 0);
        // cross-upload: lane 0 gets lane 2's old content and vice versa
        let back = a
            .swap_lanes(&[], &[(0, &down[1]), (2, &down[0])])
            .unwrap();
        assert!(back.is_empty());
        assert_eq!(a.lane(0).k[0], 300.0);
        assert_eq!(a.lane(2).k[0], 100.0);
        assert_eq!(a.lane(1), &lane1, "untouched lane changed");
        assert_eq!(a.traffic.elems_in, 2 * 2 * 8);
    }

    #[test]
    fn swap_wall_time_is_attributed_per_direction() {
        let mut a = HostLaneArena::new(2, 4096);
        fill(&mut a, 0, 1.0);
        // out-only call: download time accrues, upload time must not
        let down = a.swap_lanes(&[0], &[]).unwrap();
        assert!(a.traffic.out_ns > 0, "download wall time not recorded");
        assert_eq!(a.traffic.in_ns, 0,
                   "upload time accrued on an out-only swap");
        // in-only call: only the upload counter moves
        let out_before = a.traffic.out_ns;
        a.swap_lanes(&[], &[(1, &down[0])]).unwrap();
        assert!(a.traffic.in_ns > 0, "upload wall time not recorded");
        assert_eq!(a.traffic.out_ns, out_before,
                   "download time accrued on an in-only swap");
    }

    #[test]
    fn arena_mixed_swap_downloads_before_upload() {
        let mut a = HostLaneArena::new(2, 4);
        fill(&mut a, 0, 10.0);
        let incoming = LaneKv { k: vec![7.0; 4], v: vec![8.0; 4] };
        // lane 0 appears in both: must get its *old* content back
        let down = a.swap_lanes(&[0], &[(0, &incoming)]).unwrap();
        assert_eq!(down[0].k[0], 10.0);
        assert_eq!(a.lane(0).k, vec![7.0; 4]);
    }

    #[test]
    fn arena_rejects_bad_args() {
        let mut a = HostLaneArena::new(2, 4);
        assert!(a.swap_lanes(&[5], &[]).is_err());
        let short = LaneKv { k: vec![0.0; 3], v: vec![0.0; 4] };
        assert!(a.swap_lanes(&[], &[(0, &short)]).is_err());
        let ok = LaneKv::zeros(4);
        assert!(a.swap_lanes(&[], &[(5, &ok)]).is_err());
    }

    #[test]
    fn gather_scatter_are_inverse() {
        let (l, b, stride) = (2usize, 3usize, 4usize);
        let cache: Vec<f32> = (0..l * b * stride).map(|i| i as f32).collect();
        for lane in 0..b {
            let slab = gather_lane(&cache, lane, l, b, stride);
            assert_eq!(slab.len(), l * stride);
            let mut copy = vec![0.0; cache.len()];
            scatter_lane(&mut copy, lane, l, b, stride, &slab);
            let back = gather_lane(&copy, lane, l, b, stride);
            assert_eq!(back, slab);
        }
    }

    #[test]
    fn lane_kv_sizes() {
        let kv = LaneKv::zeros(10);
        assert_eq!(kv.elems(), 20);
        assert_eq!(kv.host_bytes(), 80);
        assert!(!kv.is_empty());
        assert!(LaneKv::default().is_empty());
    }
}
