//! Golden-I/O verification: execute the exported HLO graphs on the exact
//! inputs python ran through `model.decode_fn` / `model.prefill_fn` at
//! export time, and compare every output tensor elementwise.  This is the
//! cross-language numerical contract for the whole AOT path.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::model_meta::ModelMeta;
use crate::runtime::devcache::gather_lane;
use crate::runtime::weights::{read_weights, HostTensor};

const DECODE_OUTS: &[&str] = &["logits", "kc", "vc", "valid", "log_beta",
                               "attn", "k_new", "v_new"];
const PREFILL_OUTS: &[&str] = &["logits", "kc", "vc", "valid", "log_beta",
                                "attn_slots", "attn_chunk", "k_chunk",
                                "v_chunk"];
/// the mixed graph returns the prefill tuple (attn_slots mode-fused)
const MIXED_OUTS: &[&str] = PREFILL_OUTS;
const DECODE_INS: &[&str] = &["token", "pos", "kc", "vc", "valid",
                              "write_slot", "inject_flag", "inject_slot",
                              "inject_k", "inject_v"];
const PREFILL_INS: &[&str] = &["tokens", "pos", "in_mask", "kc", "vc",
                               "valid", "write_slots"];
/// unified step-plan mixed operand order: the prefill operands plus `mode`
/// and the decode graph's inject tail, so retrieval fuses like every other
/// policy
const MIXED_INS: &[&str] = &["tokens", "pos", "in_mask", "mode", "kc", "vc",
                             "valid", "write_slots", "inject_flag",
                             "inject_slot", "inject_k", "inject_v"];
/// inputs that the graphs expect as i32 (goldens store everything as f32)
const I32_INPUTS: &[&str] = &["token", "tokens", "pos", "write_slot",
                              "inject_slot", "write_slots"];

pub fn run_goldens(dir: &Path) -> Result<String> {
    let meta = ModelMeta::load(dir)?;
    let client = xla::PjRtClient::cpu()?;
    let weights = read_weights(&dir.join("weights.bin"))?;
    let gates = read_weights(&dir.join("gates_default.bin"))?;

    let mut report = String::new();
    let mut kinds = vec![
        ("decode", DECODE_INS, DECODE_OUTS, "golden_decode.bin"),
        ("prefill", PREFILL_INS, PREFILL_OUTS, "golden_prefill.bin"),
    ];
    match meta.pick("mixed", 8, 256, "mlp") {
        Some(mx) if dir.join("golden_mixed.bin").is_file() => {
            anyhow::ensure!(mx.has_inject(),
                            "mixed artifact {} lacks inject operands; \
                             re-export with python -m compile.aot", mx.file);
            kinds.push(("mixed", MIXED_INS, MIXED_OUTS, "golden_mixed.bin"));
        }
        _ => report.push_str("mixed    skipped (legacy export: no mixed \
                              graph or golden)\n"),
    }
    for (kind, ins, outs, golden_file) in kinds {
        let golden = read_weights(&dir.join(golden_file))?;
        // goldens were exported at (b=8, m=256)
        let spec = meta
            .pick(kind, 8, 256, "mlp")
            .with_context(|| format!("no {kind} artifact at (8, >=256)"))?;
        anyhow::ensure!(spec.m == 256, "golden expects m=256, found {}", spec.m);
        let exe = super::compile_hlo(&client, &meta.dir.join(&spec.file))?;
        // goldens store caches monolithically ([L,B,H,M,dh]); the per-lane
        // artifacts take and return one [L,H,M,dh] slab per batch lane
        let dims = meta.dims;
        let stride = dims.hkv * spec.m * dims.dh;
        let lane_shape = [dims.layers, dims.hkv, spec.m, dims.dh];

        let mut args: Vec<xla::PjRtBuffer> = Vec::new();
        for p in &meta.param_order {
            args.push(upload(&client, &weights[&p.name], false)?);
        }
        for g in &meta.gate_order {
            args.push(upload(&client, &gates[&g.name], false)?);
        }
        for name in ins {
            let t = golden
                .get(&format!("in.{name}"))
                .with_context(|| format!("golden missing in.{name}"))?;
            if *name == "kc" || *name == "vc" {
                for lane in 0..spec.b {
                    let slab = gather_lane(&t.data, lane, dims.layers,
                                           spec.b, stride);
                    args.push(client.buffer_from_host_buffer(
                        &slab, &lane_shape, None)?);
                }
            } else {
                args.push(upload(&client, t, I32_INPUTS.contains(name))?);
            }
        }
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        let mut results = exe.execute_b(&arg_refs)?;
        let results = results.swap_remove(0);

        // expected output tensors, with per-lane caches expanded to match
        let mut expected: Vec<(String, Vec<f32>)> = Vec::new();
        for name in outs {
            let want = golden
                .get(&format!("out.{name}"))
                .with_context(|| format!("golden missing out.{name}"))?;
            if *name == "kc" || *name == "vc" {
                for lane in 0..spec.b {
                    expected.push((
                        format!("{name}[{lane}]"),
                        gather_lane(&want.data, lane, dims.layers, spec.b,
                                    stride),
                    ));
                }
            } else {
                expected.push((name.to_string(), want.data.clone()));
            }
        }
        anyhow::ensure!(results.len() == expected.len(),
                        "{kind}: {} outputs, expected {}", results.len(),
                        expected.len());
        for (buf, (name, want)) in results.iter().zip(&expected) {
            let got = buf.to_literal_sync()?.to_vec::<f32>()?;
            let max_err = max_abs_err(&got, want);
            let tol = 2e-3; // logit-scale f32 accumulation across the stack
            writeln!(report, "{kind:8} {name:12} n={:8} max|err|={max_err:.2e} {}",
                     got.len(), if max_err < tol { "OK" } else { "FAIL" })?;
            anyhow::ensure!(max_err < tol,
                            "{kind} output {name} diverges: {max_err}");
        }
    }
    report.push_str("golden selftest: ALL OK\n");
    Ok(report)
}

/// Artifact-contract verification that runs WITHOUT a PJRT runtime (the
/// vendored xla stub cannot execute HLO): meta.json parses, every listed
/// artifact file exists and is non-empty, each artifact's declared
/// `runtime_inputs` follow the canonical `StepPlan` operand order of its
/// kind, weight/gate/vocab blobs are present, the golden I/O blobs carry
/// every tensor of each kind's contract with dimension-consistent element
/// counts, and the mixed-tick capability is self-consistent (mixed
/// artifact <-> mixed golden + output order + inject operands).  CI
/// replays the python job's freshly exported artifact through this check;
/// the numerical replay (`run_goldens`) runs wherever the real xla
/// bindings are linked.
pub fn verify_structural(dir: &Path) -> Result<String> {
    let meta = ModelMeta::load(dir)?;
    let d = meta.dims;
    let mut report = String::new();
    for a in &meta.artifacts {
        let p = meta.dir.join(&a.file);
        anyhow::ensure!(p.is_file(), "artifact file missing: {p:?}");
        let bytes = std::fs::metadata(&p)?.len();
        anyhow::ensure!(bytes > 0, "artifact file empty: {p:?}");
        verify_operand_order(a)?;
        writeln!(report, "artifact {:32} {:8} b={} m={} layout={} {:6} KiB",
                 a.file, a.kind, a.b, a.m, a.cache_layout, bytes / 1024)?;
    }
    for f in ["weights.bin", "vocab.json"] {
        anyhow::ensure!(dir.join(f).is_file(), "missing {f}");
    }
    for v in &meta.gate_variants {
        let f = format!("gates_{v}.bin");
        anyhow::ensure!(dir.join(&f).is_file(), "missing {f}");
    }
    // goldens were exported at (b=8, m=256); validate tensor inventories
    // and the layout-bearing element counts against the model dims
    let (b, m, c) = (8usize, 256usize, meta.chunk);
    let cache_len = d.layers * b * d.hkv * m * d.dh;
    let lbh = d.layers * b * d.hkv;
    let mut kinds: Vec<(&str, &[&str], &[&str], &str)> = vec![
        ("decode", DECODE_INS, DECODE_OUTS, "golden_decode.bin"),
        ("prefill", PREFILL_INS, PREFILL_OUTS, "golden_prefill.bin"),
    ];
    let has_mixed = meta.supports_mixed(b, m, "mlp");
    if has_mixed {
        anyhow::ensure!(!meta.mixed_outputs.is_empty(),
                        "mixed artifact without mixed_outputs in meta.json");
        anyhow::ensure!(dir.join("golden_mixed.bin").is_file(),
                        "mixed artifact without golden_mixed.bin");
        let inject = meta
            .pick("mixed", b, m, "mlp")
            .map(|a| a.has_inject())
            .unwrap_or(false);
        anyhow::ensure!(inject,
                        "mixed artifact lacks inject operands; re-export \
                         with python -m compile.aot");
        kinds.push(("mixed", MIXED_INS, MIXED_OUTS, "golden_mixed.bin"));
    }
    for (kind, ins, outs, golden_file) in kinds {
        let golden = read_weights(&dir.join(golden_file))?;
        for name in ins {
            let t = golden
                .get(&format!("in.{name}"))
                .with_context(|| format!("{golden_file} missing in.{name}"))?;
            let want = match *name {
                "kc" | "vc" => Some(cache_len),
                "valid" => Some(cache_len / d.dh),
                "mode" => Some(b),
                "tokens" | "in_mask" => Some(b * c),
                "token" => Some(b),
                "inject_flag" | "inject_slot" => Some(lbh),
                "inject_k" | "inject_v" => Some(lbh * d.dh),
                _ => None,
            };
            if let Some(want) = want {
                anyhow::ensure!(t.data.len() == want,
                                "{golden_file} in.{name}: {} elements, \
                                 expected {want}", t.data.len());
            }
        }
        for name in outs {
            let t = golden
                .get(&format!("out.{name}"))
                .with_context(|| format!("{golden_file} missing out.{name}"))?;
            let want = match *name {
                "kc" | "vc" => Some(cache_len),
                "valid" => Some(cache_len / d.dh),
                "attn" | "attn_slots" => Some(d.layers * b * d.hkv * m),
                "attn_chunk" => Some(d.layers * b * d.hkv * c),
                "logits" if kind == "decode" => Some(b * d.vocab),
                "logits" => Some(b * c * d.vocab),
                _ => None,
            };
            if let Some(want) = want {
                anyhow::ensure!(t.data.len() == want,
                                "{golden_file} out.{name}: {} elements, \
                                 expected {want}", t.data.len());
            }
        }
        writeln!(report, "golden   {golden_file:32} {kind:8} \
                          {} in / {} out tensors OK", ins.len(), outs.len())?;
    }
    writeln!(report, "mixed-step capability: {}",
             if has_mixed {
                 "present (inject-capable)"
             } else {
                 "absent (legacy export: mixed plans degrade to per-kind \
                  graph calls)"
             })?;
    report.push_str("structural selftest: ALL OK\n");
    Ok(report)
}

/// Check an artifact's declared `runtime_inputs` against the canonical
/// `StepPlan` operand order of its kind: the leading operands and the
/// post-cache tail must match exactly (the B per-lane kc/vc buffers sit in
/// between).  Artifacts exported before the field record nothing and pass
/// vacuously.
fn verify_operand_order(a: &crate::model_meta::ArtifactSpec) -> Result<()> {
    if a.runtime_inputs.is_empty() {
        return Ok(());
    }
    let (lead, tail): (&[&str], &[&str]) = match a.kind.as_str() {
        "decode" => (&["token", "pos"],
                     &["valid", "write_slot", "inject_flag", "inject_slot",
                       "inject_k", "inject_v"]),
        "prefill" => (&["tokens", "pos", "in_mask"],
                      &["valid", "write_slots"]),
        "mixed" => (&["tokens", "pos", "in_mask", "mode"],
                    &["valid", "write_slots", "inject_flag", "inject_slot",
                      "inject_k", "inject_v"]),
        other => anyhow::bail!("unknown artifact kind `{other}`"),
    };
    let ri = &a.runtime_inputs;
    anyhow::ensure!(ri.len() > lead.len() + tail.len(),
                    "{}: runtime_inputs too short for its kind", a.file);
    for (i, want) in lead.iter().enumerate() {
        anyhow::ensure!(ri[i] == *want,
                        "{}: operand {i} is `{}`, step-plan contract wants \
                         `{want}`", a.file, ri[i]);
    }
    for (i, want) in tail.iter().rev().enumerate() {
        let got = &ri[ri.len() - 1 - i];
        anyhow::ensure!(got == want,
                        "{}: tail operand `{got}` where the step-plan \
                         contract wants `{want}`", a.file);
    }
    // everything between lead and tail must be cache operands
    let ncache = ri.len() - lead.len() - tail.len();
    let want_cache = 2 * a.b;
    anyhow::ensure!(ncache == want_cache,
                    "{}: {ncache} cache operands, layout {} wants \
                     {want_cache}", a.file, a.cache_layout);
    for name in &ri[lead.len()..lead.len() + ncache] {
        anyhow::ensure!(name.starts_with("kc") || name.starts_with("vc"),
                        "{}: `{name}` in the cache operand span", a.file);
    }
    Ok(())
}

fn upload(client: &xla::PjRtClient, t: &HostTensor,
          as_i32: bool) -> Result<xla::PjRtBuffer> {
    if as_i32 {
        let ints: Vec<i32> = t.data.iter().map(|&x| x as i32).collect();
        Ok(client.buffer_from_host_buffer(&ints, &t.shape, None)?)
    } else {
        Ok(client.buffer_from_host_buffer(&t.data, &t.shape, None)?)
    }
}

fn max_abs_err(a: &[f32], b: &[f32]) -> f32 {
    if a.len() != b.len() {
        return f32::INFINITY;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Sanity for the helpers; the full golden run needs artifacts and lives in
/// rust/tests/golden.rs.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_err_basics() {
        assert_eq!(max_abs_err(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_err(&[1.0], &[1.0, 2.0]), f32::INFINITY);
    }

    #[test]
    fn operand_order_check_enforces_step_plan_contract() {
        let meta = crate::model_meta::test_meta();
        // the inject-capable mixed artifact passes as declared
        let mixed = meta.pick("mixed", 8, 100, "mlp").unwrap();
        verify_operand_order(mixed).unwrap();
        // undeclared runtime_inputs pass vacuously (pre-field exports)
        let decode = meta.pick("decode", 8, 100, "mlp").unwrap();
        verify_operand_order(decode).unwrap();
        // a shuffled tail violates the contract
        let mut bad = mixed.clone();
        let n = bad.runtime_inputs.len();
        bad.runtime_inputs.swap(n - 1, n - 2);
        assert!(verify_operand_order(&bad).is_err());
        // dropping a cache operand breaks the layout arity
        let mut short = mixed.clone();
        short.runtime_inputs.remove(4);
        assert!(verify_operand_order(&short).is_err());
    }
}
