//! Vocabulary layout shared with the python build path (artifacts/vocab.json).
//!
//! The synthetic vocabulary is structured: control tokens give the task
//! grammar, "symbol" tokens carry content (keys/values/tags), "word" tokens
//! are filler, digits encode numbers.  The rust workload generators and the
//! tokenizer are derived entirely from this layout, which keeps them
//! compatible with the corpus the gates were trained on.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// Control-token ids (must mirror python/compile/vocab.py).
#[derive(Debug, Clone)]
pub struct Vocab {
    pub size: usize,
    pub control: BTreeMap<String, u32>,
    pub sym_base: u32,
    pub num_syms: u32,
    pub word_base: u32,
    pub num_words: u32,
    pub digit_base: u32,
    pub num_digits: u32,
}

macro_rules! control_getters {
    ($($fn_name:ident => $key:literal),+ $(,)?) => {
        $(pub fn $fn_name(&self) -> u32 {
            self.control[$key]
        })+
    };
}

impl Vocab {
    pub fn load(path: &Path) -> anyhow::Result<Vocab> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Vocab> {
        let control = j
            .get("control")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("vocab.json: missing control map"))?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_usize().unwrap_or(0) as u32))
            .collect();
        Ok(Vocab {
            size: j.usize_field("vocab_size")?,
            control,
            sym_base: j.usize_field("sym_base")? as u32,
            num_syms: j.usize_field("num_syms")? as u32,
            word_base: j.usize_field("word_base")? as u32,
            num_words: j.usize_field("num_words")? as u32,
            digit_base: j.usize_field("digit_base")? as u32,
            num_digits: j.usize_field("num_digits")? as u32,
        })
    }

    /// Built-in layout mirroring python/compile/vocab.py — used by tests and
    /// as a fallback when artifacts are absent (MockBackend runs).
    pub fn builtin() -> Vocab {
        let names = [
            "<pad>", "<bos>", "<eos>", "<sep>", "<query>", "<ans>", "<key>",
            "<val>", "<think>", "<row>", "<exec>", "<session>", "<user>",
            "<assistant>", "<q>", "<update>", "<shot>", "<label>",
            "<find_min>", "<find_max>", "<choice>", "<correct>", "<niah>",
            "<sum>", "<count>", "<target>", "<plus>", "<minus>", "<times>",
            "<equals>", "<hop>", "</think>",
        ];
        let control =
            names.iter().enumerate().map(|(i, n)| (n.to_string(), i as u32)).collect();
        Vocab {
            size: 512,
            control,
            sym_base: 32,
            num_syms: 256,
            word_base: 288,
            num_words: 192,
            digit_base: 480,
            num_digits: 10,
        }
    }

    control_getters! {
        pad => "<pad>", bos => "<bos>", eos => "<eos>", sep => "<sep>",
        query => "<query>", ans => "<ans>", key => "<key>", val => "<val>",
        think => "<think>", row => "<row>", exec_tok => "<exec>",
        session => "<session>", user => "<user>", assistant => "<assistant>",
        update => "<update>", shot => "<shot>", label => "<label>",
        find_min => "<find_min>", find_max => "<find_max>", niah => "<niah>",
        count => "<count>", target => "<target>", plus => "<plus>",
        minus => "<minus>", equals => "<equals>", hop => "<hop>",
        end_think => "</think>",
    }

    pub fn sym(&self, i: u32) -> u32 {
        debug_assert!(i < self.num_syms);
        self.sym_base + i
    }
    pub fn word(&self, i: u32) -> u32 {
        debug_assert!(i < self.num_words);
        self.word_base + i
    }
    pub fn digit(&self, i: u32) -> u32 {
        debug_assert!(i < self.num_digits);
        self.digit_base + i
    }
    pub fn digit_value(&self, tok: u32) -> Option<u32> {
        (tok >= self.digit_base && tok < self.digit_base + self.num_digits)
            .then(|| tok - self.digit_base)
    }
    pub fn is_sym(&self, tok: u32) -> bool {
        tok >= self.sym_base && tok < self.sym_base + self.num_syms
    }

    /// Human-readable token name (Fig 5/13-19 dumps).
    pub fn name(&self, tok: u32) -> String {
        for (n, &id) in &self.control {
            if id == tok {
                return n.clone();
            }
        }
        if self.is_sym(tok) {
            format!("s{}", tok - self.sym_base)
        } else if tok >= self.word_base && tok < self.word_base + self.num_words {
            format!("w{}", tok - self.word_base)
        } else if let Some(d) = self.digit_value(tok) {
            format!("{d}")
        } else {
            format!("<aux{tok}>")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_layout_is_consistent() {
        let v = Vocab::builtin();
        assert_eq!(v.sym_base + v.num_syms, v.word_base);
        assert_eq!(v.word_base + v.num_words, v.digit_base);
        assert_eq!(v.bos(), 1);
        assert_eq!(v.eos(), 2);
        assert_eq!(v.query(), 4);
        assert_eq!(v.name(1), "<bos>");
        assert_eq!(v.name(v.sym(3)), "s3");
        assert_eq!(v.name(v.digit(7)), "7");
        assert_eq!(v.digit_value(v.digit(7)), Some(7));
        assert_eq!(v.digit_value(v.sym(0)), None);
    }

    #[test]
    fn from_json_roundtrip() {
        let src = r#"{
            "vocab_size": 512,
            "control": {"<bos>": 1, "<eos>": 2, "<pad>": 0, "<sep>": 3,
                        "<query>": 4, "<ans>": 5, "<key>": 6, "<val>": 7,
                        "<think>": 8, "<row>": 9, "<exec>": 10, "<session>": 11,
                        "<user>": 12, "<assistant>": 13, "<q>": 14,
                        "<update>": 15, "<shot>": 16, "<label>": 17,
                        "<find_min>": 18, "<find_max>": 19, "<choice>": 20,
                        "<correct>": 21, "<niah>": 22, "<sum>": 23,
                        "<count>": 24, "<target>": 25, "<plus>": 26,
                        "<minus>": 27, "<times>": 28, "<equals>": 29,
                        "<hop>": 30, "</think>": 31},
            "sym_base": 32, "num_syms": 256,
            "word_base": 288, "num_words": 192,
            "digit_base": 480, "num_digits": 10
        }"#;
        let v = Vocab::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(v.size, 512);
        assert_eq!(v.bos(), 1);
        assert_eq!(v.sym(0), 32);
    }
}
