//! Streaming statistics and latency histograms for metrics + bench harness.

/// Welford online mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Half-width of the 95% CI of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 { f64::NAN } else { 1.96 * self.std() / (self.n as f64).sqrt() }
    }
}

/// Exact percentile over a retained sample (fine at our scales).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn count(&self) -> usize {
        self.xs.len()
    }
    /// p in [0, 100]; nearest-rank.
    pub fn pct(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (self.xs.len() - 1) as f64).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }
}

/// Log-bucketed latency histogram (microseconds), fixed memory.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: [u64; 32],
    stats: OnlineStats,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: [0; 32], stats: OnlineStats::new() }
    }
    pub fn record_us(&mut self, us: f64) {
        let idx = if us < 1.0 { 0 } else { (us.log2() as usize).min(31) };
        self.buckets[idx] += 1;
        self.stats.push(us);
    }
    pub fn count(&self) -> u64 {
        self.stats.count()
    }
    pub fn mean_us(&self) -> f64 {
        self.stats.mean()
    }
    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn pct_us(&self, p: f64) -> f64 {
        let total = self.stats.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.std() - 2.138).abs() < 1e-2);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::default();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.pct(50.0) - 50.0).abs() <= 1.0);
        assert!((p.pct(95.0) - 95.0).abs() <= 1.0);
    }

    #[test]
    fn histogram_percentile_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 0..1000 {
            h.record_us(10.0 + i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.pct_us(50.0) <= h.pct_us(99.0));
        assert!(h.mean_us() > 10.0);
    }
}
