//! Streaming statistics and latency histograms for metrics + bench harness.

use super::rng::Rng;

/// Welford online mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Half-width of the 95% CI of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 { f64::NAN } else { 1.96 * self.std() / (self.n as f64).sqrt() }
    }
}

/// Exact percentile over a retained sample (fine at our scales).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn count(&self) -> usize {
        self.xs.len()
    }
    /// p in [0, 100]; nearest-rank.
    pub fn pct(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (self.xs.len() - 1) as f64).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }
}

/// Bounded streaming summary: Welford moments plus a fixed-size uniform
/// reservoir (Vitter's Algorithm R) for approximate percentiles.  Memory is
/// O(capacity) no matter how many samples are pushed — long-lived engines
/// record one sample per event forever, so metric series must never grow
/// with uptime.  The reservoir RNG is seeded deterministically: summaries
/// are reproducible run-to-run.
#[derive(Debug, Clone)]
pub struct StreamSummary {
    stats: OnlineStats,
    sample: Vec<f64>,
    cap: usize,
    rng: Rng,
}

impl Default for StreamSummary {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamSummary {
    /// Default capacity comfortably bounds memory (4 KiB of f64) while
    /// keeping p95/p99 estimates stable at serving sample rates.
    pub fn new() -> Self {
        Self::with_capacity(512)
    }

    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        StreamSummary {
            stats: OnlineStats::new(),
            sample: Vec::with_capacity(cap.min(1024)),
            cap,
            rng: Rng::new(0x5eed_0f_5a_a7_1e5),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.stats.push(x);
        if self.sample.len() < self.cap {
            self.sample.push(x);
        } else {
            // Algorithm R: element n replaces a reservoir slot w.p. cap/n
            let j = self.rng.below(self.stats.count() as usize);
            if j < self.cap {
                self.sample[j] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.stats.count()
    }
    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }
    pub fn std(&self) -> f64 {
        self.stats.std()
    }
    pub fn min(&self) -> f64 {
        self.stats.min()
    }
    pub fn max(&self) -> f64 {
        self.stats.max()
    }
    pub fn ci95(&self) -> f64 {
        self.stats.ci95()
    }

    /// Approximate percentile (exact until `capacity` samples, reservoir
    /// estimate beyond); p in [0, 100], nearest-rank.  `None` until the
    /// first sample — callers render `-`, never NaN.
    pub fn pct(&self, p: f64) -> Option<f64> {
        if self.sample.is_empty() {
            return None;
        }
        let mut xs = self.sample.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        Some(xs[rank.min(xs.len() - 1)])
    }
}

/// Log-bucketed latency histogram (microseconds), fixed memory.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: [u64; 32],
    stats: OnlineStats,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: [0; 32], stats: OnlineStats::new() }
    }
    pub fn record_us(&mut self, us: f64) {
        let idx = if us < 1.0 { 0 } else { (us.log2() as usize).min(31) };
        self.buckets[idx] += 1;
        self.stats.push(us);
    }
    pub fn count(&self) -> u64 {
        self.stats.count()
    }
    pub fn mean_us(&self) -> f64 {
        self.stats.mean()
    }
    /// Raw bucket counts; bucket i covers [2^i, 2^(i+1)) microseconds
    /// (metrics exposition renders these as cumulative Prometheus buckets).
    pub fn buckets(&self) -> &[u64; 32] {
        &self.buckets
    }
    /// Approximate percentile from bucket boundaries (upper bound).
    /// `None` until the first sample — callers render `-`, never NaN.
    pub fn pct_us(&self, p: f64) -> Option<f64> {
        let total = self.stats.count();
        if total == 0 {
            return None;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some((1u64 << (i + 1)) as f64);
            }
        }
        Some(self.stats.max())
    }
}

/// Render an optional statistic for human-readable summaries: `-` until the
/// first sample (replacing the NaN the f64 math would otherwise emit).
pub fn fmt_opt(v: Option<f64>, decimals: usize) -> String {
    match v {
        Some(x) => format!("{x:.decimals$}"),
        None => "-".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.std() - 2.138).abs() < 1e-2);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::default();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.pct(50.0) - 50.0).abs() <= 1.0);
        assert!((p.pct(95.0) - 95.0).abs() <= 1.0);
    }

    #[test]
    fn stream_summary_is_bounded_and_tracks_percentiles() {
        let mut s = StreamSummary::with_capacity(64);
        for i in 0..10_000 {
            s.push((i % 1000) as f64);
        }
        assert_eq!(s.count(), 10_000);
        // memory stays at capacity no matter how many samples arrived
        assert!(s.sample.len() <= 64);
        assert!((s.mean() - 499.5).abs() < 1.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 999.0);
        // reservoir percentiles approximate the uniform distribution
        let p50 = s.pct(50.0).unwrap();
        assert!((200.0..800.0).contains(&p50), "p50 {p50}");
        assert!(s.pct(10.0).unwrap() <= s.pct(90.0).unwrap());
    }

    #[test]
    fn stream_summary_exact_under_capacity() {
        let mut s = StreamSummary::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.pct(0.0), Some(1.0));
        assert_eq!(s.pct(100.0), Some(100.0));
        assert!((s.pct(50.0).unwrap() - 50.0).abs() <= 1.0);
        // empty series report None, not NaN (metrics render `-`)
        assert_eq!(StreamSummary::new().pct(50.0), None);
    }

    #[test]
    fn stream_summary_is_deterministic() {
        let run = || {
            let mut s = StreamSummary::with_capacity(32);
            for i in 0..5_000 {
                s.push((i * 7 % 997) as f64);
            }
            (s.pct(50.0), s.pct(95.0))
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn histogram_percentile_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 0..1000 {
            h.record_us(10.0 + i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.pct_us(50.0).unwrap() <= h.pct_us(99.0).unwrap());
        assert!(h.mean_us() > 10.0);
        assert_eq!(LatencyHistogram::new().pct_us(50.0), None);
        assert_eq!(h.buckets().iter().sum::<u64>(), 1000);
    }

    #[test]
    fn fmt_opt_renders_dash_for_empty() {
        assert_eq!(fmt_opt(None, 2), "-");
        assert_eq!(fmt_opt(Some(1.234), 2), "1.23");
        assert_eq!(fmt_opt(Some(3.0), 0), "3");
    }
}
