//! Minimal JSON parser/writer (serde is unavailable offline — see DESIGN.md §2).
//!
//! Supports the full JSON grammar we exchange with the python build path
//! (meta.json, vocab.json, golden_episodes.jsonl) plus a writer used by the
//! eval harness to emit machine-readable tables.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj["a"]["b"][2]`-style path access: keys separated by '.'.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid usize field `{key}`"))
    }
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid str field `{key}`"))
    }

    // -- construction helpers ----------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    /// Compact writer with stable key order (BTreeMap) — good for goldens.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= 1;
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-decode multi-byte utf-8 in place
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, false], "c": {}}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].str_field("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""café – ünïcode""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café – ünïcode");
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x"}"#).unwrap();
        assert_eq!(v.usize_field("n").unwrap(), 7);
        assert!(v.usize_field("s").is_err());
        assert!(v.usize_field("missing").is_err());
    }
}
