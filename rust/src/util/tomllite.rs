//! TOML-subset parser for engine config files (the toml crate is
//! unavailable offline).  Supported: `[section]` headers, `key = value`
//! with string/int/float/bool/array values, `#` comments.  Values are
//! surfaced as `Json` so config code shares accessors with meta.json.

use std::collections::BTreeMap;

use super::json::Json;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse into {"section.key": value}; keys before any section have no prefix.
pub fn parse(src: &str) -> Result<BTreeMap<String, Json>, TomlError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (ln, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
            section = name.trim().to_string();
            if section.is_empty() {
                return Err(err("empty section name"));
            }
            continue;
        }
        let (key, val) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.insert(full, parse_value(val.trim(), ln + 1)?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a `#` inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, line: usize) -> Result<Json, TomlError> {
    let err = |msg: String| TomlError { line, msg };
    if v.is_empty() {
        return Err(err("empty value".into()));
    }
    if let Some(rest) = v.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| err("unterminated string".into()))?;
        return Ok(Json::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if v == "true" {
        return Ok(Json::Bool(true));
    }
    if v == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(rest) = v.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| err("unterminated array".into()))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), line)?);
        }
        return Ok(Json::Arr(items));
    }
    v.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(format!("cannot parse value `{v}`")))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let src = r#"
# engine config
artifacts_dir = "artifacts"   # where AOT outputs live

[engine]
budget = 256
policy = "trimkv"
stream = true
temperature = 0.0

[scheduler]
max_batch = 8
budgets = [64, 128, 256]
"#;
        let m = parse(src).unwrap();
        assert_eq!(m["artifacts_dir"].as_str().unwrap(), "artifacts");
        assert_eq!(m["engine.budget"].as_usize().unwrap(), 256);
        assert_eq!(m["engine.policy"].as_str().unwrap(), "trimkv");
        assert_eq!(m["engine.stream"].as_bool().unwrap(), true);
        assert_eq!(m["scheduler.budgets"].as_arr().unwrap().len(), 3);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let m = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(m["name"].as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("= 3").is_err());
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = what").is_err());
    }

    #[test]
    fn nested_arrays() {
        let m = parse("x = [[1, 2], [3]]").unwrap();
        let outer = m["x"].as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_arr().unwrap().len(), 2);
    }
}
