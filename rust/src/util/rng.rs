//! Deterministic PRNG (xoshiro256++ seeded via splitmix64).
//!
//! The `rand` crate is unavailable offline; every randomized component in
//! the engine (sampler, workload generators, property tests) threads one of
//! these explicitly so runs are reproducible from a single seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (e.g. per request id).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut out: Vec<usize> = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
        self.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(9);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
