//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args,
//! and generated `--help` text.  Used by the `trimkv` binary, the examples
//! and the bench harnesses.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Owned so callers can derive defaults from a single source of truth
    /// (e.g. `EngineConfig::default()`) instead of duplicating literals.
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    program: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
    spec: Vec<ArgSpec>,
}

impl Args {
    pub fn spec() -> SpecBuilder {
        SpecBuilder { spec: Vec::new() }
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values
            .get(name)
            .map(String::as_str)
            .or_else(|| self.default_of(name))
    }
    fn default_of(&self, name: &str) -> Option<&str> {
        self.spec.iter().find(|s| s.name == name).and_then(|s| s.default.as_deref())
    }
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn usize(&self, name: &str) -> anyhow::Result<usize> {
        parse(self.get(name), name)
    }
    pub fn u64(&self, name: &str) -> anyhow::Result<u64> {
        parse(self.get(name), name)
    }
    pub fn f64(&self, name: &str) -> anyhow::Result<f64> {
        parse(self.get(name), name)
    }
    pub fn usize_list(&self, name: &str) -> anyhow::Result<Vec<usize>> {
        let raw = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        raw.split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad usize in --{name}: `{s}`"))
            })
            .collect()
    }

    pub fn help(&self) -> String {
        let mut out = format!("usage: {} [options]\n\noptions:\n", self.program);
        for s in &self.spec {
            let tail = if s.is_flag {
                String::new()
            } else {
                format!(" <v>{}", s.default.as_ref().map(|d| format!(" [default {d}]")).unwrap_or_default())
            };
            out.push_str(&format!("  --{}{}\n      {}\n", s.name, tail, s.help));
        }
        out
    }
}

fn parse<T: std::str::FromStr>(v: Option<&str>, name: &str) -> anyhow::Result<T> {
    let v = v.ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
    v.parse()
        .map_err(|_| anyhow::anyhow!("invalid value for --{name}: `{v}`"))
}

pub struct SpecBuilder {
    spec: Vec<ArgSpec>,
}

impl SpecBuilder {
    pub fn opt(mut self, name: &'static str, default: impl Into<String>,
               help: &'static str) -> Self {
        self.spec.push(ArgSpec { name, help, default: Some(default.into()),
                                 is_flag: false });
        self
    }
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.spec.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.spec.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn parse_env(self) -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().collect();
        self.parse(&argv)
    }

    pub fn parse(self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args {
            program: argv.first().cloned().unwrap_or_default(),
            spec: self.spec,
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if rest == "help" {
                    println!("{}", args.help());
                    std::process::exit(0);
                }
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = args
                    .spec
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}"))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{key} takes no value");
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.split_whitespace().map(String::from))
            .collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = Args::spec()
            .opt("budget", "256", "kv budget")
            .opt("policy", "trimkv", "eviction policy")
            .flag("verbose", "chatty")
            .parse(&argv("--budget 512 --verbose extra"))
            .unwrap();
        assert_eq!(a.usize("budget").unwrap(), 512);
        assert_eq!(a.get("policy"), Some("trimkv")); // default
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::spec()
            .opt("m", "1", "m")
            .parse(&argv("--m=42"))
            .unwrap();
        assert_eq!(a.usize("m").unwrap(), 42);
    }

    #[test]
    fn rejects_unknown_and_bad_values() {
        let s = || Args::spec().opt("m", "1", "m").flag("f", "f");
        assert!(s().parse(&argv("--nope 1")).is_err());
        assert!(s().parse(&argv("--m")).is_err());
        assert!(s().parse(&argv("--f=1")).is_err());
        let a = s().parse(&argv("--m xyz")).unwrap();
        assert!(a.usize("m").is_err());
    }

    #[test]
    fn usize_list() {
        let a = Args::spec()
            .opt("budgets", "64,128,256", "list")
            .parse(&argv(""))
            .unwrap();
        assert_eq!(a.usize_list("budgets").unwrap(), vec![64, 128, 256]);
    }
}
