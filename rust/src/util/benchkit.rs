//! Bench harness for `cargo bench` targets (criterion is unavailable
//! offline; benches use `harness = false` and this module).
//!
//! Provides warmup + timed iterations with mean/p50/p95 reporting, a
//! plain-text table renderer shared by the paper-table benches, and a
//! machine-readable `BENCH_<name>.json` emitter so the perf trajectory is
//! tracked across PRs.

use std::path::PathBuf;
use std::time::Instant;

use super::json::Json;
use super::stats::{OnlineStats, Percentiles};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub ci95_us: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_us / 1e6)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_us", num_or_null(self.mean_us)),
            ("p50_us", num_or_null(self.p50_us)),
            ("p95_us", num_or_null(self.p95_us)),
            ("ci95_us", num_or_null(self.ci95_us)),
        ])
    }
}

/// JSON numbers cannot hold NaN/inf (single-iteration CIs produce them).
fn num_or_null(x: f64) -> Json {
    if x.is_finite() { Json::num(x) } else { Json::Null }
}

/// Shared `--quick` mode for the bench suite (the CI bench-smoke job):
/// enabled by a `--quick` argv flag or `BENCH_QUICK=1`, it trims warmup and
/// iteration counts (see [`iters`]) so every bench finishes in seconds
/// while still emitting its full `BENCH_*.json` record.
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Scale a bench's (warmup, iters) pair for the active mode: unchanged
/// normally, cut to (1, max(iters/10, 3)) under `--quick`.
pub fn iters(warmup: u64, full_iters: u64) -> (u64, u64) {
    if quick() {
        (1, (full_iters / 10).max(3))
    } else {
        (warmup, full_iters)
    }
}

/// One entry of the `regress_on` block in `BENCH_*.json`: the scalar the
/// CI bench-smoke job gates on against the committed `BENCH_baseline.json`
/// (>10% move in the losing direction fails the job; a null baseline value
/// means "seed me" and only reports).
pub fn gate(value: f64, higher_is_better: bool) -> Json {
    Json::obj(vec![
        ("value", num_or_null(value)),
        ("higher_is_better", Json::Bool(higher_is_better)),
    ])
}

/// Timed results as a JSON array (one object per `BenchResult`).
pub fn results_json(results: &[BenchResult]) -> Json {
    Json::Arr(results.iter().map(BenchResult::to_json).collect())
}

/// Write `BENCH_<name>.json` into the working directory (repo root under
/// `cargo bench`): the machine-readable perf record tracked across PRs.
/// `payload` should be an object; a "bench" field with the name is added.
pub fn write_bench_json(name: &str, payload: Json) -> std::io::Result<PathBuf> {
    let wrapped = match payload {
        Json::Obj(mut map) => {
            map.insert("bench".into(), Json::str(name));
            Json::Obj(map)
        }
        other => Json::obj(vec![("bench", Json::str(name)),
                                ("results", other)]),
    };
    let path = PathBuf::from(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{wrapped}\n"))?;
    Ok(path)
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = OnlineStats::new();
    let mut pct = Percentiles::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let us = t0.elapsed().as_secs_f64() * 1e6;
        stats.push(us);
        pct.push(us);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: stats.mean(),
        p50_us: pct.pct(50.0),
        p95_us: pct.pct(95.0),
        ci95_us: stats.ci95(),
    }
}

pub fn report(results: &[BenchResult]) {
    println!("{:<44} {:>10} {:>12} {:>12} {:>12}", "bench", "iters", "mean", "p50", "p95");
    for r in results {
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_us(r.mean_us),
            fmt_us(r.p50_us),
            fmt_us(r.p95_us)
        );
    }
}

pub fn fmt_us(us: f64) -> String {
    if us.is_nan() {
        "-".into()
    } else if us < 1e3 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.2}s", us / 1e6)
    }
}

/// Fixed-width ASCII table used by the paper-table reproductions.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells);
    }
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:>w$}", s, w = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
    /// CSV form for downstream plotting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') { format!("\"{s}\"") } else { s.to_string() }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_us >= 0.0);
        assert_eq!(r.iters, 10);
        assert!(r.p50_us <= r.p95_us);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "acc"]);
        t.row(vec!["trimkv".into(), "0.91".into()]);
        t.row(vec!["h2o".into(), "0.5".into()]);
        let s = t.render();
        assert!(s.contains("trimkv"));
        assert_eq!(s.lines().count(), 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "method,acc");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn gate_entries_serialize() {
        let g = gate(7.0, true);
        let s = format!("{g}");
        assert!(s.contains("\"value\""));
        assert!(s.contains("true"));
        let s = format!("{}", gate(f64::NAN, false));
        assert!(s.contains("null"), "NaN gate value must serialize as null");
    }

    #[test]
    fn fmt_us_scales() {
        assert_eq!(fmt_us(12.0), "12.0µs");
        assert_eq!(fmt_us(2500.0), "2.50ms");
        assert_eq!(fmt_us(3.2e6), "3.20s");
    }
}
