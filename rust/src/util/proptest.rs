//! Seeded property-testing mini-framework (proptest is unavailable offline).
//!
//! `forall` runs a property over N random cases; on failure it retries the
//! failing case with shrunken integer inputs (halving toward zero) via the
//! `Shrink` helper and reports the seed so the case replays exactly.
//!
//! ```ignore
//! forall("cache never exceeds budget", 200, |rng| {
//!     let budget = rng.range(1, 64);
//!     ...
//!     prop_assert!(cache.len() <= budget, "len {} budget {}", ...);
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

/// Assert inside a property; returns Err instead of panicking so the runner
/// can report the seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {} ({}:{})",
                               stringify!($cond), file!(), line!()));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!("assertion failed: {} — {} ({}:{})",
                               stringify!($cond), format!($($fmt)+),
                               file!(), line!()));
        }
    };
}

/// Assert equality with debug output.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{} != {}: {:?} vs {:?} ({}:{})",
                               stringify!($a), stringify!($b), a, b,
                               file!(), line!()));
        }
    }};
}

/// Run `prop` on `cases` random inputs derived from a fixed master seed
/// (overridable with TRIMKV_PROP_SEED for replay).  Panics with the case
/// seed on the first failure.
pub fn forall<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng) -> PropResult,
{
    let master = std::env::var("TRIMKV_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xdead_beef_u64);
    for case in 0..cases {
        let seed = master
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed}):\n  {msg}\n\
                 replay: TRIMKV_PROP_SEED={master} (case index {case})"
            );
        }
    }
}

/// Integer shrinking helper: yields progressively smaller candidates.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut cur = x;
    while cur > 0 {
        cur /= 2;
        out.push(cur);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("below stays below", 100, |rng| {
            let n = rng.range(1, 1000);
            let x = rng.below(n);
            prop_assert!(x < n, "x={x} n={n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn forall_reports_failures() {
        forall("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn shrink_reaches_zero() {
        let s = shrink_usize(100);
        assert_eq!(*s.last().unwrap(), 0);
        assert!(s.windows(2).all(|w| w[0] > w[1]));
    }
}
