//! Substrate utilities built in-repo (no third-party equivalents available
//! offline): JSON/TOML parsing, CLI args, PRNG, stats, bench harness and a
//! property-testing mini-framework.  See DESIGN.md §2.

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tomllite;
