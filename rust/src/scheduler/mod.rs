//! Request admission and continuous batching.
//!
//! Requests wait in a bounded FIFO; whenever a batch lane frees up the
//! batcher assigns the next request to it (vLLM-style continuous batching —
//! lanes are never drained to a barrier).  Prefill/decode interleaving is
//! decided per tick by the engine (`prefill_priority` config).

use std::collections::VecDeque;

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub stop_at_eos: bool,
    /// free-form tag used by the eval harness to route grading
    pub tag: String,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, stop_at_eos: true, tag: String::new() }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    Length,
    Aborted,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tag: String,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub ttft_us: f64,
    pub e2e_us: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum AdmitError {
    #[error("queue full (capacity {0})")]
    QueueFull(usize),
    #[error("empty prompt")]
    EmptyPrompt,
}

/// Bounded FIFO wait queue with admission control.
#[derive(Debug)]
pub struct WaitQueue {
    q: VecDeque<Request>,
    capacity: usize,
}

impl WaitQueue {
    pub fn new(capacity: usize) -> WaitQueue {
        WaitQueue { q: VecDeque::new(), capacity }
    }
    pub fn admit(&mut self, req: Request) -> Result<(), AdmitError> {
        if req.prompt.is_empty() {
            return Err(AdmitError::EmptyPrompt);
        }
        if self.q.len() >= self.capacity {
            return Err(AdmitError::QueueFull(self.capacity));
        }
        self.q.push_back(req);
        Ok(())
    }
    pub fn pop(&mut self) -> Option<Request> {
        self.q.pop_front()
    }
    pub fn len(&self) -> usize {
        self.q.len()
    }
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let mut q = WaitQueue::new(2);
        q.admit(Request::new(1, vec![1], 4)).unwrap();
        q.admit(Request::new(2, vec![1], 4)).unwrap();
        assert!(matches!(
            q.admit(Request::new(3, vec![1], 4)),
            Err(AdmitError::QueueFull(2))
        ));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn rejects_empty_prompt() {
        let mut q = WaitQueue::new(2);
        assert!(matches!(
            q.admit(Request::new(1, vec![], 4)),
            Err(AdmitError::EmptyPrompt)
        ));
    }
}
