//! Request admission and continuous batching.
//!
//! Requests wait in a bounded FIFO; whenever a batch lane frees up the
//! batcher assigns the next request to it (vLLM-style continuous batching —
//! lanes are never drained to a barrier).  Prefill/decode interleaving is
//! decided per tick by the engine (`prefill_priority` config).

use std::collections::VecDeque;

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub stop_at_eos: bool,
    /// free-form tag used by the eval harness to route grading
    pub tag: String,
    /// Conversation this turn belongs to. Turns of one session run in
    /// submission order; between turns the session's KV cache is retained
    /// (parked on its lane or swapped to the host `SessionStore`).
    pub session: Option<String>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, stop_at_eos: true,
                  tag: String::new(), session: None }
    }

    pub fn with_session(mut self, session: impl Into<String>) -> Request {
        self.session = Some(session.into());
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    Length,
    Aborted,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tag: String,
    /// Session this turn belonged to, when session-routed.
    pub session: Option<String>,
    /// Length of the full fed stream (all turns) for session requests.
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub ttft_us: f64,
    pub e2e_us: f64,
}

#[derive(Debug)]
pub enum AdmitError {
    QueueFull(usize),
    EmptyPrompt,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull(cap) => write!(f, "queue full (capacity {cap})"),
            AdmitError::EmptyPrompt => write!(f, "empty prompt"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Bounded FIFO wait queue with admission control, session-aware: the
/// engine pops the first request whose session is *admissible* (not already
/// decoding on a lane), which keeps per-session turn order while letting
/// unrelated conversations overtake a blocked one.
#[derive(Debug)]
pub struct WaitQueue {
    q: VecDeque<Request>,
    capacity: usize,
}

impl WaitQueue {
    pub fn new(capacity: usize) -> WaitQueue {
        WaitQueue { q: VecDeque::new(), capacity }
    }
    pub fn admit(&mut self, req: Request) -> Result<(), AdmitError> {
        if req.prompt.is_empty() {
            return Err(AdmitError::EmptyPrompt);
        }
        if self.q.len() >= self.capacity {
            return Err(AdmitError::QueueFull(self.capacity));
        }
        self.q.push_back(req);
        Ok(())
    }
    pub fn pop(&mut self) -> Option<Request> {
        self.q.pop_front()
    }
    /// Index of the first queued request accepted by `admissible`
    /// (FIFO within and across sessions).
    pub fn find_admissible<F: Fn(&Request) -> bool>(&self, admissible: F)
        -> Option<usize> {
        self.q.iter().position(admissible)
    }
    /// Peek a queued request by index.
    pub fn get(&self, idx: usize) -> Option<&Request> {
        self.q.get(idx)
    }
    /// Remove a specific queued request (paired with `find_admissible`).
    pub fn take(&mut self, idx: usize) -> Option<Request> {
        self.q.remove(idx)
    }
    /// Queued turns for this session (close-barrier accounting).
    pub fn session_count(&self, id: &str) -> usize {
        self.q.iter().filter(|r| r.session.as_deref() == Some(id)).count()
    }
    /// Is any queued turn waiting on this session?
    pub fn has_session(&self, id: &str) -> bool {
        self.session_count(id) > 0
    }
    pub fn len(&self) -> usize {
        self.q.len()
    }
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let mut q = WaitQueue::new(2);
        q.admit(Request::new(1, vec![1], 4)).unwrap();
        q.admit(Request::new(2, vec![1], 4)).unwrap();
        assert!(matches!(
            q.admit(Request::new(3, vec![1], 4)),
            Err(AdmitError::QueueFull(2))
        ));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn session_admissibility_preserves_turn_order() {
        let mut q = WaitQueue::new(8);
        q.admit(Request::new(1, vec![1], 4).with_session("a")).unwrap();
        q.admit(Request::new(2, vec![1], 4).with_session("a")).unwrap();
        q.admit(Request::new(3, vec![1], 4)).unwrap();
        assert!(q.has_session("a"));
        assert!(!q.has_session("b"));
        // session "a" busy on a lane: first admissible is the sessionless #3
        let idx = q
            .find_admissible(|r| r.session.as_deref() != Some("a"))
            .unwrap();
        assert_eq!(q.get(idx).unwrap().id, 3);
        assert_eq!(q.take(idx).unwrap().id, 3);
        // "a" free again: its turns come out in submission order
        let idx = q.find_admissible(|_| true).unwrap();
        assert_eq!(q.take(idx).unwrap().id, 1);
        assert_eq!(q.take(0).unwrap().id, 2);
        assert!(q.is_empty());
    }

    #[test]
    fn rejects_empty_prompt() {
        let mut q = WaitQueue::new(2);
        assert!(matches!(
            q.admit(Request::new(1, vec![], 4)),
            Err(AdmitError::EmptyPrompt)
        ));
    }
}
