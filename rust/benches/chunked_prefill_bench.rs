//! Tables 4 / 9 / 10 reproduction: chunked-prefill comparison vs LocRet —
//! long prompts are compressed chunk-by-chunk before generation.  Shape to
//! match: TRIM-KV >= LocRet; both near FullKV on compressible QA.

use trimkv::eval::bench_support::{bench_n, load_ctx};
use trimkv::eval::{results_table, run_suite};
use trimkv::workload::suites;

fn main() {
    let Some(ctx) = load_ctx("chunked_prefill") else { return };
    if !ctx.meta.gate_variants.iter().any(|v| v == "locret") {
        println!("note: locret gates not trained; comparing trimkv vs heuristics only");
    }
    let n = bench_n(16);
    let budget = 48usize;
    let max_m = ctx.max_slots(8);
    let suite = suites::longqa(&ctx.vocab, n, 23);
    let mut all = Vec::new();
    // policies sharing the default gates reuse one backend
    let mut backend = ctx.backend(8, max_m, "default");
    for policy in ["trimkv", "snapkv", "streaming_llm", "fullkv"] {
        let eff = if policy == "fullkv" { max_m - ctx.meta.chunk - 1 } else { budget };
        let (r, be) = run_suite(backend, &ctx.cfg, &ctx.vocab, policy, eff,
                                &suite).expect("chunked run");
        backend = be;
        all.push(r);
    }
    if ctx.meta.gate_variants.iter().any(|v| v == "locret") {
        let be = ctx.backend(8, max_m, "locret");
        let (r, _) = run_suite(be, &ctx.cfg, &ctx.vocab, "locret", budget,
                               &suite).expect("locret run");
        all.push(r);
    }
    println!("=== Tables 4/9/10 analog (chunked prefill) ===\n{}",
             results_table(&all).render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/chunked_prefill.csv",
                   results_table(&all).to_csv()).ok();
}
