//! Pipelined-tick bench: the serial submit-then-wait loop vs the software
//! pipeline that overlaps the next tick's host work (step assembly,
//! admission, chained snapshot swaps) with the in-flight device step.
//!
//! The MockBackend's synthetic execute latency stands in for the device:
//! `wait` pays the configured latency NET of host time already elapsed
//! since `submit`, so a serial tick costs host + device while a pipelined
//! tick approaches max(host, device).  Session churn under the eager swap
//! policy keeps real host work (admission planning + lane-sized memcpy
//! swaps) inside every overlap window.  Token streams are asserted
//! bit-identical between the two loops at every latency point — the bench
//! doubles as an end-to-end equivalence check.
//!
//! Deterministic CI gates (machine-independent): the pipelined loop's
//! host-gap tick count (structurally zero) and the fraction of swap
//! batches that ride an overlap window.  Wall-clock mean and speedup are
//! tracked with the loose wall-time threshold like every other bench.
//!
//! Emits `BENCH_pipeline.json` (util::benchkit) for the CI bench-smoke
//! job's regression gate.
//!
//!   cargo bench --bench pipeline_overlap [-- --quick]

use std::time::Instant;

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::runtime::MockBackend;
use trimkv::scheduler::Request;
use trimkv::util::benchkit::{bench, gate, iters, report, results_json,
                             write_bench_json, BenchResult};
use trimkv::util::json::Json;

const BATCH: usize = 4;
const BUDGET: usize = 24;
const SESSIONS: u64 = 6;
const REQUESTS: u64 = 18;
/// Synthetic device latencies: host-bound, balanced, device-bound.
const LATENCIES_US: [u64; 3] = [0, 50, 200];

struct RunStats {
    wall_ms: f64,
    mean_step_us: f64,
    host_gap_ticks: u64,
    overlap_us: u64,
    swap_batches: u64,
    swap_batches_overlapped: u64,
    streams: Vec<(u64, Vec<u32>)>,
}

fn run_workload(pipeline: bool, latency_us: u64) -> RunStats {
    let cfg = EngineConfig {
        policy: "trimkv".into(),
        budget: BUDGET,
        batch: BATCH,
        max_new_tokens: 8,
        chunked_prefill: true,
        mixed_ticks: true,
        swap_policy: "eager".into(),
        pipeline,
        ..Default::default()
    };
    let backend = MockBackend::new(BATCH, BUDGET + 24)
        .with_synthetic_latency_us(latency_us);
    let mut e = Engine::new(backend, cfg, 2).expect("engine");
    for i in 0..REQUESTS {
        let plen = 4 + (i as usize * 7) % 45;
        let prompt: Vec<u32> =
            (0..plen).map(|j| 32 + (j % 64) as u32).collect();
        e.submit(Request::new(i, prompt, 6)
                 .with_session(format!("s{}", i % SESSIONS)))
            .unwrap();
    }
    let t0 = Instant::now();
    let mut rs = e.run_to_completion().unwrap();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    rs.sort_by_key(|r| r.id);
    RunStats {
        wall_ms,
        mean_step_us: e.metrics.step_us.mean(),
        host_gap_ticks: e.obs.journal.host_gap_ticks,
        overlap_us: e.obs.journal.overlap_ns / 1000,
        swap_batches: e.metrics.swap_batches,
        swap_batches_overlapped: e.metrics.swap_batches_overlapped,
        streams: rs.into_iter().map(|r| (r.id, r.tokens)).collect(),
    }
}

fn main() {
    println!("=== pipelined vs serial tick loop ({REQUESTS} session turns, \
              {SESSIONS} dialogues over {BATCH} lanes, eager swaps) ===");
    println!("{:<11} {:<10} {:>10} {:>13} {:>9} {:>11} {:>10}",
             "latency_us", "mode", "wall_ms", "mean_step_us", "host_gap",
             "overlap_ms", "swaps_ovl");
    let mut lat_json = Vec::new();
    let mut overlap_fraction = 0.0;
    let mut host_gap_total = 0u64;
    for lat in LATENCIES_US {
        let serial = run_workload(false, lat);
        let piped = run_workload(true, lat);
        assert_eq!(serial.streams, piped.streams,
                   "pipelining changed a token stream at {lat}us latency");
        assert_eq!(piped.host_gap_ticks, 0,
                   "pipelined loop left a host gap at {lat}us latency");
        assert!(piped.swap_batches_overlapped > 0,
                "no swap batch rode an overlap window at {lat}us latency");
        host_gap_total += piped.host_gap_ticks;
        // pure scheduling counters: identical at every latency point
        overlap_fraction = piped.swap_batches_overlapped as f64
            / piped.swap_batches.max(1) as f64;
        for (mode, s) in [("serial", &serial), ("pipelined", &piped)] {
            println!("{:<11} {:<10} {:>10.2} {:>13.1} {:>9} {:>11.2} {:>10}",
                     lat, mode, s.wall_ms, s.mean_step_us, s.host_gap_ticks,
                     s.overlap_us as f64 / 1e3, s.swap_batches_overlapped);
        }
        lat_json.push(Json::obj(vec![
            ("latency_us", Json::num(lat as f64)),
            ("serial_wall_ms", Json::num(serial.wall_ms)),
            ("pipelined_wall_ms", Json::num(piped.wall_ms)),
            ("serial_mean_step_us", Json::num(serial.mean_step_us)),
            ("pipelined_mean_step_us", Json::num(piped.mean_step_us)),
            ("pipelined_overlap_us", Json::num(piped.overlap_us as f64)),
            ("swap_batches", Json::num(piped.swap_batches as f64)),
            ("swap_batches_overlapped",
             Json::num(piped.swap_batches_overlapped as f64)),
        ]));
    }

    // wall-time distribution at the device-bound point, where the overlap
    // win is the whole host side of the tick
    let hot = *LATENCIES_US.last().unwrap();
    let (warmup, n) = iters(2, 10);
    let mut results: Vec<BenchResult> = Vec::new();
    for (name, pipeline) in [("workload/serial", false),
                             ("workload/pipelined", true)] {
        results.push(bench(name, warmup, n, || {
            std::hint::black_box(run_workload(pipeline, hot));
        }));
    }
    report(&results);
    let speedup = results[0].mean_us / results[1].mean_us;
    println!("pipelined speedup at {hot}us device latency: {speedup:.3}x \
              (overlapped swap fraction {overlap_fraction:.2})");

    let payload = Json::obj(vec![
        ("batch", Json::num(BATCH as f64)),
        ("budget", Json::num(BUDGET as f64)),
        ("requests", Json::num(REQUESTS as f64)),
        ("sessions", Json::num(SESSIONS as f64)),
        ("latencies", Json::Arr(lat_json)),
        ("results", results_json(&results)),
        // CI gate: host-gap and the overlapped-swap fraction are pure
        // scheduling counters (deterministic on the mock); the wall-time
        // pair carries the loose shared-runner threshold in the baseline
        ("regress_on", Json::obj(vec![
            ("pipeline_host_gap_ticks",
             gate(host_gap_total as f64, false)),
            ("pipeline_overlapped_swap_fraction",
             gate(overlap_fraction, true)),
            ("pipeline_workload_mean_us", gate(results[1].mean_us, false)),
            ("pipeline_speedup", gate(speedup, true)),
        ])),
    ]);
    let path = write_bench_json("pipeline", payload).expect("bench json");
    println!("wrote {}", path.display());
}
