//! Shared-prefix store bench: TTFT collapse when a fleet's common system
//! prompts are served from the prefix cache instead of re-prefilled.
//!
//! The workload is `workload::shared_prefix_mix`: every arrival opens with
//! one of a few fixed 96-token "system prompts" (Zipf-picked) plus a short
//! unique tail.  The warm arm first runs one padded request per prefix so
//! the store holds each prefix at the 64-token boundary, then serves the
//! mix: admission seeds every lane from the cached slab + frozen retention
//! state and prefills only the tail.  The cold arm is the identical engine
//! with the store disabled.
//!
//! Inline correctness asserts (the bench doubles as an end-to-end check):
//! - every warm token stream is bit-exact with the cold arm — the cached
//!   slab plus TRIM-KV's creation-time scores reproduce the cold lane
//!   verbatim;
//! - the warm arm's hit/miss/insert/saved counters land on their exact
//!   closed-form values (the mix and the store are both deterministic).
//!
//! Deterministic CI gates: the prefix hit/miss/insert counters and
//! `prefill_tokens_saved` (pure accounting over a fixed arrival sequence).
//! The TTFT collapse ratio and warm serve time carry the loose wall-time
//! threshold — the synthetic device latency makes prefill ticks visible
//! but shared runners jitter.
//!
//! Emits `BENCH_prefix.json` (util::benchkit) for the CI bench-smoke job's
//! regression gate.
//!
//!   cargo bench --bench prefix_reuse [-- --quick]

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::runtime::MockBackend;
use trimkv::scheduler::{Request, Response};
use trimkv::util::benchkit::{bench, gate, iters, report, results_json,
                             write_bench_json, BenchResult};
use trimkv::util::json::Json;
use trimkv::util::rng::Rng;
use trimkv::workload::{shared_prefix_mix, Arrival};

const BATCH: usize = 4;
const BUDGET: usize = 48;
/// Synthetic device step latency: prefill ticks dominate TTFT, so the
/// skipped-prefix savings are visible on the clock.
const LATENCY_US: u64 = 200;
const PREFIXES: usize = 4;
const PREFIX_TOKENS: usize = 96;
/// Store granularity: every 96-token prefix shares its 64-token head.
const CHUNK_TOKENS: usize = 64;
const REQUESTS: usize = 16;
const MIX_SEED: u64 = 13;

fn cfg(warm: bool) -> EngineConfig {
    EngineConfig {
        policy: "trimkv".into(),
        budget: BUDGET,
        batch: BATCH,
        chunked_prefill: true,
        mixed_ticks: true,
        prefix_enabled: warm,
        prefix_chunk_tokens: CHUNK_TOKENS,
        ..Default::default()
    }
}

fn make_engine(warm: bool) -> Engine<MockBackend> {
    let backend = MockBackend::new(BATCH, BUDGET + 24)
        .with_synthetic_latency_us(LATENCY_US);
    Engine::new(backend, cfg(warm), 2).expect("engine")
}

/// The fixed prefix pool behind `shared_prefix_mix(MIX_SEED, ..)`: the mix
/// draws its pool first from a fresh `Rng`, so the same draws reproduce it.
/// `main` asserts every arrival actually opens with one of these, so a
/// change to the workload generator fails loudly here instead of silently
/// desynchronizing the warm-up set.
fn prefix_pool() -> Vec<Vec<u32>> {
    let mut rng = Rng::new(MIX_SEED);
    (0..PREFIXES)
        .map(|_| (0..PREFIX_TOKENS).map(|_| 32 + rng.below(64) as u32).collect())
        .collect()
}

/// One warm-up request per prefix, padded to the next store boundary
/// (96 + 32 = 128 tokens) so each prefix publishes at depths 64 and 128.
fn warmups(pool: &[Vec<u32>]) -> Vec<Arrival> {
    pool.iter()
        .enumerate()
        .map(|(i, p)| {
            let mut prompt = p.clone();
            prompt.extend(
                (0..2 * CHUNK_TOKENS - PREFIX_TOKENS)
                    .map(|t| 32 + ((i * 13 + t) % 64) as u32));
            Arrival { id: 1000 + i as u64, session: None, prompt, max_new: 2 }
        })
        .collect()
}

/// Serve `arrivals` to completion; returns per-request token streams
/// (sorted by id) and the mean time-to-first-token.
fn serve(engine: &mut Engine<MockBackend>, arrivals: &[Arrival])
    -> (Vec<(u64, Vec<u32>)>, f64) {
    for a in arrivals {
        engine
            .submit(Request::new(a.id, a.prompt.clone(), a.max_new))
            .expect("admit");
    }
    let rs: Vec<Response> = engine.run_to_completion().expect("serve");
    assert_eq!(rs.len(), arrivals.len(), "lost a response");
    let ttft_mean =
        rs.iter().map(|r| r.ttft_us).sum::<f64>() / rs.len() as f64;
    let mut streams: Vec<(u64, Vec<u32>)> =
        rs.into_iter().map(|r| (r.id, r.tokens)).collect();
    streams.sort_by_key(|(id, _)| *id);
    (streams, ttft_mean)
}

fn main() {
    let arrivals =
        shared_prefix_mix(MIX_SEED, PREFIXES, PREFIX_TOKENS, REQUESTS, 1.0);
    let pool = prefix_pool();
    for a in &arrivals {
        assert!(pool.iter().any(|p| a.prompt.starts_with(p)),
                "arrival {} does not open with a pool prefix (generator \
                 changed?)", a.id);
    }
    println!("=== shared-prefix reuse ({REQUESTS} arrivals over {PREFIXES} \
              {PREFIX_TOKENS}-token prefixes, chunk {CHUNK_TOKENS}, \
              {BATCH} lanes, {LATENCY_US}us device step) ===");

    // canonical runs: correctness asserts + deterministic counters
    let mut cold = make_engine(false);
    let (cold_streams, cold_ttft) = serve(&mut cold, &arrivals);

    let mut warm = make_engine(true);
    let warm_set = warmups(&pool);
    serve(&mut warm, &warm_set);
    let store = warm.prefix_store().expect("store enabled");
    let after_warmup = store.counters();
    assert_eq!((after_warmup.hits, after_warmup.misses, after_warmup.inserts),
               (0, PREFIXES as u64, 2 * PREFIXES as u64),
               "warm-up pass must publish each prefix at both boundaries");
    let (warm_streams, warm_ttft) = serve(&mut warm, &arrivals);
    assert_eq!(warm_streams, cold_streams,
               "prefix-cache hit changed a token stream");
    let c = warm.prefix_store().expect("store enabled").counters();
    let saved = (REQUESTS * CHUNK_TOKENS) as u64;
    assert_eq!(c.hits, REQUESTS as u64, "an arrival missed the warm store");
    assert_eq!(c.misses, PREFIXES as u64, "only warm-ups may miss");
    assert_eq!(c.inserts, 2 * PREFIXES as u64,
               "hit lanes must not republish their prefix");
    assert_eq!(c.prefill_tokens_saved, saved);
    assert_eq!(c.evictions, 0, "the pool fits the default byte budget");

    let collapse = cold_ttft / warm_ttft;
    println!("{:<6} {:>12} {:>14}", "arm", "ttft_us", "prefill_saved");
    println!("{:<6} {:>12.0} {:>14}", "cold", cold_ttft, 0);
    println!("{:<6} {:>12.0} {:>14}", "warm", warm_ttft,
             c.prefill_tokens_saved);
    println!("ttft collapse: {collapse:.2}x");
    // sanity floor: a hit skips 64 of ~110 prompt tokens, so TTFT must
    // drop well clear of noise; the gated value lives in the baseline
    assert!(collapse > 1.2,
            "warm TTFT did not collapse ({collapse:.2}x) — seeding fell \
             back to full prefill?");

    // wall-time distribution over repeated serves (store stays warm; the
    // cold engine re-prefills every prompt each iteration)
    let (warmup_iters, n_iters) = iters(1, 5);
    let mut results: Vec<BenchResult> = Vec::new();
    results.push(bench("serve/cold", warmup_iters, n_iters, || {
        std::hint::black_box(serve(&mut cold, &arrivals));
    }));
    results.push(bench("serve/warm", warmup_iters, n_iters, || {
        std::hint::black_box(serve(&mut warm, &arrivals));
    }));
    report(&results);

    let payload = Json::obj(vec![
        ("batch", Json::num(BATCH as f64)),
        ("budget", Json::num(BUDGET as f64)),
        ("prefixes", Json::num(PREFIXES as f64)),
        ("prefix_tokens", Json::num(PREFIX_TOKENS as f64)),
        ("chunk_tokens", Json::num(CHUNK_TOKENS as f64)),
        ("requests", Json::num(REQUESTS as f64)),
        ("latency_us", Json::num(LATENCY_US as f64)),
        ("cold_ttft_us", Json::num(cold_ttft)),
        ("warm_ttft_us", Json::num(warm_ttft)),
        ("results", results_json(&results)),
        // CI gates: the counters are deterministic accounting over a fixed
        // mix; the TTFT collapse and warm serve time carry the loose
        // wall-time threshold in the baseline
        ("regress_on", Json::obj(vec![
            ("prefix_hits_total", gate(c.hits as f64, true)),
            ("prefix_misses_total", gate(c.misses as f64, false)),
            ("prefix_inserts_total", gate(c.inserts as f64, false)),
            ("prefix_prefill_tokens_saved",
             gate(c.prefill_tokens_saved as f64, true)),
            ("prefix_ttft_collapse", gate(collapse, true)),
            ("prefix_warm_serve_mean_us",
             gate(results[1].mean_us, false)),
        ])),
    ]);
    let path = write_bench_json("prefix", payload).expect("bench json");
    println!("wrote {}", path.display());
}
