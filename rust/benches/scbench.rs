//! Table 2 reproduction: SCBench analog — per-task accuracy at one budget.
//! Shape to match: all eviction methods fail retr_kv (incompressible);
//! TRIM-KV leads the compressible tasks; manyshot stays easy for everyone.

use trimkv::eval::bench_support::{bench_n, load_ctx};
use trimkv::eval::{results_table, run_suite};
use trimkv::workload::suites;

fn main() {
    let Some(mut ctx) = load_ctx("scbench") else { return };
    let n = bench_n(16);
    let budget = 40usize;
    let policies = ["trimkv", "snapkv", "h2o", "streaming_llm", "fullkv"];
    let tasks = ["retr_kv", "manyshot", "math_find", "multi_session", "summary"];
    // token-by-token prefill: eviction pressure applies over the whole
    // sequence (the paper's long-horizon setting), not just past chunk 1
    ctx.cfg.chunked_prefill = false;
    let max_m = ctx.max_slots(8);
    let mut backend = ctx.backend(8, max_m, "default");
    let mut all = Vec::new();
    for task in tasks {
        let suite = suites::scbench(&ctx.vocab, task, n, 17);
        for policy in policies {
            let eff = if policy == "fullkv" {
                max_m - ctx.meta.chunk - 1
            } else {
                budget
            };
            let (mut r, be) = run_suite(backend, &ctx.cfg, &ctx.vocab, policy,
                                        eff, &suite).expect("scbench run");
            backend = be;
            r.task = task.to_string();
            all.push(r);
        }
    }
    println!("=== Table 2 analog (SCBench) ===\n{}", results_table(&all).render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/scbench.csv", results_table(&all).to_csv()).ok();
}
