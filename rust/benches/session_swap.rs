//! Session subsystem microbench: cost of serving one extra dialogue turn
//! with KV snapshot/swap versus re-prefilling the whole history (what a
//! session-less engine must do every turn).  Host-side mechanics only —
//! runs on the MockBackend, so it measures the engine + swap-path overhead
//! (slot-table snapshot, lane slab download/upload, store bookkeeping),
//! not model FLOPs.  With real artifacts the gap widens further: re-prefill
//! pays a graph execution per history token.
//!
//!   cargo bench --bench session_swap

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::runtime::MockBackend;
use trimkv::scheduler::Request;
use trimkv::util::benchkit::{bench, report, BenchResult};

fn engine(budget: usize, swap_policy: &str) -> Engine<MockBackend> {
    let cfg = EngineConfig {
        policy: "trimkv".into(),
        budget,
        batch: 1,
        chunked_prefill: false,
        swap_policy: swap_policy.into(),
        ..Default::default()
    };
    Engine::new(MockBackend::new(1, budget + 20), cfg, 2).unwrap()
}

fn history_prompt(len: usize) -> Vec<u32> {
    (0..len).map(|i| 32 + (i as u32 % 64)).collect()
}

fn main() {
    let budget = 48usize;
    let mut results: Vec<BenchResult> = Vec::new();
    let mut ratios: Vec<(usize, f64)> = Vec::new();
    for &ctx in &[128usize, 512, 1024] {
        // build a session whose history is `ctx` tokens, swapped out to host
        let mut e = engine(budget, "eager");
        e.submit(Request::new(0, history_prompt(ctx), 1).with_session("bench"))
            .unwrap();
        e.run_to_completion().unwrap();
        let template = e.sessions().get("bench").unwrap().clone();
        let turn: Vec<u32> = vec![40, 41];

        // (a) session turn: swap-in + ~3 decode ticks + swap-out
        let mut id = 1u64;
        let r = bench(&format!("session_turn/ctx={ctx}"), 5, 50, || {
            // reset to the template so history does not grow across iters
            e.sessions_mut().insert("bench".into(), template.clone());
            e.submit(Request::new(id, turn.clone(), 1).with_session("bench"))
                .unwrap();
            id += 1;
            e.run_to_completion().unwrap();
        });
        let session_mean = r.mean_us;
        results.push(r);

        // (b) swap-out + swap-in round-trip with a minimal turn between
        let mut e2 = engine(budget, "lazy");
        e2.submit(Request::new(0, history_prompt(ctx), 1).with_session("rt"))
            .unwrap();
        e2.run_to_completion().unwrap();
        let r = bench(&format!("swap_roundtrip/ctx={ctx}"), 5, 100, || {
            e2.flush_sessions().unwrap(); // parked -> host (swap-out)
            // next turn swaps back in and re-parks
            e2.submit(Request::new(99, vec![40], 1).with_session("rt"))
                .unwrap();
            e2.run_to_completion().unwrap();
        });
        results.push(r);

        // (c) the session-less alternative: re-prefill all ctx tokens
        let mut e3 = engine(budget, "lazy");
        let full: Vec<u32> = {
            let mut p = history_prompt(ctx);
            p.extend(&turn);
            p
        };
        let r = bench(&format!("reprefill_turn/ctx={ctx}"), 2, 10, || {
            e3.submit(Request::new(7, full.clone(), 1)).unwrap();
            e3.run_to_completion().unwrap();
        });
        ratios.push((ctx, r.mean_us / session_mean.max(1e-9)));
        results.push(r);
    }
    println!("=== session swap vs re-prefill (budget {budget}, mock backend) ===");
    report(&results);
    println!();
    for (ctx, ratio) in ratios {
        let verdict = if ratio > 1.0 { "session wins" } else { "re-prefill wins" };
        println!("ctx {ctx:5}: re-prefill / session-turn = {ratio:6.1}x  ({verdict})");
    }
    // snapshot footprint is O(budget), not O(history): the whole point of
    // swapping a memory-bounded cache
    use trimkv::runtime::ModelBackend;
    let mb = MockBackend::new(1, budget + 20);
    let slab_bytes = 2 * mb.lane_kv_len() * 4; // K + V, f32
    println!("\nper-session K/V slab at budget {budget}: {} KiB \
              (independent of ctx)", slab_bytes / 1024);
}
