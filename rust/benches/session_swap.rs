//! Session subsystem microbench: cost of serving one extra dialogue turn
//! with KV snapshot/swap versus re-prefilling the whole history (what a
//! session-less engine must do every turn), plus the batched-swap scaling
//! law: swap time/traffic is O(swapped lanes) and flat in batch size.
//! Host-side mechanics only — runs on the MockBackend, so it measures the
//! engine + swap-path overhead (slot-table snapshot, per-lane slab
//! transfer, store bookkeeping), not model FLOPs.  With real artifacts the
//! gap widens further: re-prefill pays a graph execution per history token.
//!
//! Emits `BENCH_session_swap.json` (util::benchkit) so the perf trajectory
//! is tracked across PRs.
//!
//!   cargo bench --bench session_swap

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::runtime::{LaneKv, MockBackend, ModelBackend};
use trimkv::scheduler::Request;
use trimkv::util::benchkit::{bench, gate, iters, report, results_json,
                             write_bench_json, BenchResult};
use trimkv::util::json::Json;

fn engine(budget: usize, swap_policy: &str) -> Engine<MockBackend> {
    let cfg = EngineConfig {
        policy: "trimkv".into(),
        budget,
        batch: 1,
        chunked_prefill: false,
        swap_policy: swap_policy.into(),
        ..Default::default()
    };
    Engine::new(MockBackend::new(1, budget + 20), cfg, 2).unwrap()
}

fn history_prompt(len: usize) -> Vec<u32> {
    (0..len).map(|i| 32 + (i as u32 % 64)).collect()
}

fn main() {
    let budget = 48usize;
    let mut results: Vec<BenchResult> = Vec::new();
    let mut ratios: Vec<(usize, f64)> = Vec::new();
    for &ctx in &[128usize, 512, 1024] {
        // build a session whose history is `ctx` tokens, swapped out to host
        let mut e = engine(budget, "eager");
        e.submit(Request::new(0, history_prompt(ctx), 1).with_session("bench"))
            .unwrap();
        e.run_to_completion().unwrap();
        let template = e.sessions().get("bench").unwrap().clone();
        let turn: Vec<u32> = vec![40, 41];

        // (a) session turn: swap-in + ~3 decode ticks + swap-out
        let (w, n) = iters(5, 50);
        let mut id = 1u64;
        let r = bench(&format!("session_turn/ctx={ctx}"), w, n, || {
            // reset to the template so history does not grow across iters
            e.sessions_mut().insert("bench".into(), template.clone());
            e.submit(Request::new(id, turn.clone(), 1).with_session("bench"))
                .unwrap();
            id += 1;
            e.run_to_completion().unwrap();
        });
        let session_mean = r.mean_us;
        results.push(r);

        // (b) swap-out + swap-in round-trip with a minimal turn between
        let mut e2 = engine(budget, "lazy");
        e2.submit(Request::new(0, history_prompt(ctx), 1).with_session("rt"))
            .unwrap();
        e2.run_to_completion().unwrap();
        let (w, n) = iters(5, 100);
        let r = bench(&format!("swap_roundtrip/ctx={ctx}"), w, n, || {
            e2.flush_sessions().unwrap(); // parked -> host (swap-out)
            // next turn swaps back in and re-parks
            e2.submit(Request::new(99, vec![40], 1).with_session("rt"))
                .unwrap();
            e2.run_to_completion().unwrap();
        });
        results.push(r);

        // (c) the session-less alternative: re-prefill all ctx tokens
        let mut e3 = engine(budget, "lazy");
        let full: Vec<u32> = {
            let mut p = history_prompt(ctx);
            p.extend(&turn);
            p
        };
        let (w, n) = iters(2, 10);
        let r = bench(&format!("reprefill_turn/ctx={ctx}"), w, n, || {
            e3.submit(Request::new(7, full.clone(), 1)).unwrap();
            e3.run_to_completion().unwrap();
        });
        ratios.push((ctx, r.mean_us / session_mean.max(1e-9)));
        results.push(r);
    }
    println!("=== session swap vs re-prefill (budget {budget}, mock backend) ===");
    report(&results);
    println!();
    for (ctx, ratio) in &ratios {
        let verdict = if *ratio > 1.0 { "session wins" } else { "re-prefill wins" };
        println!("ctx {ctx:5}: re-prefill / session-turn = {ratio:6.1}x  ({verdict})");
    }

    // --- batched swap scaling: O(swapped lanes), flat in batch size ------
    // one mixed swap_lanes call per iteration (n lanes out + n lanes in);
    // transfer counters give exact per-call element traffic
    let mut scaling: Vec<(usize, usize, f64, f64, f64)> = Vec::new();
    let mut scaling_results: Vec<BenchResult> = Vec::new();
    for &batch in &[2usize, 4, 8] {
        let mut mb = MockBackend::new(batch, budget + 20);
        let lane_len = mb.lane_kv_len();
        let slab = LaneKv { k: vec![0.5; lane_len], v: vec![0.25; lane_len] };
        for n in [1usize, 2, batch] {
            let lanes: Vec<usize> = (0..n).collect();
            let inn: Vec<(usize, &LaneKv)> =
                lanes.iter().map(|&i| (i, &slab)).collect();
            let before = mb.swap_traffic();
            let (w, it) = iters(3, 200);
            let r = bench(&format!("swap_lanes/b={batch}/n={n}"), w, it, || {
                mb.swap_lanes(&lanes, &inn).unwrap();
            });
            let after = mb.swap_traffic();
            let calls = (after.swap_calls - before.swap_calls) as f64;
            let eo = (after.elems_out - before.elems_out) as f64 / calls;
            let ei = (after.elems_in - before.elems_in) as f64 / calls;
            assert_eq!(eo as usize, n * 2 * lane_len,
                       "swap traffic is not O(swapped lanes)");
            scaling.push((batch, n, r.mean_us, eo, ei));
            scaling_results.push(r);
        }
    }
    println!("\n=== batched swap scaling (elements moved per call) ===");
    report(&scaling_results);
    let one_lane: Vec<f64> = scaling
        .iter()
        .filter(|&&(_, n, ..)| n == 1)
        .map(|&(_, _, _, eo, _)| eo)
        .collect();
    assert!(one_lane.windows(2).all(|w| w[0] == w[1]),
            "single-lane swap traffic varies with batch size: {one_lane:?}");
    println!("\nswapping 1 lane moves {} elements at every batch size \
              (flat in B; linear in swapped-lane count)", one_lane[0]);
    results.extend(scaling_results);

    // snapshot footprint is O(budget), not O(history): the whole point of
    // swapping a memory-bounded cache
    let mb = MockBackend::new(1, budget + 20);
    let slab_bytes = 2 * mb.lane_kv_len() * 4; // K + V, f32
    println!("\nper-session K/V slab at budget {budget}: {} KiB \
              (independent of ctx)", slab_bytes / 1024);

    // machine-readable record for cross-PR perf tracking
    let payload = Json::obj(vec![
        ("budget", Json::num(budget as f64)),
        ("results", results_json(&results)),
        ("reprefill_over_session", Json::Arr(
            ratios.iter().map(|&(ctx, ratio)| Json::obj(vec![
                ("ctx", Json::num(ctx as f64)),
                ("ratio", Json::num(ratio)),
            ])).collect())),
        ("swap_scaling", Json::Arr(
            scaling.iter().map(|&(b, n, mean_us, eo, ei)| Json::obj(vec![
                ("batch", Json::num(b as f64)),
                ("lanes_swapped", Json::num(n as f64)),
                ("mean_us", Json::num(mean_us)),
                ("elems_out_per_call", Json::num(eo)),
                ("elems_in_per_call", Json::num(ei)),
            ])).collect())),
        // CI gate: one-lane swap traffic is exact and machine-independent;
        // the ratio catches a session path that stops beating re-prefill
        ("regress_on", Json::obj(vec![
            ("one_lane_swap_elems", gate(one_lane[0], false)),
            ("reprefill_over_session_ctx1024",
             gate(ratios.last().map(|&(_, r)| r).unwrap_or(f64::NAN), true)),
        ])),
    ]);
    let path = write_bench_json("session_swap", payload).expect("bench json");
    println!("wrote {}", path.display());
}
