//! Mixed-tick scheduler bench: decode latency under a long-prompt
//! admission, alternating vs fused scheduling.
//!
//! The serving regime the ROADMAP north-star targets: a decode-heavy batch
//! (7 of 8 lanes streaming tokens) takes one 256-token prompt.  The
//! alternating scheduler must pick a phase per tick — `prefill_priority`
//! stalls every decoder for the whole prefill, `!prefill_priority` starves
//! the prompt until the decoders drain — while the mixed scheduler fuses a
//! decode token for every streaming lane *and* a budgeted prefill chunk
//! into each backend step.  Host-side mechanics on the MockBackend, so the
//! numbers isolate scheduling, not model FLOPs; the tick-denominated
//! metrics (tokens per tick, TTFT in ticks) are fully deterministic and
//! machine-independent — those are what CI gates on.
//!
//! Emits `BENCH_mixed_tick.json` (util::benchkit) with a `regress_on`
//! block for the CI bench-smoke job.
//!
//!   cargo bench --bench mixed_tick [-- --quick]

use std::time::Instant;

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::runtime::MockBackend;
use trimkv::scheduler::Request;
use trimkv::util::benchkit::{bench, gate, iters, report, results_json,
                             write_bench_json, BenchResult};
use trimkv::util::json::Json;

const BATCH: usize = 8;
const BUDGET: usize = 48;
const DECODERS: u64 = 7;
const LONG_PROMPT: usize = 256; // 16 chunks of the mock's c = 16

struct ModeStats {
    name: &'static str,
    /// ticks from the long admission until its prompt is fully prefilled
    /// (== its TTFT in ticks; the first sample lands on the last one)
    ttft_ticks: u64,
    ttft_ms: f64,
    /// tokens the 7 streaming lanes decoded inside that window
    decode_tokens_during_prefill: u64,
    /// the stall-free criterion: 7.0 means every decoder progressed every
    /// tick of the prefill window
    decode_tok_per_tick_under_prefill: f64,
    /// worst tick gap between any lane's consecutive tokens
    tbt_ticks_max: f64,
    wall_ms: f64,
}

fn run_mode(name: &'static str, mixed: bool, priority: bool,
            tick_budget: usize) -> ModeStats {
    let cfg = EngineConfig {
        policy: "trimkv".into(),
        budget: BUDGET,
        batch: BATCH,
        max_new_tokens: 64,
        chunked_prefill: true,
        mixed_ticks: mixed,
        prefill_priority: priority,
        tick_token_budget: tick_budget,
        ..Default::default()
    };
    let mut e = Engine::new(MockBackend::new(BATCH, BUDGET + 20), cfg, 2)
        .expect("engine");
    for i in 0..DECODERS {
        e.submit(Request::new(i, vec![1, 40 + i as u32], 64)).unwrap();
    }
    // reach steady decode on the streaming lanes
    while e.metrics.tokens_decoded < DECODERS {
        e.tick().unwrap();
    }
    let long: Vec<u32> = (0..LONG_PROMPT).map(|i| 32 + (i % 64) as u32).collect();
    e.submit(Request::new(100, long, 4)).unwrap();
    let total_prefill = DECODERS as u64 * 2 + LONG_PROMPT as u64;
    let (ticks0, dec0) = (e.ticks(), e.metrics.tokens_decoded);
    let t0 = Instant::now();
    while e.metrics.tokens_prefilled < total_prefill {
        e.tick().unwrap();
    }
    let window_ticks = e.ticks() - ticks0;
    // the long lane samples its first token on the window's last tick;
    // everything else decoded in the window came from the streaming lanes
    let dec_tokens = (e.metrics.tokens_decoded - dec0).saturating_sub(1);
    let rs = e.run_to_completion().unwrap();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let ttft_ms = rs
        .iter()
        .find(|r| r.id == 100)
        .map(|r| r.ttft_us / 1e3)
        .expect("long request response");
    ModeStats {
        name,
        ttft_ticks: window_ticks,
        ttft_ms,
        decode_tokens_during_prefill: dec_tokens,
        decode_tok_per_tick_under_prefill: dec_tokens as f64
            / window_ticks.max(1) as f64,
        tbt_ticks_max: e.metrics.tbt_ticks.max(),
        wall_ms,
    }
}

fn main() {
    let modes: Vec<ModeStats> = vec![
        run_mode("mixed", true, false, 0),
        // tight budget: 7 decoders reserved, 3 prompt tokens per tick —
        // prefill stretches out, decode throughput is untouched
        run_mode("mixed_budget10", true, false, 10),
        run_mode("alternating_prefill_priority", false, true, 0),
        run_mode("alternating_decode_first", false, false, 0),
    ];
    println!("=== decode progress under a {LONG_PROMPT}-token admission \
              ({DECODERS} streaming lanes, mock backend) ===");
    println!("{:<30} {:>10} {:>10} {:>12} {:>12} {:>8}",
             "mode", "ttft_tk", "ttft_ms", "dec_in_win", "dec/tick", "gap_max");
    for s in &modes {
        println!("{:<30} {:>10} {:>10.2} {:>12} {:>12.2} {:>8.0}",
                 s.name, s.ttft_ticks, s.ttft_ms,
                 s.decode_tokens_during_prefill,
                 s.decode_tok_per_tick_under_prefill, s.tbt_ticks_max);
    }
    let mixed = &modes[0];
    assert_eq!(mixed.decode_tok_per_tick_under_prefill, DECODERS as f64,
               "mixed scheduling must keep every decoder moving every tick");
    assert_eq!(mixed.tbt_ticks_max, 1.0, "mixed tick stalled a decoder");

    // wall-time distribution of the full contended workload per scheduler
    let (warmup, n) = iters(3, 15);
    let mut results: Vec<BenchResult> = Vec::new();
    for (name, mixed_on, prio) in [
        ("workload/mixed", true, false),
        ("workload/alternating", false, true),
    ] {
        results.push(bench(name, warmup, n, || {
            std::hint::black_box(run_mode("timed", mixed_on, prio, 0));
        }));
    }
    report(&results);

    let payload = Json::obj(vec![
        ("batch", Json::num(BATCH as f64)),
        ("budget", Json::num(BUDGET as f64)),
        ("long_prompt", Json::num(LONG_PROMPT as f64)),
        ("results", results_json(&results)),
        ("modes", Json::Arr(modes.iter().map(|s| Json::obj(vec![
            ("mode", Json::str(s.name)),
            ("ttft_ticks", Json::num(s.ttft_ticks as f64)),
            ("ttft_ms", Json::num(s.ttft_ms)),
            ("decode_tokens_during_prefill",
             Json::num(s.decode_tokens_during_prefill as f64)),
            ("decode_tok_per_tick_under_prefill",
             Json::num(s.decode_tok_per_tick_under_prefill)),
            ("tbt_ticks_max", Json::num(s.tbt_ticks_max)),
            ("wall_ms", Json::num(s.wall_ms)),
        ])).collect())),
        // CI gate: tick-denominated metrics are deterministic; the wall
        // time gate catches engine-side slowdowns of the fused path
        ("regress_on", Json::obj(vec![
            ("mixed_decode_tok_per_tick_under_prefill",
             gate(mixed.decode_tok_per_tick_under_prefill, true)),
            ("mixed_ttft_ticks", gate(mixed.ttft_ticks as f64, false)),
            ("mixed_workload_mean_us", gate(results[0].mean_us, false)),
        ])),
    ]);
    let path = write_bench_json("mixed_tick", payload).expect("bench json");
    println!("wrote {}", path.display());
}
