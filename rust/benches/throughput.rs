//! Table 6 reproduction: decode throughput and wall time per method over a
//! (context length x batch) grid at a fixed KV budget.  The shape to match:
//! bounded-cache methods (TRIM-KV, SnapKV) beat FullKV at long context, and
//! TRIM-KV's O(M) policy is no slower than SnapKV's heuristic; the
//! retrieval baseline gains no throughput over FullKV.

use trimkv::eval::bench_support::{bench_n, load_ctx};
use trimkv::eval::{run_suite, throughput_table};
use trimkv::workload::suites;

fn main() {
    let Some(ctx) = load_ctx("throughput") else { return };
    let n = bench_n(6);
    let budget = 96usize;
    let grid = [(256usize, 8usize), (512, 8)];
    let methods = ["fullkv", "retrieval", "snapkv", "trimkv"];
    let mut results = Vec::new();
    for (ctx_len, batch) in grid {
        // fullkv/retrieval keep everything resident; bounded methods load
        // the smallest artifact that fits their budget (that IS the win)
        for method in methods {
            let (slots_needed, eff_budget) = if method == "fullkv" {
                (ctx_len + 96 + ctx.meta.chunk, ctx_len + 80)
            } else {
                (budget + ctx.meta.chunk + 1, budget)
            };
            let max_m = ctx.max_slots(batch);
            if slots_needed > max_m {
                println!("skip {method} @ ctx {ctx_len} (needs {slots_needed} slots)");
                continue;
            }
            let backend = ctx.backend(batch, slots_needed, "default");
            let suite = suites::throughput(&ctx.vocab, ctx_len, n, 7);
            let (mut r, _) = run_suite(backend, &ctx.cfg, &ctx.vocab, method,
                                       eff_budget, &suite)
                .expect("throughput run");
            r.task = format!("ctx{ctx_len}b{batch}");
            println!("{method:>12} ctx {ctx_len} batch {batch}: \
                      {:.1} tok/s, {:.2} ms/step", r.tok_s, r.decode_ms_p50);
            results.push(r);
        }
    }
    println!("\n=== Table 6 analog ===\n{}", throughput_table(&results).render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/throughput.csv",
                   throughput_table(&results).to_csv()).ok();
}
