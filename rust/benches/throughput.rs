//! Table 6 reproduction: decode throughput and wall time per method over a
//! (context length x batch) grid at a fixed KV budget.  The shape to match:
//! bounded-cache methods (TRIM-KV, SnapKV) beat FullKV at long context, and
//! TRIM-KV's O(M) policy is no slower than SnapKV's heuristic; the
//! retrieval baseline gains no throughput over FullKV.
//!
//! Emits `BENCH_throughput.json` (util::benchkit) so the perf trajectory is
//! tracked across PRs; without artifacts the record is marked skipped.

use trimkv::eval::bench_support::{bench_n, load_ctx};
use trimkv::eval::{run_suite, throughput_table, SuiteResult};
use trimkv::util::benchkit::{gate, quick, write_bench_json};
use trimkv::util::json::Json;
use trimkv::workload::suites;

fn results_json(results: &[SuiteResult]) -> Json {
    Json::Arr(results.iter().map(|r| Json::obj(vec![
        ("method", Json::str(r.policy.clone())),
        ("budget", Json::num(r.budget as f64)),
        ("ctx", Json::str(r.task.clone())),
        ("n", Json::num(r.n as f64)),
        ("tok_s", Json::num(r.tok_s)),
        ("decode_ms_p50", Json::num(r.decode_ms_p50)),
        ("wall_s", Json::num(r.wall_s)),
    ])).collect())
}

fn main() {
    let Some(ctx) = load_ctx("throughput") else {
        let payload = Json::obj(vec![
            ("skipped", Json::Bool(true)),
            ("reason", Json::str("no artifacts; run `make artifacts`")),
        ]);
        let path = write_bench_json("throughput", payload).expect("bench json");
        println!("wrote {} (skipped marker)", path.display());
        return;
    };
    let n = if quick() { 2 } else { bench_n(6) };
    let budget = 96usize;
    let grid: &[(usize, usize)] =
        if quick() { &[(256, 8)] } else { &[(256, 8), (512, 8)] };
    let methods = ["fullkv", "retrieval", "snapkv", "trimkv"];
    let mut results = Vec::new();
    for &(ctx_len, batch) in grid {
        // fullkv/retrieval keep everything resident; bounded methods load
        // the smallest artifact that fits their budget (that IS the win)
        for method in methods {
            let (slots_needed, eff_budget) = if method == "fullkv" {
                (ctx_len + 96 + ctx.meta.chunk, ctx_len + 80)
            } else {
                (budget + ctx.meta.chunk + 1, budget)
            };
            let max_m = ctx.max_slots(batch);
            if slots_needed > max_m {
                println!("skip {method} @ ctx {ctx_len} (needs {slots_needed} slots)");
                continue;
            }
            let backend = ctx.backend(batch, slots_needed, "default");
            let suite = suites::throughput(&ctx.vocab, ctx_len, n, 7);
            let (mut r, _) = run_suite(backend, &ctx.cfg, &ctx.vocab, method,
                                       eff_budget, &suite)
                .expect("throughput run");
            r.task = format!("ctx{ctx_len}b{batch}");
            println!("{method:>12} ctx {ctx_len} batch {batch}: \
                      {:.1} tok/s, {:.2} ms/step", r.tok_s, r.decode_ms_p50);
            results.push(r);
        }
    }
    println!("\n=== Table 6 analog ===\n{}", throughput_table(&results).render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/throughput.csv",
                   throughput_table(&results).to_csv()).ok();
    // CI gate: bounded-cache decode throughput at the first grid cell
    let trimkv_tok_s = results
        .iter()
        .find(|r| r.policy == "trimkv")
        .map(|r| r.tok_s)
        .unwrap_or(f64::NAN);
    let payload = Json::obj(vec![
        ("budget", Json::num(budget as f64)),
        ("results", results_json(&results)),
        ("regress_on", Json::obj(vec![
            ("trimkv_tok_s", gate(trimkv_tok_s, true)),
        ])),
    ]);
    let path = write_bench_json("throughput", payload).expect("bench json");
    println!("wrote {}", path.display());
}
