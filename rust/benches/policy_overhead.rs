//! Appendix A.2 reproduction: per-step policy cost vs cache size M.
//! TRIM-KV's victim selection is O(M); R-KV/KeyDiff pay O(M^2 dh) for key
//! similarity.  Pure host-side microbench — no artifacts needed.

use trimkv::kvcache::{HeadState, SlotEntry};
use trimkv::policy::Policy;
use trimkv::util::benchkit::{bench, report, BenchResult};
use trimkv::util::rng::Rng;

fn filled_head(m: usize, dh: usize, rng: &mut Rng) -> HeadState {
    let mut h = HeadState::new(m + 2, dh, true);
    for s in 0..m {
        let key: Vec<f32> = (0..dh).map(|_| rng.normal() as f32).collect();
        h.insert(
            s,
            SlotEntry {
                pos: s as i64,
                token: rng.below(512) as u32,
                log_beta: -(rng.f32() * 2.0 + 0.001),
                acc_attn: rng.f32(),
                ema_attn: rng.f32(),
                last_attn: rng.f32(),
            },
            Some(&key),
        );
    }
    h
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    for &m in &[64usize, 128, 256, 512] {
        let mut rng = Rng::new(9);
        let head = filled_head(m, 32, &mut rng);
        for name in ["trimkv", "h2o", "snapkv", "streaming_llm", "rkv", "keydiff"] {
            let mut pol = Policy::from_name(name, m, 1).unwrap();
            let r = bench(&format!("{name}/M={m}"), 20, 200, || {
                std::hint::black_box(pol.select_victim(&head, m as i64 + 5));
            });
            results.push(r);
        }
    }
    println!("=== Appendix A.2 analog: victim-selection cost vs M ===");
    report(&results);
    // sanity: trimkv must scale ~linearly, rkv superlinearly
    let t64 = results.iter().find(|r| r.name == "trimkv/M=64").unwrap().mean_us;
    let t512 = results.iter().find(|r| r.name == "trimkv/M=512").unwrap().mean_us;
    let r64 = results.iter().find(|r| r.name == "rkv/M=64").unwrap().mean_us;
    let r512 = results.iter().find(|r| r.name == "rkv/M=512").unwrap().mean_us;
    println!("\ntrimkv 512/64 ratio: {:.1}x (O(M) expected ~8x)", t512 / t64);
    println!("rkv    512/64 ratio: {:.1}x (O(M^2) expected ~64x)", r512 / r64);
}
