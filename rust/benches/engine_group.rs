//! Engine-group bench: aggregate token throughput of N routed replicas vs
//! one engine, plus the migration-correctness gate.
//!
//! The MockBackend's synthetic execute latency stands in for the device:
//! every replica thread sleeps its own step latency concurrently, so a
//! well-routed group approaches N× the single-engine token rate.  The
//! workload is a deterministic skewed session mix
//! (`workload::session_mix`): hot conversations pin to hash homes, cold
//! ones and one-shots spread by lane availability, and the router's
//! rebalancer may move a quiescent session off a saturated replica.
//!
//! Inline correctness asserts (the bench doubles as an end-to-end check):
//! - every per-request token stream at N=2 is bit-exact with N=1 —
//!   placement and migration are scheduling changes only;
//! - both replicas finish work under the skewed mix (no starvation);
//! - a session explicitly migrated between turns answers bit-exactly like
//!   a never-migrated engine (TRIM-KV's creation-time scores make the
//!   moved cache valid verbatim).
//!
//! Deterministic CI gates: the routed / migrated counters (placement is
//! pure accounting — submit order is fixed and responses drain after all
//! submits, so the decision sequence is machine-independent).  Wall-clock
//! tok/s and the N=2 scaling ratio carry the loose wall-time threshold.
//!
//! Emits `BENCH_group.json` (util::benchkit) for the CI bench-smoke job's
//! regression gate.
//!
//!   cargo bench --bench engine_group [-- --quick]

use std::time::Instant;

use trimkv::config::EngineConfig;
use trimkv::engine::Engine;
use trimkv::router::EngineGroup;
use trimkv::runtime::MockBackend;
use trimkv::scheduler::Request;
use trimkv::util::benchkit::{bench, gate, iters, report, results_json,
                             write_bench_json, BenchResult};
use trimkv::util::json::Json;
use trimkv::workload::{session_mix, Arrival};

const BATCH: usize = 4;
const BUDGET: usize = 24;
/// Synthetic device step latency: device-bound, so scaling is visible.
const LATENCY_US: u64 = 200;
const SESSIONS: usize = 8;
const TURNS: usize = 64;
const MIX_SEED: u64 = 11;

fn cfg() -> EngineConfig {
    EngineConfig {
        policy: "trimkv".into(),
        budget: BUDGET,
        batch: BATCH,
        chunked_prefill: true,
        mixed_ticks: true,
        ..Default::default()
    }
}

fn make_group(n: usize, latency_us: u64) -> EngineGroup {
    EngineGroup::spawn(n, true, |_| {
        let backend = MockBackend::new(BATCH, BUDGET + 24)
            .with_synthetic_latency_us(latency_us);
        Engine::new(backend, cfg(), 2)
    })
    .expect("group")
}

struct RunStats {
    wall_ms: f64,
    tokens: u64,
    streams: Vec<(u64, Vec<u32>)>,
    routed: u64,
    rebalances: u64,
    /// finished requests per replica, parsed off the aggregated scrape
    finished: Vec<u64>,
}

fn run_group(n: usize, arrivals: &[Arrival]) -> RunStats {
    let group = make_group(n, LATENCY_US);
    let t0 = Instant::now();
    for a in arrivals {
        let mut req = Request::new(a.id, a.prompt.clone(), a.max_new);
        if let Some(s) = &a.session {
            req = req.with_session(s.clone());
        }
        group.submit(req);
    }
    let mut streams = Vec::with_capacity(arrivals.len());
    let mut tokens = 0u64;
    for _ in 0..arrivals.len() {
        let r = group.recv_blocking().expect("group response");
        tokens += r.tokens.len() as u64;
        streams.push((r.id, r.tokens));
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let m = group.router.metrics();
    let text = group.metrics_snapshot().expect("scrape");
    let mut finished = vec![0u64; n];
    for line in text.lines() {
        if let Some(rest) =
            line.strip_prefix("trimkv_requests_finished_total{replica=\"")
        {
            if let Some((i, v)) = rest.split_once("\"} ") {
                finished[i.parse::<usize>().unwrap()] =
                    v.parse::<f64>().unwrap() as u64;
            }
        }
    }
    group.shutdown();
    streams.sort_by_key(|(id, _)| *id);
    RunStats {
        wall_ms,
        tokens,
        streams,
        routed: m.routed,
        rebalances: m.rebalances,
        finished,
    }
}

/// Migration-correctness scenario: K two-turn sessions, each explicitly
/// migrated to the other replica between its turns; a plain single engine
/// is the never-migrated reference.  Returns the migration counter.
fn migration_check() -> u64 {
    let group = make_group(2, 0);
    let turn1 = |s: usize| -> Vec<u32> {
        (0..6).map(|j| 32 + ((s * 7 + j) % 64) as u32).collect()
    };
    let turn2 = |s: usize| -> Vec<u32> {
        (0..3).map(|j| 40 + ((s * 5 + j) % 48) as u32).collect()
    };
    const K: usize = 4;
    let mut grouped: Vec<Vec<Vec<u32>>> = Vec::new();
    for s in 0..K {
        let sid = format!("mig-{s}");
        group.submit(Request::new(s as u64, turn1(s), 4).with_session(&sid));
        let r1 = group.recv_blocking().expect("turn 1");
        let target = 1 - group.router.replica_for(&sid);
        group.migrate_session(&sid, target).expect("migration");
        group.submit(
            Request::new(100 + s as u64, turn2(s), 4).with_session(&sid));
        let r2 = group.recv_blocking().expect("turn 2");
        grouped.push(vec![r1.tokens, r2.tokens]);
    }
    let migrations = group.router.metrics().migrations;
    group.shutdown();
    // never-migrated reference: one engine per session, both turns local
    for (s, got) in grouped.iter().enumerate() {
        let mut e = Engine::new(MockBackend::new(BATCH, BUDGET + 24),
                                cfg(), 2).expect("engine");
        let mut want = Vec::new();
        for (t, prompt) in [turn1(s), turn2(s)].into_iter().enumerate() {
            e.submit(Request::new(t as u64, prompt, 4).with_session("ref"))
                .unwrap();
            let rs = e.run_to_completion().unwrap();
            want.push(rs[0].tokens.clone());
        }
        assert_eq!(got, &want,
                   "migrated session {s} diverged from the never-migrated \
                    reference");
    }
    migrations
}

fn main() {
    let arrivals = session_mix(MIX_SEED, SESSIONS, TURNS, 0.5, 1.0);
    println!("=== engine group scaling ({TURNS} arrivals, {SESSIONS} \
              skewed sessions, {BATCH} lanes/replica, {LATENCY_US}us \
              device step) ===");

    // canonical runs: correctness asserts + deterministic counters
    let one = run_group(1, &arrivals);
    let two = run_group(2, &arrivals);
    assert_eq!(one.streams, two.streams,
               "replication changed a token stream");
    assert!(two.finished.iter().all(|&f| f > 0),
            "a replica starved under the skewed mix: {:?}", two.finished);
    assert_eq!(one.routed, TURNS as u64);
    assert_eq!(two.routed, TURNS as u64);
    let migrations = migration_check();
    assert_eq!(migrations, 4, "migration scenario lost a handoff");

    println!("{:<9} {:>10} {:>8} {:>10} {:>11} {:>14}",
             "replicas", "wall_ms", "tokens", "tok_s", "rebalances",
             "finished/repl");
    for (n, s) in [(1usize, &one), (2, &two)] {
        println!("{:<9} {:>10.2} {:>8} {:>10.0} {:>11} {:>14}",
                 n, s.wall_ms, s.tokens,
                 s.tokens as f64 / (s.wall_ms / 1e3), s.rebalances,
                 format!("{:?}", s.finished));
    }

    // wall-time distribution over repeated runs (spawn + serve + join)
    let (warmup, n_iters) = iters(1, 5);
    let mut results: Vec<BenchResult> = Vec::new();
    for (name, n) in [("serve/n1", 1usize), ("serve/n2", 2)] {
        results.push(bench(name, warmup, n_iters, || {
            std::hint::black_box(run_group(n, &arrivals));
        }));
    }
    report(&results);
    let tokens = one.tokens as f64;
    let n1_tok_s = tokens / (results[0].mean_us / 1e6);
    let n2_tok_s = tokens / (results[1].mean_us / 1e6);
    let scaling = n2_tok_s / n1_tok_s;
    println!("aggregate throughput: n1 {n1_tok_s:.0} tok/s, n2 \
              {n2_tok_s:.0} tok/s -> {scaling:.2}x scaling");
    // sanity floor (broken routing serializes to ~1x); the ≥1.7x target
    // is the baseline-gated value
    assert!(scaling > 1.4,
            "N=2 scaling collapsed to {scaling:.2}x (routing serialized?)");

    let payload = Json::obj(vec![
        ("batch", Json::num(BATCH as f64)),
        ("budget", Json::num(BUDGET as f64)),
        ("turns", Json::num(TURNS as f64)),
        ("sessions", Json::num(SESSIONS as f64)),
        ("latency_us", Json::num(LATENCY_US as f64)),
        ("tokens", Json::num(tokens)),
        ("n1_tok_s", Json::num(n1_tok_s)),
        ("n2_tok_s", Json::num(n2_tok_s)),
        ("rebalances_n2", Json::num(two.rebalances as f64)),
        ("finished_per_replica_n2", Json::arr_usize(
            &two.finished.iter().map(|&f| f as usize).collect::<Vec<_>>())),
        ("results", results_json(&results)),
        // CI gates: routed/migrated are deterministic accounting; tok/s
        // and the scaling ratio carry the loose wall-time threshold in
        // the baseline
        ("regress_on", Json::obj(vec![
            ("group_routed_total", gate(two.routed as f64, false)),
            ("group_migrations_total", gate(migrations as f64, true)),
            ("group_scaling_n2", gate(scaling, true)),
            ("group_n2_tok_s", gate(n2_tok_s, true)),
        ])),
    ]);
    let path = write_bench_json("group", payload).expect("bench json");
    println!("wrote {}", path.display());
}
