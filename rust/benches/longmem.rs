//! Tables 3 / 8 reproduction: LongMemEval analog — multi-session memory
//! accuracy under a budget ladder, split by question type.  Shape to match:
//! TRIM-KV degrades gracefully as the budget shrinks; StreamingLLM/SnapKV
//! collapse.

use trimkv::eval::bench_support::{bench_n, load_ctx};
use trimkv::eval::{pareto_table, results_table, run_suite};
use trimkv::workload::suites;

fn main() {
    let Some(mut ctx) = load_ctx("longmem") else { return };
    let n = bench_n(16);
    let budgets = [16usize, 32, 64];
    let policies = ["trimkv", "snapkv", "streaming_llm", "fullkv"];
    // token-by-token prefill: eviction pressure applies over the whole
    // sequence (the paper's long-horizon setting), not just past chunk 1
    ctx.cfg.chunked_prefill = false;
    let max_m = ctx.max_slots(8);
    let mut backend = ctx.backend(8, max_m, "default");
    let mut all = Vec::new();
    for qtype in ["single", "update"] {
        let suite = suites::longmem(&ctx.vocab, qtype, n, 5);
        let mut results = Vec::new();
        for policy in policies {
            for &budget in &budgets {
                if policy == "fullkv" && budget != *budgets.last().unwrap() {
                    continue;
                }
                let eff = if policy == "fullkv" {
                    max_m - ctx.meta.chunk - 1
                } else {
                    budget
                };
                let (mut r, be) = run_suite(backend, &ctx.cfg, &ctx.vocab,
                                            policy, eff, &suite)
                    .expect("longmem run");
                backend = be;
                r.task = qtype.to_string();
                if policy == "fullkv" {
                    r.budget = *budgets.last().unwrap();
                }
                results.push(r);
            }
        }
        println!("\n=== LongMemEval analog, qtype={qtype} ===\n{}",
                 pareto_table(&results, &budgets).render());
        all.extend(results);
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/longmem.csv",
                   results_table(&all).to_csv()).ok();
}
