//! Fig 3 / 6 / 7 reproduction: Pareto frontier of pass@1 vs KV budget on
//! the math suites (GSM8K / MATH-500 / AIME analogs), all eviction policies
//! plus the KeyDiff comparison and the loss-ablation gate variants
//! (Table 5) when they were trained.

use trimkv::eval::bench_support::{bench_n, load_ctx};
use trimkv::eval::{pareto_table, results_table, run_suite};
use trimkv::workload::suites;

fn main() {
    let Some(mut ctx) = load_ctx("pareto_math") else { return };
    let n = bench_n(16);
    let budgets = [16usize, 24, 40, 64];
    let policies = ["trimkv", "snapkv", "h2o", "rkv", "streaming_llm",
                    "keydiff", "random", "retrieval", "fullkv"];
    // token-by-token prefill: eviction pressure applies over the whole
    // sequence (the paper's long-horizon setting), not just past chunk 1
    ctx.cfg.chunked_prefill = false;
    let max_m = ctx.max_slots(8);
    let mut backend = ctx.backend(8, max_m, "default");
    let mut all = Vec::new();
    for tier in ["gsm8k", "math500", "aime"] {
        let suite = suites::math(&ctx.vocab, tier, n, 42);
        println!("\n=== math tier {tier} (n={n}) ===");
        let mut results = Vec::new();
        for policy in policies {
            for &budget in &budgets {
                // fullkv only makes sense unconstrained
                if policy == "fullkv" && budget != *budgets.last().unwrap() {
                    continue;
                }
                let eff_budget = if policy == "fullkv" {
                    max_m - ctx.meta.chunk - 1
                } else {
                    budget
                };
                let (mut r, be) = run_suite(backend, &ctx.cfg, &ctx.vocab,
                                            policy, eff_budget, &suite)
                    .expect("suite run");
                backend = be;
                r.task = tier.to_string();
                if policy == "fullkv" {
                    r.budget = budget; // report under the sweep column
                }
                results.push(r);
            }
        }
        println!("{}", pareto_table(&results, &budgets).render());
        all.extend(results);
    }
    // Table 5 analog: loss-ablation gate variants, gsm8k tier at one budget
    let ablations: Vec<String> = ctx
        .meta
        .gate_variants
        .iter()
        .filter(|v| v.starts_with("no_") || v.starts_with("cap"))
        .cloned()
        .collect();
    if !ablations.is_empty() {
        println!("\n=== Table 5 analog: gate-objective ablations ===");
        let suite = suites::math(&ctx.vocab, "gsm8k", n, 42);
        let mut results = Vec::new();
        for variant in &ablations {
            let be = ctx.backend(8, max_m, variant);
            let (mut r, _) = run_suite(be, &ctx.cfg, &ctx.vocab, "trimkv", 48,
                                       &suite).expect("ablation run");
            r.policy = format!("trimkv[{variant}]");
            results.push(r);
        }
        println!("{}", results_table(&results).render());
        all.extend(results);
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/pareto_math.csv",
                   results_table(&all).to_csv()).ok();
    println!("wrote bench_results/pareto_math.csv");
}
