//! Tables 1 / 7 reproduction: LongProc analog — per-task accuracy/F1 under
//! KV budgets, across output-length tiers.  Shape to match: TRIM-KV best
//! eviction policy per column; margins widen at tighter budgets.

use trimkv::eval::bench_support::{bench_n, load_ctx};
use trimkv::eval::{results_table, run_suite};
use trimkv::workload::suites;

fn main() {
    let Some(mut ctx) = load_ctx("longproc") else { return };
    let n = bench_n(12);
    let budgets = [24usize, 48];
    let policies = ["trimkv", "rkv", "snapkv", "h2o", "streaming_llm", "fullkv"];
    // token-by-token prefill: eviction pressure applies over the whole
    // sequence (the paper's long-horizon setting), not just past chunk 1
    ctx.cfg.chunked_prefill = false;
    let max_m = ctx.max_slots(8);
    let mut backend = ctx.backend(8, max_m, "default");
    let mut all = Vec::new();
    for task in ["table", "countdown", "copy"] {
        for tier in 0..2usize {
            let suite = suites::longproc(&ctx.vocab, task, tier, n, 11);
            for policy in policies {
                for &budget in &budgets {
                    if policy == "fullkv" && budget != budgets[0] {
                        continue;
                    }
                    let eff = if policy == "fullkv" {
                        max_m - ctx.meta.chunk - 1
                    } else {
                        budget
                    };
                    let (mut r, be) = run_suite(backend, &ctx.cfg, &ctx.vocab,
                                                policy, eff, &suite)
                        .expect("longproc run");
                    backend = be;
                    r.task = format!("{task}/t{tier}");
                    all.push(r);
                }
            }
        }
    }
    println!("=== Tables 1/7 analog (LongProc) ===\n{}",
             results_table(&all).render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/longproc.csv",
                   results_table(&all).to_csv()).ok();
}
