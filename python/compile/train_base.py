"""Train the trimkv-tiny base model on the synthetic task mixture.

This replaces the paper's pretrained Qwen3 backbone (no network access on
this testbed — see DESIGN.md §2).  The model is trained with weighted
next-token prediction on packed episodes, then frozen; the retention gates
are trained on top by train_gates.py.

Usage:  cd python && python -m compile.train_base [--steps N] [--out DIR]
Writes: artifacts/base.npz, artifacts/base_metrics.json
"""

from __future__ import annotations

import argparse
import json
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks
from . import vocab as V
from .model import CONFIG, forward_full, init_params
from .optim import adam_init, adam_update, cosine_lr


def make_batch(rng: random.Random, batch: int, seq: int, mix: str):
    rows, wts, segs = tasks.pack_batch(rng, batch, seq + 1, mix)
    toks = np.asarray(rows, np.int32)
    wts = np.asarray(wts, np.float32)
    segs = np.asarray(segs, np.int32)
    # inputs are t, targets are t+1; target weight follows the target token;
    # cross-segment targets (the first token of the next episode) get 0 weight
    w = wts[:, 1:] * (segs[:, 1:] == segs[:, :-1])
    return toks[:, :-1], toks[:, 1:], w, segs[:, :-1]


def loss_fn(params, x, y, w, seg, cfg):
    logits = forward_full(params, x, cfg, segments=seg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return (nll * w).sum() / w.sum()


@jax.jit
def train_step(params, opt, x, y, w, seg, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y, w, seg, CONFIG)
    params, opt = adam_update(params, grads, opt, lr, weight_decay=1e-4)
    return params, opt, loss


def eval_teacher_forced(params, rng: random.Random, cfg, n: int = 80,
                        pad_to: int = 512) -> dict:
    """Answer-token argmax accuracy per task family (full cache).

    Episodes are padded to a fixed length so a single jit specialization
    serves the whole eval (single-core testbed: recompiles dominate)."""
    fwd = jax.jit(lambda p, t: jnp.argmax(forward_full(p, t, cfg), axis=-1))
    per: dict[str, list[float]] = {}
    for _ in range(n):
        ep = tasks.sample_episode(rng, "all")
        toks = ep.tokens[:pad_to]
        padded = np.zeros((1, pad_to), np.int32)
        padded[0, : len(toks)] = toks
        pred = np.asarray(fwd(params, jnp.asarray(padded)))[0]
        span = range(ep.prompt_end - 1, min(len(ep.tokens), pad_to) - 1)
        ok = all(int(pred[i]) == ep.tokens[i + 1] for i in span)
        per.setdefault(ep.task, []).append(1.0 if ok else 0.0)
    return {k: float(np.mean(v)) for k, v in sorted(per.items())}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1400)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=448)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mix", default="all")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    cfg = CONFIG
    rng = random.Random(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adam_init(params)
    t0 = time.time()
    losses = []
    for step in range(args.steps):
        x, y, w, seg = make_batch(rng, args.batch, args.seq, args.mix)
        lr = cosine_lr(step, args.lr, args.steps)
        params, opt, loss = train_step(params, opt, x, y, w, seg, lr)
        losses.append(float(loss))
        if step % 100 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"lr {lr:.2e} elapsed {time.time()-t0:.0f}s", flush=True)

    acc = eval_teacher_forced(params, random.Random(123), cfg)
    print("teacher-forced accuracy:", acc)

    np.savez(f"{args.out}/base.npz", **{k: np.asarray(v) for k, v in params.items()})
    with open(f"{args.out}/base_metrics.json", "w") as f:
        json.dump({"final_loss": float(np.mean(losses[-50:])),
                   "loss_curve": losses[::10],
                   "teacher_forced_acc": acc,
                   "steps": args.steps, "batch": args.batch,
                   "seq": args.seq, "wall_s": time.time() - t0}, f, indent=1)
    print(f"saved base model ({sum(v.size for v in params.values())} params) "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
