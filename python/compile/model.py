"""Layer-2: the `trimkv-tiny` model — a miniature Qwen3-style decoder.

Architecture (matches the Qwen3 family the paper uses, scaled down):
  RMSNorm -> GQA attention (RoPE, Hq query heads sharing Hkv KV heads)
  RMSNorm -> SwiGLU MLP, untied LM head.

Three execution modes share the same weights:
  forward_full    standard causal attention — the frozen teacher and the
                  base-model training graph
  forward_gated   retention-gated attention (paper Eq. 3) via the L1 Pallas
                  kernel (or its jnp oracle) — the gate-training graph
  decode_fn /     the AOT serving graphs the rust engine executes: explicit
  prefill_fn      KV slot caches, in-graph scatter of new tokens into
                  rust-chosen slots, validity-masked attention, retention
                  gate scores as an output.  Weights are runtime inputs so
                  one HLO artifact serves every gate-ablation variant.

The retention gate g is a single-hidden-layer MLP (paper §5.1) applied to the
post-norm layer input; its bias is initialized large so training starts from
"no forgetting" (paper Fig. 9 ablation).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import decode_attention, retention_attention
from .kernels.ref import (
    decode_attention_ref,
    expand_kv,
    retention_attention_ref,
    NEG_INF,
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d: int = 128          # model width (sized for the single-core testbed)
    layers: int = 4
    hq: int = 4           # query heads
    hkv: int = 2          # kv heads (GQA group = hq // hkv)
    dh: int = 32          # head dim
    ffn: int = 256        # SwiGLU hidden
    gate_hidden: int = 48  # retention-gate MLP hidden (paper: 512 @ 4B scale)
    gate_bias_init: float = 8.0  # paper: 18.0 @ 128K ctx; scaled to ctx 2K
    rope_theta: float = 10000.0

    @property
    def group(self) -> int:
        return self.hq // self.hkv


CONFIG = ModelConfig()


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Base-model parameters as a flat {name: array} dict (fixed iteration
    order = insertion order; meta.json and weights.bin rely on it)."""
    p: dict[str, jax.Array] = {}
    k_iter = iter(jax.random.split(key, 8 * cfg.layers + 3))

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape) * scale).astype(jnp.float32)

    p["embed"] = nrm(next(k_iter), (cfg.vocab, cfg.d), 0.02)
    for l in range(cfg.layers):
        s = 1.0 / math.sqrt(cfg.d)
        p[f"l{l}.ln1"] = jnp.ones((cfg.d,), jnp.float32)
        p[f"l{l}.wq"] = nrm(next(k_iter), (cfg.d, cfg.hq * cfg.dh), s)
        p[f"l{l}.wk"] = nrm(next(k_iter), (cfg.d, cfg.hkv * cfg.dh), s)
        p[f"l{l}.wv"] = nrm(next(k_iter), (cfg.d, cfg.hkv * cfg.dh), s)
        p[f"l{l}.wo"] = nrm(next(k_iter), (cfg.hq * cfg.dh, cfg.d), s)
        p[f"l{l}.ln2"] = jnp.ones((cfg.d,), jnp.float32)
        p[f"l{l}.wg"] = nrm(next(k_iter), (cfg.d, cfg.ffn), s)
        p[f"l{l}.wu"] = nrm(next(k_iter), (cfg.d, cfg.ffn), s)
        p[f"l{l}.wd"] = nrm(next(k_iter), (cfg.ffn, cfg.d), 1.0 / math.sqrt(cfg.ffn))
    p["lnf"] = jnp.ones((cfg.d,), jnp.float32)
    p["lm_head"] = nrm(next(k_iter), (cfg.d, cfg.vocab), 1.0 / math.sqrt(cfg.d))
    return p


def init_gates(cfg: ModelConfig, key: jax.Array, *, linear: bool = False,
               bias: float | None = None) -> dict:
    """Retention-gate parameters.  `linear=True` ablates the MLP (Fig. 9)."""
    g: dict[str, jax.Array] = {}
    b0 = cfg.gate_bias_init if bias is None else bias
    keys = jax.random.split(key, 2 * cfg.layers)
    for l in range(cfg.layers):
        s = 1.0 / math.sqrt(cfg.d)
        if linear:
            g[f"g{l}.w1"] = (jax.random.normal(keys[2 * l], (cfg.d, cfg.hkv)) * s
                             ).astype(jnp.float32)
            g[f"g{l}.b1"] = jnp.full((cfg.hkv,), b0, jnp.float32)
        else:
            g[f"g{l}.w1"] = (jax.random.normal(keys[2 * l], (cfg.d, cfg.gate_hidden))
                             * s).astype(jnp.float32)
            g[f"g{l}.b1"] = jnp.zeros((cfg.gate_hidden,), jnp.float32)
            g[f"g{l}.w2"] = (jax.random.normal(keys[2 * l + 1],
                                               (cfg.gate_hidden, cfg.hkv))
                             * (1.0 / math.sqrt(cfg.gate_hidden))).astype(jnp.float32)
            g[f"g{l}.b2"] = jnp.full((cfg.hkv,), b0, jnp.float32)
    return g


def gate_log_beta(gates: dict, l: int, h: jax.Array) -> jax.Array:
    """log beta = log sigmoid(g(h)) for layer l; h [..., d] -> [..., Hkv].

    Computed as -softplus(-z) for numerical stability (beta -> 1 means
    log_beta -> 0-)."""
    w1, b1 = gates[f"g{l}.w1"], gates[f"g{l}.b1"]
    z = h @ w1 + b1
    if f"g{l}.w2" in gates:
        z = jax.nn.silu(z) @ gates[f"g{l}.w2"] + gates[f"g{l}.b2"]
    return -jax.nn.softplus(-z)


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x [..., T, H, dh] or [..., H, dh]; pos broadcastable
    to x's leading time axes."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs      # [..., half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over the head axis, which sits between pos axes and dh
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(params: dict, cfg: ModelConfig, l: int, h: jax.Array):
    """h [..., d] -> q [..., Hq, dh], k/v [..., Hkv, dh]."""
    lead = h.shape[:-1]
    q = (h @ params[f"l{l}.wq"]).reshape(*lead, cfg.hq, cfg.dh)
    k = (h @ params[f"l{l}.wk"]).reshape(*lead, cfg.hkv, cfg.dh)
    v = (h @ params[f"l{l}.wv"]).reshape(*lead, cfg.hkv, cfg.dh)
    return q, k, v


def _mlp(params: dict, l: int, x: jax.Array) -> jax.Array:
    h = rmsnorm(x, params[f"l{l}.ln2"])
    return x + (jax.nn.silu(h @ params[f"l{l}.wg"]) * (h @ params[f"l{l}.wu"])
                ) @ params[f"l{l}.wd"]


# --------------------------------------------------------------------------
# training-time forward passes
# --------------------------------------------------------------------------
def forward_full(params: dict, tokens: jax.Array, cfg: ModelConfig = CONFIG,
                 return_attn: bool = False, segments: jax.Array | None = None):
    """Standard causal forward. tokens [B, T] -> logits [B, T, V].

    `segments` [B, T] (optional) makes attention block-diagonal across packed
    training episodes.  With return_attn=True also returns per-layer attention
    probabilities [L, B, Hkv, T, T] (mean over each GQA group) — used as the
    regression target for the LocRet baseline's retaining heads."""
    b, t = tokens.shape
    pos = jnp.arange(t)[None, :]
    x = jnp.take(params["embed"], tokens, axis=0)
    scale = 1.0 / math.sqrt(cfg.dh)
    causal = (jnp.arange(t)[:, None] >= jnp.arange(t)[None, :])[None]
    if segments is not None:
        causal = causal & (segments[:, :, None] == segments[:, None, :])
    attns = []
    for l in range(cfg.layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(params, cfg, l, h)                  # [B,T,H,dh]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        q = q.transpose(0, 2, 1, 3)                        # [B,Hq,T,dh]
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        k_e, v_e = expand_kv(k, cfg.hq), expand_kv(v, cfg.hq)
        s = jnp.einsum("bhtd,bhid->bhti", q, k_e) * scale
        s = jnp.where(causal[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        if return_attn:
            attns.append(p.reshape(b, cfg.hkv, cfg.group, t, t).mean(axis=2))
        o = jnp.einsum("bhti,bhid->bhtd", p, v_e)
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.hq * cfg.dh)
        x = x + o @ params[f"l{l}.wo"]
        x = _mlp(params, l, x)
    logits = rmsnorm(x, params["lnf"]) @ params["lm_head"]
    if return_attn:
        return logits, jnp.stack(attns)
    return logits


def forward_gated(params: dict, gates: dict, tokens: jax.Array,
                  cfg: ModelConfig = CONFIG, impl: str = "ref",
                  segments: jax.Array | None = None):
    """Retention-gated forward (paper Eq. 3). Returns (logits, log_betas)
    with log_betas [L, B, Hkv, T].  impl: "ref" (materialized oracle — fast
    under jit on CPU for small T; supports `segments`) or "pallas" (the L1
    flash kernel)."""
    b, t = tokens.shape
    pos = jnp.arange(t)[None, :]
    x = jnp.take(params["embed"], tokens, axis=0)
    if impl == "ref":
        def attn(q, k, v, lb):
            return retention_attention_ref(q, k, v, lb, segments=segments)
    else:
        assert segments is None, "pallas kernel path has no segment support"
        attn = retention_attention
    log_betas = []
    for l in range(cfg.layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(params, cfg, l, h)
        lb = gate_log_beta(gates, l, h)                    # [B,T,Hkv]
        lb = lb.transpose(0, 2, 1)                         # [B,Hkv,T]
        log_betas.append(lb)
        q = rope(q, pos, cfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope(k, pos, cfg.rope_theta).transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        o = attn(q, k, v, lb)                              # [B,Hq,T,dh]
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.hq * cfg.dh)
        x = x + o @ params[f"l{l}.wo"]
        x = _mlp(params, l, x)
    logits = rmsnorm(x, params["lnf"]) @ params["lm_head"]
    return logits, jnp.stack(log_betas)


# --------------------------------------------------------------------------
# serving graphs (AOT-exported; executed by the rust engine)
# --------------------------------------------------------------------------
def _scatter_slot(cache: jax.Array, new: jax.Array, slot: jax.Array,
                  m: int) -> jax.Array:
    """cache [B,H,M,dh], new [B,H,dh], slot [B,H] -> cache with new written."""
    oh = jax.nn.one_hot(slot, m, dtype=cache.dtype)        # [B,H,M]
    return cache * (1.0 - oh[..., None]) + new[:, :, None, :] * oh[..., None]


def decode_fn(params: dict, gates: dict, token: jax.Array, pos: jax.Array,
              kc: jax.Array, vc: jax.Array, valid: jax.Array,
              write_slot: jax.Array, inject_flag: jax.Array,
              inject_slot: jax.Array, inject_k: jax.Array,
              inject_v: jax.Array, cfg: ModelConfig = CONFIG,
              attn_impl: str = "pallas"):
    """One decode step over M cache slots (rust hot path).

    token [B] i32          next input token per lane
    pos   [B] i32          absolute position of that token
    kc/vc [L,B,Hkv,M,dh]   device-resident KV slot caches
    valid [L,B,Hkv,M] f32  1.0 = live slot (device-resident)
    write_slot [L,B,Hkv]   slot each layer/head writes the new token into
                           (rust's eviction decision: the previous victim)
    inject_*               optional KV re-admission (retrieval baseline):
                           where inject_flag==1, (inject_k, inject_v) are
                           written into inject_slot before attention.

    Returns dict: logits [B,V], kc/vc/valid (updated), log_beta [L,B,Hkv],
    attn [L,B,Hkv,M] (group-mean probs), k_new [L,B,Hkv,dh].
    """
    b = token.shape[0]
    m = kc.shape[3]
    x = jnp.take(params["embed"], token, axis=0)           # [B,d]
    kc_out, vc_out, valid_out = [], [], []
    log_betas, attns, k_news, v_news = [], [], [], []
    for l in range(cfg.layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q, k_new, v_new = _qkv(params, cfg, l, h)          # [B,H,dh]
        lb = gate_log_beta(gates, l, h)                    # [B,Hkv]
        # lift to [B,1,H,dh] so rope's time axis broadcasts correctly
        q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k_new = rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]

        kl, vl, val = kc[l], vc[l], valid[l]
        # retrieval re-admission first, then the new token's write
        ih = jax.nn.one_hot(inject_slot[l], m, dtype=kl.dtype) \
            * inject_flag[l][..., None]
        kl = kl * (1.0 - ih[..., None]) + inject_k[l][:, :, None, :] * ih[..., None]
        vl = vl * (1.0 - ih[..., None]) + inject_v[l][:, :, None, :] * ih[..., None]
        val = jnp.maximum(val, ih)
        kl = _scatter_slot(kl, k_new, write_slot[l], m)
        vl = _scatter_slot(vl, v_new, write_slot[l], m)
        oh = jax.nn.one_hot(write_slot[l], m, dtype=val.dtype)
        val = jnp.maximum(val, oh)

        if attn_impl == "pallas":
            o, probs = decode_attention(q, kl, vl, val)
        else:
            o, probs = decode_attention_ref(q, kl, vl, val)
        attns.append(probs.reshape(b, cfg.hkv, cfg.group, m).mean(axis=2))
        x = x + o.reshape(b, cfg.hq * cfg.dh) @ params[f"l{l}.wo"]
        x = _mlp(params, l, x)
        kc_out.append(kl)
        vc_out.append(vl)
        valid_out.append(val)
        log_betas.append(lb)
        k_news.append(k_new)
        v_news.append(v_new)
    logits = rmsnorm(x, params["lnf"]) @ params["lm_head"]
    return {
        "logits": logits,
        "kc": jnp.stack(kc_out),
        "vc": jnp.stack(vc_out),
        "valid": jnp.stack(valid_out),
        "log_beta": jnp.stack(log_betas),
        "attn": jnp.stack(attns),
        "k_new": jnp.stack(k_news),
        "v_new": jnp.stack(v_news),
    }


def prefill_fn(params: dict, gates: dict, tokens: jax.Array, pos: jax.Array,
               in_mask: jax.Array, kc: jax.Array, vc: jax.Array,
               valid: jax.Array, write_slots: jax.Array,
               cfg: ModelConfig = CONFIG):
    """Prefill one chunk of C tokens against the resident cache.

    tokens [B,C] i32, pos [B,C] i32, in_mask [B,C] f32 (0 = padding)
    kc/vc [L,B,Hkv,M,dh], valid [L,B,Hkv,M]
    write_slots [L,B,Hkv,C] i32  slot for each chunk position (rust points
                                 padding at a reserved trash slot)

    Chunk queries attend to live resident slots plus causally to earlier
    chunk positions.  Returns dict: logits [B,C,V], kc/vc/valid (updated),
    log_beta [L,B,Hkv,C], attn_slots [L,B,Hkv,M] (attention mass received by
    each resident slot, summed over chunk queries — H2O/SnapKV signal),
    attn_chunk [L,B,Hkv,C] (mass received by each chunk position),
    k_chunk [L,B,Hkv,C,dh].
    """
    b, c = tokens.shape
    m = kc.shape[3]
    scale = 1.0 / math.sqrt(cfg.dh)
    x = jnp.take(params["embed"], tokens, axis=0)          # [B,C,d]
    causal = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
    kc_out, vc_out, valid_out = [], [], []
    log_betas, attn_slots, attn_chunks, k_chunks, v_chunks = [], [], [], [], []
    for l in range(cfg.layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q, k_new, v_new = _qkv(params, cfg, l, h)          # [B,C,H,dh]
        lb = gate_log_beta(gates, l, h)                    # [B,C,Hkv]
        q = rope(q, pos, cfg.rope_theta).transpose(0, 2, 1, 3)      # [B,Hq,C,dh]
        k_new = rope(k_new, pos, cfg.rope_theta)                    # [B,C,Hkv,dh]
        k_t = k_new.transpose(0, 2, 1, 3)                           # [B,Hkv,C,dh]
        v_t = v_new.transpose(0, 2, 1, 3)

        kl, vl, val = kc[l], vc[l], valid[l]
        # attention: resident slots ++ intra-chunk causal
        k_all = jnp.concatenate([expand_kv(kl, cfg.hq),
                                 expand_kv(k_t, cfg.hq)], axis=2)   # [B,Hq,M+C,dh]
        v_all = jnp.concatenate([expand_kv(vl, cfg.hq),
                                 expand_kv(v_t, cfg.hq)], axis=2)
        s = jnp.einsum("bhcd,bhkd->bhck", q, k_all) * scale
        mask_slots = expand_kv(val, cfg.hq)[:, :, None, :] > 0.5    # [B,Hq,1,M]
        mask_chunk = (causal[None, None] & (in_mask[:, None, None, :] > 0.5))
        mask_chunk = jnp.broadcast_to(mask_chunk, (b, cfg.hq, c, c))
        mask = jnp.concatenate(
            [jnp.broadcast_to(mask_slots, (b, cfg.hq, c, m)), mask_chunk], axis=3)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # a row with no live slot and first position still has itself; padding
        # rows attend to themselves only — harmless, they are never read.
        o = jnp.einsum("bhck,bhkd->bhcd", p, v_all)
        o = o.transpose(0, 2, 1, 3).reshape(b, c, cfg.hq * cfg.dh)
        x = x + o @ params[f"l{l}.wo"]
        x = _mlp(params, l, x)

        # aggregate attention received (group-mean over q heads, masked sum
        # over real chunk queries)
        pg = p.reshape(b, cfg.hkv, cfg.group, c, m + c).mean(axis=2)
        wq_mask = in_mask[:, None, :, None]                 # [B,1,C,1]
        received = (pg * wq_mask).sum(axis=2)               # [B,Hkv,M+C]
        attn_slots.append(received[:, :, :m])
        attn_chunks.append(received[:, :, m:])

        # scatter chunk KV into rust-assigned slots
        oh = jax.nn.one_hot(write_slots[l], m, dtype=kl.dtype)      # [B,Hkv,C,M]
        keep = jnp.maximum(0.0, 1.0 - oh.sum(axis=2))               # clobbered?
        kl = kl * keep[..., None] + jnp.einsum("bhcm,bhcd->bhmd", oh, k_t)
        vl = vl * keep[..., None] + jnp.einsum("bhcm,bhcd->bhmd", oh, v_t)
        live = oh * in_mask[:, None, :, None]               # pads never go live
        val = jnp.maximum(val * keep, live.sum(axis=2).clip(0.0, 1.0))

        kc_out.append(kl)
        vc_out.append(vl)
        valid_out.append(val)
        log_betas.append(lb.transpose(0, 2, 1))
        k_chunks.append(k_t)
        v_chunks.append(v_t)
    logits = rmsnorm(x, params["lnf"]) @ params["lm_head"]
    return {
        "logits": logits,
        "kc": jnp.stack(kc_out),
        "vc": jnp.stack(vc_out),
        "valid": jnp.stack(valid_out),
        "log_beta": jnp.stack(log_betas),
        "attn_slots": jnp.stack(attn_slots),
        "attn_chunk": jnp.stack(attn_chunks),
        "k_chunk": jnp.stack(k_chunks),
        "v_chunk": jnp.stack(v_chunks),
    }


def step_fn_mixed(params, gates, tokens, pos, in_mask, mode, kc, vc,
                  valid, write_slots, inject_flag=None, inject_slot=None,
                  inject_k=None, inject_v=None, cfg: ModelConfig = CONFIG):
    """One fused *mixed tick*: every lane advances in a single graph call —
    decoding lanes by one token, mid-prefill lanes by a budgeted chunk — so
    a long prompt admission never stalls the decode stream (TRIM-KV scores
    tokens at creation time, so fusing the phases changes no eviction
    semantics; Sarathi-style stall-free batching).

    The chunk formulation subsumes decode: a decoding lane feeds a 1-token
    chunk (`in_mask` = [1, 0, ...]), which attends to its live resident
    slots plus itself — exactly `decode_fn`'s provisional-write semantics.

    tokens/pos/in_mask  [B,C] as in `prefill_fn`; decode lanes use column 0
    mode                [B] f32, 1.0 = decode lane, 0.0 = chunk-fill lane
    kc/vc/valid/write_slots  as in `prefill_fn`
    inject_*            optional KV re-admission, mirroring `decode_fn`:
                        where inject_flag [L,B,Hkv] == 1, (inject_k,
                        inject_v) [L,B,Hkv,dh] are written into inject_slot
                        and marked live *before* attention — the retrieval
                        baseline's re-injection no longer forces the engine
                        off the fused path.

    Returns the `prefill_fn` dict with one change: for decode lanes the
    token's self-attention mass (attn_chunk[..., 0]) is folded into its
    write slot of `attn_slots`, so the engine consumes one [M] row per
    decode lane exactly as it consumes `decode_fn`'s `attn` output."""
    m = kc.shape[3]
    if inject_flag is not None:
        # retrieval re-admission ahead of attention, all layers at once
        # (prefill_fn consumes kc[l] per layer, so pre-scattering the full
        # [L,...] tensors is exactly decode_fn's per-layer rule)
        ih = jax.nn.one_hot(inject_slot, m, dtype=kc.dtype) \
            * inject_flag[..., None]                        # [L,B,Hkv,M]
        kc = kc * (1.0 - ih[..., None]) + inject_k[..., None, :] * ih[..., None]
        vc = vc * (1.0 - ih[..., None]) + inject_v[..., None, :] * ih[..., None]
        valid = jnp.maximum(valid, ih)
    out = prefill_fn(params, gates, tokens, pos, in_mask, kc, vc, valid,
                     write_slots, cfg=cfg)
    self_slot = write_slots[:, :, :, 0]                     # [L,B,Hkv]
    oh = jax.nn.one_hot(self_slot, m, dtype=out["attn_slots"].dtype)
    self_mass = out["attn_chunk"][:, :, :, 0] * mode[None, :, None]
    out["attn_slots"] = out["attn_slots"] + oh * self_mass[..., None]
    return out


def decode_fn_lanes(params, gates, token, pos, kc_lanes, vc_lanes, valid,
                    write_slot, inject_flag, inject_slot, inject_k, inject_v,
                    cfg: ModelConfig = CONFIG, attn_impl: str = "pallas"):
    """Per-lane cache-residency variant of `decode_fn` (the O(lane) session
    swap): kc/vc arrive as B separate `[L, Hkv, M, dh]` buffers — one per
    batch lane — and the updated caches return the same way, so the serving
    runtime can download/upload one lane's buffers without touching any
    other lane.  XLA fuses the stack/split with the in-graph scatter, so
    steady-state decode cost is unchanged; only residency changes."""
    kc = jnp.stack(list(kc_lanes), axis=1)       # [L, B, Hkv, M, dh]
    vc = jnp.stack(list(vc_lanes), axis=1)
    out = decode_fn(params, gates, token, pos, kc, vc, valid, write_slot,
                    inject_flag, inject_slot, inject_k, inject_v, cfg=cfg,
                    attn_impl=attn_impl)
    b = token.shape[0]
    out["kc"] = [out["kc"][:, i] for i in range(b)]
    out["vc"] = [out["vc"][:, i] for i in range(b)]
    return out


def prefill_fn_lanes(params, gates, tokens, pos, in_mask, kc_lanes, vc_lanes,
                     valid, write_slots, cfg: ModelConfig = CONFIG):
    """Per-lane cache-residency variant of `prefill_fn`; see
    `decode_fn_lanes` for the layout contract."""
    kc = jnp.stack(list(kc_lanes), axis=1)
    vc = jnp.stack(list(vc_lanes), axis=1)
    out = prefill_fn(params, gates, tokens, pos, in_mask, kc, vc, valid,
                     write_slots, cfg=cfg)
    b = tokens.shape[0]
    out["kc"] = [out["kc"][:, i] for i in range(b)]
    out["vc"] = [out["vc"][:, i] for i in range(b)]
    return out


def step_fn_mixed_lanes(params, gates, tokens, pos, in_mask, mode, kc_lanes,
                        vc_lanes, valid, write_slots, inject_flag=None,
                        inject_slot=None, inject_k=None, inject_v=None,
                        cfg: ModelConfig = CONFIG):
    """Per-lane cache-residency variant of `step_fn_mixed`; see
    `decode_fn_lanes` for the layout contract."""
    kc = jnp.stack(list(kc_lanes), axis=1)
    vc = jnp.stack(list(vc_lanes), axis=1)
    out = step_fn_mixed(params, gates, tokens, pos, in_mask, mode, kc, vc,
                        valid, write_slots, inject_flag, inject_slot,
                        inject_k, inject_v, cfg=cfg)
    b = tokens.shape[0]
    out["kc"] = [out["kc"][:, i] for i in range(b)]
    out["vc"] = [out["vc"][:, i] for i in range(b)]
    return out


# --------------------------------------------------------------------------
# weight (de)serialization — flat order contract shared with rust
# --------------------------------------------------------------------------
def param_names(cfg: ModelConfig) -> list[str]:
    names = ["embed"]
    for l in range(cfg.layers):
        names += [f"l{l}.{n}" for n in
                  ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")]
    names += ["lnf", "lm_head"]
    return names


def gate_names(cfg: ModelConfig, linear: bool = False) -> list[str]:
    out = []
    for l in range(cfg.layers):
        out += [f"g{l}.w1", f"g{l}.b1"]
        if not linear:
            out += [f"g{l}.w2", f"g{l}.b2"]
    return out


def save_weights_bin(path: str, arrays: dict[str, np.ndarray]) -> None:
    """trimkv weights.bin format (little-endian):
    magic 'TKVW' u32 | n u32 | per array: name_len u32, name bytes,
    ndim u32, dims u32*, f32 data."""
    import struct
    with open(path, "wb") as f:
        f.write(b"TKVW")
        f.write(struct.pack("<I", len(arrays)))
        for name, arr in arrays.items():
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.tobytes())


def load_weights_bin(path: str) -> dict[str, np.ndarray]:
    import struct
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == b"TKVW", "bad magic"
    off = 4
    (n,) = struct.unpack_from("<I", data, off); off += 4
    out: dict[str, np.ndarray] = {}
    for _ in range(n):
        (nl,) = struct.unpack_from("<I", data, off); off += 4
        name = data[off:off + nl].decode(); off += nl
        (nd,) = struct.unpack_from("<I", data, off); off += 4
        dims = struct.unpack_from(f"<{nd}I", data, off); off += 4 * nd
        cnt = int(np.prod(dims)) if nd else 1
        arr = np.frombuffer(data, dtype="<f4", count=cnt, offset=off).reshape(dims)
        off += 4 * cnt
        out[name] = arr
    return out
