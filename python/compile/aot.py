"""AOT export: lower the serving graphs to HLO text + dump weight blobs.

This is the only bridge between the python build path and the rust serving
engine.  Interchange contract (consumed by rust/src/model_meta.rs and
rust/src/runtime/):

  artifacts/
    decode_b{B}_m{M}[_pl][_lin].hlo.txt   one decode step (model.decode_fn)
    prefill_b{B}_m{M}[_pl][_lin].hlo.txt  one chunk prefill (model.prefill_fn)
    mixed_b{B}_m{M}[_pl].hlo.txt      one fused mixed step incl. the
                                      retrieval inject tail
                                      (model.step_fn_mixed); each artifact's
                                      meta entry records `runtime_inputs` —
                                      the StepPlan operand order the rust
                                      structural selftest verifies
    weights.bin                       base parameters (TKVW format)
    gates_<variant>.bin               gate parameters per trained variant
    meta.json                         dims, artifact table, tensor orders
    vocab.json                        vocabulary layout
    golden_decode.bin /               runtime I/O pairs for the rust golden
    golden_prefill.bin                tests (inputs + expected outputs)
    golden_episodes.jsonl             sample episodes for workload parity

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Cache layout (recorded per artifact in meta.json as `cache_layout`):
  per_lane    kc/vc are B separate [L,Hkv,M,dh] operands, one per batch
              lane, returned the same way — the runtime can swap one lane's
              session KV in O(lane) without touching the others.  This is
              the only layout; the legacy monolithic single-pair layout was
              removed at the end of its deprecation window and the rust
              runtime rejects such exports.

Usage: cd python && python -m compile.aot [--out ../artifacts] [--quick]
"""

from __future__ import annotations

import argparse
import json
import random
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tasks
from . import vocab as V
from .model import (CONFIG, decode_fn, decode_fn_lanes, gate_names,
                    init_gates, init_params, param_names, prefill_fn,
                    prefill_fn_lanes, save_weights_bin, step_fn_mixed,
                    step_fn_mixed_lanes)

CHUNK = 64  # prefill chunk length C

# (batch, slots) variants exported by default; the engine picks the smallest
# M >= its configured budget, and B by its batching mode.
DECODE_VARIANTS = [(1, 256), (1, 768), (8, 128), (8, 256), (8, 768)]
PREFILL_VARIANTS = [(1, 256), (1, 768), (8, 128), (8, 256), (8, 768)]
# mixed-tick graphs (decode + chunk-fill fused per lane); b=1 has no
# prefill/decode contention, so only batched variants are exported
MIXED_VARIANTS = [(8, 128), (8, 256), (8, 768)]
LIN_VARIANTS = [(8, 256)]  # gate-architecture ablation (Fig. 9)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def cache_specs(cfg, b, m):
    """kc/vc runtime-input specs: B per-lane [L,H,M,dh] pairs, keyed
    kc0..kc{B-1}/vc0..vc{B-1}."""
    L, H, dh = cfg.layers, cfg.hkv, cfg.dh
    sp = {f"kc{i}": spec((L, H, m, dh)) for i in range(b)}
    sp.update({f"vc{i}": spec((L, H, m, dh)) for i in range(b)})
    return sp


def decode_specs(cfg, b, m):
    L, H, dh = cfg.layers, cfg.hkv, cfg.dh
    sp = dict(
        token=spec((b,), jnp.int32),
        pos=spec((b,), jnp.int32),
    )
    sp.update(cache_specs(cfg, b, m))
    sp.update(
        valid=spec((L, b, H, m)),
        write_slot=spec((L, b, H), jnp.int32),
        inject_flag=spec((L, b, H)),
        inject_slot=spec((L, b, H), jnp.int32),
        inject_k=spec((L, b, H, dh)),
        inject_v=spec((L, b, H, dh)),
    )
    return sp


def prefill_specs(cfg, b, m, c=CHUNK):
    L, H, dh = cfg.layers, cfg.hkv, cfg.dh
    sp = dict(
        tokens=spec((b, c), jnp.int32),
        pos=spec((b, c), jnp.int32),
        in_mask=spec((b, c)),
    )
    sp.update(cache_specs(cfg, b, m))
    sp.update(
        valid=spec((L, b, H, m)),
        write_slots=spec((L, b, H, c), jnp.int32),
    )
    return sp


def mixed_specs(cfg, b, m, c=CHUNK):
    """Like prefill, plus the per-lane `mode` operand (1.0 = decode lane)
    inserted after in_mask, plus the decode graph's retrieval inject tail —
    the runtime's unified StepPlan operand contract (the rust structural
    selftest verifies this exact lead/tail order)."""
    L, H, dh = cfg.layers, cfg.hkv, cfg.dh
    sp = dict(
        tokens=spec((b, c), jnp.int32),
        pos=spec((b, c), jnp.int32),
        in_mask=spec((b, c)),
        mode=spec((b,)),
    )
    sp.update(cache_specs(cfg, b, m))
    sp.update(
        valid=spec((L, b, H, m)),
        write_slots=spec((L, b, H, c), jnp.int32),
        inject_flag=spec((L, b, H)),
        inject_slot=spec((L, b, H), jnp.int32),
        inject_k=spec((L, b, H, dh)),
        inject_v=spec((L, b, H, dh)),
    )
    return sp


DECODE_OUT_ORDER = ["logits", "kc", "vc", "valid", "log_beta", "attn",
                    "k_new", "v_new"]
PREFILL_OUT_ORDER = ["logits", "kc", "vc", "valid", "log_beta", "attn_slots",
                     "attn_chunk", "k_chunk", "v_chunk"]
MIXED_OUT_ORDER = PREFILL_OUT_ORDER  # same tuple; attn_slots is mode-fused


def build_fn(kind, cfg, pnames, gnames, attn_impl, b):
    """Flat-signature wrapper: fn(*params, *gates, *runtime) -> tuple.

    The runtime cache operands are B kc buffers then B vc buffers (each
    [L,Hkv,M,dh]); the output tuple expands the same way, in the
    DECODE/PREFILL/MIXED_OUT_ORDER position of kc/vc."""
    np_, ng = len(pnames), len(gnames)
    # leading runtime operands before the caches, per kind:
    #   decode  (token, pos) | prefill (tokens, pos, in_mask)
    #   mixed   (tokens, pos, in_mask, mode)
    lead_n = {"decode": 2, "prefill": 3, "mixed": 4}[kind]

    def fn(*args):
        params = dict(zip(pnames, args[:np_]))
        gates = dict(zip(gnames, args[np_:np_ + ng]))
        rt = args[np_ + ng:]
        head, rest = rt[:lead_n], rt[lead_n:]
        kcs, vcs, tail = rest[:b], rest[b:2 * b], rest[2 * b:]
        if kind == "decode":
            out = decode_fn_lanes(params, gates, *head, kcs, vcs, *tail,
                                  cfg=cfg, attn_impl=attn_impl)
            names = DECODE_OUT_ORDER
        elif kind == "mixed":
            out = step_fn_mixed_lanes(params, gates, *head, kcs, vcs,
                                      *tail, cfg=cfg)
            names = MIXED_OUT_ORDER
        else:
            out = prefill_fn_lanes(params, gates, *head, kcs, vcs, *tail,
                                   cfg=cfg)
            names = PREFILL_OUT_ORDER
        outs = []
        for k in names:
            if k in ("kc", "vc"):
                outs.extend(out[k])  # B per-lane buffers
            else:
                outs.append(out[k])
        return tuple(outs)

    return fn


def lower_variant(kind, cfg, b, m, params_np, gates_np, linear, attn_impl):
    pnames = param_names(cfg)
    gnames = gate_names(cfg, linear=linear)
    fn = build_fn(kind, cfg, pnames, gnames, attn_impl, b)
    pspecs = [spec(params_np[n].shape) for n in pnames]
    gspecs = [spec(gates_np[n].shape) for n in gnames]
    rspecs = {
        "decode": lambda: decode_specs(cfg, b, m),
        "prefill": lambda: prefill_specs(cfg, b, m),
        "mixed": lambda: mixed_specs(cfg, b, m),
    }[kind]()
    lowered = jax.jit(fn).lower(*pspecs, *gspecs, *rspecs.values())
    return to_hlo_text(lowered), list(rspecs.keys())


def export_goldens(out, cfg, params, gates, b, m):
    """Run one decode step + one prefill chunk in python; dump I/O pairs."""
    L, H, dh = cfg.layers, cfg.hkv, cfg.dh
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 8)
    n_live = m // 4
    kc = jax.random.normal(ks[0], (L, b, H, m, dh)) * 0.3
    vc = jax.random.normal(ks[1], (L, b, H, m, dh)) * 0.3
    valid = jnp.zeros((L, b, H, m)).at[:, :, :, :n_live].set(1.0)
    token = jax.random.randint(ks[2], (b,), 0, cfg.vocab)
    pos = jnp.full((b,), n_live, jnp.int32)
    write_slot = jnp.full((L, b, H), n_live, jnp.int32)
    zf = jnp.zeros((L, b, H))
    zs = jnp.zeros((L, b, H), jnp.int32)
    zk = jnp.zeros((L, b, H, dh))
    ins = dict(token=token, pos=pos, kc=kc, vc=vc, valid=valid,
               write_slot=write_slot, inject_flag=zf, inject_slot=zs,
               inject_k=zk, inject_v=zk)
    outs = decode_fn(params, gates, *ins.values(), cfg=cfg)
    blob = {f"in.{k}": np.asarray(v, np.float32) for k, v in ins.items()}
    blob.update({f"out.{k}": np.asarray(outs[k], np.float32)
                 for k in DECODE_OUT_ORDER})
    save_weights_bin(f"{out}/golden_decode.bin", blob)

    c = CHUNK
    toks = jax.random.randint(ks[3], (b, c), 0, cfg.vocab)
    posc = jnp.broadcast_to(jnp.arange(n_live, n_live + c)[None], (b, c)
                            ).astype(jnp.int32)
    in_mask = jnp.ones((b, c))
    ws = jnp.broadcast_to(jnp.arange(n_live, n_live + c)[None, None, None],
                          (L, b, H, c)).astype(jnp.int32)
    pins = dict(tokens=toks, pos=posc, in_mask=in_mask, kc=kc, vc=vc,
                valid=valid, write_slots=ws)
    pouts = prefill_fn(params, gates, *pins.values(), cfg=cfg)
    blob = {f"in.{k}": np.asarray(v, np.float32) for k, v in pins.items()}
    blob.update({f"out.{k}": np.asarray(pouts[k], np.float32)
                 for k in PREFILL_OUT_ORDER})
    save_weights_bin(f"{out}/golden_prefill.bin", blob)

    # mixed tick: first half of the lanes decode one token (1-token chunks,
    # padding pointed at the trash slot m-1 as the engine does), second half
    # prefill a full chunk.  Lane 0 additionally re-injects one retrieval
    # entry per (layer, head) into a dead slot, so the golden replay covers
    # the inject operands numerically, not just structurally.
    nd = b // 2
    mode = jnp.concatenate([jnp.ones((nd,)), jnp.zeros((b - nd,))])
    mtoks = toks.at[:nd, 1:].set(0)
    mmask = in_mask.at[:nd, 1:].set(0.0)
    mws = ws.at[:, :nd, :, 1:].set(m - 1)
    inj_flag = jnp.zeros((L, b, H)).at[:, 0, :].set(1.0)
    inj_slot = jnp.full((L, b, H), m - 2, jnp.int32)  # dead, != any write
    inj_k = jax.random.normal(ks[4], (L, b, H, dh)) * 0.3
    inj_v = jax.random.normal(ks[5], (L, b, H, dh)) * 0.3
    mins = dict(tokens=mtoks, pos=posc, in_mask=mmask, mode=mode, kc=kc,
                vc=vc, valid=valid, write_slots=mws, inject_flag=inj_flag,
                inject_slot=inj_slot, inject_k=inj_k, inject_v=inj_v)
    mouts = step_fn_mixed(params, gates, *mins.values(), cfg=cfg)
    blob = {f"in.{k}": np.asarray(v, np.float32) for k, v in mins.items()}
    blob.update({f"out.{k}": np.asarray(mouts[k], np.float32)
                 for k in MIXED_OUT_ORDER})
    save_weights_bin(f"{out}/golden_mixed.bin", blob)


def export_episodes(out, n_per: int = 6):
    rng = random.Random(2024)
    with open(f"{out}/golden_episodes.jsonl", "w") as f:
        for task, gen in tasks.GENERATORS.items():
            for _ in range(n_per):
                ep = gen(rng)
                f.write(json.dumps({
                    "task": ep.task, "tokens": ep.tokens,
                    "prompt_end": ep.prompt_end,
                    "answer_start": ep.answer_start, "answer": ep.answer,
                }) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only export the (8,256) pair (fast iteration)")
    ap.add_argument("--attn-impl", default="pallas", choices=["pallas", "ref"])
    ap.add_argument("--smoke", action="store_true",
                    help="initialize random params/gates instead of loading "
                         "trained checkpoints (CI export smoke test; the "
                         "graphs and goldens stay numerically consistent, "
                         "only untrained)")
    args = ap.parse_args()
    out = args.out
    cfg = CONFIG
    t0 = time.time()

    import glob
    import os
    os.makedirs(out, exist_ok=True)
    if args.smoke:
        # CI smoke path: no training run available — random weights keep
        # every downstream contract (shapes, operand order, goldens) intact
        params_np = {k: np.asarray(v) for k, v in
                     init_params(cfg, jax.random.PRNGKey(0)).items()}
        gates_np = {k: np.asarray(v) for k, v in
                    init_gates(cfg, jax.random.PRNGKey(1)).items()}
        save_weights_bin(f"{out}/gates_default.bin", gates_np)
        gate_files = []
        gate_variants = ["default"]
    else:
        params_np = dict(np.load(f"{out}/base.npz"))
        # all trained gate variants -> .bin; 'default' drives the goldens
        gate_files = sorted(glob.glob(f"{out}/gates_*.npz"))
        if not gate_files:
            raise SystemExit("no gates_*.npz found; run train_gates first")
        gates_np = None
        for gf in gate_files:
            name = os.path.basename(gf)[len("gates_"):-len(".npz")]
            g = dict(np.load(gf))
            save_weights_bin(f"{out}/gates_{name}.bin", g)
            if name == "default":
                gates_np = g
        if gates_np is None:
            gates_np = dict(np.load(gate_files[0]))
        gate_variants = [os.path.basename(f)[len("gates_"):-len(".npz")]
                         for f in gate_files]
    params = {k: jnp.asarray(v) for k, v in params_np.items()}
    gates = {k: jnp.asarray(v) for k, v in gates_np.items()}
    save_weights_bin(f"{out}/weights.bin", params_np)

    dec_vars = [(8, 256)] if args.quick else DECODE_VARIANTS
    pre_vars = [(8, 256)] if args.quick else PREFILL_VARIANTS
    mix_vars = [(8, 256)] if args.quick else MIXED_VARIANTS
    artifacts = []
    for kind, variants in (("decode", dec_vars), ("prefill", pre_vars),
                           ("mixed", mix_vars)):
        for b, m in variants:
            fname = f"{kind}_b{b}_m{m}_pl.hlo.txt"
            hlo, rt_order = lower_variant(kind, cfg, b, m, params_np,
                                          gates_np, False, args.attn_impl)
            with open(f"{out}/{fname}", "w") as f:
                f.write(hlo)
            artifacts.append({"kind": kind, "b": b, "m": m,
                              "c": 1 if kind == "decode" else CHUNK,
                              "file": fname, "gate_arch": "mlp",
                              "cache_layout": "per_lane",
                              "runtime_inputs": rt_order})
            print(f"lowered {fname} ({len(hlo)//1024} KiB, "
                  f"{time.time()-t0:.0f}s)", flush=True)

    # linear-gate ablation graphs, if that variant was trained
    lin_files = [f for f in gate_files if "linear" in f]
    if lin_files and not args.quick:
        lin_np = dict(np.load(lin_files[0]))
        for kind in ("decode", "prefill"):
            for b, m in LIN_VARIANTS:
                fname = f"{kind}_b{b}_m{m}_pl_lin.hlo.txt"
                hlo, rt_order = lower_variant(kind, cfg, b, m, params_np,
                                              lin_np, True, args.attn_impl)
                with open(f"{out}/{fname}", "w") as f:
                    f.write(hlo)
                artifacts.append({"kind": kind, "b": b, "m": m,
                                  "c": CHUNK if kind == "prefill" else 1,
                                  "file": fname, "gate_arch": "linear",
                                  "cache_layout": "per_lane",
                                  "runtime_inputs": rt_order})

    meta = {
        "model": {"vocab": cfg.vocab, "d": cfg.d, "layers": cfg.layers,
                  "hq": cfg.hq, "hkv": cfg.hkv, "dh": cfg.dh,
                  "ffn": cfg.ffn, "gate_hidden": cfg.gate_hidden,
                  "rope_theta": cfg.rope_theta},
        "chunk": CHUNK,
        "param_order": [{"name": n, "shape": list(params_np[n].shape)}
                        for n in param_names(cfg)],
        "gate_order": [{"name": n, "shape": list(gates_np[n].shape)}
                       for n in gate_names(cfg)],
        "gate_order_linear": [{"name": n}
                              for n in gate_names(cfg, linear=True)],
        "decode_outputs": DECODE_OUT_ORDER,
        "prefill_outputs": PREFILL_OUT_ORDER,
        "mixed_outputs": MIXED_OUT_ORDER,
        "gate_variants": gate_variants,
        "artifacts": artifacts,
    }
    with open(f"{out}/meta.json", "w") as f:
        json.dump(meta, f, indent=1)
    with open(f"{out}/vocab.json", "w") as f:
        json.dump(V.vocab_json(), f, indent=1)

    export_goldens(out, cfg, params, gates, 8, 256)
    export_episodes(out)
    print(f"aot export complete in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
