"""Pallas capacity-loss kernel (paper Eq. 5), forward + backward.

The loss needs the retention load  s_t = sum_{i<=t} beta_i^{t-i}  for every
step t without materializing the T x T retention matrix.  The paper does this
with a custom Triton kernel; here we tile (t-block x i-block) on the Pallas
grid and accumulate per-t partial sums — the same block-parallel reduction,
mapped to VMEM tiles (DESIGN.md §3).

Forward returns the scalar hinge loss; the per-t load s is kept as the
residual so the backward kernel only revisits blocks where s_t > M:
  dL/dlog_beta_i = sum_{t>=i} g_t (t-i) exp((t-i) log_beta_i),
  g_t = [s_t > M] / (B H T (t+1)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 64


def _fit_block(block: int, t: int) -> int:
    """Largest block size <= `block` that divides t (grid must tile exactly)."""
    b = min(block, t)
    while t % b:
        b -= 1
    return b


def _load_kernel(lb_ref, s_ref, *, block_i: int):
    """s_t = sum_{i<=t} exp((t-i) log_beta_i) for one (row, t-block)."""
    lbfull = lb_ref[0]                   # [T]
    t_total = lbfull.shape[0]
    bt = s_ref.shape[1]
    t_pos = pl.program_id(1) * bt + jnp.arange(bt)
    n_ib = t_total // block_i

    def body(j, s):
        lbb = jax.lax.dynamic_slice_in_dim(lbfull, j * block_i, block_i)
        i_pos = j * block_i + jnp.arange(block_i)
        dist = t_pos[:, None] - i_pos[None, :]
        ret = jnp.where(dist >= 0, jnp.exp(dist * lbb[None, :]), 0.0)
        return s + ret.sum(axis=1)

    s0 = jnp.zeros((bt,), lbfull.dtype)
    s_ref[0] = jax.lax.fori_loop(0, n_ib, body, s0)


def _grad_kernel(lb_ref, g_ref, dlb_ref, *, block_t: int):
    """dlog_beta for one (row, i-block): sum over t blocks of g_t (t-i) ret."""
    lbb = lb_ref[0]                      # [Bi]
    gfull = g_ref[0]                     # [T]
    t_total = gfull.shape[0]
    bi = lbb.shape[0]
    i_pos = pl.program_id(1) * bi + jnp.arange(bi)
    n_tb = t_total // block_t

    def body(j, dlb):
        gb = jax.lax.dynamic_slice_in_dim(gfull, j * block_t, block_t)
        t_pos = j * block_t + jnp.arange(block_t)
        dist = t_pos[:, None] - i_pos[None, :]               # [Bt, Bi]
        ret = jnp.where(dist >= 0, jnp.exp(dist * lbb[None, :]), 0.0)
        return dlb + (gb[:, None] * dist * ret).sum(axis=0)

    dlb0 = jnp.zeros((bi,), lbb.dtype)
    dlb_ref[0] = jax.lax.fori_loop(0, n_tb, body, dlb0)


def retention_load(log_beta, block_t: int = DEFAULT_BLOCK_T,
                   interpret: bool = True):
    """Per-step cache load s_t [B, H, T] (public: also used by Fig-5c sparsity)."""
    b, h, t = log_beta.shape
    bt = _fit_block(block_t, t)
    lbf = log_beta.reshape(b * h, t)
    s = pl.pallas_call(
        functools.partial(_load_kernel, block_i=bt),
        grid=(b * h, t // bt),
        in_specs=[pl.BlockSpec((1, t), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, bt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b * h, t), log_beta.dtype),
        interpret=interpret,
    )(lbf)
    return s.reshape(b, h, t)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def capacity_loss(log_beta, m: float, block_t: int = DEFAULT_BLOCK_T,
                  interpret: bool = True):
    """Scalar capacity loss; matches ``ref.capacity_loss_ref``."""
    loss, _ = _cap_fwd(log_beta, m, block_t, interpret)
    return loss


def _cap_fwd(log_beta, m, block_t, interpret):
    b, h, t = log_beta.shape
    s = retention_load(log_beta, block_t, interpret)
    ti = jnp.arange(t, dtype=log_beta.dtype)
    hinge = jnp.maximum(0.0, s - m) / (ti + 1.0)
    loss = hinge.mean(axis=-1).mean()
    return loss, (log_beta, s)


def _cap_bwd(m, block_t, interpret, res, dl):
    log_beta, s = res
    b, h, t = log_beta.shape
    bt = _fit_block(block_t, t)
    ti = jnp.arange(t, dtype=log_beta.dtype)
    g = jnp.where(s > m, 1.0, 0.0) / ((ti + 1.0) * t * b * h) * dl
    lbf = log_beta.reshape(b * h, t)
    gf = g.reshape(b * h, t)
    dlb = pl.pallas_call(
        functools.partial(_grad_kernel, block_t=bt),
        grid=(b * h, t // bt),
        in_specs=[
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b * h, t), log_beta.dtype),
        interpret=interpret,
    )(lbf, gf)
    return (dlb.reshape(b, h, t),)


capacity_loss.defvjp(_cap_fwd, _cap_bwd)
