"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the Pallas kernels (and, transitively, the AOT
graphs the rust engine executes) are tested against.  They materialize the
full attention / retention matrices, so they are O(T^2) memory — fine for
tests and for small-model gate training, wrong for production; the Pallas
kernels implement the blocked versions.

Shapes (GQA handled natively here):
  q        [B, Hq,  T, dh]
  k, v     [B, Hkv, T, dh]   (Hq % Hkv == 0, group = Hq // Hkv)
  log_beta [B, Hkv, T]       log of the retention gate output, <= 0
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def expand_kv(x: jax.Array, hq: int) -> jax.Array:
    """[B, Hkv, ...] -> [B, Hq, ...] by repeating each kv head over its group."""
    hkv = x.shape[1]
    group = hq // hkv
    return jnp.repeat(x, group, axis=1)


def retention_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                            log_beta: jax.Array,
                            segments: jax.Array | None = None) -> jax.Array:
    """Retention-gated causal attention (paper Eq. 3).

    attention logits: q_t . k_i / sqrt(dh) + (t - i) * log_beta_i   for i <= t
    `segments` [B, T] optionally restricts attention to a block-diagonal
    pattern (packed-episode training).
    """
    b, hq, t, dh = q.shape
    k_e = expand_kv(k, hq)
    v_e = expand_kv(v, hq)
    lb_e = expand_kv(log_beta, hq)  # [B, Hq, T]

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bhtd,bhid->bhti", q, k_e) * scale
    ti = jnp.arange(t)
    dist = ti[:, None] - ti[None, :]                       # t - i
    s = s + dist[None, None, :, :] * lb_e[:, :, None, :]   # decay bias
    mask = (dist >= 0)[None]
    if segments is not None:
        mask = mask & (segments[:, :, None] == segments[:, None, :])
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhti,bhid->bhtd", p, v_e)


def capacity_loss_ref(log_beta: jax.Array, m: float) -> jax.Array:
    """Capacity loss (paper Eq. 5), mean over batch and kv heads.

    L = (1/T) sum_t (1/t) max(0, sum_{i<=t} beta_i^{t-i} - M), t 1-indexed.
    """
    b, h, t = log_beta.shape
    ti = jnp.arange(t)
    dist = ti[:, None] - ti[None, :]
    expo = dist[None, None] * log_beta[:, :, None, :]      # (t-i) log beta_i
    # mask the exponent (not the value) so gradients stay NaN-free: for i > t
    # the exponent would be a large positive number whose exp overflows.
    expo = jnp.where((dist >= 0)[None, None], expo, NEG_INF)
    s = jnp.exp(expo).sum(-1)                              # [B, H, T]
    hinge = jnp.maximum(0.0, s - m) / (ti[None, None] + 1.0)
    return hinge.mean(axis=-1).mean()


def retention_matrix_ref(log_beta: jax.Array) -> jax.Array:
    """beta_i^{t-i} lower-triangular matrix [..., T, T] (Fig. 4 top)."""
    t = log_beta.shape[-1]
    ti = jnp.arange(t)
    dist = ti[:, None] - ti[None, :]
    expo = jnp.where(dist >= 0, dist * log_beta[..., None, :], NEG_INF)
    return jnp.exp(expo)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-query attention over M cache slots with a validity mask.

    q     [B, Hq, dh]
    k, v  [B, Hkv, M, dh]
    valid [B, Hkv, M]  (1.0 = live slot, 0.0 = hole)
    Returns (o [B, Hq, dh], probs [B, Hq, M]).
    """
    b, hq, dh = q.shape
    k_e = expand_kv(k, hq)
    v_e = expand_kv(v, hq)
    m_e = expand_kv(valid, hq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bhd,bhmd->bhm", q, k_e) * scale
    s = jnp.where(m_e > 0.5, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # a fully-invalid row would produce uniform garbage; zero it instead
    any_valid = m_e.sum(-1, keepdims=True) > 0.5
    p = jnp.where(any_valid, p, 0.0)
    o = jnp.einsum("bhm,bhmd->bhd", p, v_e)
    return o, p
