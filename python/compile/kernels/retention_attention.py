"""Pallas retention-gated flash attention (paper Eq. 3), forward + backward.

The retention decay `(t - i) * log(beta_i)` is an additive bias on the
attention logits, so the kernel is a standard two-pass online-softmax flash
attention with one extra bias row streamed alongside K.  See DESIGN.md §3 for
the TPU mapping (VMEM tiles via BlockSpec, MXU matmuls); here we run under
``interpret=True`` so the same kernel lowers to plain HLO executable on the
CPU PJRT plugin.

Layout: heads are pre-expanded to the query-head count by the wrapper (GQA
groups repeat their KV head), so kernels see
  q, k, v   [N, T, dh]      with N = B * Hq
  log_beta  [N, T]
The custom-vjp wrapper sums GQA-group gradients back onto the KV heads.

Backward follows the flash-attention-2 decomposition with one extra output:
  dS = P * (dP - D),  dP = dO V^T,  D_t = sum_d dO_td O_td
  dlog_beta_i = sum_t dS_ti * (t - i)        (the retention-gate gradient)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _fwd_kernel(q_ref, k_ref, v_ref, lb_ref, o_ref, lse_ref, *, block_k: int):
    """One (head, q-block) grid cell: online softmax over all k blocks."""
    qb = q_ref[0]                      # [Bq, dh]
    kfull = k_ref[0]                   # [T, dh]
    vfull = v_ref[0]                   # [T, dh]
    lbfull = lb_ref[0]                 # [T]
    t_total, dh = kfull.shape
    bq = qb.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, qb.dtype))

    q_pos = pl.program_id(1) * bq + jnp.arange(bq)          # absolute t
    n_kb = t_total // block_k

    def body(j, carry):
        m_i, l_i, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kfull, j * block_k, block_k)
        vb = jax.lax.dynamic_slice_in_dim(vfull, j * block_k, block_k)
        lbb = jax.lax.dynamic_slice_in_dim(lbfull, j * block_k, block_k)
        k_pos = j * block_k + jnp.arange(block_k)
        dist = q_pos[:, None] - k_pos[None, :]               # t - i
        s = (qb @ kb.T) * scale + dist * lbb[None, :]
        s = jnp.where(dist >= 0, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ vb
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, qb.dtype)
    l0 = jnp.zeros((bq,), qb.dtype)
    acc0 = jnp.zeros((bq, dh), qb.dtype)
    m_f, l_f, acc_f = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[0] = acc_f / l_f[:, None]
    lse_ref[0] = m_f + jnp.log(l_f)


def _dq_kernel(q_ref, k_ref, v_ref, lb_ref, do_ref, lse_ref, dd_ref, dq_ref,
               *, block_k: int):
    """dq for one (head, q-block): dq_t = sum_i dS_ti k_i * scale."""
    qb = q_ref[0]
    kfull = k_ref[0]
    vfull = v_ref[0]
    lbfull = lb_ref[0]
    dob = do_ref[0]
    lseb = lse_ref[0]
    ddb = dd_ref[0]                                          # D_t
    t_total, dh = kfull.shape
    bq = qb.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, qb.dtype))
    q_pos = pl.program_id(1) * bq + jnp.arange(bq)
    n_kb = t_total // block_k

    def body(j, dq):
        kb = jax.lax.dynamic_slice_in_dim(kfull, j * block_k, block_k)
        vb = jax.lax.dynamic_slice_in_dim(vfull, j * block_k, block_k)
        lbb = jax.lax.dynamic_slice_in_dim(lbfull, j * block_k, block_k)
        k_pos = j * block_k + jnp.arange(block_k)
        dist = q_pos[:, None] - k_pos[None, :]
        s = (qb @ kb.T) * scale + dist * lbb[None, :]
        s = jnp.where(dist >= 0, s, NEG_INF)
        p = jnp.exp(s - lseb[:, None])
        dp = dob @ vb.T
        ds = p * (dp - ddb[:, None])
        return dq + (ds @ kb) * scale

    dq0 = jnp.zeros((bq, dh), qb.dtype)
    dq_ref[0] = jax.lax.fori_loop(0, n_kb, body, dq0)


def _dkv_kernel(q_ref, k_ref, v_ref, lb_ref, do_ref, lse_ref, dd_ref,
                dk_ref, dv_ref, dlb_ref, *, block_q: int):
    """dk, dv, dlog_beta for one (head, k-block): loop over q blocks."""
    kb = k_ref[0]                                            # [Bk, dh]
    vb = v_ref[0]
    lbb = lb_ref[0]                                          # [Bk]
    qfull = q_ref[0]                                         # [T, dh]
    dofull = do_ref[0]
    lsefull = lse_ref[0]
    ddfull = dd_ref[0]
    t_total, dh = qfull.shape
    bk = kb.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, kb.dtype))
    k_pos = pl.program_id(1) * bk + jnp.arange(bk)
    n_qb = t_total // block_q

    def body(j, carry):
        dk, dv, dlb = carry
        qb = jax.lax.dynamic_slice_in_dim(qfull, j * block_q, block_q)
        dob = jax.lax.dynamic_slice_in_dim(dofull, j * block_q, block_q)
        lseb = jax.lax.dynamic_slice_in_dim(lsefull, j * block_q, block_q)
        ddb = jax.lax.dynamic_slice_in_dim(ddfull, j * block_q, block_q)
        q_pos = j * block_q + jnp.arange(block_q)
        dist = q_pos[:, None] - k_pos[None, :]               # [Bq, Bk]
        s = (qb @ kb.T) * scale + dist * lbb[None, :]
        s = jnp.where(dist >= 0, s, NEG_INF)
        p = jnp.exp(s - lseb[:, None])
        dp = dob @ vb.T
        ds = p * (dp - ddb[:, None])
        dv = dv + p.T @ dob
        dk = dk + (ds.T @ qb) * scale
        dlb = dlb + (ds * dist).sum(axis=0)
        return dk, dv, dlb

    dk0 = jnp.zeros((bk, dh), kb.dtype)
    dv0 = jnp.zeros((bk, dh), kb.dtype)
    dlb0 = jnp.zeros((bk,), kb.dtype)
    dk_f, dv_f, dlb_f = jax.lax.fori_loop(0, n_qb, body, (dk0, dv0, dlb0))
    dk_ref[0] = dk_f
    dv_ref[0] = dv_f
    dlb_ref[0] = dlb_f


def _fwd_pallas(q, k, v, lb, block_q, block_k, interpret):
    n, t, dh = q.shape
    grid = (n, t // block_q)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t, dh), q.dtype),
            jax.ShapeDtypeStruct((n, t), q.dtype),
        ],
        interpret=interpret,
    )(q, k, v, lb)


def _bwd_pallas(q, k, v, lb, o, lse, do, block_q, block_k, interpret):
    n, t, dh = q.shape
    dd = jnp.sum(do * o, axis=-1)                            # D_t  [N, T]
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, block_k=block_k),
        grid=(n, t // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, lb, do, lse, dd)

    dk, dv, dlb = pl.pallas_call(
        functools.partial(_dkv_kernel, block_q=block_q),
        grid=(n, t // block_k),
        in_specs=[
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t, dh), q.dtype),
            jax.ShapeDtypeStruct((n, t, dh), q.dtype),
            jax.ShapeDtypeStruct((n, t), q.dtype),
        ],
        interpret=interpret,
    )(q, k, v, lb, do, lse, dd)
    return dq, dk, dv, dlb


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def retention_attention(q, k, v, log_beta,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True):
    """Retention-gated causal flash attention with GQA.

    q [B,Hq,T,dh], k/v [B,Hkv,T,dh], log_beta [B,Hkv,T] -> o [B,Hq,T,dh]
    Matches ``ref.retention_attention_ref`` to float32 tolerance.
    """
    o, _ = _ra_fwd(q, k, v, log_beta, block_q, block_k, interpret)
    return o


def _fit_block(block: int, t: int) -> int:
    """Largest block size <= `block` that divides t (grid must tile exactly)."""
    b = min(block, t)
    while t % b:
        b -= 1
    return b


def _flatten_heads(q, k, v, lb):
    b, hq, t, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k_e = jnp.repeat(k, group, axis=1).reshape(b * hq, t, dh)
    v_e = jnp.repeat(v, group, axis=1).reshape(b * hq, t, dh)
    lb_e = jnp.repeat(lb, group, axis=1).reshape(b * hq, t)
    return q.reshape(b * hq, t, dh), k_e, v_e, lb_e


def _ra_fwd(q, k, v, log_beta, block_q, block_k, interpret):
    b, hq, t, dh = q.shape
    bq = _fit_block(block_q, t)
    bk = _fit_block(block_k, t)
    qf, kf, vf, lbf = _flatten_heads(q, k, v, log_beta)
    o, lse = _fwd_pallas(qf, kf, vf, lbf, bq, bk, interpret)
    res = (q, k, v, log_beta, o, lse)
    return o.reshape(b, hq, t, dh), res


def _ra_bwd(block_q, block_k, interpret, res, do):
    q, k, v, log_beta, o, lse = res
    b, hq, t, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    bq = _fit_block(block_q, t)
    bk = _fit_block(block_k, t)
    qf, kf, vf, lbf = _flatten_heads(q, k, v, log_beta)
    dof = do.reshape(b * hq, t, dh)
    dq, dk_e, dv_e, dlb_e = _bwd_pallas(qf, kf, vf, lbf, o, lse, dof,
                                        bq, bk, interpret)
    # fold GQA-group gradients back onto the kv heads
    dk = dk_e.reshape(b, hkv, group, t, dh).sum(axis=2)
    dv = dv_e.reshape(b, hkv, group, t, dh).sum(axis=2)
    dlb = dlb_e.reshape(b, hkv, group, t).sum(axis=2)
    return dq.reshape(b, hq, t, dh), dk, dv, dlb


retention_attention.defvjp(_ra_fwd, _ra_bwd)
