"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from .capacity_loss import capacity_loss, retention_load
from .decode_attention import decode_attention
from .retention_attention import retention_attention

__all__ = [
    "capacity_loss",
    "retention_load",
    "decode_attention",
    "retention_attention",
]
