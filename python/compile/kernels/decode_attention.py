"""Pallas single-query decode attention over M cache slots.

This is the serving hot path: one query per sequence attends to the resident
KV slots under a validity mask (holes left by eviction are masked out).  The
kernel is lowered (interpret=True) inside the AOT decode graph that the rust
engine executes every step, so its cost structure — O(M) per head regardless
of the true context length — is exactly the paper's bounded-memory claim.

It also emits the post-softmax attention probabilities, which the rust-side
H2O / SnapKV / R-KV baseline policies consume as their importance signal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, p_ref):
    q = q_ref[0]                        # [dh]
    k = k_ref[0]                        # [M, dh]
    v = v_ref[0]
    valid = valid_ref[0]                # [M]
    dh = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = (k @ q) * scale
    s = jnp.where(valid > 0.5, s, NEG_INF)
    m = s.max()
    p = jnp.exp(s - m)
    l = p.sum()
    p = p / l
    # fully-masked row (no live slots): output zeros, not NaN
    any_valid = valid.sum() > 0.5
    p = jnp.where(any_valid, p, 0.0)
    o_ref[0] = p @ v
    p_ref[0] = p


def decode_attention(q, k, v, valid, interpret: bool = True):
    """q [B,Hq,dh], k/v [B,Hkv,M,dh], valid [B,Hkv,M] ->
    (o [B,Hq,dh], probs [B,Hq,M]); matches ``ref.decode_attention_ref``."""
    b, hq, dh = q.shape
    hkv, m = k.shape[1], k.shape[2]
    group = hq // hkv
    k_e = jnp.repeat(k, group, axis=1).reshape(b * hq, m, dh)
    v_e = jnp.repeat(v, group, axis=1).reshape(b * hq, m, dh)
    valid_e = jnp.repeat(valid, group, axis=1).reshape(b * hq, m)
    qf = q.reshape(b * hq, dh)
    o, p = pl.pallas_call(
        _decode_kernel,
        grid=(b * hq,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, m, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hq, dh), q.dtype),
            jax.ShapeDtypeStruct((b * hq, m), q.dtype),
        ],
        interpret=interpret,
    )(qf, k_e, v_e, valid_e)
    return o.reshape(b, hq, dh), p.reshape(b, hq, m)
