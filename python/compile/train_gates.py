"""Train the retention gates (paper §4.2) on top of the frozen base model.

Objective (paper Eq. 4-6):
    L = D_KL(teacher || student) + L_NTP + lambda_cap * L_cap
where the student is the retention-gated model (Eq. 3) and the teacher the
frozen standard-attention model.  Only gate parameters receive gradients.

Also trains the paper's ablation variants (Table 5, Figs 8-10) and the
LocRet baseline's retaining heads (Appendix B.3 comparison):
    --no-kl / --no-ntp / --no-cap      loss-term ablations
    --linear-gate                      gate-architecture ablation
    --cap-m M / --gate-bias B          hyperparameter ablations
    --corpus math|general|all          training-data ablation
    --objective locret                 regression to max-future-attention
                                       (LocRet-style retaining heads)

Usage:  cd python && python -m compile.train_gates [--name default] [...]
Writes: artifacts/gates_<name>.npz (+ _metrics.json)
"""

from __future__ import annotations

import argparse
import functools
import json
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks
from .kernels import capacity_loss as capacity_loss_kernel
from .kernels.ref import capacity_loss_ref
from .model import (CONFIG, forward_full, forward_gated, gate_log_beta,
                    init_gates)
from .optim import adam_init, adam_update, cosine_lr
from .train_base import make_batch


def gate_loss_fn(gates, params, x, y, w, seg, cfg, *, use_kl, use_ntp, use_cap,
                 cap_m, lam_cap, impl, cap_impl):
    teacher = jax.lax.stop_gradient(forward_full(params, x, cfg, segments=seg))
    logits, log_betas = forward_gated(params, gates, x, cfg, impl=impl,
                                      segments=seg)
    loss = 0.0
    parts = {}
    if use_kl:
        pt = jax.nn.softmax(teacher, axis=-1)
        kl = (pt * (jax.nn.log_softmax(teacher, -1)
                    - jax.nn.log_softmax(logits, -1))).sum(-1)
        loss_kl = (kl * (w > 0)).mean()
        loss = loss + loss_kl
        parts["kl"] = loss_kl
    if use_ntp:
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        loss_ntp = (nll * w).sum() / w.sum()
        loss = loss + loss_ntp
        parts["ntp"] = loss_ntp
    if use_cap:
        cap = capacity_loss_ref if cap_impl == "ref" else capacity_loss_kernel
        # mean the per-layer losses (gates are trained jointly; Eq. 6)
        loss_cap = jnp.mean(jnp.stack(
            [cap(log_betas[l], cap_m) for l in range(cfg.layers)]))
        loss = loss + lam_cap * loss_cap
        parts["cap"] = loss_cap
    return loss, parts


def locret_loss_fn(gates, params, x, seg, cfg):
    """LocRet-style retaining heads: per-layer/head/token score beta_i is
    regressed (MSE) onto the max attention token i receives from any future
    query in the frozen teacher (clipped causal-attention importance)."""
    _, attn = forward_full(params, x, cfg, return_attn=True, segments=seg)
    attn = jax.lax.stop_gradient(attn)                     # [L,B,Hkv,T,T]
    target = attn.max(axis=3).clip(0.0, 1.0)               # [L,B,Hkv,T]
    b, t = x.shape
    xe = jnp.take(params["embed"], x, axis=0)
    loss = 0.0
    h = xe
    # run the backbone once more to get per-layer inputs (cheap at this scale)
    from .model import rmsnorm, _qkv, _mlp, rope
    import math as _math
    from .kernels.ref import expand_kv, NEG_INF
    pos = jnp.arange(t)[None, :]
    scale = 1.0 / _math.sqrt(cfg.dh)
    causal = (jnp.arange(t)[:, None] >= jnp.arange(t)[None, :])[None]
    if seg is not None:
        causal = causal & (seg[:, :, None] == seg[:, None, :])
    for l in range(cfg.layers):
        hn = rmsnorm(h, params[f"l{l}.ln1"])
        beta = jnp.exp(gate_log_beta(gates, l, hn))        # [B,T,Hkv]
        pred = beta.transpose(0, 2, 1)                     # [B,Hkv,T]
        loss = loss + jnp.mean((pred - target[l]) ** 2)
        q, k, v = _qkv(params, cfg, l, hn)
        q = rope(q, pos, cfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope(k, pos, cfg.rope_theta).transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        k_e, v_e = expand_kv(k, cfg.hq), expand_kv(v, cfg.hq)
        s = jnp.einsum("bhtd,bhid->bhti", q, k_e) * scale
        s = jnp.where(causal[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhti,bhid->bhtd", p, v_e)
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.hq * cfg.dh)
        h = h + o @ params[f"l{l}.wo"]
        h = _mlp(params, l, h)
    return loss / cfg.layers, {}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--name", default="default")
    ap.add_argument("--steps", type=int, default=700)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--seq", type=int, default=384)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--corpus", default="all",
                    choices=["math", "general", "all"])
    ap.add_argument("--cap-m", type=float, default=48.0)
    ap.add_argument("--lam-cap", type=float, default=1.0)
    ap.add_argument("--gate-bias", type=float, default=None)
    ap.add_argument("--no-kl", action="store_true")
    ap.add_argument("--no-ntp", action="store_true")
    ap.add_argument("--no-cap", action="store_true")
    ap.add_argument("--linear-gate", action="store_true")
    ap.add_argument("--objective", default="trimkv",
                    choices=["trimkv", "locret"])
    ap.add_argument("--impl", default="ref", choices=["ref", "pallas"],
                    help="retention-attention implementation for training; "
                         "'ref' is the jnp oracle (bit-identical math, faster "
                         "on the single-core CPU); 'pallas' exercises the L1 "
                         "kernels end-to-end")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    cfg = CONFIG
    rng = random.Random(args.seed + 1000)
    base = dict(np.load(f"{args.out}/base.npz"))
    params = {k: jnp.asarray(v) for k, v in base.items()}
    gates = init_gates(cfg, jax.random.PRNGKey(args.seed + 7),
                       linear=args.linear_gate, bias=args.gate_bias)
    opt = adam_init(gates)

    if args.objective == "locret":
        def full_loss(g, x, y, w, seg):
            return locret_loss_fn(g, params, x, seg, cfg)
    else:
        def full_loss(g, x, y, w, seg):
            return gate_loss_fn(
                g, params, x, y, w, seg, cfg,
                use_kl=not args.no_kl, use_ntp=not args.no_ntp,
                use_cap=not args.no_cap, cap_m=args.cap_m,
                lam_cap=args.lam_cap, impl=args.impl, cap_impl="ref")

    @jax.jit
    def step_fn(gates, opt, x, y, w, seg, lr):
        (loss, parts), grads = jax.value_and_grad(
            full_loss, has_aux=True)(gates, x, y, w, seg)
        gates, opt = adam_update(gates, grads, opt, lr)
        return gates, opt, loss, parts

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        x, y, w, seg = make_batch(rng, args.batch, args.seq, args.corpus)
        lr = cosine_lr(step, args.lr, args.steps)
        gates, opt, loss, parts = step_fn(gates, opt, x, y, w, seg, lr)
        losses.append(float(loss))
        if step % 100 == 0 or step == args.steps - 1:
            extra = " ".join(f"{k}={float(v):.4f}" for k, v in parts.items())
            print(f"step {step:5d} loss {float(loss):.4f} {extra} "
                  f"elapsed {time.time()-t0:.0f}s", flush=True)

    np.savez(f"{args.out}/gates_{args.name}.npz",
             **{k: np.asarray(v) for k, v in gates.items()})
    with open(f"{args.out}/gates_{args.name}_metrics.json", "w") as f:
        json.dump({"final_loss": float(np.mean(losses[-50:])),
                   "loss_curve": losses[::10],
                   "args": vars(args), "wall_s": time.time() - t0}, f, indent=1)
    print(f"saved gates_{args.name} in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
