"""Synthetic task generators — the training corpus for the base model and gates.

Each generator emits a full token sequence plus per-token loss weights and
the answer span.  The grammar is tuned so the lookup circuit the tasks need
is the classic induction pattern (… A B … A -> B): every value token
immediately follows its key token, and episodes carry several query/answer
pairs so the supervision is dense enough for the circuit to emerge at this
model scale (see DESIGN.md §2).

The same grammar is re-implemented in rust/src/workload/ for serving-time
evaluation; the shared contract is the vocabulary layout in `vocab.py`
(exported to artifacts/vocab.json) plus the golden episodes exported by
aot.py which the rust side must parse and grade.

Task families (paper benchmark analogs, see DESIGN.md §2):
  recall        GSM8K/MATH analog: key-value facts, filler, queries -> values
  chain         AIME analog: multi-hop pointer chase with chain-of-thought
  copy          LongProc copy/transform analog: replay a symbol span
  proc_table    LongProc HTML->TSV analog: tagged rows -> ordered extraction
  countdown     LongProc Countdown analog: digit arithmetic trace
  manyshot      SCBench ICL.ManyShot analog: many (x y) shots, then query
  find_minmax   SCBench Math.Find analog: min/max over a long digit list
  multi_session LongMemEval analog: sessions of facts, question about one
  niah          SCBench Retr.KV analog: one needle pair in a long haystack
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable

from . import vocab as V

ANSWER_WEIGHT = 10.0
STRUCT_WEIGHT = 0.1

# keys/values are drawn from a reduced symbol pool: dense enough supervision
# per symbol for the tiny model while keeping the task non-trivial
SYM_POOL = 64


@dataclass
class Episode:
    task: str
    tokens: list[int]          # full sequence incl. BOS .. EOS
    answer_start: int          # index of the first graded answer token
    answer: list[int]          # the graded answer tokens (excl. EOS)
    weights: list[float]       # per-token NTP loss weight (len == tokens)
    meta: dict = field(default_factory=dict)

    @property
    def prompt(self) -> list[int]:
        """Tokens the serving side feeds as the request prompt."""
        return self.tokens[: self.prompt_end]

    @property
    def prompt_end(self) -> int:
        return self.meta.get("prompt_end", self.answer_start)


def _mk(task: str, toks: list[int], ans_start: int, ans: list[int],
        meta: dict | None = None, extra_answer_spans=()) -> Episode:
    w = [STRUCT_WEIGHT] * len(toks)
    for i in range(ans_start, len(toks)):
        w[i] = ANSWER_WEIGHT
    for lo, hi in extra_answer_spans:
        for i in range(lo, hi):
            w[i] = ANSWER_WEIGHT
    return Episode(task, toks, ans_start, ans, w, meta or {})


def _filler(rng: random.Random, n: int) -> list[int]:
    return [V.word(rng.randrange(V.NUM_WORDS)) for _ in range(n)]


def _sym(rng: random.Random) -> int:
    return rng.randrange(SYM_POOL)


# --------------------------------------------------------------------------
# recall: <bos> (<key> k v  filler*)xN ... (<query> k v)xQ <eos>
# Values sit immediately after their key; the last query is the graded one.
# --------------------------------------------------------------------------
def gen_recall(rng: random.Random, n_pairs: int = 8, filler: int = 5,
               n_queries: int = 3) -> Episode:
    keys = rng.sample(range(SYM_POOL), n_pairs)
    vals = [_sym(rng) for _ in keys]
    kv = dict(zip(keys, vals))
    toks = [V.BOS]
    for k, v in kv.items():
        toks += [V.KEY, V.sym(k), V.sym(v)]
        toks += _filler(rng, rng.randrange(filler + 1))
    spans = []
    # queries hit *distinct* keys: with repeated keys the model can learn a
    # copy-the-previous-answer shortcut instead of the lookup circuit
    qs = rng.sample(keys, min(n_queries, len(keys)))
    for q in qs[:-1]:
        toks += [V.QUERY, V.sym(q)]
        spans.append((len(toks), len(toks) + 1))
        toks += [V.sym(kv[q])]
    toks += [V.QUERY, V.sym(qs[-1])]
    ans_start = len(toks)
    toks += [V.sym(kv[qs[-1]]), V.EOS]
    return _mk("recall", toks, ans_start, [V.sym(kv[qs[-1]])],
               {"n_pairs": n_pairs, "query_key": qs[-1]}, spans)


# --------------------------------------------------------------------------
# copy: <bos> s1 .. sn <sep> s1 .. sn <eos>   (LongProc copy analog; also
# the precursor task for the induction circuit)
# --------------------------------------------------------------------------
def gen_copy(rng: random.Random, n: int = 6) -> Episode:
    syms = [_sym(rng) for _ in range(n)]
    toks = [V.BOS] + [V.sym(s) for s in syms] + [V.SEP]
    ans_start = len(toks)
    toks += [V.sym(s) for s in syms] + [V.EOS]
    return _mk("copy", toks, ans_start, toks[ans_start:-1], {"n": n})


# --------------------------------------------------------------------------
# chain: pointer chase k0 -> k1 -> ... -> k_h, emitted hop by hop between
# <think> ... </think>, then the final answer after <ans>.
# --------------------------------------------------------------------------
def gen_chain(rng: random.Random, n_pairs: int = 8, hops: int = 3,
              filler: int = 3) -> Episode:
    syms = rng.sample(range(SYM_POOL), n_pairs + hops + 1)
    chain = syms[: hops + 1]
    distract = syms[hops + 1:]
    pairs = [(chain[i], chain[i + 1]) for i in range(hops)]
    for d in distract:
        pairs.append((d, rng.choice(distract)))
    rng.shuffle(pairs)
    toks = [V.BOS]
    for a, b in pairs:
        toks += [V.KEY, V.sym(a), V.sym(b)]
        toks += _filler(rng, rng.randrange(filler + 1))
    toks += [V.QUERY, V.sym(chain[0]), V.HOP, V.digit(hops), V.THINK]
    prompt_end = len(toks)
    think_start = len(toks)
    # chain-of-thought: re-query each hop explicitly so the lookup circuit
    # is reused hop by hop: <query> k_i k_{i+1}
    for i in range(hops):
        toks += [V.QUERY, V.sym(chain[i]), V.sym(chain[i + 1])]
    toks += [V.END_THINK, V.ANS]
    ans_start = len(toks)
    toks += [V.sym(chain[hops]), V.EOS]
    ep = _mk("chain", toks, ans_start, [V.sym(chain[hops])],
             {"hops": hops, "prompt_end": prompt_end,
              "think_start": think_start})
    for i in range(think_start, ans_start):
        ep.weights[i] = ANSWER_WEIGHT
    return ep


# --------------------------------------------------------------------------
# proc_table: <row> tag v1 v2 ... <exec> tags <ans> -> emit requested rows.
# --------------------------------------------------------------------------
def gen_proc_table(rng: random.Random, n_rows: int = 6, row_width: int = 2,
                   n_extract: int = 2) -> Episode:
    tags = rng.sample(range(SYM_POOL), n_rows)
    rows = {t: [_sym(rng) for _ in range(row_width)] for t in tags}
    toks = [V.BOS]
    for t in tags:
        toks += [V.ROW, V.sym(t)] + [V.sym(v) for v in rows[t]]
        toks += _filler(rng, rng.randrange(3))
    want = rng.sample(tags, n_extract)
    toks += [V.EXEC]
    for t in want:
        toks += [V.sym(t)]
    toks += [V.ANS]
    ans_start = len(toks)
    ans: list[int] = []
    for t in want:
        ans += [V.ROW, V.sym(t)] + [V.sym(v) for v in rows[t]]
    toks += ans + [V.EOS]
    return _mk("proc_table", toks, ans_start, ans,
               {"n_rows": n_rows, "n_extract": n_extract})


# --------------------------------------------------------------------------
# countdown: start digit + ops; model emits the full evaluation trace.
# --------------------------------------------------------------------------
def gen_countdown(rng: random.Random, n_steps: int = 4) -> Episode:
    start = rng.randrange(10)
    cur = start
    ops: list[tuple[int, int]] = []
    trace: list[int] = []
    for _ in range(n_steps):
        op = rng.choice([V.PLUS, V.MINUS])
        operand = rng.randrange(1, 10)
        cur = (cur + operand) % 10 if op == V.PLUS else (cur - operand) % 10
        ops.append((op, operand))
        trace += [op, V.digit(operand), V.EQUALS, V.digit(cur)]
    toks = [V.BOS, V.COUNT, V.digit(start), V.SEP]
    for op, operand in ops:
        toks += [op, V.digit(operand)]
    toks += [V.THINK]
    prompt_end = len(toks)
    toks += trace + [V.END_THINK, V.ANS]
    ans_start = len(toks)
    toks += [V.digit(cur), V.EOS]
    ep = _mk("countdown", toks, ans_start, [V.digit(cur)],
             {"prompt_end": prompt_end, "n_steps": n_steps})
    for i in range(prompt_end, ans_start):
        ep.weights[i] = ANSWER_WEIGHT
    return ep


# --------------------------------------------------------------------------
# manyshot: repeated (x y) demonstrations of a fixed mapping, then queries.
# --------------------------------------------------------------------------
def gen_manyshot(rng: random.Random, domain: int = 4, n_shots: int = 16) -> Episode:
    dom = rng.sample(range(SYM_POOL), domain)
    f = {d: _sym(rng) for d in dom}
    toks = [V.BOS]
    for _ in range(n_shots):
        d = rng.choice(dom)
        toks += [V.SHOT, V.sym(d), V.sym(f[d])]
    q = rng.choice(dom)
    toks += [V.QUERY, V.sym(q)]
    ans_start = len(toks)
    toks += [V.sym(f[q]), V.EOS]
    return _mk("manyshot", toks, ans_start, [V.sym(f[q])],
               {"domain": domain, "n_shots": n_shots})


# --------------------------------------------------------------------------
# find_minmax: long digit list; find min or max.
# --------------------------------------------------------------------------
def gen_find_minmax(rng: random.Random, n: int = 32) -> Episode:
    xs = [rng.randrange(10) for _ in range(n)]
    want_max = rng.random() < 0.5
    marker = V.FIND_MAX if want_max else V.FIND_MIN
    toks = [V.BOS, marker] + [V.digit(x) for x in xs] + [V.ANS]
    ans_start = len(toks)
    res = max(xs) if want_max else min(xs)
    toks += [V.digit(res), V.EOS]
    return _mk("find_minmax", toks, ans_start, [V.digit(res)],
               {"n": n, "max": want_max})


# --------------------------------------------------------------------------
# multi_session: sessions of facts with filler chat; facts may be updated in
# later sessions; final query asks the latest value.  LongMemEval analog.
# --------------------------------------------------------------------------
def gen_multi_session(rng: random.Random, n_sessions: int = 3,
                      facts_per: int = 3, filler: int = 8,
                      qtype: str | None = None) -> Episode:
    qtype = qtype or rng.choice(["single", "update", "multi"])
    store: dict[int, int] = {}
    toks = [V.BOS]
    key_session: dict[int, int] = {}
    updated: set[int] = set()
    for s in range(n_sessions):
        toks += [V.SESSION, V.digit(s % 10)]
        for _ in range(facts_per):
            if qtype == "update" and store and rng.random() < 0.4:
                k = rng.choice(list(store.keys()))
                v = _sym(rng)
                toks += [V.UPDATE, V.sym(k), V.sym(v)]
                updated.add(k)
            else:
                k = _sym(rng)
                while k in store:
                    k = _sym(rng)
                v = _sym(rng)
                toks += [V.KEY, V.sym(k), V.sym(v)]
            store[k] = v
            key_session[k] = s
        toks += [V.USER] + _filler(rng, rng.randrange(filler + 1))
        toks += [V.ASSISTANT] + _filler(rng, rng.randrange(filler + 1))
    pool = list(updated) if (qtype == "update" and updated) else list(store)
    qk = rng.choice(pool)
    toks += [V.SEP, V.QUERY, V.sym(qk)]
    ans_start = len(toks)
    toks += [V.sym(store[qk]), V.EOS]
    return _mk("multi_session", toks, ans_start, [V.sym(store[qk])],
               {"n_sessions": n_sessions, "qtype": qtype,
                "key_session": key_session.get(qk, 0)})


# --------------------------------------------------------------------------
# niah: one needle <niah> k v in a long filler haystack; query at the end.
# --------------------------------------------------------------------------
def gen_niah(rng: random.Random, haystack: int = 100) -> Episode:
    k, v = _sym(rng), _sym(rng)
    pos = rng.randrange(max(1, haystack - 4))
    toks = [V.BOS]
    toks += _filler(rng, pos)
    toks += [V.NIAH, V.sym(k), V.sym(v)]
    toks += _filler(rng, haystack - pos)
    toks += [V.QUERY, V.sym(k)]
    ans_start = len(toks)
    toks += [V.sym(v), V.EOS]
    return _mk("niah", toks, ans_start, [V.sym(v)],
               {"needle_pos": pos, "haystack": haystack})


GENERATORS: dict[str, Callable[..., Episode]] = {
    "recall": gen_recall,
    "copy": gen_copy,
    "chain": gen_chain,
    "proc_table": gen_proc_table,
    "countdown": gen_countdown,
    "manyshot": gen_manyshot,
    "find_minmax": gen_find_minmax,
    "multi_session": gen_multi_session,
    "niah": gen_niah,
}


def sample_episode(rng: random.Random, mix: str = "math") -> Episode:
    """Sample one episode from a named corpus mixture.

    "math"    — reasoning-heavy mix (OpenR1-Math analog)
    "general" — long-context mix (SynthLong/BookSum analog)
    "all"     — union
    """
    if mix == "math":
        r = rng.random()
        if r < 0.3:
            return gen_recall(rng, n_pairs=rng.randrange(4, 12),
                              filler=rng.randrange(2, 7),
                              n_queries=rng.randrange(2, 5))
        if r < 0.45:
            return gen_copy(rng, n=rng.randrange(3, 10))
        if r < 0.7:
            return gen_chain(rng, n_pairs=rng.randrange(5, 10),
                             hops=rng.randrange(2, 5))
        if r < 0.88:
            return gen_countdown(rng, n_steps=rng.randrange(2, 7))
        return gen_find_minmax(rng, n=rng.randrange(12, 48))
    if mix == "general":
        r = rng.random()
        if r < 0.3:
            return gen_multi_session(rng, n_sessions=rng.randrange(2, 5))
        if r < 0.55:
            return gen_niah(rng, haystack=rng.randrange(30, 120))
        if r < 0.75:
            return gen_proc_table(rng, n_rows=rng.randrange(4, 9))
        if r < 0.9:
            return gen_manyshot(rng, n_shots=rng.randrange(8, 24))
        return gen_copy(rng, n=rng.randrange(4, 12))
    return sample_episode(rng, "math") if rng.random() < 0.5 else \
        sample_episode(rng, "general")


def pack_batch(rng: random.Random, batch: int, seq_len: int,
               mix: str = "math"
               ) -> tuple[list[list[int]], list[list[float]], list[list[int]]]:
    """Pack episodes back-to-back into fixed-length rows for LM training.

    Returns (tokens, loss_weight, segment_ids), each [batch][seq_len].
    segment_ids keep attention block-diagonal across packed episodes —
    without this, symbol collisions across episodes make queries ambiguous
    and the lookup circuit cannot be learned.
    """
    rows, weights, segs = [], [], []
    for _ in range(batch):
        row: list[int] = []
        wt: list[float] = []
        sg: list[int] = []
        seg = 0
        while len(row) < seq_len:
            ep = sample_episode(rng, mix)
            row += ep.tokens
            wt += ep.weights
            sg += [seg] * len(ep.tokens)
            seg += 1
        rows.append(row[:seq_len])
        weights.append(wt[:seq_len])
        segs.append(sg[:seq_len])
    return rows, weights, segs
