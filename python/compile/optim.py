"""Minimal Adam + schedules (self-contained; optax is not assumed present)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, *, b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        return p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps) - lr * weight_decay * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}


def cosine_lr(step: int, base: float, total: int, warmup: int = 50) -> float:
    if step < warmup:
        return base * (step + 1) / warmup
    frac = (step - warmup) / max(1, total - warmup)
    return base * 0.5 * (1 + math.cos(math.pi * min(1.0, frac)))
