"""Synthetic vocabulary shared between the python (training) and rust (serving) sides.

The 512-token vocabulary is structured: a block of control tokens that give the
synthetic tasks their grammar, a block of "symbol" tokens used as keys/values/
tags, a block of "word" tokens used as natural-language-like filler, and a
small auxiliary block.  `aot.py` serializes this layout to artifacts/vocab.json
so the rust tokenizer/workload generators stay byte-compatible with the
training corpus.
"""

from __future__ import annotations

VOCAB_SIZE = 512

# --- control tokens -------------------------------------------------------
PAD = 0
BOS = 1
EOS = 2
SEP = 3
QUERY = 4
ANS = 5
KEY = 6
VAL = 7
THINK = 8
ROW = 9
EXEC = 10
SESSION = 11
USER = 12
ASSISTANT = 13
QMARK = 14
UPDATE = 15
SHOT = 16
LABEL = 17
FIND_MIN = 18
FIND_MAX = 19
CHOICE = 20
CORRECT = 21
NIAH = 22
SUM = 23
COUNT = 24
TARGET = 25
PLUS = 26
MINUS = 27
TIMES = 28
EQUALS = 29
HOP = 30
END_THINK = 31

CONTROL_NAMES = {
    PAD: "<pad>", BOS: "<bos>", EOS: "<eos>", SEP: "<sep>",
    QUERY: "<query>", ANS: "<ans>", KEY: "<key>", VAL: "<val>",
    THINK: "<think>", ROW: "<row>", EXEC: "<exec>", SESSION: "<session>",
    USER: "<user>", ASSISTANT: "<assistant>", QMARK: "<q>", UPDATE: "<update>",
    SHOT: "<shot>", LABEL: "<label>", FIND_MIN: "<find_min>",
    FIND_MAX: "<find_max>", CHOICE: "<choice>", CORRECT: "<correct>",
    NIAH: "<niah>", SUM: "<sum>", COUNT: "<count>", TARGET: "<target>",
    PLUS: "<plus>", MINUS: "<minus>", TIMES: "<times>", EQUALS: "<equals>",
    HOP: "<hop>", END_THINK: "</think>",
}

# --- symbol tokens (keys, values, tags) -----------------------------------
SYM_BASE = 32
NUM_SYMS = 256

# --- filler "word" tokens ---------------------------------------------------
WORD_BASE = SYM_BASE + NUM_SYMS  # 288
NUM_WORDS = 192

# --- digits / aux -----------------------------------------------------------
DIGIT_BASE = WORD_BASE + NUM_WORDS  # 480
NUM_DIGITS = 10
AUX_BASE = DIGIT_BASE + NUM_DIGITS  # 490 .. 511 reserved

assert AUX_BASE + 22 == VOCAB_SIZE


def sym(i: int) -> int:
    assert 0 <= i < NUM_SYMS
    return SYM_BASE + i


def word(i: int) -> int:
    assert 0 <= i < NUM_WORDS
    return WORD_BASE + i


def digit(i: int) -> int:
    assert 0 <= i < NUM_DIGITS
    return DIGIT_BASE + i


def token_name(t: int) -> str:
    if t in CONTROL_NAMES:
        return CONTROL_NAMES[t]
    if SYM_BASE <= t < SYM_BASE + NUM_SYMS:
        return f"s{t - SYM_BASE}"
    if WORD_BASE <= t < WORD_BASE + NUM_WORDS:
        return f"w{t - WORD_BASE}"
    if DIGIT_BASE <= t < DIGIT_BASE + NUM_DIGITS:
        return str(t - DIGIT_BASE)
    return f"<aux{t}>"


def vocab_json() -> dict:
    """Layout descriptor serialized to artifacts/vocab.json for the rust side."""
    return {
        "vocab_size": VOCAB_SIZE,
        "control": {name: tok for tok, name in CONTROL_NAMES.items()},
        "sym_base": SYM_BASE,
        "num_syms": NUM_SYMS,
        "word_base": WORD_BASE,
        "num_words": NUM_WORDS,
        "digit_base": DIGIT_BASE,
        "num_digits": NUM_DIGITS,
    }
