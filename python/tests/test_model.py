"""L2 model tests: graph-mode consistency, gating semantics, serialization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIG


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def gates():
    return M.init_gates(CFG, jax.random.PRNGKey(2))


def test_forward_full_shapes(params):
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = M.forward_full(params, toks, CFG)
    assert logits.shape == (2, 16, CFG.vocab)
    logits2, attn = M.forward_full(params, toks, CFG, return_attn=True)
    assert attn.shape == (CFG.layers, 2, CFG.hkv, 16, 16)
    assert jnp.abs(logits - logits2).max() == 0.0
    # attention rows are causal distributions
    assert jnp.abs(attn.sum(-1) - 1.0).max() < 1e-4
    assert float(attn[0, 0, 0, 0, 5]) == 0.0


def test_gated_equals_full_when_beta_one(params):
    """With gate bias -> +inf (beta = 1) retention-gated == standard."""
    g1 = M.init_gates(CFG, jax.random.PRNGKey(3), bias=30.0)
    # zero the input-dependent weights so the gate is exactly the bias
    g1 = {k: (jnp.zeros_like(v) if ".w" in k else v) for k, v in g1.items()}
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 24), 0, CFG.vocab)
    full = M.forward_full(params, toks, CFG)
    gated, lbs = M.forward_gated(params, g1, toks, CFG, impl="ref")
    assert jnp.abs(full - gated).max() < 1e-3
    assert jnp.exp(lbs).min() > 0.999


def test_gated_pallas_matches_ref(params, gates):
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 64), 0, CFG.vocab)
    l1, b1 = M.forward_gated(params, gates, toks, CFG, impl="ref")
    l2, b2 = M.forward_gated(params, gates, toks, CFG, impl="pallas")
    assert jnp.abs(b1 - b2).max() < 1e-6
    assert jnp.abs(l1 - l2).max() < 2e-3  # logit-scale f32 accumulation


def test_decode_replay_matches_full(params, gates):
    """Streaming decode with a big-enough cache must equal full attention."""
    B, T, Msl = 2, 20, 32
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, T), 0, CFG.vocab)
    full = M.forward_full(params, toks, CFG)
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    kc = jnp.zeros((L, B, H, Msl, dh))
    vc = jnp.zeros_like(kc)
    valid = jnp.zeros((L, B, H, Msl))
    zf, zs = jnp.zeros((L, B, H)), jnp.zeros((L, B, H), jnp.int32)
    zk = jnp.zeros((L, B, H, dh))
    for t in range(T):
        ws = jnp.full((L, B, H), t, jnp.int32)
        out = M.decode_fn(params, gates, toks[:, t],
                          jnp.full((B,), t, jnp.int32), kc, vc, valid, ws,
                          zf, zs, zk, zk, cfg=CFG)
        kc, vc, valid = out["kc"], out["vc"], out["valid"]
        assert jnp.abs(out["logits"] - full[:, t]).max() < 1e-4
    assert float(valid.sum()) == L * B * H * T


def test_decode_beta_matches_gate(params, gates):
    """The decode graph's log_beta output equals gate(post-norm h) directly."""
    B, Msl = 1, 16
    token = jnp.array([7], jnp.int32)
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    out = M.decode_fn(params, gates, token, jnp.array([0], jnp.int32),
                      jnp.zeros((L, B, H, Msl, dh)),
                      jnp.zeros((L, B, H, Msl, dh)),
                      jnp.zeros((L, B, H, Msl)),
                      jnp.zeros((L, B, H), jnp.int32),
                      jnp.zeros((L, B, H)), jnp.zeros((L, B, H), jnp.int32),
                      jnp.zeros((L, B, H, dh)), jnp.zeros((L, B, H, dh)),
                      cfg=CFG)
    x = params["embed"][7][None]
    h = M.rmsnorm(x, params["l0.ln1"])
    lb0 = M.gate_log_beta(gates, 0, h)
    assert jnp.abs(out["log_beta"][0, 0] - lb0[0]).max() < 1e-6


def test_prefill_then_decode_consistency(params, gates):
    """Chunked prefill + decode equals full attention on the same stream."""
    B, C, Msl = 1, 8, 32
    T = 2 * C + 3
    toks = jax.random.randint(jax.random.PRNGKey(8), (B, T), 0, CFG.vocab)
    full = M.forward_full(params, toks, CFG)
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    kc = jnp.zeros((L, B, H, Msl, dh))
    vc = jnp.zeros_like(kc)
    valid = jnp.zeros((L, B, H, Msl))
    for ci in range(2):
        sl = slice(ci * C, (ci + 1) * C)
        pos = jnp.arange(ci * C, (ci + 1) * C)[None].astype(jnp.int32)
        ws = jnp.broadcast_to(jnp.arange(ci * C, (ci + 1) * C)[None, None, None],
                              (L, B, H, C)).astype(jnp.int32)
        out = M.prefill_fn(params, gates, toks[:, sl], pos, jnp.ones((B, C)),
                           kc, vc, valid, ws, cfg=CFG)
        kc, vc, valid = out["kc"], out["vc"], out["valid"]
        assert jnp.abs(out["logits"] - full[:, sl]).max() < 1e-4
    zf, zs = jnp.zeros((L, B, H)), jnp.zeros((L, B, H), jnp.int32)
    zk = jnp.zeros((L, B, H, dh))
    for t in range(2 * C, T):
        ws = jnp.full((L, B, H), t, jnp.int32)
        out = M.decode_fn(params, gates, toks[:, t],
                          jnp.full((B,), t, jnp.int32), kc, vc, valid, ws,
                          zf, zs, zk, zk, cfg=CFG)
        kc, vc, valid = out["kc"], out["vc"], out["valid"]
        assert jnp.abs(out["logits"] - full[:, t]).max() < 1e-4


def test_prefill_padding_never_goes_live(params, gates):
    B, C, Msl = 1, 8, 32
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    toks = jnp.ones((B, C), jnp.int32)
    in_mask = jnp.array([[1, 1, 1, 0, 0, 0, 0, 0]], jnp.float32)
    # pads all point at the reserved trash slot (M-1)
    ws = np.zeros((L, B, H, C), np.int32)
    ws[..., :3] = np.arange(3)
    ws[..., 3:] = Msl - 1
    out = M.prefill_fn(params, gates, toks, jnp.arange(C)[None].astype(jnp.int32),
                       in_mask, jnp.zeros((L, B, H, Msl, dh)),
                       jnp.zeros((L, B, H, Msl, dh)), jnp.zeros((L, B, H, Msl)),
                       jnp.asarray(ws), cfg=CFG)
    valid = out["valid"]
    assert float(valid[..., Msl - 1].max()) == 0.0
    assert float(valid.sum()) == L * B * H * 3


def test_eviction_hole_is_masked(params, gates):
    """After clearing a slot's valid bit, attention ignores its contents."""
    B, Msl = 1, 16
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    kc = jax.random.normal(jax.random.PRNGKey(9), (L, B, H, Msl, dh))
    vc = jax.random.normal(jax.random.PRNGKey(10), (L, B, H, Msl, dh))
    valid = jnp.zeros((L, B, H, Msl)).at[..., :4].set(1.0)
    args = (jnp.array([3], jnp.int32), jnp.array([4], jnp.int32),
            kc, vc, valid, jnp.full((L, B, H), 4, jnp.int32),
            jnp.zeros((L, B, H)), jnp.zeros((L, B, H), jnp.int32),
            jnp.zeros((L, B, H, dh)), jnp.zeros((L, B, H, dh)))
    out1 = M.decode_fn(params, gates, *args, cfg=CFG)
    # corrupt an invalid slot: result must not change
    kc2 = kc.at[:, :, :, 9].set(99.0)
    out2 = M.decode_fn(params, gates, args[0], args[1], kc2, *args[3:], cfg=CFG)
    assert jnp.abs(out1["logits"] - out2["logits"]).max() == 0.0
    # corrupt a live slot: result must change
    kc3 = kc.at[:, :, :, 1].set(99.0)
    out3 = M.decode_fn(params, gates, args[0], args[1], kc3, *args[3:], cfg=CFG)
    assert jnp.abs(out1["logits"] - out3["logits"]).max() > 1e-4


def test_mixed_step_decode_lane_matches_decode_fn(params, gates):
    """A decode lane of the fused mixed tick (1-token chunk, mode=1) equals
    `decode_fn`: logits, gate scores, k/v of the new token, and the fused
    attn_slots row (self mass folded into the write slot)."""
    B, C, Msl = 2, 8, 32
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    n_live = 6
    kc = jax.random.normal(ks[0], (L, B, H, Msl, dh)) * 0.3
    vc = jax.random.normal(ks[1], (L, B, H, Msl, dh)) * 0.3
    valid = jnp.zeros((L, B, H, Msl)).at[..., :n_live].set(1.0)
    toks = jax.random.randint(ks[2], (B, C), 0, CFG.vocab)

    # mixed call: lane 0 decodes token toks[0,0]; lane 1 prefills a chunk
    mode = jnp.array([1.0, 0.0])
    in_mask = jnp.ones((B, C)).at[0, 1:].set(0.0)
    pos = jnp.broadcast_to(jnp.arange(n_live, n_live + C)[None],
                           (B, C)).astype(jnp.int32)
    ws = jnp.broadcast_to(jnp.arange(n_live, n_live + C)[None, None, None],
                          (L, B, H, C)).astype(jnp.int32)
    ws = ws.at[:, 0, :, 1:].set(Msl - 1)  # decode-lane padding -> trash
    mixed = M.step_fn_mixed(params, gates, toks, pos, in_mask, mode,
                            kc, vc, valid, ws, cfg=CFG)

    # reference decode step over the same caches (lane 1's token ignored)
    dec = M.decode_fn(params, gates, toks[:, 0],
                      jnp.full((B,), n_live, jnp.int32), kc, vc, valid,
                      jnp.full((L, B, H), n_live, jnp.int32),
                      jnp.zeros((L, B, H)), jnp.zeros((L, B, H), jnp.int32),
                      jnp.zeros((L, B, H, dh)), jnp.zeros((L, B, H, dh)),
                      cfg=CFG)
    assert jnp.abs(mixed["logits"][0, 0] - dec["logits"][0]).max() < 2e-3
    assert jnp.abs(mixed["log_beta"][:, 0, :, 0]
                   - dec["log_beta"][:, 0]).max() < 1e-5
    assert jnp.abs(mixed["k_chunk"][:, 0, :, 0] - dec["k_new"][:, 0]).max() < 1e-5
    assert jnp.abs(mixed["v_chunk"][:, 0, :, 0] - dec["v_new"][:, 0]).max() < 1e-5
    # the fused attention row: residents + the new token at its write slot
    assert jnp.abs(mixed["attn_slots"][:, 0] - dec["attn"][:, 0]).max() < 1e-4
    # decode-lane cache state advanced identically (pads only touched trash)
    assert jnp.abs(mixed["kc"][:, 0, :, :Msl - 1]
                   - dec["kc"][:, 0, :, :Msl - 1]).max() < 1e-5
    assert jnp.abs(mixed["valid"][:, 0, :, :Msl - 1]
                   - dec["valid"][:, 0, :, :Msl - 1]).max() == 0.0


def test_mixed_step_chunk_lane_matches_prefill_fn(params, gates):
    """A chunk-fill lane of the mixed tick is bit-compatible with
    `prefill_fn` on the same inputs (mode only affects decode lanes)."""
    B, C, Msl = 2, 8, 32
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    n_live = 5
    kc = jax.random.normal(ks[0], (L, B, H, Msl, dh)) * 0.3
    vc = jax.random.normal(ks[1], (L, B, H, Msl, dh)) * 0.3
    valid = jnp.zeros((L, B, H, Msl)).at[..., :n_live].set(1.0)
    toks = jax.random.randint(ks[2], (B, C), 0, CFG.vocab)
    in_mask = jnp.ones((B, C)).at[0, 1:].set(0.0)
    pos = jnp.broadcast_to(jnp.arange(n_live, n_live + C)[None],
                           (B, C)).astype(jnp.int32)
    ws = jnp.broadcast_to(jnp.arange(n_live, n_live + C)[None, None, None],
                          (L, B, H, C)).astype(jnp.int32)
    ws = ws.at[:, 0, :, 1:].set(Msl - 1)
    mode = jnp.array([1.0, 0.0])
    mixed = M.step_fn_mixed(params, gates, toks, pos, in_mask, mode,
                            kc, vc, valid, ws, cfg=CFG)
    pre = M.prefill_fn(params, gates, toks, pos, in_mask, kc, vc, valid,
                       ws, cfg=CFG)
    # chunk lane (lane 1, mode=0): every output identical to prefill_fn
    assert jnp.abs(mixed["logits"][1] - pre["logits"][1]).max() == 0.0
    assert jnp.abs(mixed["attn_slots"][:, 1] - pre["attn_slots"][:, 1]).max() == 0.0
    assert jnp.abs(mixed["kc"][:, 1] - pre["kc"][:, 1]).max() == 0.0
    assert jnp.abs(mixed["valid"][:, 1] - pre["valid"][:, 1]).max() == 0.0


def test_mixed_step_inject_matches_decode_fn_inject(params, gates):
    """The mixed graph's retrieval re-injection is bit-compatible with
    `decode_fn`'s: same pre-attention write, same valid promotion, same
    downstream numbers for the injecting decode lane."""
    B, C, Msl = 2, 8, 32
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    ks = jax.random.split(jax.random.PRNGKey(14), 6)
    n_live = 6
    kc = jax.random.normal(ks[0], (L, B, H, Msl, dh)) * 0.3
    vc = jax.random.normal(ks[1], (L, B, H, Msl, dh)) * 0.3
    valid = jnp.zeros((L, B, H, Msl)).at[..., :n_live].set(1.0)
    toks = jax.random.randint(ks[2], (B, C), 0, CFG.vocab)
    # lane 0 decodes AND injects one mirrored entry per (layer, head) into
    # a dead slot; lane 1 prefills a chunk
    inj_flag = jnp.zeros((L, B, H)).at[:, 0, :].set(1.0)
    inj_slot = jnp.full((L, B, H), Msl - 2, jnp.int32)
    inj_k = jax.random.normal(ks[3], (L, B, H, dh)) * 0.3
    inj_v = jax.random.normal(ks[4], (L, B, H, dh)) * 0.3
    mode = jnp.array([1.0, 0.0])
    in_mask = jnp.ones((B, C)).at[0, 1:].set(0.0)
    pos = jnp.broadcast_to(jnp.arange(n_live, n_live + C)[None],
                           (B, C)).astype(jnp.int32)
    ws = jnp.broadcast_to(jnp.arange(n_live, n_live + C)[None, None, None],
                          (L, B, H, C)).astype(jnp.int32)
    ws = ws.at[:, 0, :, 1:].set(Msl - 1)
    mixed = M.step_fn_mixed(params, gates, toks, pos, in_mask, mode,
                            kc, vc, valid, ws, inj_flag, inj_slot,
                            inj_k, inj_v, cfg=CFG)
    dec = M.decode_fn(params, gates, toks[:, 0],
                      jnp.full((B,), n_live, jnp.int32), kc, vc, valid,
                      jnp.full((L, B, H), n_live, jnp.int32),
                      inj_flag, inj_slot, inj_k, inj_v, cfg=CFG)
    assert jnp.abs(mixed["logits"][0, 0] - dec["logits"][0]).max() < 2e-3
    assert jnp.abs(mixed["attn_slots"][:, 0] - dec["attn"][:, 0]).max() < 1e-4
    # injected slot is live and carries the injected content on lane 0
    assert float(mixed["valid"][:, 0, :, Msl - 2].min()) == 1.0
    assert jnp.abs(mixed["kc"][:, 0, :, Msl - 2] - inj_k[:, 0]).max() == 0.0
    assert jnp.abs(mixed["vc"][:, 0, :, Msl - 2] - inj_v[:, 0]).max() == 0.0
    # the injected entry is attended: zeroing the flag changes the logits
    no_inj = M.step_fn_mixed(params, gates, toks, pos, in_mask, mode,
                             kc, vc, valid, ws, jnp.zeros((L, B, H)),
                             inj_slot, inj_k, inj_v, cfg=CFG)
    assert jnp.abs(no_inj["logits"][0, 0] - mixed["logits"][0, 0]).max() > 1e-5
    # lane 1 (no inject flags) is untouched by the inject operands
    assert jnp.abs(no_inj["logits"][1] - mixed["logits"][1]).max() == 0.0


def test_mixed_step_without_inject_args_unchanged(params, gates):
    """Omitting the optional inject operands equals passing all-zero flags
    (the exported graph always takes them; hand-written callers may not)."""
    B, C, Msl = 2, 4, 16
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    ks = jax.random.split(jax.random.PRNGKey(15), 3)
    kc = jax.random.normal(ks[0], (L, B, H, Msl, dh)) * 0.3
    vc = jax.random.normal(ks[1], (L, B, H, Msl, dh)) * 0.3
    valid = jnp.zeros((L, B, H, Msl)).at[..., :3].set(1.0)
    toks = jax.random.randint(ks[2], (B, C), 0, CFG.vocab)
    in_mask = jnp.ones((B, C))
    pos = jnp.broadcast_to(jnp.arange(3, 3 + C)[None], (B, C)).astype(jnp.int32)
    ws = jnp.broadcast_to(jnp.arange(3, 3 + C)[None, None, None],
                          (L, B, H, C)).astype(jnp.int32)
    mode = jnp.zeros((B,))
    plain = M.step_fn_mixed(params, gates, toks, pos, in_mask, mode, kc, vc,
                            valid, ws, cfg=CFG)
    zeroed = M.step_fn_mixed(params, gates, toks, pos, in_mask, mode, kc, vc,
                             valid, ws, jnp.zeros((L, B, H)),
                             jnp.zeros((L, B, H), jnp.int32),
                             jnp.zeros((L, B, H, dh)),
                             jnp.zeros((L, B, H, dh)), cfg=CFG)
    for k in ("logits", "kc", "vc", "valid", "attn_slots"):
        assert jnp.abs(plain[k] - zeroed[k]).max() == 0.0


def test_mixed_lanes_variant_matches_monolithic(params, gates):
    """The per-lane cache layout of the mixed graph returns the same
    numbers as the monolithic formulation, split per lane."""
    B, C, Msl = 2, 4, 16
    L, H, dh = CFG.layers, CFG.hkv, CFG.dh
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    kc = jax.random.normal(ks[0], (L, B, H, Msl, dh)) * 0.3
    vc = jax.random.normal(ks[1], (L, B, H, Msl, dh)) * 0.3
    valid = jnp.zeros((L, B, H, Msl)).at[..., :3].set(1.0)
    toks = jax.random.randint(ks[2], (B, C), 0, CFG.vocab)
    in_mask = jnp.ones((B, C)).at[0, 1:].set(0.0)
    pos = jnp.broadcast_to(jnp.arange(3, 3 + C)[None], (B, C)).astype(jnp.int32)
    ws = jnp.broadcast_to(jnp.arange(3, 3 + C)[None, None, None],
                          (L, B, H, C)).astype(jnp.int32)
    ws = ws.at[:, 0, :, 1:].set(Msl - 1)
    mode = jnp.array([1.0, 0.0])
    # exercise the full exported signature incl. an active injection
    inj_flag = jnp.zeros((L, B, H)).at[:, 0, :].set(1.0)
    inj_slot = jnp.full((L, B, H), Msl - 2, jnp.int32)
    inj_k = jax.random.normal(jax.random.PRNGKey(99), (L, B, H, dh)) * 0.3
    mono = M.step_fn_mixed(params, gates, toks, pos, in_mask, mode, kc, vc,
                           valid, ws, inj_flag, inj_slot, inj_k, inj_k,
                           cfg=CFG)
    kcs = [kc[:, i] for i in range(B)]
    vcs = [vc[:, i] for i in range(B)]
    lanes = M.step_fn_mixed_lanes(params, gates, toks, pos, in_mask, mode,
                                  kcs, vcs, valid, ws, inj_flag, inj_slot,
                                  inj_k, inj_k, cfg=CFG)
    assert jnp.abs(lanes["logits"] - mono["logits"]).max() < 1e-6
    for i in range(B):
        assert jnp.abs(lanes["kc"][i] - mono["kc"][:, i]).max() < 1e-6
        assert jnp.abs(lanes["vc"][i] - mono["vc"][:, i]).max() < 1e-6


def test_weights_bin_roundtrip(tmp_path, params):
    arrays = {k: np.asarray(v) for k, v in params.items()}
    p = str(tmp_path / "w.bin")
    M.save_weights_bin(p, arrays)
    back = M.load_weights_bin(p)
    assert set(back) == set(arrays)
    for k in arrays:
        assert back[k].shape == arrays[k].shape
        assert np.abs(back[k] - arrays[k]).max() == 0.0


def test_param_and_gate_name_order(params, gates):
    assert M.param_names(CFG) == list(params.keys())
    assert M.gate_names(CFG) == list(gates.keys())
