"""Synthetic task generator invariants (the grading contract with rust)."""

import random

import pytest

from compile import tasks
from compile import vocab as V


@pytest.mark.parametrize("task", list(tasks.GENERATORS))
def test_episode_well_formed(task):
    rng = random.Random(7)
    for _ in range(20):
        ep = tasks.GENERATORS[task](rng)
        assert ep.tokens[0] == V.BOS
        assert ep.tokens[-1] == V.EOS
        assert 0 < ep.prompt_end <= ep.answer_start < len(ep.tokens)
        assert all(0 <= t < V.VOCAB_SIZE for t in ep.tokens)
        assert ep.answer == ep.tokens[ep.answer_start:len(ep.tokens) - 1]
        assert len(ep.weights) == len(ep.tokens)
        # answer tokens always carry the high loss weight
        assert all(ep.weights[i] == tasks.ANSWER_WEIGHT
                   for i in range(ep.answer_start, len(ep.tokens)))


def test_recall_answer_is_queried_value():
    rng = random.Random(1)
    for _ in range(30):
        ep = tasks.gen_recall(rng)
        toks = ep.tokens
        qkey = toks[ep.answer_start - 1]
        # value immediately follows <key> k
        vals = [toks[i + 2] for i in range(len(toks) - 2)
                if toks[i] == V.KEY and toks[i + 1] == qkey]
        assert vals and vals[0] == ep.answer[0]


def test_recall_multi_queries_are_consistent():
    rng = random.Random(9)
    for _ in range(20):
        ep = tasks.gen_recall(rng, n_queries=4)
        toks = ep.tokens
        kv = {toks[i + 1]: toks[i + 2] for i in range(len(toks) - 2)
              if toks[i] == V.KEY}
        for i in range(len(toks) - 2):
            if toks[i] == V.QUERY:
                assert kv[toks[i + 1]] == toks[i + 2]


def test_copy_replays_span():
    rng = random.Random(11)
    for _ in range(20):
        ep = tasks.gen_copy(rng, n=5)
        toks = ep.tokens
        assert toks[1:6] == ep.answer
        assert toks[6] == V.SEP


def test_chain_trace_is_valid():
    rng = random.Random(2)
    for _ in range(30):
        ep = tasks.gen_chain(rng, hops=3)
        toks = ep.tokens
        mapping = {toks[i + 1]: toks[i + 2] for i in range(len(toks) - 2)
                   if toks[i] == V.KEY}
        start = toks[toks.index(V.QUERY) + 1]
        cur = start
        for _ in range(3):
            cur = mapping[cur]
        assert cur == ep.answer[0]
        # the think span re-queries each hop: <query> k_i k_{i+1}
        i = ep.meta["think_start"]
        hop_cur = start
        while toks[i] == V.QUERY:
            assert toks[i + 1] == hop_cur
            hop_cur = toks[i + 2]
            i += 3
        assert hop_cur == ep.answer[0]


def test_countdown_trace_arithmetic():
    rng = random.Random(3)
    for _ in range(30):
        ep = tasks.gen_countdown(rng, n_steps=3)
        toks = ep.tokens
        cur = toks[2] - V.DIGIT_BASE
        i = ep.prompt_end
        while toks[i] != V.END_THINK:
            op, opd, eq, res = toks[i:i + 4]
            assert eq == V.EQUALS
            cur = (cur + (opd - V.DIGIT_BASE)) % 10 if op == V.PLUS \
                else (cur - (opd - V.DIGIT_BASE)) % 10
            assert res - V.DIGIT_BASE == cur
            i += 4
        assert ep.answer[0] - V.DIGIT_BASE == cur


def test_multi_session_latest_value_wins():
    rng = random.Random(4)
    for _ in range(40):
        ep = tasks.gen_multi_session(rng)
        toks = ep.tokens
        qkey = toks[ep.answer_start - 1]
        latest = None
        for i in range(len(toks) - 2):
            if toks[i] in (V.KEY, V.UPDATE) and toks[i + 1] == qkey:
                latest = toks[i + 2]
        assert latest == ep.answer[0]


def test_niah_needle_is_answer():
    rng = random.Random(8)
    for _ in range(20):
        ep = tasks.gen_niah(rng, haystack=40)
        toks = ep.tokens
        i = toks.index(V.NIAH)
        assert toks[i + 1] == toks[ep.answer_start - 1]  # queried key
        assert toks[i + 2] == ep.answer[0]


def test_find_minmax_answer():
    rng = random.Random(5)
    for _ in range(30):
        ep = tasks.gen_find_minmax(rng, n=20)
        digs = [t - V.DIGIT_BASE for t in ep.tokens[2:2 + 20]]
        want = max(digs) if ep.meta["max"] else min(digs)
        assert ep.answer[0] - V.DIGIT_BASE == want


def test_manyshot_mapping_consistent():
    rng = random.Random(12)
    for _ in range(20):
        ep = tasks.gen_manyshot(rng)
        toks = ep.tokens
        f = {}
        for i in range(len(toks) - 2):
            if toks[i] == V.SHOT:
                x, y = toks[i + 1], toks[i + 2]
                assert f.setdefault(x, y) == y  # mapping is a function
        q = toks[ep.answer_start - 1]
        assert f[q] == ep.answer[0]


def test_pack_batch_shapes_and_weights():
    rng = random.Random(6)
    rows, wts, segs = tasks.pack_batch(rng, 3, 128, "all")
    assert len(rows) == 3 and all(len(r) == 128 for r in rows)
    assert all(len(w) == 128 for w in wts)
    assert any(w == tasks.ANSWER_WEIGHT for row in wts for w in row)
    # segments are non-decreasing within each row
    for sg in segs:
        assert all(sg[i] <= sg[i + 1] for i in range(len(sg) - 1))


def test_vocab_layout_is_consistent():
    j = V.vocab_json()
    assert j["vocab_size"] == 512
    assert j["sym_base"] + j["num_syms"] == j["word_base"]
    assert j["word_base"] + j["num_words"] == j["digit_base"]
    names = set()
    for t in range(V.VOCAB_SIZE):
        n = V.token_name(t)
        assert n not in names, f"duplicate token name {n}"
        names.add(n)
